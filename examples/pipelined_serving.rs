//! Pipelined throughput serving — the demo for the block-pipelined
//! executor, the throughput planning objective, and elastic
//! drain-and-flush.
//!
//! Part 1 plans the same model under both objectives and prints the
//! pipeline-stage decomposition: the latency objective minimizes the *sum*
//! of stages, the throughput objective the *max* (the steady-state per-item
//! service time once stages overlap).
//!
//! Part 2 serves the same request stream through the [`Server`] twice —
//! lockstep vs `pipeline_depth > 1` — and reports measured requests/sec
//! plus the router's per-stage occupancy.
//!
//! Part 3 runs the pipelined server through a scripted node outage: the
//! plan swap drains the in-flight generation, rebuilds the pipeline on the
//! surviving cluster, and loses nothing.
//!
//! ```bash
//! cargo run --release --example pipelined_serving
//! ```

use std::time::{Duration, Instant};

use flexpie::compute::{Tensor, WeightStore};
use flexpie::config::PipelineExperiment;
use flexpie::cost::{CostSource, Objective};
use flexpie::elastic::{ConditionTrace, ElasticConfig};
use flexpie::model::zoo;
use flexpie::partition::Plan;
use flexpie::planner::exhaustive::stage_costs;
use flexpie::planner::{Dpp, DppConfig};
use flexpie::serve::{ServeConfig, Server};
use flexpie::util::bench::Table;

fn plan_for(model: &flexpie::model::Model, cost: &CostSource, objective: Objective) -> Plan {
    Dpp::with_config(model, cost, DppConfig { objective, ..Default::default() }).plan()
}

fn main() {
    let exp = PipelineExperiment::default();
    let model = zoo::edgenet(16);
    let tb = exp.testbed();
    let cost = CostSource::analytic(&tb);

    // ---- 1. one model, two objectives --------------------------------------
    println!(
        "model {} on {} × {} @ {:.1} Gb/s, pipeline depth {}\n",
        model.name,
        exp.nodes,
        tb.topology,
        tb.bandwidth.as_gbps(),
        exp.pipeline_depth
    );
    let mut table = Table::new(["objective", "plan", "sum (ms)", "bottleneck (ms)"]);
    let mut plans = Vec::new();
    for objective in Objective::ALL {
        let plan = plan_for(&model, &cost, objective);
        let stages = stage_costs(&model, &plan, &cost);
        let sum: f64 = stages.iter().sum();
        let bottleneck = stages.iter().cloned().fold(0.0f64, f64::max);
        table.row([
            objective.name().to_string(),
            plan.render(),
            format!("{:.3}", sum * 1e3),
            format!("{:.3}", bottleneck * 1e3),
        ]);
        plans.push((objective, plan));
    }
    table.print();

    // ---- 2. lockstep vs pipelined serving ----------------------------------
    let serve_plan = plans
        .iter()
        .find(|(o, _)| *o == exp.objective)
        .map(|(_, p)| p.clone())
        .expect("objective planned above");
    let weights = WeightStore::for_model(&model, 42);
    let l0 = &model.layers[0];
    let n_requests = exp.requests;
    let mut measured = Vec::new();
    for depth in [1usize, exp.pipeline_depth] {
        let server = Server::start(
            model.clone(),
            serve_plan.clone(),
            weights.clone(),
            tb.clone(),
            ServeConfig {
                max_batch: 1,
                batch_window: Duration::ZERO,
                queue_depth: 64,
                pipeline_depth: depth,
                ..ServeConfig::default()
            },
        );
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..n_requests)
            .map(|i| {
                server
                    .submit(Tensor::random(l0.in_h, l0.in_w, l0.in_c, i as u64))
                    .expect("admission failed")
            })
            .collect();
        for rx in rxs {
            rx.recv().expect("request lost");
        }
        let rps = n_requests as f64 / t0.elapsed().as_secs_f64();
        let stats = server.shutdown();
        measured.push(rps);
        match stats.pipeline {
            Some(p) => println!("depth {depth}: {rps:.1} req/s | {p}"),
            None => println!("depth {depth}: {rps:.1} req/s (lockstep)"),
        }
    }
    println!(
        "pipelining gained {:.2}x requests/sec on this host\n",
        measured[1] / measured[0].max(1e-9)
    );

    // ---- 3. drain-and-flush across a node outage ---------------------------
    println!("--- elastic pipelined serving across a scripted outage ---");
    let item = {
        let p = flexpie::planner::plan_for_testbed(&model, &tb);
        flexpie::engine::evaluate(&model, &p, &tb).total
    };
    let trace = ConditionTrace::stable(exp.nodes).with_outage(2, 3.5 * item, 8.5 * item);
    let server = Server::start_elastic(
        model.clone(),
        weights,
        tb,
        trace,
        ServeConfig {
            max_batch: 1,
            batch_window: Duration::ZERO,
            queue_depth: 64,
            pipeline_depth: exp.pipeline_depth,
            ..ServeConfig::default()
        },
        ElasticConfig::default(),
    );
    let rxs: Vec<_> = (0..n_requests)
        .map(|i| {
            server
                .submit(Tensor::random(l0.in_h, l0.in_w, l0.in_c, 1000 + i as u64))
                .expect("admission failed")
        })
        .collect();
    let mut by_nodes = [0usize; 8];
    for rx in rxs {
        let resp = rx.recv().expect("request lost across drain-and-flush");
        by_nodes[resp.nodes.min(7)] += 1;
    }
    let stats = server.shutdown();
    println!(
        "served {} requests; node-count histogram: {:?}",
        stats.requests,
        &by_nodes[1..=exp.nodes]
    );
    if let Some(p) = &stats.pipeline {
        println!("pipeline: {p}");
    }
    if let Some(m) = &stats.adaptation {
        println!("adaptation (checks = generations on this path): {m}");
    }
}
