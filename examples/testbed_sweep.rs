//! Testbed sweep: how FlexPie's chosen plan *adapts* to the cluster — the
//! paper's core motivation ("the optimal partition scheme obtained from one
//! testbed will no longer be the optimal after we switch to another").
//!
//! Sweeps node count × topology × bandwidth for MobileNet and prints the
//! plan shape (scheme histogram + fusion count) and the win over the best
//! fixed baseline.
//!
//! ```bash
//! cargo run --release --example testbed_sweep
//! ```

use flexpie::cost::CostSource;
use flexpie::engine;
use flexpie::model::zoo;
use flexpie::net::{Bandwidth, Testbed, Topology};
use flexpie::partition::{Plan, Scheme};
use flexpie::planner::Dpp;
use flexpie::util::bench::Table;

fn scheme_histogram(plan: &Plan) -> String {
    let mut counts = [0usize; 4];
    for s in &plan.steps {
        counts[s.scheme.code() as usize] += 1;
    }
    Scheme::ALL
        .iter()
        .zip(counts)
        .filter(|(_, c)| *c > 0)
        .map(|(s, c)| format!("{s}×{c}"))
        .collect::<Vec<_>>()
        .join(" ")
}

fn main() {
    let model = zoo::mobilenet_v1(224, 1000);
    let mut table = Table::new([
        "nodes", "topology", "bw", "FlexPie (ms)", "best fixed (ms)", "speedup", "NT", "schemes",
    ]);

    for nodes in [3usize, 4, 5, 6] {
        for topology in [Topology::Ring, Topology::Ps] {
            for gbps in [5.0, 1.0, 0.5] {
                let tb = Testbed::new(nodes, topology, Bandwidth::gbps(gbps));
                let cost = CostSource::analytic(&tb);
                let plan = Dpp::new(&model, &cost).plan();
                let flex = engine::evaluate(&model, &plan, &tb).total_ms();
                let best_fixed = Scheme::ALL
                    .iter()
                    .map(|&s| {
                        engine::evaluate(
                            &model,
                            &Plan::uniform(s, model.n_layers()),
                            &tb,
                        )
                        .total_ms()
                    })
                    .fold(f64::INFINITY, f64::min);
                table.row([
                    nodes.to_string(),
                    topology.name().to_string(),
                    format!("{gbps} Gb/s"),
                    format!("{flex:.2}"),
                    format!("{best_fixed:.2}"),
                    format!("{:.2}x", best_fixed / flex),
                    plan.n_fused_layers().to_string(),
                    scheme_histogram(&plan),
                ]);
            }
        }
    }
    table.print();
    println!("\nNote how the scheme mix and fusion count shift with the testbed —");
    println!("no fixed partition scheme is optimal everywhere (paper §2.2).");
}
