//! Quickstart: plan, evaluate, execute and verify one model on a simulated
//! edge cluster.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use flexpie::cost::CostSource;
use flexpie::engine;
use flexpie::model::zoo;
use flexpie::net::{Bandwidth, Testbed, Topology};
use flexpie::planner::Dpp;

fn main() {
    // 1. A model (the EdgeNet quickstart CNN) and a testbed: 4 edge devices
    //    on a 5 Gb/s ring — the paper's SRIO-class configuration.
    let model = zoo::edgenet(64);
    let testbed = Testbed::new(4, Topology::Ring, Bandwidth::gbps(5.0));
    println!(
        "model: {} ({} layers, {:.1} MFLOPs)",
        model.name,
        model.n_layers(),
        model.total_flops() / 1e6
    );

    // 2. Plan with FlexPie's DPP (here against the analytic cost oracle;
    //    pass a GBDT CostSource for the paper's learned-CE setup).
    let cost = CostSource::analytic(&testbed);
    let (plan, stats) = Dpp::new(&model, &cost).plan_with_stats();
    println!("plan:  {}", plan.render());
    println!(
        "search: {:.2} ms, {} compute + {} sync estimator queries ({} pruned)",
        stats.elapsed.as_secs_f64() * 1e3,
        stats.compute_queries,
        stats.sync_queries,
        stats.candidates_pruned
    );

    // 3. Evaluate on the simulated testbed (the virtual clock).
    let report = engine::evaluate(&model, &plan, &testbed);
    println!(
        "simulated inference: {:.3} ms total = {:.3} ms compute + {:.3} ms sync ({} B moved)",
        report.total_ms(),
        report.compute * 1e3,
        report.sync * 1e3,
        report.bytes_moved
    );

    // 4. Execute with real numerics on the simulated cluster and verify
    //    against the single-node reference.
    let diff = engine::verify_plan(&model, &plan, &testbed, 42);
    println!("distributed vs single-node reference: max |diff| = {diff}");
    assert_eq!(diff, 0.0);
    println!("quickstart OK");
}
