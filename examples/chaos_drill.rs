//! Chaos drill — kill any node (the leader included) and watch the cluster
//! shrug it off.
//!
//! Generates a seeded, fully deterministic fault schedule (leader strike
//! guaranteed, back-to-back kills, bandwidth collapses), prints it, then
//! serves a request stream through the elastic pipelined server under that
//! schedule and audits every request: bit-identical outputs, zero silent
//! drops, completion order preserved. The same seed always replays the
//! same drill.
//!
//! ```bash
//! cargo run --release --example chaos_drill
//! cargo run --release --example chaos_drill -- --seed 23 --requests 40 --depth 4
//! ```

use flexpie::elastic::{run_chaos, ChaosEvent, ChaosSchedule, ElasticConfig};
use flexpie::engine;
use flexpie::model::zoo;
use flexpie::net::{Bandwidth, Testbed, Topology};
use flexpie::planner::plan_for_testbed;
use flexpie::serve::ServeConfig;
use flexpie::util::cli::Args;
use std::time::Duration;

fn main() {
    let args = Args::from_env();
    let seed = args.u64_or("seed", 11);
    let nodes = args.usize_or("nodes", 4);
    let requests = args.u64_or("requests", 24);
    let depth = args.usize_or("depth", 3);
    let slots = args.usize_or("slots", 8);

    let model = zoo::edgenet(16);
    let base = Testbed::new(nodes, Topology::Ring, Bandwidth::gbps(1.0));
    let plan = plan_for_testbed(&model, &base);
    let c0 = engine::evaluate(&model, &plan, &base).total;

    let schedule = ChaosSchedule::generate(nodes, seed, slots, 2.0 * c0);
    println!(
        "chaos drill: seed {seed}, {nodes} nodes, {} events over {:.1} virtual s \
         (slot = {:.3} s), leader strike: {}\n",
        schedule.len(),
        schedule.horizon(),
        schedule.slot,
        schedule.kills_leader()
    );
    for e in &schedule.events {
        match *e {
            ChaosEvent::Kill { node, from, until } => {
                println!("  t={from:7.3}s  KILL node {node} until {until:.3}s");
            }
            ChaosEvent::Collapse { factor, from, until } => {
                println!("  t={from:7.3}s  BANDWIDTH ×{factor:.2} until {until:.3}s");
            }
        }
    }

    let cfg = ServeConfig {
        max_batch: 1,
        batch_window: Duration::ZERO,
        queue_depth: 64,
        pipeline_depth: depth,
        replay_budget: args.u64_or("replay-budget", 3) as u32,
    };
    println!("\nserving {requests} requests through the pipelined elastic server...");
    let out = run_chaos(
        &model,
        &base,
        &schedule,
        cfg,
        ElasticConfig::default(),
        requests,
        1_000 * (seed + 1),
    );
    println!("\noutcome: {out}");
    println!("RESULT {}", out.to_json().to_string());
    match out.verify() {
        Ok(()) => println!("\nall invariants held: no silent drops, no corruption, order kept"),
        Err(e) => {
            println!("\nINVARIANT VIOLATION: {e}");
            std::process::exit(1);
        }
    }
}
