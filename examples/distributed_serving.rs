//! Distributed serving over real sockets — the wire-transport walkthrough.
//!
//! Everything the process deployment does, in one runnable program:
//!
//! 1. host a TTL-leased **registry** (the discovery + liveness service),
//! 2. boot **node daemons** (in threads here; `flexpie-node` gives each
//!    its own OS process — same code path either way),
//! 3. **install a plan**: the coordinator resolves the live daemons,
//!    elects the lowest id leader, and ships model + plan + seed + peer
//!    table over the versioned frame codec — weights never travel, they
//!    derive deterministically from the seed on every node,
//! 4. serve requests through the standard [`Server`] front-end riding the
//!    TCP mesh, verifying each response **bit-identical** to the
//!    single-process reference.
//!
//! The `kill -9` half of the story needs real processes — see
//! `rust/tests/process_e2e.rs`, where SIGKILLing workers *and* the leader
//! must pass the chaos audit (zero silent drops, preserved order).
//!
//! ```bash
//! cargo run --release --example distributed_serving
//! cargo run --release --example distributed_serving -- --nodes 4 --requests 12
//! ```

use std::time::Duration;

use flexpie::compute::{run_reference, Tensor, WeightStore};
use flexpie::config::TransportExperiment;
use flexpie::model::zoo;
use flexpie::partition::{Plan, Scheme};
use flexpie::serve::{ServeConfig, Server};
use flexpie::transport::coord::ProcessCluster;
use flexpie::transport::daemon::{self, DaemonOpts};
use flexpie::transport::registry::{self, RegistryServer};
use flexpie::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let exp = TransportExperiment {
        nodes: args.usize_or("nodes", 3),
        requests: args.usize_or("requests", 8),
        seed: args.u64_or("seed", 5),
        ..Default::default()
    };

    // 1. the registry: daemons lease their addresses here; an expired
    //    lease is how everyone learns a node is dead
    let reg = RegistryServer::spawn(&exp.registry, Duration::from_millis(exp.ttl_ms))
        .expect("registry bind");
    println!("registry up at {} (ttl {} ms)", reg.addr(), exp.ttl_ms);

    // 2. node daemons — one per device; threads here, processes in prod
    for id in 0..exp.nodes as u32 {
        let mut opts = DaemonOpts::new(id, reg.addr());
        opts.tcp = exp.tcp_opts();
        std::thread::spawn(move || {
            let _ = daemon::run(opts);
        });
    }
    for e in registry::await_nodes(reg.addr(), exp.nodes, Duration::from_secs(10))
        .expect("daemons register")
    {
        println!("  node {} ctl={} data={}", e.node, e.ctl_addr, e.data_addr);
    }

    // 3. install the plan on the live set
    let model = zoo::by_name(&exp.model).expect("zoo model");
    let plan = Plan::uniform(Scheme::InH, model.n_layers());
    let mut pc = ProcessCluster::connect(reg.addr(), exp.nodes, Duration::from_secs(10))
        .expect("cluster bring-up");
    pc.install(&model, &plan, exp.seed).expect("plan install");
    println!(
        "installed {} on {} daemons over TCP, leader node {}\n",
        model.name,
        pc.nodes(),
        pc.leader()
    );

    // 4. serve through the standard front-end, verifying bit-exactness
    let server = Server::start_process(pc, ServeConfig::default());
    let ws = WeightStore::for_model(&model, exp.seed);
    let l0 = &model.layers[0];
    for i in 0..exp.requests as u64 {
        let input = Tensor::random(l0.in_h, l0.in_w, l0.in_c, 0xD15C + i);
        let reference = run_reference(&model, &ws, &input);
        let resp = server.infer(input).expect("request served");
        let exact = reference.max_abs_diff(&resp.output) == 0.0;
        println!(
            "request {i}: seq {} on {} nodes (leader {}) — bit-identical: {exact}",
            resp.seq, resp.nodes, resp.leader
        );
        assert!(exact, "wire output diverged from reference");
    }

    let stats = server.shutdown();
    println!(
        "\nserved {} requests, {} failover(s), {} failed — zero silent drops by construction",
        stats.requests, stats.process_failovers, stats.failed_on_dead_cluster
    );
}
