//! Wire-fault drill — corrupt, drop, delay, duplicate, partition and
//! throttle frames on the wire, and watch replay recovery leave no
//! request behind.
//!
//! Generates a seeded, fully deterministic [`FaultSchedule`] (window 0
//! always corrupts a frame, so every drill proves the checksum path),
//! prints it, then replays the request stream through the lockstep
//! cluster with every node's fabric wrapped in a fault injector. The
//! audit: every request completes bit-identical to the fault-free
//! reference — re-executed under a bounded replay budget when a fault
//! aborts it — or is explicitly failed. The same seed always replays the
//! same drill.
//!
//! ```bash
//! cargo run --release --example fault_drill
//! cargo run --release --example fault_drill -- --seed 23 --requests 16 --budget 4
//! ```

use std::time::Duration;

use flexpie::compute::WeightStore;
use flexpie::config::FaultExperiment;
use flexpie::model::zoo;
use flexpie::partition::{Plan, Scheme};
use flexpie::transport::fault::run_faulted;
use flexpie::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let defaults = FaultExperiment::default();
    let exp = FaultExperiment {
        seed: args.u64_or("seed", defaults.seed),
        nodes: args.usize_or("nodes", defaults.nodes),
        windows: args.usize_or("windows", defaults.windows),
        window_ops: args.u64_or("window-ops", defaults.window_ops),
        requests: args.u64_or("requests", defaults.requests),
        replay_budget: args.u64_or("budget", defaults.replay_budget as u64) as u32,
        ..defaults
    };

    let model = zoo::by_name(&exp.model).expect("zoo model");
    let plan = Plan::uniform(Scheme::InH, model.n_layers());
    let weights = WeightStore::for_model(&model, 5);

    let schedule = exp.schedule();
    println!(
        "fault drill: seed {}, {} nodes, {} events over {} send ops \
         (window = {} ops), replay budget {}\n",
        exp.seed,
        exp.nodes,
        schedule.len(),
        exp.windows as u64 * exp.window_ops,
        exp.window_ops,
        exp.replay_budget
    );
    for e in &schedule.events {
        println!("  op {:>5}  src {}  span {:>3}  {:?}", e.at, e.src, e.span, e.fault);
    }

    println!("\nserving {} requests through the fault-wrapped cluster...", exp.requests);
    let out = run_faulted(
        &model,
        &plan,
        &weights,
        &schedule,
        exp.requests,
        1_000 * (exp.seed + 1),
        exp.replay_budget,
        Duration::from_millis(400),
    );
    println!("\noutcome: {out}");
    println!("RESULT {}", out.to_json().to_string());
    match out.verify() {
        Ok(()) => {
            println!("\nall invariants held: no silent drops, no corrupted numerics");
        }
        Err(e) => {
            println!("\nINVARIANT VIOLATION: {e}");
            std::process::exit(1);
        }
    }
}
