//! Elastic serving under a diurnal-drift scenario — the dynamic-environment
//! demo the static paper testbed can't express.
//!
//! Part 1 drives the [`ElasticController`] directly through one compressed
//! "day" of bandwidth drift (100% → 40% → 100% over 60 virtual seconds) and
//! logs every adaptation event: when the monitor tripped, why, and what the
//! replan bought. Part 2 runs the full serving path ([`Server`] router +
//! batcher + simulated cluster with real numerics) on the same scenario plus
//! a scripted node outage, showing failover and recovery between batches
//! with zero lost requests.
//!
//! ```bash
//! cargo run --release --example elastic_serving
//! ```

use std::time::Duration;

use flexpie::compute::{Tensor, WeightStore};
use flexpie::config::ElasticExperiment;
use flexpie::elastic::{ConditionTrace, ElasticController};
use flexpie::model::zoo;
use flexpie::net::{Bandwidth, Testbed, Topology};
use flexpie::serve::{ServeConfig, Server};
use flexpie::util::bench::Table;

fn main() {
    let exp = ElasticExperiment::default(); // diurnal-drift, 120 s horizon
    let nodes = 4;
    let base = Testbed::new(nodes, Topology::Ring, Bandwidth::gbps(1.0));

    // ---- 1. controller over one compressed day ----------------------------
    let model = zoo::mobilenet_v1(224, 1000).truncated(12);
    println!(
        "scenario: {} (seed {}) on {} × {} @ {:.1} Gb/s\nmodel: {} ({} layers)\n",
        exp.profile,
        exp.seed,
        nodes,
        base.topology,
        base.bandwidth.as_gbps(),
        model.name,
        model.n_layers()
    );
    let trace = exp.trace(nodes).expect("valid profile");
    let mut ctl = ElasticController::new(
        model.clone(),
        base.clone(),
        trace,
        exp.controller_config(),
    );

    let steps = 240;
    let dt = exp.horizon / steps as f64;
    let mut peak_cost = 0.0f64;
    for k in 0..steps {
        let d = ctl.on_batch(k as f64 * dt);
        peak_cost = peak_cost.max(d.cost_per_item);
        if let Some(reason) = d.reason {
            println!(
                "t={:7.2}s  REPLAN {:?}: {} nodes, {:.3} ms/item under new plan",
                k as f64 * dt,
                reason,
                d.testbed.nodes,
                d.cost_per_item * 1e3
            );
        }
    }
    let m = ctl.metrics();
    println!("\nadaptation over {:.0}s: {m}", exp.horizon);
    println!("peak per-item cost across the day: {:.3} ms", peak_cost * 1e3);
    println!(
        "plan cache: {} entries, {:.0}% hit rate",
        ctl.cache().len(),
        m.cache_hit_rate() * 100.0
    );
    // which condition cells a day of drift leaves warm (by bandwidth bucket)
    let mut warm: Vec<u32> = ctl.cache().keys().iter().map(|k| k.snapshot.bw_bucket).collect();
    warm.sort_unstable();
    println!("warm cells (bandwidth buckets, 1/8 steps): {warm:?}\n");
    if !ctl.events().is_empty() {
        let mut t = Table::new(["t (s)", "reason", "nodes", "before (ms)", "after (ms)"]);
        for e in ctl.events() {
            t.row([
                format!("{:.2}", e.t),
                format!("{:?}", e.reason),
                e.nodes.to_string(),
                format!("{:.3}", e.cost_before * 1e3),
                format!("{:.3}", e.cost_after * 1e3),
            ]);
        }
        t.print();
    }

    // ---- 2. full serving path with drift + node churn ----------------------
    println!("\n--- serving path (real numerics, drift + scripted outage) ---");
    let serve_model = zoo::edgenet(16);
    let weights = WeightStore::for_model(&serve_model, 42);
    // Script the outage in units of the measured per-item cost so the
    // failover provably lands inside the 24-request run (the virtual clock
    // advances by roughly one plan cost per batch).
    let item_cost = {
        let p = flexpie::planner::plan_for_testbed(&serve_model, &base);
        flexpie::engine::evaluate(&serve_model, &p, &base).total
    };
    let trace = ConditionTrace::diurnal_drift(nodes, exp.seed)
        .with_outage(2, 4.5 * item_cost, 9.5 * item_cost);
    let server = Server::start_elastic(
        serve_model.clone(),
        weights,
        base,
        trace,
        ServeConfig {
            max_batch: 1,
            batch_window: Duration::ZERO,
            queue_depth: 32,
            ..ServeConfig::default()
        },
        exp.controller_config(),
    );
    let l0 = &serve_model.layers[0];
    let n_requests = 24;
    let mut by_nodes = [0usize; 8];
    for i in 0..n_requests {
        let resp = server
            .infer(Tensor::random(l0.in_h, l0.in_w, l0.in_c, i as u64))
            .expect("request lost");
        by_nodes[resp.nodes.min(7)] += 1;
    }
    let stats = server.shutdown();
    println!(
        "served {} requests in {} batches; node-count histogram: {:?}",
        stats.requests,
        stats.batches,
        &by_nodes[1..=nodes]
    );
    if let Some(m) = stats.adaptation {
        println!("router adaptation: {m}");
    }
    if let Some(s) = stats.boundary_stall {
        // replanning runs on the background planner thread, so boundaries
        // should report microsecond-scale acquisitions even across swaps
        println!("batch-boundary plan acquisition: {s}");
    }
}
