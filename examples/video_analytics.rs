//! Video-analytics workload — the IoT scenario the paper's introduction
//! motivates (image processing / video analysis on edge clusters): a camera
//! produces frames at a fixed rate; each frame must clear the distributed
//! inference pipeline within a deadline.
//!
//! Demonstrates how FlexPie's planning translates into SLO headroom: the
//! simulated per-frame inference time of FlexPie's plan vs the fixed
//! baselines determines the maximum sustainable frame rate on the same
//! cluster, and the serving stack (router + batcher) is driven with a
//! paced frame stream to verify end-to-end behaviour with real numerics.
//!
//! ```bash
//! cargo run --release --example video_analytics
//! ```

use std::time::{Duration, Instant};

use flexpie::baselines::Solution;
use flexpie::compute::{Tensor, WeightStore};
use flexpie::cost::CostSource;
use flexpie::engine;
use flexpie::metrics::summarize;
use flexpie::model::zoo;
use flexpie::net::{Bandwidth, Testbed, Topology};
use flexpie::serve::{ServeConfig, Server};
use flexpie::util::bench::Table;

fn main() {
    // The camera-side model: EdgeNet at 64×64 (a realistic thumbnail
    // analytics network), on a 4-device 1 Gb/s ring.
    let model = zoo::edgenet(64);
    let testbed = Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0));
    let cost = CostSource::analytic(&testbed);

    // --- 1. SLO analysis: what frame rate can each solution sustain? -------
    println!("== per-frame inference time and sustainable FPS (simulated testbed) ==");
    let mut table = Table::new(["solution", "per-frame (ms)", "max FPS", "meets 30 FPS?"]);
    let mut flex_time = f64::INFINITY;
    for sol in Solution::ALL {
        let plan = sol.plan(&model, &cost);
        let t = engine::evaluate(&model, &plan, &testbed).total;
        if sol == Solution::FlexPie {
            flex_time = t;
        }
        let fps = 1.0 / t;
        table.row([
            sol.name().to_string(),
            format!("{:.3}", t * 1e3),
            format!("{fps:.0}"),
            if fps >= 30.0 { "yes".into() } else { "NO".to_string() },
        ]);
    }
    table.print();

    // --- 2. Drive the serving stack with a paced 30 FPS stream -------------
    let plan = Solution::FlexPie.plan(&model, &cost);
    println!("\nplan: {}", plan.render());
    let weights = WeightStore::for_model(&model, 77);
    let server = Server::start(
        model.clone(),
        plan,
        weights.clone(),
        testbed,
        ServeConfig {
            max_batch: 4,
            batch_window: Duration::from_millis(1),
            queue_depth: 64,
            ..ServeConfig::default()
        },
    );

    let frames = 90usize;
    let frame_interval = Duration::from_millis(33); // ~30 FPS
    let mut pending = Vec::new();
    let mut dropped = 0usize;
    let t0 = Instant::now();
    for f in 0..frames {
        // pace the camera
        let due = t0 + frame_interval * f as u32;
        if let Some(wait) = due.checked_duration_since(Instant::now()) {
            std::thread::sleep(wait);
        }
        let frame = Tensor::random(64, 64, 3, f as u64);
        match server.submit(frame) {
            Ok(rx) => pending.push((f, Instant::now(), rx)),
            Err(_) => dropped += 1, // backpressure: drop the frame
        }
    }
    let mut latencies = Vec::new();
    let mut verified = 0usize;
    for (f, submitted, rx) in pending {
        let resp = rx.recv().expect("frame response");
        latencies.push(submitted.elapsed());
        if f % 30 == 0 {
            let reference = flexpie::compute::run_reference(
                &model,
                &weights,
                &Tensor::random(64, 64, 3, f as u64),
            );
            assert_eq!(reference.max_abs_diff(&resp.output), 0.0, "frame {f}");
            verified += 1;
        }
    }
    let wall = t0.elapsed();

    println!("\n== 30 FPS stream report ({frames} frames) ==");
    println!("frame latency (host): {}", summarize(&latencies));
    println!(
        "sustained: {:.1} FPS over {:.2}s, {dropped} dropped, {verified} frames verified",
        (frames - dropped) as f64 / wall.as_secs_f64(),
        wall.as_secs_f64()
    );
    println!(
        "simulated per-frame inference on the edge cluster: {:.3} ms ({:.0} FPS headroom)",
        flex_time * 1e3,
        1.0 / flex_time
    );
    let stats = server.shutdown();
    println!(
        "router: {} frames in {} batches (max batch {})",
        stats.requests, stats.batches, stats.max_batch_seen
    );
    println!("video_analytics OK");
}
