//! Forecast-warmed serving on measured conditions — the proactive loop the
//! reactive elastic stack (PRs 1–4) was missing.
//!
//! Part 1 rides one compressed diurnal "day" twice with the same hidden
//! world: once reactively (trace-driven, the old behavior) and once through
//! the full telemetry path (probes → ring-buffer store → EWMA+trend
//! forecaster → background pre-warming), then prints the side-by-side
//! comparison: cache hits, forecast hit/miss counters, mean horizon error
//! and boundary-stall percentiles. Part 2 serves real inferences through
//! [`Server::start_telemetry`]: the batches' own boundary exchanges are the
//! bandwidth probe, and outputs stay bit-exact while the measured monitor
//! adapts.
//!
//! ```bash
//! cargo run --release --example forecast_serving
//! ```

use std::time::Duration;

use flexpie::compute::{Tensor, WeightStore};
use flexpie::config::ForecastExperiment;
use flexpie::elastic::{ConditionTrace, ElasticConfig, ElasticFrontend};
use flexpie::metrics::{AdaptationMetrics, Summary};
use flexpie::model::zoo;
use flexpie::net::{Bandwidth, Testbed, Topology};
use flexpie::serve::{ServeConfig, Server};
use flexpie::telemetry::TelemetrySource;
use flexpie::util::bench::Table;

fn drive(mut fe: ElasticFrontend, exp: &ForecastExperiment) -> (AdaptationMetrics, Summary) {
    for k in 0..exp.boundaries() {
        let d = fe.acquire(k as f64 * exp.boundary_dt);
        assert_eq!(d.nodes, 4, "diurnal drift must not drop nodes");
        fe.quiesce(); // deterministic: pre-warms land before the next boundary
    }
    fe.finish()
}

fn main() {
    let exp = ForecastExperiment::default(); // diurnal-drift, one 60 s day
    let nodes = 4;
    let base = Testbed::new(nodes, Topology::Ring, Bandwidth::gbps(1.0));
    let model = zoo::edgenet(16);
    println!(
        "world: {} (seed {}), {} boundaries at {:.1}s | model {} | horizon {} boundaries\n",
        exp.profile,
        exp.seed,
        exp.boundaries(),
        exp.boundary_dt,
        model.name,
        exp.horizon_boundaries
    );

    // ---- 1. reactive vs forecast over the same hidden world ---------------
    let world = exp.world(nodes).expect("valid profile");
    let reactive = ElasticFrontend::start(
        model.clone(),
        base.clone(),
        world.clone(),
        ElasticConfig { cache_capacity: exp.cache_capacity, ..ElasticConfig::default() },
    );
    let (rm, rstalls) = drive(reactive, &exp);

    let source = TelemetrySource::new(world, &base, exp.telemetry_config());
    let store = source.store();
    let forecast = ElasticFrontend::start_with_source(
        model.clone(),
        base.clone(),
        Box::new(source),
        exp.elastic_config(),
    );
    let (fm, fstalls) = drive(forecast, &exp);

    let mut t = Table::new(["metric", "reactive (trace)", "forecast (measured)"]);
    let row = |t: &mut Table, name: &str, a: String, b: String| t.row([name.into(), a, b]);
    row(&mut t, "replans", rm.replans.to_string(), fm.replans.to_string());
    row(&mut t, "cache hits", rm.cache_hits.to_string(), fm.cache_hits.to_string());
    row(
        &mut t,
        "cache hit rate",
        format!("{:.0}%", rm.cache_hit_rate() * 100.0),
        format!("{:.0}%", fm.cache_hit_rate() * 100.0),
    );
    row(&mut t, "forecast pre-warms", "-".into(), fm.forecast_plans.to_string());
    row(
        &mut t,
        "forecast hits/misses",
        "-".into(),
        format!("{}/{}", fm.forecast_hits, fm.forecast_misses),
    );
    row(
        &mut t,
        "mean horizon err (buckets)",
        "-".into(),
        format!("{:.2}", fm.forecast_mean_bucket_err()),
    );
    row(
        &mut t,
        "boundary stall p99",
        format!("{:?}", rstalls.p99),
        format!("{:?}", fstalls.p99),
    );
    row(
        &mut t,
        "boundary stall max",
        format!("{:?}", rstalls.max),
        format!("{:?}", fstalls.max),
    );
    t.print();
    println!("\ntelemetry ingestion: {}", store.stats());
    println!("forecast path detail: {fm}");

    // ---- 2. real serving through the measured path -------------------------
    println!("\n--- serving path (real numerics, measured conditions) ---");
    let item_cost = {
        let p = flexpie::planner::plan_for_testbed(&model, &base);
        flexpie::engine::evaluate(&model, &p, &base).total
    };
    // a mid-stream collapse the probes must detect from serving traffic
    let world = ConditionTrace::stable(nodes).with_bandwidth_dip(
        4.5 * item_cost,
        f64::INFINITY,
        0.15,
    );
    let server = Server::start_telemetry(
        model.clone(),
        WeightStore::for_model(&model, 42),
        base,
        world,
        exp.telemetry_config(),
        ServeConfig {
            max_batch: 1,
            batch_window: Duration::ZERO,
            queue_depth: 32,
            ..ServeConfig::default()
        },
        ElasticConfig::default(),
    );
    let l0 = &model.layers[0];
    let n_requests = 24;
    for i in 0..n_requests {
        server
            .infer(Tensor::random(l0.in_h, l0.in_w, l0.in_c, i as u64))
            .expect("request lost");
    }
    let stats = server.shutdown();
    println!("served {} requests in {} batches", stats.requests, stats.batches);
    if let Some(m) = stats.adaptation {
        println!("measured-path adaptation: {m}");
    }
    if let Some(s) = stats.boundary_stall {
        println!("batch-boundary plan acquisition: {s}");
    }
}
