//! End-to-end serving driver — the full three-layer system on a real small
//! workload, proving all layers compose:
//!
//! 1. **L1/L2 artifacts**: loads the AOT-compiled JAX/Pallas kernels
//!    (`artifacts/*.hlo.txt`, built by `make artifacts`) through the PJRT
//!    runtime and cross-checks them against the native Rust kernels.
//! 2. **Planner**: DPP picks the partition plan for a 4-node, 5 Gb/s ring
//!    edge cluster.
//! 3. **Serving**: the router + dynamic batcher serves a batched request
//!    stream through the simulated cluster with real numerics; every
//!    response is verified against the single-node reference.
//!
//! Reports latency (host wall-clock), throughput, batching behaviour and
//! the simulated per-inference time. Recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_serving
//! ```

use std::time::{Duration, Instant};

use flexpie::compute::{run_reference, Tensor, WeightStore};
use flexpie::cost::CostSource;
use flexpie::engine;
use flexpie::metrics::summarize;
use flexpie::model::zoo;
use flexpie::net::{Bandwidth, Testbed, Topology};
use flexpie::planner::Dpp;
use flexpie::runtime::{signature, Runtime};
use flexpie::serve::{ServeConfig, Server};

fn main() {
    let model = zoo::edgenet(64);
    let weights = WeightStore::for_model(&model, 42);
    let testbed = Testbed::new(4, Topology::Ring, Bandwidth::gbps(5.0));

    // ---- 1. AOT artifacts through PJRT ------------------------------------
    match Runtime::load(std::path::Path::new("artifacts")) {
        Ok(rt) => {
            println!(
                "PJRT runtime: platform={} artifacts={}",
                rt.platform(),
                rt.n_artifacts()
            );
            let mut cur = Tensor::random(64, 64, 3, 7);
            let t0 = Instant::now();
            for (i, layer) in model.layers.iter().enumerate() {
                cur = rt
                    .execute_layer(layer, &weights.layers[i], &cur)
                    .unwrap_or_else(|e| panic!("layer {} via PJRT: {e}", layer.name));
            }
            let first = t0.elapsed();
            let reference = run_reference(&model, &weights, &Tensor::random(64, 64, 3, 7));
            let diff = reference.max_abs_diff(&cur);
            println!(
                "  full chain via AOT JAX/Pallas kernels: {:?} (incl. compile), \
                 |Δ| vs native = {diff:.2e}"
            , first);
            assert!(diff < 1e-3);
            // warm pass (compiled executables cached)
            let t1 = Instant::now();
            let mut cur = Tensor::random(64, 64, 3, 8);
            for (i, layer) in model.layers.iter().enumerate() {
                cur = rt.execute_layer(layer, &weights.layers[i], &cur).unwrap();
            }
            println!("  warm chain: {:?}", t1.elapsed());
            let sig = signature(&model.layers[0], 16, 16);
            println!("  example signature: {sig}");
        }
        Err(e) => {
            println!("PJRT runtime unavailable ({e}); run `make artifacts` first.");
            println!("continuing with native kernels only\n");
        }
    }

    // ---- 2. Plan -----------------------------------------------------------
    let cost = CostSource::analytic(&testbed);
    let plan = Dpp::new(&model, &cost).plan();
    let est = engine::evaluate(&model, &plan, &testbed);
    println!("\nplan: {}", plan.render());
    println!(
        "simulated inference on {}-node {} @ {} Gb/s: {:.3} ms",
        testbed.nodes,
        testbed.topology,
        testbed.bandwidth.as_gbps(),
        est.total_ms()
    );

    // ---- 3. Serve a batched request stream --------------------------------
    let n_requests = 128usize;
    let server = Server::start(
        model.clone(),
        plan,
        weights.clone(),
        testbed,
        ServeConfig {
            max_batch: 8,
            batch_window: Duration::from_millis(1),
            queue_depth: 256,
            ..ServeConfig::default()
        },
    );

    let t0 = Instant::now();
    let mut pending = Vec::new();
    for i in 0..n_requests {
        let input = Tensor::random(64, 64, 3, i as u64);
        match server.submit(input) {
            Ok(rx) => pending.push((i, Instant::now(), rx)),
            Err(e) => println!("request {i} rejected: {e:?}"),
        }
    }
    let mut latencies = Vec::new();
    let mut batch_sizes = Vec::new();
    let mut verified = 0usize;
    for (i, submitted, rx) in pending {
        let resp = rx.recv().expect("response");
        latencies.push(submitted.elapsed());
        batch_sizes.push(resp.batch_size);
        // verify a sample of responses against the reference
        if i % 16 == 0 {
            let reference =
                run_reference(&model, &weights, &Tensor::random(64, 64, 3, i as u64));
            assert_eq!(reference.max_abs_diff(&resp.output), 0.0, "request {i}");
            verified += 1;
        }
    }
    let wall = t0.elapsed();
    let stats = server.shutdown();

    println!("\n== serving report ({n_requests} requests) ==");
    println!("latency: {}", summarize(&latencies));
    println!(
        "throughput: {:.1} req/s host wall-clock ({:.3} s total)",
        n_requests as f64 / wall.as_secs_f64(),
        wall.as_secs_f64()
    );
    println!(
        "batching: {} batches, max batch {}, mean batch {:.2}",
        stats.batches,
        stats.max_batch_seen,
        n_requests as f64 / stats.batches as f64
    );
    println!(
        "simulated per-inference time: {:.3} ms ({} responses spot-verified vs reference)",
        est.total_ms(),
        verified
    );
    println!("e2e_serving OK");
}
