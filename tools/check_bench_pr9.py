#!/usr/bin/env python3
"""Check a measured load-harness run against the committed BENCH_pr9.json.

The committed file holds the machine-independent facts of the suite ladder
(structure, seeds, request totals, zero-loss gates); the measured file is
what `cargo bench --bench load_harness` (or `flexpie-load suite --out`)
wrote on this machine. This script is the CI tripwire that keeps the two
from drifting: if someone edits the suite table in
rust/src/bench/harness.rs, the committed trajectory point must move with
it, in the same PR.

Latency magnitudes are machine-dependent and are deliberately NOT checked
— only structure: counts, conservation, determinism gates, percentile
monotonicity, and the B2 chaos minima.

Usage: check_bench_pr9.py [--profile smoke|full] EXPECTED.json MEASURED.json
"""

import argparse
import json
import sys


def fail(msg):
    print(f"check_bench_pr9: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--profile", choices=["smoke", "full"], default="smoke")
    ap.add_argument("expected")
    ap.add_argument("measured")
    args = ap.parse_args()

    with open(args.expected) as f:
        expected = json.load(f)
    with open(args.measured) as f:
        measured = json.load(f)

    if measured.get("bench") != expected.get("bench"):
        fail(f"bench name {measured.get('bench')!r} != {expected.get('bench')!r}")
    if measured.get("pr") != expected.get("pr"):
        fail(f"pr {measured.get('pr')!r} != {expected.get('pr')!r}")

    got = {s["suite"]: s for s in measured.get("suites", [])}
    want_names = [s["suite"] for s in expected["suites"]]
    if sorted(got) != sorted(want_names):
        fail(f"suite set {sorted(got)} != committed {sorted(want_names)}")

    for want in expected["suites"]:
        name = want["suite"]
        m = got[name]

        def eq(key, want_v, got_v):
            if got_v != want_v:
                fail(f"{name}: {key} = {got_v!r}, committed expectation {want_v!r}")

        eq("mode", want["mode"], m["mode"])
        eq("agents", want["agents"], m["agents"])
        eq("slo_ms", want["slo_ms"], m["slo_ms"])
        eq("sent", want["sent"][args.profile], m["sent"])
        eq("mismatches", 0, m["mismatches"])

        if m["ok"] + m["shed"] + m["failed"] != m["sent"]:
            fail(
                f"{name}: conservation broken: ok {m['ok']} + shed {m['shed']}"
                f" + failed {m['failed']} != sent {m['sent']}"
            )

        if want["deterministic"]:
            eq("ok", m["sent"], m["ok"])
            eq("shed", 0, m["shed"])
            eq("failed", 0, m["failed"])
            eq("slo_violation_frac", 0.0, m["slo_violation_frac"])

        pct = [m["p50_us"], m["p90_us"], m["p99_us"], m["p999_us"]]
        if any(b < a for a, b in zip(pct, pct[1:])):
            fail(f"{name}: percentiles not monotone: {pct}")

        chaos = want.get("chaos")
        if chaos:
            if m["failovers"] < chaos["min_failovers"]:
                fail(f"{name}: failovers {m['failovers']} < {chaos['min_failovers']}")
            if m["replays"] < chaos["min_replays"]:
                fail(f"{name}: replays {m['replays']} < {chaos['min_replays']}")

    print(f"check_bench_pr9: OK — {len(want_names)} suites match the committed trajectory point")


if __name__ == "__main__":
    main()
