#!/usr/bin/env python3
"""Gate the per-suite trace/metrics artifacts the load harness writes.

`cargo bench --bench load_harness` (or `flexpie-load suite --artifacts DIR`)
leaves two files per suite in the artifact directory:

  trace_<suite>.json   — merged span trees (queue/service/wire decomposition)
  metrics_<suite>.json — flat named-counter snapshot (Registry::to_json)

This script is the CI tripwire for the tracing contract:

  * every tree re-passes conservation: |total − (queue+service+wire)| within
    the merger's tolerance (15% of total, 3 ms absolute floor) for trees the
    merger called well-formed — catches a merger that stamps well_formed
    without checking;
  * stage spans nest: per-tree stage busy time never exceeds the service
    component it decomposes;
  * ≥ --min-well-formed of trees are well-formed (chaos suites, which
    truncate trees by design when a daemon dies mid-request, only need one);
  * process-mode suites observed at least one nonzero wire component —
    an all-zero wire column means the daemon service spans never made it
    back and the decomposition silently degenerated;
  * the counter snapshot conserves: ok + shed + failed == sent, the server's
    per-reason shed counters equal the agents' wire observations, and the
    tree count in the trace file equals trace.traces in the metrics file.

Latency magnitudes are machine-dependent and deliberately not checked.

Usage: check_trace.py [--dir bench_results] [--min-well-formed 0.99]
"""

import argparse
import glob
import json
import os
import sys

TOL_FRAC = 0.15
TOL_ABS_NS = 3_000_000


def fail(msg):
    print(f"check_trace: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def check_trees(suite, doc):
    mode = doc.get("mode")
    trees = doc.get("trees", [])
    if not trees:
        fail(f"{suite}: no span trees — tracing is always on, so zero trees is a regression")

    well_formed = 0
    wire_nonzero = 0
    for t in trees:
        total = t["total_ns"]
        parts = t["queue_ns"] + t["service_ns"] + t["wire_ns"]
        stage_sum = sum(ns for _, ns in t.get("stages", []))
        if t["well_formed"]:
            well_formed += 1
            if t["truncated"]:
                fail(f"{suite}: trace {t['trace']} is both well_formed and truncated")
            if total <= 0:
                fail(f"{suite}: trace {t['trace']} well-formed with total_ns {total}")
            tol = max(TOL_FRAC * total, TOL_ABS_NS)
            if abs(total - parts) > tol:
                fail(
                    f"{suite}: trace {t['trace']} conservation broken: total {total} ns"
                    f" vs queue+service+wire {parts} ns (tol {tol:.0f} ns)"
                )
            if stage_sum > t["service_ns"] + TOL_ABS_NS:
                fail(
                    f"{suite}: trace {t['trace']} stage spans do not nest: stage sum"
                    f" {stage_sum} ns > service {t['service_ns']} ns"
                )
        if t["wire_ns"] > 0:
            wire_nonzero += 1

    frac = well_formed / len(trees)
    chaos = "chaos" in suite
    floor = 1 / len(trees) if chaos else args.min_well_formed
    if frac < floor:
        fail(
            f"{suite}: only {well_formed}/{len(trees)} trees well-formed"
            f" ({frac:.3f} < {floor:.3f})"
        )
    if mode == "process" and wire_nonzero == 0:
        fail(f"{suite}: process mode but every wire component is zero")
    return len(trees), frac


def check_metrics(suite, reg, n_trees):
    def get(key):
        if key not in reg:
            fail(f"{suite}: metrics missing counter {key!r}")
        return reg[key]

    sent = get("agents.sent")
    ok, shed, failed = get("agents.ok"), get("agents.shed"), get("agents.failed")
    if ok + shed + failed != sent:
        fail(f"{suite}: conservation broken: ok {ok} + shed {shed} + failed {failed} != sent {sent}")
    if get("router.shed.queue_full") + get("router.shed.stopped") != shed:
        fail(f"{suite}: server shed counters disagree with the agents' {shed} wire sheds")
    if get("router.shed.failed") != failed:
        fail(f"{suite}: server failure counter disagrees with the agents' {failed} failures")
    traces, wf = get("trace.traces"), get("trace.well_formed")
    if traces != n_trees:
        fail(f"{suite}: metrics say {traces} traces but the trace file holds {n_trees} trees")
    if wf > traces:
        fail(f"{suite}: well_formed {wf} > traces {traces}")


def main():
    trace_files = sorted(glob.glob(os.path.join(args.dir, "trace_*.json")))
    if not trace_files:
        fail(f"no trace_*.json under {args.dir!r} — did the bench run with artifacts enabled?")

    checked = 0
    for tpath in trace_files:
        suite = os.path.basename(tpath)[len("trace_") : -len(".json")]
        with open(tpath) as f:
            doc = json.load(f)
        if doc.get("suite") != suite:
            fail(f"{tpath}: suite field {doc.get('suite')!r} != filename suite {suite!r}")
        n_trees, frac = check_trees(suite, doc)

        mpath = os.path.join(args.dir, f"metrics_{suite}.json")
        if not os.path.exists(mpath):
            fail(f"{suite}: trace file present but {mpath} missing")
        with open(mpath) as f:
            reg = json.load(f)
        check_metrics(suite, reg, n_trees)
        print(f"check_trace: {suite}: {n_trees} trees, {frac:.1%} well-formed — ok")
        checked += 1

    print(f"check_trace: OK — {checked} suite(s) pass nesting, conservation and wire gates")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="bench_results")
    ap.add_argument("--min-well-formed", type=float, default=0.99)
    args = ap.parse_args()
    main()
