//! Per-request distributed tracing: spans, a bounded lock-free flight
//! recorder, and a deterministic span-tree merger.
//!
//! Every admitted request gets a trace id at the front door (or at
//! [`crate::serve::Server`]'s in-process submit). As the request moves
//! through the serving vertical, each participant records **spans** —
//! `(trace id, generation, kind, node, start, duration)` tuples — into a
//! process-local [`FlightRecorder`]: the router records queue wait and the
//! end-to-end interval, pipeline stage threads record per-stage busy time,
//! node daemons record their compute interval, and the coordinator
//! synthesizes the wire span from its measured round trip minus the
//! daemon-reported service time (clocks across processes are *not*
//! synchronized, so only process-local intervals and shipped durations are
//! ever trusted).
//!
//! Recording is built for the steady-state serving path: the recorder is a
//! fixed-size ring of seqlock-stamped slots, writes are lock-free
//! (`fetch_add` on a cursor plus relaxed stores), and nothing allocates —
//! the `FLEXPIE_ALLOC_GUARD` gate stays honest with tracing on. Draining
//! ([`FlightRecorder::snapshot`]) allocates, but only at dump time.
//!
//! [`merge_spans`] turns a bag of records — arriving out of order,
//! duplicated, or with whole nodes missing — into one [`TraceTree`] per
//! `(trace id, generation)`, deterministically (sort + dedupe, last-writer
//! -wins on conflicting duplicates), and validates each tree: components
//! must nest inside the end-to-end interval (same-recorder spans only) and
//! queue + service + wire must sum to the total within a tolerance. A tree
//! with no end-to-end span (a dropped node, a failed attempt) is marked
//! `truncated` — never a panic.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Span kind codes (the `kind` field of [`SpanRecord`]).
pub const KIND_QUEUE: u8 = 0;
/// Compute interval: `node_main` wall time on the recording node.
pub const KIND_SERVICE: u8 = 1;
/// Wire time: coordinator round trip minus daemon-reported service.
pub const KIND_WIRE: u8 = 2;
/// One pipeline stage's busy time for this request (`node` = stage index).
pub const KIND_STAGE: u8 = 3;
/// End-to-end: enqueue at admission → response completed.
pub const KIND_TOTAL: u8 = 4;
/// Codes above this are corrupt and dropped by the merger.
pub const KIND_MAX: u8 = KIND_TOTAL;

/// The node id routers/coordinators record under (daemons use their real
/// node id). Mirrors the wire codec's `CTL_NODE`.
pub const CTL_NODE: u32 = u32::MAX;

/// Decomposition tolerance: |total − (queue+service+wire)| must be within
/// `TOL_FRAC · total + TOL_ABS_NS`.
pub const TOL_FRAC: f64 = 0.15;
pub const TOL_ABS_NS: u64 = 3_000_000;

/// One span. Plain-old-data and fixed-size so it can live in a lock-free
/// ring slot and travel the wire as six little-endian fields.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct SpanRecord {
    pub trace_id: u64,
    /// Plan generation (the wire term) the span was recorded under.
    pub gen: u64,
    /// One of the `KIND_*` codes.
    pub kind: u8,
    /// Recording node id; `CTL_NODE` for router/coordinator spans, the
    /// stage index for `KIND_STAGE`.
    pub node: u32,
    /// Start instant in the *recording process's* clock (ns since its
    /// recorder epoch). Comparable only between spans of the same node.
    pub start_ns: u64,
    pub dur_ns: u64,
}

// --- flight recorder -----------------------------------------------------

/// One seqlock-stamped ring slot: `ver` is odd while a write is in
/// flight; readers accept a slot only when `ver` is even and unchanged
/// across the field reads.
struct Slot {
    ver: AtomicU64,
    f: [AtomicU64; 5],
}

/// Bounded per-process span buffer: fixed-size ring, lock-free writes,
/// zero allocation in steady state. Oldest spans are overwritten when the
/// ring wraps — a flight recorder, not a database.
pub struct FlightRecorder {
    slots: Box<[Slot]>,
    cursor: AtomicU64,
    ids: AtomicU64,
    epoch: Instant,
}

/// Default ring capacity: 5 spans per request × thousands of in-flight
/// requests before wrap, at ~48 B/slot.
pub const DEFAULT_CAPACITY: usize = 16 * 1024;

impl Default for FlightRecorder {
    fn default() -> Self {
        FlightRecorder::with_capacity(DEFAULT_CAPACITY)
    }
}

impl FlightRecorder {
    pub fn new() -> FlightRecorder {
        FlightRecorder::default()
    }

    pub fn with_capacity(capacity: usize) -> FlightRecorder {
        let cap = capacity.max(1);
        let slots = (0..cap)
            .map(|_| Slot { ver: AtomicU64::new(0), f: Default::default() })
            .collect::<Vec<_>>()
            .into_boxed_slice();
        FlightRecorder { slots, cursor: AtomicU64::new(0), ids: AtomicU64::new(1), epoch: Instant::now() }
    }

    /// Allocate a fresh trace id (process-unique, monotonically increasing,
    /// never 0 — 0 means "untraced").
    pub fn next_trace_id(&self) -> u64 {
        self.ids.fetch_add(1, Ordering::Relaxed)
    }

    /// Nanoseconds since this recorder's epoch — the clock every span's
    /// `start_ns` is measured on.
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Record one span. Lock-free and allocation-free: a cursor
    /// `fetch_add` plus six relaxed stores under a seqlock stamp. Two
    /// writers landing on the *same* slot (a full ring wrap inside one
    /// write) can tear it; the merger treats a torn slot like any other
    /// corrupt record.
    pub fn record(&self, r: SpanRecord) {
        let i = (self.cursor.fetch_add(1, Ordering::Relaxed) % self.slots.len() as u64) as usize;
        let s = &self.slots[i];
        s.ver.fetch_add(1, Ordering::AcqRel); // odd: write in flight
        s.f[0].store(r.trace_id, Ordering::Relaxed);
        s.f[1].store(r.gen, Ordering::Relaxed);
        s.f[2].store(((r.node as u64) << 8) | r.kind as u64, Ordering::Relaxed);
        s.f[3].store(r.start_ns, Ordering::Relaxed);
        s.f[4].store(r.dur_ns, Ordering::Relaxed);
        s.ver.fetch_add(1, Ordering::Release); // even: visible
    }

    /// Spans recorded so far (including any the ring has overwritten).
    pub fn recorded(&self) -> u64 {
        self.cursor.load(Ordering::Relaxed)
    }

    /// Drain every currently-readable span. Slots mid-write are skipped,
    /// not waited on. Allocates — dump-time only.
    pub fn snapshot(&self) -> Vec<SpanRecord> {
        let mut out = Vec::new();
        for s in self.slots.iter() {
            let v0 = s.ver.load(Ordering::Acquire);
            if v0 == 0 || v0 % 2 == 1 {
                continue; // never written, or a write is in flight
            }
            let trace_id = s.f[0].load(Ordering::Relaxed);
            let gen = s.f[1].load(Ordering::Relaxed);
            let packed = s.f[2].load(Ordering::Relaxed);
            let start_ns = s.f[3].load(Ordering::Relaxed);
            let dur_ns = s.f[4].load(Ordering::Relaxed);
            if s.ver.load(Ordering::Acquire) != v0 {
                continue; // overwritten underneath us
            }
            out.push(SpanRecord {
                trace_id,
                gen,
                kind: (packed & 0xFF) as u8,
                node: (packed >> 8) as u32,
                start_ns,
                dur_ns,
            });
        }
        out
    }
}

// --- merger --------------------------------------------------------------

/// One assembled per-request span tree with its latency decomposition.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceTree {
    pub trace_id: u64,
    pub gen: u64,
    /// End-to-end ns (0 when `truncated`).
    pub total_ns: u64,
    pub queue_ns: u64,
    pub service_ns: u64,
    pub wire_ns: u64,
    /// Per-stage busy ns, sorted by stage index.
    pub stages: Vec<(u32, u64)>,
    /// No end-to-end span reached the merger — a failed attempt or a
    /// dropped node. The components above are whatever did arrive.
    pub truncated: bool,
    /// Complete, nested, and conservation holds within tolerance.
    pub well_formed: bool,
}

impl TraceTree {
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let stages = self
            .stages
            .iter()
            .map(|&(s, ns)| Json::arr([Json::Num(s as f64), Json::Num(ns as f64)]))
            .collect::<Vec<_>>();
        Json::obj(vec![
            ("trace", Json::Num(self.trace_id as f64)),
            ("gen", Json::Num(self.gen as f64)),
            ("total_ns", Json::Num(self.total_ns as f64)),
            ("queue_ns", Json::Num(self.queue_ns as f64)),
            ("service_ns", Json::Num(self.service_ns as f64)),
            ("wire_ns", Json::Num(self.wire_ns as f64)),
            ("stages", Json::Arr(stages)),
            ("truncated", Json::Bool(self.truncated)),
            ("well_formed", Json::Bool(self.well_formed)),
        ])
    }
}

/// Assemble span trees from a bag of records with the default tolerance.
/// Deterministic in the face of out-of-order, duplicated, or missing
/// delivery: records are sorted and deduped first, so any permutation of
/// the same multiset yields the same trees.
pub fn merge_spans(records: &[SpanRecord]) -> Vec<TraceTree> {
    merge_spans_tol(records, TOL_FRAC, TOL_ABS_NS)
}

/// [`merge_spans`] with an explicit conservation tolerance.
pub fn merge_spans_tol(records: &[SpanRecord], tol_frac: f64, tol_abs_ns: u64) -> Vec<TraceTree> {
    let mut recs: Vec<SpanRecord> = records
        .iter()
        .copied()
        .filter(|r| r.kind <= KIND_MAX && r.trace_id != 0)
        .collect();
    recs.sort_unstable();
    recs.dedup();

    let mut trees = Vec::new();
    let mut i = 0;
    while i < recs.len() {
        let (tid, gen) = (recs[i].trace_id, recs[i].gen);
        let mut j = i;
        while j < recs.len() && recs[j].trace_id == tid && recs[j].gen == gen {
            j += 1;
        }
        trees.push(assemble(&recs[i..j], tol_frac, tol_abs_ns));
        i = j;
    }
    trees
}

/// Build and validate one tree from the (sorted, deduped) records of one
/// `(trace id, generation)` group.
fn assemble(group: &[SpanRecord], tol_frac: f64, tol_abs_ns: u64) -> TraceTree {
    // Conflicting duplicates (same kind + node, different interval) resolve
    // to the last record in sort order — deterministic last-writer-wins.
    let pick = |kind: u8| -> Option<SpanRecord> {
        group.iter().rev().find(|r| r.kind == kind).copied()
    };
    let total = pick(KIND_TOTAL);
    let queue = pick(KIND_QUEUE);
    // Service can be reported twice — by the daemon that measured it and by
    // the coordinator that synthesized it from the Output frame. The
    // critical-path compute time is the longest one.
    let service_ns =
        group.iter().filter(|r| r.kind == KIND_SERVICE).map(|r| r.dur_ns).max().unwrap_or(0);
    let wire_ns = group.iter().filter(|r| r.kind == KIND_WIRE).map(|r| r.dur_ns).max().unwrap_or(0);

    let mut stages: Vec<(u32, u64)> = Vec::new();
    for r in group.iter().filter(|r| r.kind == KIND_STAGE) {
        match stages.iter_mut().find(|(s, _)| *s == r.node) {
            Some((_, ns)) => *ns = (*ns).max(r.dur_ns),
            None => stages.push((r.node, r.dur_ns)),
        }
    }
    stages.sort_unstable();

    let truncated = total.is_none();
    let queue_ns = queue.map_or(0, |q| q.dur_ns);
    let total_ns = total.map_or(0, |t| t.dur_ns);

    let mut well_formed = !truncated;
    if let Some(t) = total {
        let slack = (tol_frac * total_ns as f64) as u64 + tol_abs_ns;
        // conservation: the decomposition must account for the total
        let parts = queue_ns + service_ns + wire_ns;
        if parts > total_ns + slack || total_ns > parts + slack {
            well_formed = false;
        }
        // nesting: same-recorder child intervals sit inside the total.
        // Spans from other nodes carry a different process clock, so only
        // durations are checked for them.
        let t_end = t.start_ns + t.dur_ns;
        for r in group.iter().filter(|r| r.kind != KIND_TOTAL) {
            if r.kind != KIND_STAGE && r.node == t.node {
                if r.start_ns + tol_abs_ns < t.start_ns
                    || r.start_ns + r.dur_ns > t_end + slack
                {
                    well_formed = false;
                }
            }
            if r.kind != KIND_STAGE && r.dur_ns > total_ns + slack {
                well_formed = false;
            }
        }
    }

    TraceTree {
        trace_id: group[0].trace_id,
        gen: group[0].gen,
        total_ns,
        queue_ns,
        service_ns,
        wire_ns,
        stages,
        truncated,
        well_formed,
    }
}

// --- summary -------------------------------------------------------------

/// Aggregate view over merged trees — joins `RouterStats` so every server
/// shutdown reports what its tracing saw.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TraceSummary {
    pub traces: u64,
    pub well_formed: u64,
    pub truncated: u64,
    pub total_ns_sum: u64,
    pub queue_ns_sum: u64,
    pub service_ns_sum: u64,
    pub wire_ns_sum: u64,
}

impl TraceSummary {
    pub fn from_trees(trees: &[TraceTree]) -> TraceSummary {
        let mut s = TraceSummary::default();
        for t in trees {
            s.traces += 1;
            s.well_formed += t.well_formed as u64;
            s.truncated += t.truncated as u64;
            s.total_ns_sum += t.total_ns;
            s.queue_ns_sum += t.queue_ns;
            s.service_ns_sum += t.service_ns;
            s.wire_ns_sum += t.wire_ns;
        }
        s
    }
}

impl std::fmt::Display for TraceSummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mean = |sum: u64| {
            if self.traces == 0 { 0.0 } else { sum as f64 / self.traces as f64 / 1e6 }
        };
        write!(
            f,
            "traces={} well_formed={} truncated={} mean_ms total={:.3} queue={:.3} service={:.3} wire={:.3}",
            self.traces,
            self.well_formed,
            self.truncated,
            mean(self.total_ns_sum),
            mean(self.queue_ns_sum),
            mean(self.service_ns_sum),
            mean(self.wire_ns_sum)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn well_formed_group(tid: u64, gen: u64) -> Vec<SpanRecord> {
        // total [0, 10ms]; queue [0, 2ms]; wire 1ms; service 7ms (daemon
        // clock, different node) — conservation: 2+7+1 = 10.
        vec![
            SpanRecord { trace_id: tid, gen, kind: KIND_TOTAL, node: CTL_NODE, start_ns: 0, dur_ns: 10_000_000 },
            SpanRecord { trace_id: tid, gen, kind: KIND_QUEUE, node: CTL_NODE, start_ns: 0, dur_ns: 2_000_000 },
            SpanRecord { trace_id: tid, gen, kind: KIND_WIRE, node: CTL_NODE, start_ns: 2_000_000, dur_ns: 1_000_000 },
            SpanRecord { trace_id: tid, gen, kind: KIND_SERVICE, node: 3, start_ns: 55_000, dur_ns: 7_000_000 },
            SpanRecord { trace_id: tid, gen, kind: KIND_STAGE, node: 0, start_ns: 60_000, dur_ns: 3_000_000 },
            SpanRecord { trace_id: tid, gen, kind: KIND_STAGE, node: 1, start_ns: 70_000, dur_ns: 4_000_000 },
        ]
    }

    #[test]
    fn merge_assembles_well_formed_tree() {
        let trees = merge_spans(&well_formed_group(7, 2));
        assert_eq!(trees.len(), 1);
        let t = &trees[0];
        assert!(t.well_formed, "tree should validate: {t:?}");
        assert!(!t.truncated);
        assert_eq!((t.trace_id, t.gen), (7, 2));
        assert_eq!(t.total_ns, 10_000_000);
        assert_eq!(t.queue_ns, 2_000_000);
        assert_eq!(t.service_ns, 7_000_000);
        assert_eq!(t.wire_ns, 1_000_000);
        assert_eq!(t.stages, vec![(0, 3_000_000), (1, 4_000_000)]);
    }

    #[test]
    fn merge_is_order_and_duplicate_invariant() {
        // property: any shuffle + duplication of the same records yields
        // identical trees — the determinism the trace-dump path relies on
        let mut base = Vec::new();
        for tid in 1..=6u64 {
            base.extend(well_formed_group(tid, tid % 3));
        }
        let reference = merge_spans(&base);
        let mut rng = Rng::new(42);
        for _ in 0..50 {
            let mut perm = base.clone();
            // duplicate a random sample
            for _ in 0..rng.below(10) {
                let i = rng.below(base.len());
                perm.push(base[i]);
            }
            // Fisher–Yates shuffle
            for i in (1..perm.len()).rev() {
                let j = rng.below(i + 1);
                perm.swap(i, j);
            }
            assert_eq!(merge_spans(&perm), reference, "merge must be order/dup invariant");
        }
    }

    #[test]
    fn missing_total_marks_truncated_never_panics() {
        // dropped node: the end-to-end span never arrives
        let mut g = well_formed_group(9, 1);
        g.retain(|r| r.kind != KIND_TOTAL);
        let trees = merge_spans(&g);
        assert_eq!(trees.len(), 1);
        assert!(trees[0].truncated);
        assert!(!trees[0].well_formed);
        assert_eq!(trees[0].total_ns, 0);
        // components that did arrive are preserved for inspection
        assert_eq!(trees[0].service_ns, 7_000_000);
    }

    #[test]
    fn random_subsets_never_panic_and_stay_deterministic() {
        // property: dropping any subset of spans yields *some* valid answer
        // (possibly truncated trees), never a panic, and stays deterministic
        let mut base = Vec::new();
        for tid in 1..=4u64 {
            base.extend(well_formed_group(tid, 0));
        }
        let mut rng = Rng::new(7);
        for _ in 0..100 {
            let kept: Vec<SpanRecord> =
                base.iter().copied().filter(|_| rng.below(2) == 0).collect();
            let a = merge_spans(&kept);
            let b = merge_spans(&kept);
            assert_eq!(a, b);
            for t in &a {
                assert!(t.truncated || t.total_ns > 0);
            }
        }
    }

    #[test]
    fn conservation_violation_is_flagged() {
        let mut g = well_formed_group(3, 0);
        // service claims 3x the total — decomposition can't account
        g.iter_mut().find(|r| r.kind == KIND_SERVICE).unwrap().dur_ns = 30_000_000;
        let trees = merge_spans(&g);
        assert!(!trees[0].well_formed);
        assert!(!trees[0].truncated);
    }

    #[test]
    fn nesting_violation_is_flagged() {
        let mut g = well_formed_group(3, 0);
        // queue span starts long before the total's interval on the same clock
        let q = g.iter_mut().find(|r| r.kind == KIND_QUEUE).unwrap();
        q.start_ns = 0;
        let t = g.iter_mut().find(|r| r.kind == KIND_TOTAL).unwrap();
        t.start_ns = 500_000_000;
        let trees = merge_spans(&g);
        assert!(!trees[0].well_formed, "child escaping the parent interval must flag");
    }

    #[test]
    fn corrupt_kinds_and_untraced_ids_are_dropped() {
        let mut g = well_formed_group(5, 0);
        g.push(SpanRecord { trace_id: 5, gen: 0, kind: 250, node: 1, start_ns: 1, dur_ns: 1 });
        g.push(SpanRecord { trace_id: 0, gen: 0, kind: KIND_TOTAL, node: 1, start_ns: 1, dur_ns: 1 });
        let trees = merge_spans(&g);
        assert_eq!(trees.len(), 1);
        assert!(trees[0].well_formed);
    }

    #[test]
    fn recorder_round_trips_and_wraps() {
        let rec = FlightRecorder::with_capacity(8);
        for i in 0..20u64 {
            rec.record(SpanRecord {
                trace_id: i + 1,
                gen: 1,
                kind: KIND_TOTAL,
                node: 2,
                start_ns: i * 10,
                dur_ns: 5,
            });
        }
        assert_eq!(rec.recorded(), 20);
        let snap = rec.snapshot();
        assert_eq!(snap.len(), 8, "ring keeps only the last capacity spans");
        for r in &snap {
            assert!(r.trace_id > 12, "oldest spans were overwritten, kept {r:?}");
            assert_eq!(r.node, 2);
        }
    }

    #[test]
    fn recorder_is_safe_under_concurrent_writers() {
        let rec = std::sync::Arc::new(FlightRecorder::with_capacity(64));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let rec = rec.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..1000u64 {
                    rec.record(SpanRecord {
                        trace_id: t * 10_000 + i + 1,
                        gen: t,
                        kind: (i % 5) as u8,
                        node: t as u32,
                        start_ns: i,
                        dur_ns: 1,
                    });
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(rec.recorded(), 4000);
        // snapshot + merge must digest whatever survived without panicking
        let _ = merge_spans(&rec.snapshot());
    }

    #[test]
    fn trace_ids_are_unique_and_nonzero() {
        let rec = FlightRecorder::new();
        let a = rec.next_trace_id();
        let b = rec.next_trace_id();
        assert!(a != 0 && b != 0 && a != b);
    }

    #[test]
    fn summary_counts_and_display() {
        let mut recs = well_formed_group(1, 0);
        let mut cut = well_formed_group(2, 0);
        cut.retain(|r| r.kind != KIND_TOTAL);
        recs.extend(cut);
        let s = TraceSummary::from_trees(&merge_spans(&recs));
        assert_eq!(s.traces, 2);
        assert_eq!(s.well_formed, 1);
        assert_eq!(s.truncated, 1);
        let text = s.to_string();
        assert!(text.contains("traces=2"), "{text}");
    }
}
