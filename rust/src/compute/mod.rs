//! Native tensor kernels — the execution substrate for real numerics.
//!
//! Every partitioned plan can be *executed*, not just costed: each simulated
//! node computes its (possibly inflated) tiles with these kernels, halos are
//! exchanged as real data, and the assembled output is compared against the
//! single-node reference — the strongest possible check that the partition
//! geometry (halos, NT inflation, scheme realignment) is correct.
//!
//! These kernels are the *fallback/oracle* path; when an AOT-compiled HLO
//! artifact exists for a layer's exact shape, [`crate::runtime`] executes the
//! JAX/Pallas version via PJRT instead (and tests assert both paths agree).
//!
//! Layout is HWC (`idx = (y·W + x)·C + c`), matching the feature-map
//! orientation of the partition geometry and the JAX reference.
//!
//! ## The hot path (§Perf)
//!
//! Three properties keep per-node compute near hardware speed without
//! giving up the bit-exactness contract:
//!
//! * **Blocked kernels with one reduction order.** [`conv2d`] splits each
//!   output tile into an interior (every tap in-bounds — no validity
//!   branches) swept in pixel blocks of [`PIXEL_BLOCK`], so each contiguous
//!   weight row `w[ky,kx,ic,:]` is streamed once per block instead of once
//!   per pixel, plus thin boundary strips on the guarded per-pixel path.
//!   [`dense`] row-blocks the same way. Blocking only regroups *which
//!   elements share a weight load* — every output element still accumulates
//!   bias first, then taps in `(ky, kx, ic)` order — so outputs are
//!   bit-identical to the scalar kernels and across every partitioning.
//! * **Zero-copy dispatch.** When a store already holds a single patch
//!   covering a tile's clamped receptive field (the common case: inflated
//!   tiles, the leader's full input, the single-node reference), the
//!   kernels index that patch directly — no dense extract copy at all.
//! * **Recycled buffers.** [`TensorArena`] keeps freed tensor buffers on a
//!   free list so steady-state serving allocates ~nothing per batch, and
//!   [`compute_tile_set`] fans a stage's tiles over a scoped worker pool
//!   ([`ComputeConfig::tile_workers`]) with a deterministic merge by tile
//!   index — parallel and serial execution are bitwise equal because each
//!   tile's accumulation order never depends on who computes it.

use std::cell::RefCell;

use crate::model::{ConvType, LayerMeta, Model};
use crate::partition::Region;
use crate::util::rng::Rng;

/// Tuning knobs for the node-local compute hot path. Plumbed from
/// [`crate::serve::ServeConfig`] into both executors; the defaults keep
/// every entry point on the parallel, buffer-recycling path so the
/// bit-exactness audits exercise what production runs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ComputeConfig {
    /// Worker threads a stage may fan its tiles over (1 = serial).
    pub tile_workers: usize,
    /// Minimum total output volume (elements) across a tile set before the
    /// worker pool engages — below this, thread spawn overhead dominates.
    pub parallel_threshold: i64,
    /// Recycle tensor buffers through the per-stage [`TensorArena`].
    /// `false` drops every returned buffer — the baseline the allocation
    /// regression bench measures against.
    pub reuse_buffers: bool,
}

impl Default for ComputeConfig {
    fn default() -> Self {
        ComputeConfig { tile_workers: 2, parallel_threshold: 4096, reuse_buffers: true }
    }
}

impl ComputeConfig {
    /// Single-threaded variant (buffer reuse still on) — the reference
    /// against which the parallel path is asserted bitwise identical.
    pub fn serial() -> ComputeConfig {
        ComputeConfig { tile_workers: 1, ..ComputeConfig::default() }
    }
}

/// A free list of tensor buffers: `take` prefers recycling a previously
/// `give`n allocation over provisioning a fresh one, which removes the
/// allocation churn of the scatter/compute/exchange cycle — each stage
/// returns as many buffers per item as it takes, so after one warm-up item
/// the steady state allocates nothing.
#[derive(Debug, Default)]
pub struct TensorArena {
    free: Vec<Vec<f32>>,
    reuse: bool,
    /// Takes that had to provision a fresh buffer.
    pub allocs: u64,
    /// Takes served from the free list.
    pub reuses: u64,
}

/// Free-list cap — beyond this, returned buffers are dropped instead of
/// hoarded (a plan change can strand arbitrarily many).
const ARENA_MAX_FREE: usize = 256;

impl TensorArena {
    pub fn new(reuse: bool) -> TensorArena {
        TensorArena { free: Vec::new(), reuse, allocs: 0, reuses: 0 }
    }

    /// A zeroed `(h, w, c)` tensor, recycling a freed buffer when one is
    /// available (most recently freed first, for cache locality).
    pub fn take(&mut self, h: i64, w: i64, c: i64) -> Tensor {
        let len = (h * w * c) as usize;
        let mut data = match self.free.pop() {
            Some(buf) => {
                self.reuses += 1;
                buf
            }
            None => {
                self.allocs += 1;
                Vec::with_capacity(len)
            }
        };
        data.clear();
        data.resize(len, 0.0);
        Tensor { h, w, c, data }
    }

    /// Return a tensor's buffer to the free list (dropped when reuse is
    /// disabled or the list is full).
    pub fn give(&mut self, t: Tensor) {
        if self.reuse && self.free.len() < ARENA_MAX_FREE {
            self.free.push(t.data);
        }
    }

    /// Return every patch buffer of a consumed store.
    pub fn give_store(&mut self, store: &mut PatchStore) {
        for p in store.patches.drain(..) {
            self.give(p.t);
        }
    }
}

/// A dense f32 tensor over an `(h, w, c)` box.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub h: i64,
    pub w: i64,
    pub c: i64,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(h: i64, w: i64, c: i64) -> Tensor {
        Tensor { h, w, c, data: vec![0.0; (h * w * c) as usize] }
    }

    /// Reshape in place, reusing the buffer; contents are unspecified (the
    /// kernels overwrite every element of the shape they fill).
    pub fn reshape(&mut self, h: i64, w: i64, c: i64) {
        self.h = h;
        self.w = w;
        self.c = c;
        self.data.resize((h * w * c) as usize, 0.0);
    }

    /// Reshape in place and zero-fill, reusing the buffer.
    pub fn reshape_zeroed(&mut self, h: i64, w: i64, c: i64) {
        self.h = h;
        self.w = w;
        self.c = c;
        self.data.clear();
        self.data.resize((h * w * c) as usize, 0.0);
    }

    #[inline]
    pub fn at(&self, y: i64, x: i64, ch: i64) -> f32 {
        debug_assert!(y < self.h && x < self.w && ch < self.c);
        self.data[((y * self.w + x) * self.c + ch) as usize]
    }

    #[inline]
    pub fn at_mut(&mut self, y: i64, x: i64, ch: i64) -> &mut f32 {
        &mut self.data[((y * self.w + x) * self.c + ch) as usize]
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Deterministic pseudo-random tensor (inputs for tests/examples).
    pub fn random(h: i64, w: i64, c: i64, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let mut t = Tensor::zeros(h, w, c);
        for v in &mut t.data {
            *v = (rng.f64() * 2.0 - 1.0) as f32;
        }
        t
    }

    /// Max |a-b| against another tensor of the same shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!((self.h, self.w, self.c), (other.h, other.w, other.c));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }
}

/// A tensor pinned to a region of some layer's coordinate space — what a
/// node actually holds.
#[derive(Debug, Clone)]
pub struct RegionTensor {
    pub region: Region,
    pub t: Tensor,
}

impl RegionTensor {
    pub fn new(region: Region, t: Tensor) -> RegionTensor {
        assert_eq!(
            (t.h, t.w, t.c),
            (region.h1 - region.h0, region.w1 - region.w0, region.c1 - region.c0),
            "tensor shape must match region extent"
        );
        RegionTensor { region, t }
    }

    /// Copy the overlap between this patch and `dst_region` into `dst`
    /// (which covers `dst_region`). Row-contiguous overlaps collapse to
    /// `copy_from_slice` spans: whole-block when the w and c extents line
    /// up on both sides, per-`(y)` row when the channel extents do, and
    /// per-`(y, x)` channel lane otherwise — never the scalar triple loop.
    pub fn copy_into(&self, dst_region: &Region, dst: &mut Tensor) {
        let ov = self.region.intersect(dst_region);
        if ov.is_empty() {
            return;
        }
        let sc = (self.region.c1 - self.region.c0) as usize;
        let dc = (dst_region.c1 - dst_region.c0) as usize;
        let c_len = (ov.c1 - ov.c0) as usize;
        let sw = (self.region.w1 - self.region.w0) as usize;
        let dw = (dst_region.w1 - dst_region.w0) as usize;
        let w_len = (ov.w1 - ov.w0) as usize;
        let c_aligned = c_len == sc && c_len == dc;
        if c_aligned && w_len == sw && w_len == dw {
            // w and c extents align on both sides: the whole overlap is one
            // contiguous block of rows on each side
            let s0 = (ov.h0 - self.region.h0) as usize * sw * sc;
            let d0 = (ov.h0 - dst_region.h0) as usize * dw * dc;
            let n = (ov.h1 - ov.h0) as usize * w_len * c_len;
            dst.data[d0..d0 + n].copy_from_slice(&self.t.data[s0..s0 + n]);
            return;
        }
        for y in ov.h0..ov.h1 {
            let sy = (y - self.region.h0) as usize;
            let dy = (y - dst_region.h0) as usize;
            if c_aligned {
                // channel extents align: each y row of the overlap is one
                // contiguous span of w_len·c floats on both sides
                let s0 = (sy * sw + (ov.w0 - self.region.w0) as usize) * sc;
                let d0 = (dy * dw + (ov.w0 - dst_region.w0) as usize) * dc;
                dst.data[d0..d0 + w_len * c_len]
                    .copy_from_slice(&self.t.data[s0..s0 + w_len * c_len]);
            } else {
                // general case: per-pixel contiguous channel lanes
                for x in ov.w0..ov.w1 {
                    let s0 = (sy * sw + (x - self.region.w0) as usize) * sc
                        + (ov.c0 - self.region.c0) as usize;
                    let d0 = (dy * dw + (x - dst_region.w0) as usize) * dc
                        + (ov.c0 - dst_region.c0) as usize;
                    dst.data[d0..d0 + c_len].copy_from_slice(&self.t.data[s0..s0 + c_len]);
                }
            }
        }
    }

    /// Extract a sub-region as a new RegionTensor (for sending halos).
    pub fn slice(&self, sub: &Region) -> RegionTensor {
        let ov = self.region.intersect(sub);
        if ov.is_empty() {
            return RegionTensor::new(Region::empty(), Tensor::zeros(0, 0, 0));
        }
        let mut t = Tensor::zeros(ov.h1 - ov.h0, ov.w1 - ov.w0, ov.c1 - ov.c0);
        self.copy_into(&ov, &mut t);
        RegionTensor::new(ov, t)
    }

    /// [`Self::slice`] drawing the destination buffer from `arena`.
    pub fn slice_with(&self, sub: &Region, arena: &mut TensorArena) -> RegionTensor {
        let ov = self.region.intersect(sub);
        if ov.is_empty() {
            return RegionTensor::new(Region::empty(), Tensor::zeros(0, 0, 0));
        }
        let mut t = arena.take(ov.h1 - ov.h0, ov.w1 - ov.w0, ov.c1 - ov.c0);
        self.copy_into(&ov, &mut t);
        RegionTensor::new(ov, t)
    }
}

/// A node's working set for one layer: patches covering (at least) the
/// regions it holds.
#[derive(Debug, Clone, Default)]
pub struct PatchStore {
    pub patches: Vec<RegionTensor>,
}

impl PatchStore {
    pub fn new() -> PatchStore {
        PatchStore { patches: Vec::new() }
    }

    pub fn add(&mut self, p: RegionTensor) {
        if !p.region.is_empty() {
            self.patches.push(p);
        }
    }

    /// The first patch whose region contains all of `needed` — the
    /// zero-copy dispatch target: kernels can index it directly instead of
    /// extracting a dense working copy.
    fn covering(&self, needed: &Region) -> Option<&RegionTensor> {
        if needed.is_empty() {
            return None;
        }
        self.patches.iter().find(|p| p.region.contains(needed))
    }

    /// Materialize `region` as a dense tensor from the stored patches.
    /// `require_full` panics on coverage gaps inside the valid extent
    /// `valid` — gaps mean the exchange protocol failed to deliver data
    /// (outside `valid` is implicit zero padding).
    pub fn extract(&self, region: &Region, valid: &Region, require_full: bool) -> Tensor {
        let mut out = Tensor::zeros(0, 0, 0);
        self.extract_into(region, valid, require_full, &mut out);
        out
    }

    /// [`Self::extract`] into a caller-provided buffer (reshaped in place),
    /// so repeated extracts on the serving hot path recycle one allocation.
    pub fn extract_into(
        &self,
        region: &Region,
        valid: &Region,
        require_full: bool,
        out: &mut Tensor,
    ) {
        out.reshape_zeroed(
            region.h1 - region.h0,
            region.w1 - region.w0,
            region.c1 - region.c0,
        );
        for p in &self.patches {
            p.copy_into(region, out);
        }
        if require_full {
            let needed = region.intersect(valid);
            let missing = uncovered_volume(&needed, &self.patches);
            assert_eq!(
                missing,
                0,
                "coverage gap extracting {region:?}: have {} of {} cells",
                needed.volume() - missing,
                needed.volume()
            );
        }
    }
}

/// Volume of `needed` not covered by any patch region — the extract
/// coverage audit, computed by recursive box subtraction with no
/// intermediate region list: the first overlapping patch is carved out of
/// `needed` (≤ 6 disjoint remainder boxes), each remainder recursing over
/// the *later* patches only (earlier ones were already checked against an
/// enclosing box and cannot intersect a remainder).
fn uncovered_volume(needed: &Region, patches: &[RegionTensor]) -> i64 {
    if needed.is_empty() {
        return 0;
    }
    let mut hit = None;
    for (i, p) in patches.iter().enumerate() {
        let ov = p.region.intersect(needed);
        if !ov.is_empty() {
            hit = Some((i, ov));
            break;
        }
    }
    let Some((i, ov)) = hit else {
        return needed.volume();
    };
    let rest = &patches[i + 1..];
    let r = *needed;
    // needed \ ov as disjoint boxes: h slabs above/below, then w slabs
    // within the h band, then c slabs within the (h, w) band
    let subs = [
        Region { h1: ov.h0, ..r },
        Region { h0: ov.h1, ..r },
        Region { h0: ov.h0, h1: ov.h1, w1: ov.w0, ..r },
        Region { h0: ov.h0, h1: ov.h1, w0: ov.w1, ..r },
        Region { h0: ov.h0, h1: ov.h1, w0: ov.w0, w1: ov.w1, c1: ov.c0, ..r },
        Region { h0: ov.h0, h1: ov.h1, w0: ov.w0, w1: ov.w1, c0: ov.c1, ..r },
    ];
    subs.iter().filter(|s| !s.is_empty()).map(|s| uncovered_volume(s, rest)).sum()
}

/// Per-layer weights (deterministically generated — the "pre-trained model"
/// substitute; every node and the reference derive identical weights).
#[derive(Debug, Clone)]
pub struct LayerWeights {
    /// Conv: `[k·k·in_c·out_c]` in (ky, kx, ic, oc) order.
    /// Dense/Attention: `[in_c·out_c]`. Depthwise: `[k·k·c]`. Pool: empty.
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

/// All weights of a model.
#[derive(Debug, Clone)]
pub struct WeightStore {
    pub layers: Vec<LayerWeights>,
}

impl WeightStore {
    pub fn for_model(model: &Model, seed: u64) -> WeightStore {
        let layers = model
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| {
                let mut rng = Rng::new(seed ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15));
                let n_w = match l.conv_t {
                    ConvType::Standard => l.k * l.k * l.in_c * l.out_c,
                    ConvType::Depthwise => l.k * l.k * l.out_c,
                    ConvType::Pointwise => l.in_c * l.out_c,
                    ConvType::Dense | ConvType::Attention => l.in_c * l.out_c,
                    ConvType::Pool => 0,
                };
                // scale keeps activations O(1) through deep stacks
                let scale = (1.0 / (l.k * l.k * l.in_c).max(1) as f64).sqrt();
                let w = (0..n_w)
                    .map(|_| ((rng.f64() * 2.0 - 1.0) * scale) as f32)
                    .collect();
                let b = (0..l.out_c).map(|_| (rng.f64() * 0.1) as f32).collect();
                LayerWeights { w, b }
            })
            .collect();
        WeightStore { layers }
    }
}

/// Compute the output region `out_r` of `layer`, reading input from `store`
/// (which must cover the receptive field of `out_r` within the valid input
/// extent; padding is implicit zeros).
pub fn compute_region(
    layer: &LayerMeta,
    weights: &LayerWeights,
    store: &PatchStore,
    out_r: &Region,
) -> RegionTensor {
    if out_r.is_empty() {
        return RegionTensor::new(Region::empty(), Tensor::zeros(0, 0, 0));
    }
    let mut scratch = Tensor::zeros(0, 0, 0);
    let mut out = Tensor::zeros(0, 0, 0);
    compute_region_into(layer, weights, store, out_r, &mut scratch, &mut out);
    RegionTensor::new(*out_r, out)
}

/// [`compute_region`] with caller-provided buffers: `scratch` holds the
/// dense extract when one is needed, `out` is reshaped to the tile. When
/// the store holds a single patch covering the tile's clamped receptive
/// field, the kernels dispatch on the patch buffer directly — no copy.
fn compute_region_into(
    layer: &LayerMeta,
    weights: &LayerWeights,
    store: &PatchStore,
    out_r: &Region,
    scratch: &mut Tensor,
    out: &mut Tensor,
) {
    let in_needed = crate::partition::geometry::in_region(layer, out_r);
    let valid = Region::full(layer.in_h, layer.in_w, layer.in_c);
    let needed = valid.intersect(&in_needed);
    if let Some(p) = store.covering(&needed) {
        // zero-copy fast path: the kernels clamp every tap into the valid
        // extent, and `p` covers all of it
        dispatch_kernel(layer, weights, &p.t, &p.region, out_r, out);
        return;
    }
    // Hull covering the receptive field *before* clamping, so padded reads
    // index zeros naturally.
    let raw = unclamped_in_region(layer, out_r);
    store.extract_into(&raw, &needed, true, scratch);
    dispatch_kernel(layer, weights, scratch, &raw, out_r, out);
}

/// Compute a set of output tiles — `(store index, output region)` work
/// items — returning one [`RegionTensor`] per item, in item order. With
/// `cfg.tile_workers > 1` and enough total volume the items fan out over a
/// scoped worker pool in contiguous chunks; chunked results merge back in
/// item order and every tile's accumulation order is fixed by the kernels,
/// so parallel execution is bitwise identical to serial. Output and
/// scratch buffers come from (and scratches return to) `arena`.
pub fn compute_tile_set(
    layer: &LayerMeta,
    weights: &LayerWeights,
    stores: &[&PatchStore],
    items: &[(usize, Region)],
    cfg: &ComputeConfig,
    arena: &mut TensorArena,
) -> Vec<RegionTensor> {
    let total: i64 = items.iter().map(|(_, r)| r.volume()).sum();
    let workers = cfg.tile_workers.max(1).min(items.len());
    if workers <= 1 || items.len() < 2 || total < cfg.parallel_threshold {
        let mut scratch = arena.take(0, 0, 0);
        let mut results = Vec::with_capacity(items.len());
        for (si, r) in items {
            let mut out = arena.take(0, 0, 0);
            if r.is_empty() {
                out.reshape_zeroed(0, 0, 0);
                results.push(RegionTensor::new(Region::empty(), out));
            } else {
                compute_region_into(layer, weights, stores[*si], r, &mut scratch, &mut out);
                results.push(RegionTensor::new(*r, out));
            }
        }
        arena.give(scratch);
        return results;
    }

    // pre-provision every buffer serially (the arena is not shared), then
    // fan contiguous chunks over scoped workers — one scratch each
    let chunk = items.len().div_ceil(workers);
    let n_chunks = items.len().div_ceil(chunk);
    let mut outs: Vec<Tensor> = (0..items.len()).map(|_| arena.take(0, 0, 0)).collect();
    let mut scratches: Vec<Tensor> = (0..n_chunks).map(|_| arena.take(0, 0, 0)).collect();
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(n_chunks);
        for ((ich, och), scratch) in
            items.chunks(chunk).zip(outs.chunks_mut(chunk)).zip(scratches.iter_mut())
        {
            handles.push(s.spawn(move || {
                for ((si, r), out) in ich.iter().zip(och.iter_mut()) {
                    if r.is_empty() {
                        out.reshape_zeroed(0, 0, 0);
                    } else {
                        compute_region_into(layer, weights, stores[*si], r, scratch, out);
                    }
                }
            }));
        }
        for h in handles {
            h.join().expect("tile worker panicked");
        }
    });
    for s in scratches {
        arena.give(s);
    }
    items
        .iter()
        .zip(outs)
        .map(|(&(_, r), t)| {
            if r.is_empty() {
                RegionTensor::new(Region::empty(), t)
            } else {
                RegionTensor::new(r, t)
            }
        })
        .collect()
}

/// The receptive-field hull of `out_r` *without* clamping to the input
/// extent — positions outside the input read as zero (the conv padding).
pub fn unclamped_in_region(layer: &LayerMeta, r: &Region) -> Region {
    if layer.conv_t == ConvType::Attention {
        return Region::full(layer.in_h, layer.in_w, layer.in_c);
    }
    let (c0, c1) = match layer.conv_t {
        ConvType::Depthwise | ConvType::Pool => (r.c0, r.c1),
        _ => (0, layer.in_c),
    };
    Region {
        h0: r.h0 * layer.s - layer.p,
        h1: (r.h1 - 1) * layer.s - layer.p + layer.k,
        w0: r.w0 * layer.s - layer.p,
        w1: (r.w1 - 1) * layer.s - layer.p + layer.k,
        c0,
        c1,
    }
}

thread_local! {
    /// Per-thread accumulator scratch shared by every kernel invocation on
    /// the thread — kernels resize it at entry and overwrite from the bias
    /// before reading, so reuse never leaks values between calls.
    static ACC: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// The single kernel dispatch every execution path funnels through —
/// single-node reference, lockstep node tiles and pipelined stages all
/// compute each output element with the identical accumulation sequence
/// (bias, then taps in `(ky, kx, ic)` order), which is what makes
/// distributed outputs bit-identical to the reference. `input` is a dense
/// tensor covering `in_r`, which must contain every *valid* receptive
/// position of `out_r` (with `in_r.c0 <= 0` for full-channel ops).
fn dispatch_kernel(
    layer: &LayerMeta,
    weights: &LayerWeights,
    input: &Tensor,
    in_r: &Region,
    out_r: &Region,
    out: &mut Tensor,
) {
    let (oh, ow, oc) = (out_r.h1 - out_r.h0, out_r.w1 - out_r.w0, out_r.c1 - out_r.c0);
    match layer.conv_t {
        // dense writes only the x = 0 column (rows live on h, w == 1);
        // zero-fill covers any wider extent
        ConvType::Dense | ConvType::Attention => out.reshape_zeroed(oh, ow, oc),
        // conv/pool kernels overwrite every element — plain reshape
        _ => out.reshape(oh, ow, oc),
    }
    ACC.with(|cell| {
        let mut guard = cell.borrow_mut();
        let acc: &mut Vec<f32> = &mut guard;
        match layer.conv_t {
            ConvType::Standard | ConvType::Pointwise => {
                conv2d(layer, weights, input, in_r, out_r, out, acc)
            }
            ConvType::Depthwise => conv2d_depthwise(layer, weights, input, in_r, out_r, out, acc),
            ConvType::Pool => pool_avg(layer, input, in_r, out_r, out, acc),
            ConvType::Dense | ConvType::Attention => {
                dense(layer, weights, input, in_r, out_r, out, acc)
            }
        }
    });
    if layer.fused_activation {
        for v in &mut out.data {
            *v = v.max(0.0);
        }
    }
}

/// Output pixels swept per weight-row pass in the blocked conv interior —
/// the knob that turns the conv from weight-bandwidth-bound (the whole
/// filter streamed per pixel) into compute-bound (streamed once per
/// block).
const PIXEL_BLOCK: usize = 16;

/// Rows swept per weight pass in the blocked dense matmul.
const ROW_BLOCK: usize = 8;

/// Standard/pointwise conv, blocked for cache reuse: the tile splits into
/// an interior whose receptive fields are entirely in-bounds (no validity
/// branches, [`PIXEL_BLOCK`]-pixel microkernel over the contiguous `oc`
/// weight rows) and thin boundary strips on the guarded per-pixel path.
/// Both paths accumulate each element as bias, then `(ky, kx, ic)` taps
/// ascending — the one reduction order.
#[allow(clippy::too_many_arguments)]
fn conv2d(
    layer: &LayerMeta,
    weights: &LayerWeights,
    input: &Tensor,
    in_r: &Region,
    out_r: &Region,
    out: &mut Tensor,
    acc: &mut Vec<f32>,
) {
    let (k, s, p) = (layer.k, layer.s, layer.p);
    // interior bounds: oy*s - p >= 0 and oy*s - p + k <= in_h (same for x)
    let iy0 = out_r.h0.max((p + s - 1) / s).min(out_r.h1);
    let last_y = layer.in_h - k + p;
    let iy1 = if last_y >= 0 { (last_y / s + 1).clamp(iy0, out_r.h1) } else { iy0 };
    let ix0 = out_r.w0.max((p + s - 1) / s).min(out_r.w1);
    let last_x = layer.in_w - k + p;
    let ix1 = if last_x >= 0 { (last_x / s + 1).clamp(ix0, out_r.w1) } else { ix0 };

    conv2d_edge(layer, weights, input, in_r, out_r, out, (out_r.h0, iy0), (out_r.w0, out_r.w1), acc);
    conv2d_edge(layer, weights, input, in_r, out_r, out, (iy1, out_r.h1), (out_r.w0, out_r.w1), acc);
    conv2d_edge(layer, weights, input, in_r, out_r, out, (iy0, iy1), (out_r.w0, ix0), acc);
    conv2d_edge(layer, weights, input, in_r, out_r, out, (iy0, iy1), (ix1, out_r.w1), acc);
    conv2d_interior(layer, weights, input, in_r, out_r, out, (iy0, iy1), (ix0, ix1), acc);
}

/// Boundary-strip conv: per-pixel, with the invalid taps clipped out of the
/// `ky`/`kx` ranges up front instead of branch-tested per tap.
#[allow(clippy::too_many_arguments)]
fn conv2d_edge(
    layer: &LayerMeta,
    weights: &LayerWeights,
    input: &Tensor,
    in_r: &Region,
    out_r: &Region,
    out: &mut Tensor,
    ys: (i64, i64),
    xs: (i64, i64),
    acc: &mut Vec<f32>,
) {
    if ys.0 >= ys.1 || xs.0 >= xs.1 {
        return;
    }
    let (k, s, p) = (layer.k, layer.s, layer.p);
    let in_c = layer.in_c as usize;
    let out_c = layer.out_c as usize;
    let oc0 = out_r.c0 as usize;
    let oc1 = out_r.c1 as usize;
    let oc_len = oc1 - oc0;
    let bias = &weights.b[oc0..oc1];
    let in_cw = (in_r.c1 - in_r.c0) as usize;
    let in_row = (in_r.w1 - in_r.w0) as usize * in_cw;
    let c_off = (0i64 - in_r.c0) as usize; // full channel range ⇒ c0 <= 0
    let ow = (out_r.w1 - out_r.w0) as usize;
    acc.clear();
    acc.resize(oc_len, 0.0);

    for oy in ys.0..ys.1 {
        let y0 = oy * s - p;
        let ky0 = (-y0).max(0);
        let ky1 = k.min(layer.in_h - y0);
        for ox in xs.0..xs.1 {
            let x0 = ox * s - p;
            let kx0 = (-x0).max(0);
            let kx1 = k.min(layer.in_w - x0);
            acc.copy_from_slice(bias);
            for ky in ky0..ky1 {
                let row = (y0 + ky - in_r.h0) as usize * in_row;
                for kx in kx0..kx1 {
                    let px = row + (x0 + kx - in_r.w0) as usize * in_cw + c_off;
                    let xv_lane = &input.data[px..px + in_c];
                    let w_tap = ((ky * k + kx) as usize) * in_c * out_c;
                    for (ic, &xv) in xv_lane.iter().enumerate() {
                        if xv == 0.0 {
                            continue; // padding-adjacent zeros are common
                        }
                        let wrow =
                            &weights.w[w_tap + ic * out_c + oc0..w_tap + ic * out_c + oc1];
                        for (a, &wv) in acc.iter_mut().zip(wrow) {
                            *a += xv * wv;
                        }
                    }
                }
            }
            let ob = ((oy - out_r.h0) as usize * ow + (ox - out_r.w0) as usize) * oc_len;
            out.data[ob..ob + oc_len].copy_from_slice(&acc[..]);
        }
    }
}

/// Interior conv microkernel: every tap in-bounds, so the tile sweeps in
/// [`PIXEL_BLOCK`]-pixel groups and each contiguous weight row
/// `w[ky,kx,ic,:]` is loaded once per group instead of once per pixel —
/// the cache-blocking that carries the conv speedup. The per-element
/// accumulation order is unchanged from the edge path.
#[allow(clippy::too_many_arguments)]
fn conv2d_interior(
    layer: &LayerMeta,
    weights: &LayerWeights,
    input: &Tensor,
    in_r: &Region,
    out_r: &Region,
    out: &mut Tensor,
    ys: (i64, i64),
    xs: (i64, i64),
    acc: &mut Vec<f32>,
) {
    if ys.0 >= ys.1 || xs.0 >= xs.1 {
        return;
    }
    let (k, s, p) = (layer.k, layer.s, layer.p);
    let in_c = layer.in_c as usize;
    let out_c = layer.out_c as usize;
    let oc0 = out_r.c0 as usize;
    let oc1 = out_r.c1 as usize;
    let oc_len = oc1 - oc0;
    let bias = &weights.b[oc0..oc1];
    let in_cw = (in_r.c1 - in_r.c0) as usize;
    let in_row = (in_r.w1 - in_r.w0) as usize * in_cw;
    let c_off = (0i64 - in_r.c0) as usize;
    let ow = (out_r.w1 - out_r.w0) as usize;
    acc.clear();
    acc.resize(PIXEL_BLOCK * oc_len, 0.0);

    for oy in ys.0..ys.1 {
        let y0 = oy * s - p;
        let mut ox = xs.0;
        while ox < xs.1 {
            let pb = ((xs.1 - ox) as usize).min(PIXEL_BLOCK);
            for b in 0..pb {
                acc[b * oc_len..(b + 1) * oc_len].copy_from_slice(bias);
            }
            for ky in 0..k {
                let row = (y0 + ky - in_r.h0) as usize * in_row;
                for kx in 0..k {
                    let x0 = ox * s - p + kx;
                    let mut px = [0usize; PIXEL_BLOCK];
                    for (b, pxb) in px.iter_mut().enumerate().take(pb) {
                        *pxb = row + (x0 + b as i64 * s - in_r.w0) as usize * in_cw + c_off;
                    }
                    let w_tap = ((ky * k + kx) as usize) * in_c * out_c;
                    for ic in 0..in_c {
                        let wrow =
                            &weights.w[w_tap + ic * out_c + oc0..w_tap + ic * out_c + oc1];
                        for b in 0..pb {
                            let xv = input.data[px[b] + ic];
                            if xv == 0.0 {
                                continue;
                            }
                            let a = &mut acc[b * oc_len..(b + 1) * oc_len];
                            for (aj, &wv) in a.iter_mut().zip(wrow) {
                                *aj += xv * wv;
                            }
                        }
                    }
                }
            }
            for b in 0..pb {
                let ob =
                    ((oy - out_r.h0) as usize * ow + (ox - out_r.w0) as usize + b) * oc_len;
                out.data[ob..ob + oc_len].copy_from_slice(&acc[b * oc_len..(b + 1) * oc_len]);
            }
            ox += pb as i64;
        }
    }
}

/// Depthwise conv: one filter per channel; the inner loop runs over the
/// contiguous channel lane (`w[ky,kx,:]` and `x[y,x,:]` are both
/// channel-contiguous), with invalid taps clipped out of the ranges.
fn conv2d_depthwise(
    layer: &LayerMeta,
    weights: &LayerWeights,
    input: &Tensor,
    in_r: &Region,
    out_r: &Region,
    out: &mut Tensor,
    acc: &mut Vec<f32>,
) {
    let (k, s, p) = (layer.k, layer.s, layer.p);
    let out_c = layer.out_c as usize;
    let c0 = out_r.c0;
    let c_len = (out_r.c1 - out_r.c0) as usize;
    let in_cw = (in_r.c1 - in_r.c0) as usize;
    let in_row = (in_r.w1 - in_r.w0) as usize * in_cw;
    let bias = &weights.b[c0 as usize..out_r.c1 as usize];
    let ow = (out_r.w1 - out_r.w0) as usize;
    acc.clear();
    acc.resize(c_len, 0.0);

    for oy in out_r.h0..out_r.h1 {
        let y0 = oy * s - p;
        let ky0 = (-y0).max(0);
        let ky1 = k.min(layer.in_h - y0);
        for ox in out_r.w0..out_r.w1 {
            let x0 = ox * s - p;
            let kx0 = (-x0).max(0);
            let kx1 = k.min(layer.in_w - x0);
            acc.copy_from_slice(bias);
            for ky in ky0..ky1 {
                let row = (y0 + ky - in_r.h0) as usize * in_row;
                for kx in kx0..kx1 {
                    // input channel range mirrors the output's (c0..c1)
                    let px = row + (x0 + kx - in_r.w0) as usize * in_cw + (c0 - in_r.c0) as usize;
                    let xv_lane = &input.data[px..px + c_len];
                    let wq = ((ky * k + kx) as usize) * out_c + c0 as usize;
                    let ws = &weights.w[wq..wq + c_len];
                    for ((a, &xv), &wv) in acc.iter_mut().zip(xv_lane).zip(ws) {
                        *a += xv * wv;
                    }
                }
            }
            let ob = ((oy - out_r.h0) as usize * ow + (ox - out_r.w0) as usize) * c_len;
            out.data[ob..ob + c_len].copy_from_slice(&acc[..]);
        }
    }
}

/// Average pool over the contiguous channel lane (one accumulator vector
/// per pixel instead of a scalar per channel); padded taps are clipped out
/// of the ranges and the divisor stays `k²` (count-include-pad semantics,
/// same bits as the scalar kernel's per-element division).
fn pool_avg(
    layer: &LayerMeta,
    input: &Tensor,
    in_r: &Region,
    out_r: &Region,
    out: &mut Tensor,
    acc: &mut Vec<f32>,
) {
    let (k, s, p) = (layer.k, layer.s, layer.p);
    let c0 = out_r.c0;
    let c_len = (out_r.c1 - out_r.c0) as usize;
    let in_cw = (in_r.c1 - in_r.c0) as usize;
    let in_row = (in_r.w1 - in_r.w0) as usize * in_cw;
    let ow = (out_r.w1 - out_r.w0) as usize;
    let div = (k * k) as f32;
    acc.clear();
    acc.resize(c_len, 0.0);

    for oy in out_r.h0..out_r.h1 {
        let y0 = oy * s - p;
        let ky0 = (-y0).max(0);
        let ky1 = k.min(layer.in_h - y0);
        for ox in out_r.w0..out_r.w1 {
            let x0 = ox * s - p;
            let kx0 = (-x0).max(0);
            let kx1 = k.min(layer.in_w - x0);
            for a in acc.iter_mut() {
                *a = 0.0;
            }
            for ky in ky0..ky1 {
                let row = (y0 + ky - in_r.h0) as usize * in_row;
                for kx in kx0..kx1 {
                    let px = row + (x0 + kx - in_r.w0) as usize * in_cw + (c0 - in_r.c0) as usize;
                    for (a, &v) in acc.iter_mut().zip(&input.data[px..px + c_len]) {
                        *a += v;
                    }
                }
            }
            let ob = ((oy - out_r.h0) as usize * ow + (ox - out_r.w0) as usize) * c_len;
            for (o, &a) in out.data[ob..ob + c_len].iter_mut().zip(&acc[..]) {
                *o = a / div;
            }
        }
    }
}

/// Blocked dense matmul: `(rows × in_c) @ (in_c × out_c)` with rows on the
/// h axis (w == 1), swept [`ROW_BLOCK`] rows per pass so each contiguous
/// weight row `w[ic,:]` is loaded once per block. Per element the taps
/// accumulate in ascending `ic` order — same bits as the scalar loop.
fn dense(
    layer: &LayerMeta,
    weights: &LayerWeights,
    input: &Tensor,
    in_r: &Region,
    out_r: &Region,
    out: &mut Tensor,
    acc: &mut Vec<f32>,
) {
    let in_c = layer.in_c as usize;
    let out_c = layer.out_c as usize;
    let oc0 = out_r.c0 as usize;
    let oc1 = out_r.c1 as usize;
    let oc_len = oc1 - oc0;
    let bias = &weights.b[oc0..oc1];
    let in_cw = (in_r.c1 - in_r.c0) as usize;
    let in_row = (in_r.w1 - in_r.w0) as usize * in_cw;
    let c_off = (0i64 - in_r.c0) as usize;
    let ow = (out_r.w1 - out_r.w0) as usize;
    acc.clear();
    acc.resize(ROW_BLOCK * oc_len, 0.0);

    let mut row = out_r.h0;
    while row < out_r.h1 {
        let rb = ((out_r.h1 - row) as usize).min(ROW_BLOCK);
        for b in 0..rb {
            acc[b * oc_len..(b + 1) * oc_len].copy_from_slice(bias);
        }
        let mut xb = [0usize; ROW_BLOCK];
        for (b, x) in xb.iter_mut().enumerate().take(rb) {
            *x = (row + b as i64 - in_r.h0) as usize * in_row + c_off;
        }
        for ic in 0..in_c {
            let wrow = &weights.w[ic * out_c + oc0..ic * out_c + oc1];
            for b in 0..rb {
                let xv = input.data[xb[b] + ic];
                let a = &mut acc[b * oc_len..(b + 1) * oc_len];
                for (aj, &wv) in a.iter_mut().zip(wrow) {
                    *aj += xv * wv;
                }
            }
        }
        for b in 0..rb {
            let ob = (row + b as i64 - out_r.h0) as usize * ow * oc_len;
            out.data[ob..ob + oc_len].copy_from_slice(&acc[b * oc_len..(b + 1) * oc_len]);
        }
        row += rb as i64;
    }
}

/// Single-node reference: run the whole model on one device. The oracle for
/// every distributed-execution test. Double-buffered: two tensors ping-pong
/// as each layer's input and output — no per-layer clone, no patch store,
/// no allocation past the first layer's growth to the largest activation.
pub fn run_reference(model: &Model, weights: &WeightStore, input: &Tensor) -> Tensor {
    assert_eq!(
        (input.h, input.w, input.c),
        (model.layers[0].in_h, model.layers[0].in_w, model.layers[0].in_c),
        "input shape mismatch"
    );
    let mut cur = Tensor::zeros(0, 0, 0);
    let mut next = Tensor::zeros(0, 0, 0);
    for (i, layer) in model.layers.iter().enumerate() {
        let in_full = Region::full(layer.in_h, layer.in_w, layer.in_c);
        let out_full = Region::full(layer.out_h, layer.out_w, layer.out_c);
        let src = if i == 0 { input } else { &cur };
        dispatch_kernel(layer, &weights.layers[i], src, &in_full, &out_full, &mut next);
        std::mem::swap(&mut cur, &mut next);
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    fn layer(h: i64, ci: i64, co: i64, k: i64, s: i64, p: i64) -> LayerMeta {
        LayerMeta::conv("t", ConvType::Standard, h, h, ci, co, k, s, p)
    }

    fn full_store(l: &LayerMeta, t: Tensor) -> PatchStore {
        let mut s = PatchStore::new();
        s.add(RegionTensor::new(Region::full(l.in_h, l.in_w, l.in_c), t));
        s
    }

    #[test]
    fn identity_conv_1x1() {
        // 1×1 conv with identity weights reproduces the input.
        let l = LayerMeta::conv("id", ConvType::Pointwise, 4, 4, 2, 2, 1, 1, 0);
        let mut w = LayerWeights { w: vec![0.0; 4], b: vec![0.0; 2] };
        w.w[0] = 1.0; // ic0 -> oc0
        w.w[3] = 1.0; // ic1 -> oc1
        let input = Tensor::random(4, 4, 2, 1);
        let store = full_store(&l, input.clone());
        let out = compute_region(&l, &w, &store, &Region::full(4, 4, 2)).t;
        assert_eq!(out.data, input.data);
    }

    #[test]
    fn conv_known_values() {
        // 3×3 all-ones kernel over all-ones input, same padding: interior
        // outputs = 9, corners = 4, edges = 6.
        let l = layer(4, 1, 1, 3, 1, 1);
        let w = LayerWeights { w: vec![1.0; 9], b: vec![0.0] };
        let input = Tensor { h: 4, w: 4, c: 1, data: vec![1.0; 16] };
        let store = full_store(&l, input);
        let out = compute_region(&l, &w, &store, &Region::full(4, 4, 1)).t;
        assert_eq!(out.at(1, 1, 0), 9.0);
        assert_eq!(out.at(0, 0, 0), 4.0);
        assert_eq!(out.at(0, 1, 0), 6.0);
    }

    #[test]
    fn strided_conv_shape_and_values() {
        let l = layer(4, 1, 1, 3, 2, 1);
        assert_eq!(l.out_h, 2);
        let w = LayerWeights { w: vec![1.0; 9], b: vec![0.0] };
        let input = Tensor { h: 4, w: 4, c: 1, data: vec![1.0; 16] };
        let store = full_store(&l, input);
        let out = compute_region(&l, &w, &store, &Region::full(2, 2, 1)).t;
        assert_eq!(out.at(0, 0, 0), 4.0); // top-left window clipped to 2×2
        assert_eq!(out.at(1, 1, 0), 9.0);
    }

    #[test]
    fn partial_region_equals_slice_of_full() {
        // Computing a sub-region directly == slicing the full output.
        let l = layer(8, 3, 4, 3, 1, 1);
        let ws = WeightStore::for_model(
            &crate::model::Model::new("m", vec![l.clone()]),
            7,
        );
        let input = Tensor::random(8, 8, 3, 2);
        let store = full_store(&l, input);
        let full = compute_region(&l, &ws.layers[0], &store, &Region::full(8, 8, 4));
        let sub_r = Region::new(2, 5, 1, 7, 1, 3);
        let sub = compute_region(&l, &ws.layers[0], &store, &sub_r);
        for y in sub_r.h0..sub_r.h1 {
            for x in sub_r.w0..sub_r.w1 {
                for c in sub_r.c0..sub_r.c1 {
                    assert_eq!(
                        sub.t.at(y - sub_r.h0, x - sub_r.w0, c - sub_r.c0),
                        full.t.at(y, x, c)
                    );
                }
            }
        }
    }

    #[test]
    fn depthwise_channels_independent() {
        let l = LayerMeta::conv("dw", ConvType::Depthwise, 6, 6, 2, 2, 3, 1, 1);
        let m = crate::model::Model::new("m", vec![l.clone()]);
        let ws = WeightStore::for_model(&m, 3);
        let mut input = Tensor::random(6, 6, 2, 4);
        let store = full_store(&l, input.clone());
        let before = compute_region(&l, &ws.layers[0], &store, &Region::full(6, 6, 2)).t;
        // perturb channel 1 only; channel 0 output must not change
        for y in 0..6 {
            for x in 0..6 {
                *input.at_mut(y, x, 1) += 1.0;
            }
        }
        let store2 = full_store(&l, input);
        let after = compute_region(&l, &ws.layers[0], &store2, &Region::full(6, 6, 2)).t;
        for y in 0..6 {
            for x in 0..6 {
                assert_eq!(before.at(y, x, 0), after.at(y, x, 0));
                assert_ne!(before.at(y, x, 1), after.at(y, x, 1));
            }
        }
    }

    #[test]
    fn global_avg_pool() {
        let l = LayerMeta::pool("gap", 4, 4, 2, 4, 4);
        assert_eq!((l.out_h, l.out_w), (1, 1));
        let mut input = Tensor::zeros(4, 4, 2);
        for y in 0..4 {
            for x in 0..4 {
                *input.at_mut(y, x, 0) = 2.0;
                *input.at_mut(y, x, 1) = (y * 4 + x) as f32;
            }
        }
        let store = full_store(&l, input);
        let w = LayerWeights { w: vec![], b: vec![] };
        let out = compute_region(&l, &w, &store, &Region::full(1, 1, 2)).t;
        assert_eq!(out.at(0, 0, 0), 2.0);
        assert_eq!(out.at(0, 0, 1), 7.5);
    }

    #[test]
    fn dense_matches_manual_matmul() {
        let l = LayerMeta::dense("fc", 3, 4, 2);
        let m = crate::model::Model::new("m", vec![l.clone()]);
        let ws = WeightStore::for_model(&m, 5);
        let input = Tensor::random(3, 1, 4, 6);
        let store = full_store(&l, input.clone());
        let out = compute_region(&l, &ws.layers[0], &store, &Region::full(3, 1, 2)).t;
        for row in 0..3 {
            for oc in 0..2 {
                let mut acc = ws.layers[0].b[oc as usize];
                for ic in 0..4 {
                    acc += ws.layers[0].w[(ic * 2 + oc) as usize] * input.at(row, 0, ic);
                }
                assert!((out.at(row, 0, oc) - acc).abs() < 1e-6);
            }
        }
    }

    #[test]
    #[should_panic(expected = "coverage gap")]
    fn missing_halo_panics() {
        // A store holding only rows 0..2 cannot compute output rows 0..3 of
        // a 3×3 conv (row 2 needs input row 3).
        let l = layer(6, 1, 1, 3, 1, 1);
        let mut store = PatchStore::new();
        store.add(RegionTensor::new(
            Region::new(0, 2, 0, 6, 0, 1),
            Tensor::zeros(2, 6, 1),
        ));
        let w = LayerWeights { w: vec![1.0; 9], b: vec![0.0] };
        let _ = compute_region(&l, &w, &store, &Region::new(0, 3, 0, 6, 0, 1));
    }

    #[test]
    fn reference_runs_edgenet() {
        let model = zoo::edgenet(16);
        let ws = WeightStore::for_model(&model, 42);
        let input = Tensor::random(16, 16, 3, 1);
        let out = run_reference(&model, &ws, &input);
        assert_eq!((out.h, out.w, out.c), (1, 1, 10));
        assert!(out.data.iter().all(|v| v.is_finite()));
        // deterministic
        let out2 = run_reference(&model, &ws, &input);
        assert_eq!(out.data, out2.data);
    }

    #[test]
    fn uncovered_volume_matches_intersection_volume() {
        // the allocation-free coverage check must agree with the original
        // collect-then-union formulation on overlapping, partial and
        // disjoint patch sets
        let needed = Region::new(2, 10, 1, 9, 0, 4);
        let patch_sets: Vec<Vec<Region>> = vec![
            vec![],
            vec![Region::new(0, 12, 0, 12, 0, 4)],
            vec![Region::new(2, 6, 1, 9, 0, 4), Region::new(6, 10, 1, 9, 0, 4)],
            vec![Region::new(0, 7, 0, 5, 0, 4), Region::new(4, 12, 3, 12, 1, 3)],
            vec![Region::new(20, 30, 0, 5, 0, 4)],
            vec![
                Region::new(2, 10, 1, 5, 0, 2),
                Region::new(2, 10, 1, 5, 2, 4),
                Region::new(2, 10, 5, 9, 0, 4),
                Region::new(3, 8, 2, 7, 1, 3), // redundant overlap
            ],
        ];
        for regions in patch_sets {
            let patches: Vec<RegionTensor> = regions
                .iter()
                .map(|r| {
                    RegionTensor::new(
                        *r,
                        Tensor::zeros(r.h1 - r.h0, r.w1 - r.w0, r.c1 - r.c0),
                    )
                })
                .collect();
            let covered = crate::partition::intersection_volume(&regions, &[needed]);
            assert_eq!(
                uncovered_volume(&needed, &patches),
                needed.volume() - covered,
                "mismatch on {regions:?}"
            );
        }
    }

    #[test]
    fn arena_recycles_buffers() {
        let mut arena = TensorArena::new(true);
        let t = arena.take(4, 4, 2);
        assert_eq!((arena.allocs, arena.reuses), (1, 0));
        arena.give(t);
        let t2 = arena.take(2, 2, 2);
        assert_eq!((arena.allocs, arena.reuses), (1, 1));
        assert!(t2.data.iter().all(|&v| v == 0.0), "recycled buffers must be zeroed");
        // reuse disabled: give drops, every take provisions fresh
        let mut cold = TensorArena::new(false);
        let t = cold.take(4, 4, 2);
        cold.give(t);
        let _ = cold.take(4, 4, 2);
        assert_eq!((cold.allocs, cold.reuses), (2, 0));
    }

    #[test]
    fn copy_into_fast_paths_match_scalar_copy() {
        // exercise all three copy tiers against a scalar oracle
        let src_r = Region::new(1, 7, 2, 8, 0, 3);
        let src = RegionTensor::new(src_r, Tensor::random(6, 6, 3, 9));
        let cases = [
            Region::new(1, 7, 2, 8, 0, 3),  // identical: whole-block tier
            Region::new(0, 5, 2, 8, 0, 3),  // h offset, w+c aligned
            Region::new(3, 9, 0, 6, 0, 3),  // w overlap: per-row tier
            Region::new(2, 6, 4, 10, 1, 3), // channel sub-range: lane tier
        ];
        for dst_r in cases {
            let mut fast =
                Tensor::zeros(dst_r.h1 - dst_r.h0, dst_r.w1 - dst_r.w0, dst_r.c1 - dst_r.c0);
            src.copy_into(&dst_r, &mut fast);
            let mut slow = Tensor::zeros(fast.h, fast.w, fast.c);
            let ov = src_r.intersect(&dst_r);
            for y in ov.h0..ov.h1 {
                for x in ov.w0..ov.w1 {
                    for ch in ov.c0..ov.c1 {
                        *slow.at_mut(y - dst_r.h0, x - dst_r.w0, ch - dst_r.c0) =
                            src.t.at(y - src_r.h0, x - src_r.w0, ch - src_r.c0);
                    }
                }
            }
            assert_eq!(fast.data, slow.data, "copy mismatch into {dst_r:?}");
        }
    }
}
