//! Native tensor kernels — the execution substrate for real numerics.
//!
//! Every partitioned plan can be *executed*, not just costed: each simulated
//! node computes its (possibly inflated) tiles with these kernels, halos are
//! exchanged as real data, and the assembled output is compared against the
//! single-node reference — the strongest possible check that the partition
//! geometry (halos, NT inflation, scheme realignment) is correct.
//!
//! These kernels are the *fallback/oracle* path; when an AOT-compiled HLO
//! artifact exists for a layer's exact shape, [`crate::runtime`] executes the
//! JAX/Pallas version via PJRT instead (and tests assert both paths agree).
//!
//! Layout is HWC (`idx = (y·W + x)·C + c`), matching the feature-map
//! orientation of the partition geometry and the JAX reference.

use crate::model::{ConvType, LayerMeta, Model};
use crate::partition::Region;
use crate::util::rng::Rng;

/// A dense f32 tensor over an `(h, w, c)` box.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub h: i64,
    pub w: i64,
    pub c: i64,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(h: i64, w: i64, c: i64) -> Tensor {
        Tensor { h, w, c, data: vec![0.0; (h * w * c) as usize] }
    }

    #[inline]
    pub fn at(&self, y: i64, x: i64, ch: i64) -> f32 {
        debug_assert!(y < self.h && x < self.w && ch < self.c);
        self.data[((y * self.w + x) * self.c + ch) as usize]
    }

    #[inline]
    pub fn at_mut(&mut self, y: i64, x: i64, ch: i64) -> &mut f32 {
        &mut self.data[((y * self.w + x) * self.c + ch) as usize]
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Deterministic pseudo-random tensor (inputs for tests/examples).
    pub fn random(h: i64, w: i64, c: i64, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let mut t = Tensor::zeros(h, w, c);
        for v in &mut t.data {
            *v = (rng.f64() * 2.0 - 1.0) as f32;
        }
        t
    }

    /// Max |a-b| against another tensor of the same shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!((self.h, self.w, self.c), (other.h, other.w, other.c));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }
}

/// A tensor pinned to a region of some layer's coordinate space — what a
/// node actually holds.
#[derive(Debug, Clone)]
pub struct RegionTensor {
    pub region: Region,
    pub t: Tensor,
}

impl RegionTensor {
    pub fn new(region: Region, t: Tensor) -> RegionTensor {
        assert_eq!(
            (t.h, t.w, t.c),
            (region.h1 - region.h0, region.w1 - region.w0, region.c1 - region.c0),
            "tensor shape must match region extent"
        );
        RegionTensor { region, t }
    }

    /// Copy the overlap between this patch and `dst_region` into `dst`
    /// (which covers `dst_region`).
    pub fn copy_into(&self, dst_region: &Region, dst: &mut Tensor) {
        let ov = self.region.intersect(dst_region);
        if ov.is_empty() {
            return;
        }
        for y in ov.h0..ov.h1 {
            for x in ov.w0..ov.w1 {
                for ch in ov.c0..ov.c1 {
                    *dst.at_mut(y - dst_region.h0, x - dst_region.w0, ch - dst_region.c0) =
                        self.t.at(y - self.region.h0, x - self.region.w0, ch - self.region.c0);
                }
            }
        }
    }

    /// Extract a sub-region as a new RegionTensor (for sending halos).
    pub fn slice(&self, sub: &Region) -> RegionTensor {
        let ov = self.region.intersect(sub);
        let mut t =
            Tensor::zeros(ov.h1 - ov.h0, ov.w1 - ov.w0, ov.c1 - ov.c0);
        self.copy_into(&ov, &mut t);
        RegionTensor::new(ov, t)
    }
}

/// A node's working set for one layer: patches covering (at least) the
/// regions it holds.
#[derive(Debug, Clone, Default)]
pub struct PatchStore {
    pub patches: Vec<RegionTensor>,
}

impl PatchStore {
    pub fn new() -> PatchStore {
        PatchStore { patches: Vec::new() }
    }

    pub fn add(&mut self, p: RegionTensor) {
        if !p.region.is_empty() {
            self.patches.push(p);
        }
    }

    /// Materialize `region` as a dense tensor from the stored patches.
    /// `require_full` panics on coverage gaps inside the valid extent
    /// `valid` — gaps mean the exchange protocol failed to deliver data
    /// (outside `valid` is implicit zero padding).
    pub fn extract(&self, region: &Region, valid: &Region, require_full: bool) -> Tensor {
        let mut out = Tensor::zeros(
            region.h1 - region.h0,
            region.w1 - region.w0,
            region.c1 - region.c0,
        );
        for p in &self.patches {
            p.copy_into(region, &mut out);
        }
        if require_full {
            let needed = region.intersect(valid);
            let covered = crate::partition::intersection_volume(
                &self.patches.iter().map(|p| p.region).collect::<Vec<_>>(),
                &[needed],
            );
            assert_eq!(
                covered,
                needed.volume(),
                "coverage gap extracting {region:?}: have {covered} of {} cells",
                needed.volume()
            );
        }
        out
    }
}

/// Per-layer weights (deterministically generated — the "pre-trained model"
/// substitute; every node and the reference derive identical weights).
#[derive(Debug, Clone)]
pub struct LayerWeights {
    /// Conv: `[k·k·in_c·out_c]` in (ky, kx, ic, oc) order.
    /// Dense/Attention: `[in_c·out_c]`. Depthwise: `[k·k·c]`. Pool: empty.
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

/// All weights of a model.
#[derive(Debug, Clone)]
pub struct WeightStore {
    pub layers: Vec<LayerWeights>,
}

impl WeightStore {
    pub fn for_model(model: &Model, seed: u64) -> WeightStore {
        let layers = model
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| {
                let mut rng = Rng::new(seed ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15));
                let n_w = match l.conv_t {
                    ConvType::Standard => l.k * l.k * l.in_c * l.out_c,
                    ConvType::Depthwise => l.k * l.k * l.out_c,
                    ConvType::Pointwise => l.in_c * l.out_c,
                    ConvType::Dense | ConvType::Attention => l.in_c * l.out_c,
                    ConvType::Pool => 0,
                };
                // scale keeps activations O(1) through deep stacks
                let scale = (1.0 / (l.k * l.k * l.in_c).max(1) as f64).sqrt();
                let w = (0..n_w)
                    .map(|_| ((rng.f64() * 2.0 - 1.0) * scale) as f32)
                    .collect();
                let b = (0..l.out_c).map(|_| (rng.f64() * 0.1) as f32).collect();
                LayerWeights { w, b }
            })
            .collect();
        WeightStore { layers }
    }
}

/// Compute the output region `out_r` of `layer`, reading input from `store`
/// (which must cover the receptive field of `out_r` within the valid input
/// extent; padding is implicit zeros).
pub fn compute_region(
    layer: &LayerMeta,
    weights: &LayerWeights,
    store: &PatchStore,
    out_r: &Region,
) -> RegionTensor {
    if out_r.is_empty() {
        return RegionTensor::new(Region::empty(), Tensor::zeros(0, 0, 0));
    }
    let in_needed = crate::partition::geometry::in_region(layer, out_r);
    let valid = Region::full(layer.in_h, layer.in_w, layer.in_c);
    // Hull covering the receptive field *before* clamping, so padded reads
    // index zeros naturally.
    let raw = unclamped_in_region(layer, out_r);
    let input = store.extract(&raw, &valid.intersect(&in_needed), true);
    let mut out = Tensor::zeros(out_r.h1 - out_r.h0, out_r.w1 - out_r.w0, out_r.c1 - out_r.c0);

    match layer.conv_t {
        ConvType::Standard | ConvType::Pointwise => {
            conv2d(layer, weights, &input, &raw, out_r, &mut out, false)
        }
        ConvType::Depthwise => conv2d(layer, weights, &input, &raw, out_r, &mut out, true),
        ConvType::Pool => pool_avg(layer, &input, &raw, out_r, &mut out),
        ConvType::Dense | ConvType::Attention => {
            dense(layer, weights, &input, &raw, out_r, &mut out)
        }
    }

    if layer.fused_activation {
        for v in &mut out.data {
            *v = v.max(0.0);
        }
    }
    RegionTensor::new(*out_r, out)
}

/// The receptive-field hull of `out_r` *without* clamping to the input
/// extent — positions outside the input read as zero (the conv padding).
pub fn unclamped_in_region(layer: &LayerMeta, r: &Region) -> Region {
    if layer.conv_t == ConvType::Attention {
        return Region::full(layer.in_h, layer.in_w, layer.in_c);
    }
    let (c0, c1) = match layer.conv_t {
        ConvType::Depthwise | ConvType::Pool => (r.c0, r.c1),
        _ => (0, layer.in_c),
    };
    Region {
        h0: r.h0 * layer.s - layer.p,
        h1: (r.h1 - 1) * layer.s - layer.p + layer.k,
        w0: r.w0 * layer.s - layer.p,
        w1: (r.w1 - 1) * layer.s - layer.p + layer.k,
        c0,
        c1,
    }
}

/// Standard/pointwise conv, axpy-structured for vectorization (§Perf):
/// per output pixel, accumulate `acc[oc_range] += x[y,x,ic] · w[ky,kx,ic,:]`
/// over taps — the weight row over `oc` is contiguous in the
/// `(ky, kx, ic, oc)` layout, so the inner loop autovectorizes, and all
/// index arithmetic is hoisted out of it.
#[allow(clippy::too_many_arguments)]
fn conv2d(
    layer: &LayerMeta,
    weights: &LayerWeights,
    input: &Tensor,
    in_r: &Region,
    out_r: &Region,
    out: &mut Tensor,
    depthwise: bool,
) {
    if depthwise {
        return conv2d_depthwise(layer, weights, input, in_r, out_r, out);
    }
    if layer.k == 1 && layer.s == 1 && layer.p == 0 {
        return conv2d_pointwise(layer, weights, input, in_r, out_r, out);
    }
    let (k, s, p) = (layer.k, layer.s, layer.p);
    let in_c = layer.in_c as usize;
    let out_c = layer.out_c as usize;
    let oc0 = out_r.c0 as usize;
    let oc1 = out_r.c1 as usize;
    let oc_len = oc1 - oc0;
    let bias = &weights.b[oc0..oc1];
    let in_w_stride = (in_r.w1 - in_r.w0) as usize * in_c;
    let mut acc = vec![0.0f32; oc_len];

    for oy in out_r.h0..out_r.h1 {
        for ox in out_r.w0..out_r.w1 {
            acc.copy_from_slice(bias);
            for ky in 0..k {
                let y = oy * s - p + ky;
                if y < 0 || y >= layer.in_h {
                    continue;
                }
                let row_base = (y - in_r.h0) as usize * in_w_stride;
                for kx in 0..k {
                    let x = ox * s - p + kx;
                    if x < 0 || x >= layer.in_w {
                        continue;
                    }
                    let px_base = row_base
                        + (x - in_r.w0) as usize * in_c
                        + (0i64 - in_r.c0) as usize; // full channel range ⇒ c0 = 0
                    let xs = &input.data[px_base..px_base + in_c];
                    let w_tap = ((ky * k + kx) as usize) * in_c * out_c;
                    for (ic, &xv) in xs.iter().enumerate() {
                        if xv == 0.0 {
                            continue; // padding-adjacent zeros are common
                        }
                        let wrow = &weights.w[w_tap + ic * out_c + oc0..w_tap + ic * out_c + oc1];
                        for (a, &wv) in acc.iter_mut().zip(wrow) {
                            *a += xv * wv;
                        }
                    }
                }
            }
            let out_base = ((oy - out_r.h0) * (out_r.w1 - out_r.w0) + (ox - out_r.w0)) as usize
                * oc_len;
            out.data[out_base..out_base + oc_len].copy_from_slice(&acc);
        }
    }
}

/// Pointwise (1×1/s1/p0) fast path: a pure `(pixels × in_c) @ (in_c ×
/// out_c)` matmul with 4-pixel row blocking for ILP — pointwise convs carry
/// most of the FLOPs in MobileNet-style models (§Perf).
fn conv2d_pointwise(
    layer: &LayerMeta,
    weights: &LayerWeights,
    input: &Tensor,
    in_r: &Region,
    out_r: &Region,
    out: &mut Tensor,
) {
    let in_c = layer.in_c as usize;
    let out_c = layer.out_c as usize;
    let oc0 = out_r.c0 as usize;
    let oc1 = out_r.c1 as usize;
    let oc_len = oc1 - oc0;
    let bias = &weights.b[oc0..oc1];
    let in_w_stride = (in_r.w1 - in_r.w0) as usize * in_c;
    let ow_len = (out_r.w1 - out_r.w0) as usize;
    let mut acc = vec![0.0f32; 4 * oc_len];

    for oy in out_r.h0..out_r.h1 {
        let row_base = (oy - in_r.h0) as usize * in_w_stride;
        let mut ox = out_r.w0;
        while ox < out_r.w1 {
            let blk = ((out_r.w1 - ox) as usize).min(4);
            for b in 0..blk {
                acc[b * oc_len..(b + 1) * oc_len].copy_from_slice(bias);
            }
            for ic in 0..in_c {
                let wrow = &weights.w[ic * out_c + oc0..ic * out_c + oc1];
                for b in 0..blk {
                    let xv = input.data
                        [row_base + (ox + b as i64 - in_r.w0) as usize * in_c + ic];
                    if xv == 0.0 {
                        continue;
                    }
                    let a = &mut acc[b * oc_len..(b + 1) * oc_len];
                    for (aj, &wv) in a.iter_mut().zip(wrow) {
                        *aj += xv * wv;
                    }
                }
            }
            for b in 0..blk {
                let out_base = ((oy - out_r.h0) as usize * ow_len
                    + (ox - out_r.w0) as usize
                    + b)
                    * oc_len;
                out.data[out_base..out_base + oc_len]
                    .copy_from_slice(&acc[b * oc_len..(b + 1) * oc_len]);
            }
            ox += blk as i64;
        }
    }
}

/// Depthwise conv: one filter per channel; the inner loop runs over the
/// contiguous channel lane (`w[ky,kx,:]` and `x[y,x,:]` are both
/// channel-contiguous).
fn conv2d_depthwise(
    layer: &LayerMeta,
    weights: &LayerWeights,
    input: &Tensor,
    in_r: &Region,
    out_r: &Region,
    out: &mut Tensor,
) {
    let (k, s, p) = (layer.k, layer.s, layer.p);
    let out_c = layer.out_c as usize;
    let c0 = out_r.c0;
    let c_len = (out_r.c1 - out_r.c0) as usize;
    let in_c_len = (in_r.c1 - in_r.c0) as usize;
    let in_w_stride = (in_r.w1 - in_r.w0) as usize * in_c_len;
    let bias = &weights.b[c0 as usize..out_r.c1 as usize];
    let mut acc = vec![0.0f32; c_len];

    for oy in out_r.h0..out_r.h1 {
        for ox in out_r.w0..out_r.w1 {
            acc.copy_from_slice(bias);
            for ky in 0..k {
                let y = oy * s - p + ky;
                if y < 0 || y >= layer.in_h {
                    continue;
                }
                for kx in 0..k {
                    let x = ox * s - p + kx;
                    if x < 0 || x >= layer.in_w {
                        continue;
                    }
                    // input channel range mirrors the output's (c0..c1)
                    let px = (y - in_r.h0) as usize * in_w_stride
                        + (x - in_r.w0) as usize * in_c_len
                        + (c0 - in_r.c0) as usize;
                    let xs = &input.data[px..px + c_len];
                    let wq = ((ky * k + kx) as usize) * out_c + c0 as usize;
                    let ws = &weights.w[wq..wq + c_len];
                    for ((a, &xv), &wv) in acc.iter_mut().zip(xs).zip(ws) {
                        *a += xv * wv;
                    }
                }
            }
            let out_base = ((oy - out_r.h0) * (out_r.w1 - out_r.w0) + (ox - out_r.w0)) as usize
                * c_len;
            out.data[out_base..out_base + c_len].copy_from_slice(&acc);
        }
    }
}

fn pool_avg(layer: &LayerMeta, input: &Tensor, in_r: &Region, out_r: &Region, out: &mut Tensor) {
    let (k, s, p) = (layer.k, layer.s, layer.p);
    for oy in out_r.h0..out_r.h1 {
        for ox in out_r.w0..out_r.w1 {
            for oc in out_r.c0..out_r.c1 {
                let mut acc = 0.0f32;
                for ky in 0..k {
                    let y = oy * s - p + ky;
                    if y < 0 || y >= layer.in_h {
                        continue;
                    }
                    for kx in 0..k {
                        let x = ox * s - p + kx;
                        if x < 0 || x >= layer.in_w {
                            continue;
                        }
                        acc += input.at(y - in_r.h0, x - in_r.w0, oc - in_r.c0);
                    }
                }
                *out.at_mut(oy - out_r.h0, ox - out_r.w0, oc - out_r.c0) =
                    acc / (k * k) as f32;
            }
        }
    }
}

fn dense(
    layer: &LayerMeta,
    weights: &LayerWeights,
    input: &Tensor,
    in_r: &Region,
    out_r: &Region,
    out: &mut Tensor,
) {
    // (rows × in_c) @ (in_c × out_c); rows live on the h axis, w == 1.
    for row in out_r.h0..out_r.h1 {
        for oc in out_r.c0..out_r.c1 {
            let mut acc = weights.b[oc as usize];
            for ic in 0..layer.in_c {
                acc += weights.w[(ic * layer.out_c + oc) as usize]
                    * input.at(row - in_r.h0, 0, ic - in_r.c0);
            }
            *out.at_mut(row - out_r.h0, 0, oc - out_r.c0) = acc;
        }
    }
}

/// Single-node reference: run the whole model on one device. The oracle for
/// every distributed-execution test.
pub fn run_reference(model: &Model, weights: &WeightStore, input: &Tensor) -> Tensor {
    assert_eq!(
        (input.h, input.w, input.c),
        (model.layers[0].in_h, model.layers[0].in_w, model.layers[0].in_c),
        "input shape mismatch"
    );
    let mut cur = input.clone();
    for (i, layer) in model.layers.iter().enumerate() {
        let mut store = PatchStore::new();
        store.add(RegionTensor::new(
            Region::full(layer.in_h, layer.in_w, layer.in_c),
            cur,
        ));
        let out_full = Region::full(layer.out_h, layer.out_w, layer.out_c);
        cur = compute_region(layer, &weights.layers[i], &store, &out_full).t;
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    fn layer(h: i64, ci: i64, co: i64, k: i64, s: i64, p: i64) -> LayerMeta {
        LayerMeta::conv("t", ConvType::Standard, h, h, ci, co, k, s, p)
    }

    fn full_store(l: &LayerMeta, t: Tensor) -> PatchStore {
        let mut s = PatchStore::new();
        s.add(RegionTensor::new(Region::full(l.in_h, l.in_w, l.in_c), t));
        s
    }

    #[test]
    fn identity_conv_1x1() {
        // 1×1 conv with identity weights reproduces the input.
        let l = LayerMeta::conv("id", ConvType::Pointwise, 4, 4, 2, 2, 1, 1, 0);
        let mut w = LayerWeights { w: vec![0.0; 4], b: vec![0.0; 2] };
        w.w[0] = 1.0; // ic0 -> oc0
        w.w[3] = 1.0; // ic1 -> oc1
        let input = Tensor::random(4, 4, 2, 1);
        let store = full_store(&l, input.clone());
        let out = compute_region(&l, &w, &store, &Region::full(4, 4, 2)).t;
        assert_eq!(out.data, input.data);
    }

    #[test]
    fn conv_known_values() {
        // 3×3 all-ones kernel over all-ones input, same padding: interior
        // outputs = 9, corners = 4, edges = 6.
        let l = layer(4, 1, 1, 3, 1, 1);
        let w = LayerWeights { w: vec![1.0; 9], b: vec![0.0] };
        let input = Tensor { h: 4, w: 4, c: 1, data: vec![1.0; 16] };
        let store = full_store(&l, input);
        let out = compute_region(&l, &w, &store, &Region::full(4, 4, 1)).t;
        assert_eq!(out.at(1, 1, 0), 9.0);
        assert_eq!(out.at(0, 0, 0), 4.0);
        assert_eq!(out.at(0, 1, 0), 6.0);
    }

    #[test]
    fn strided_conv_shape_and_values() {
        let l = layer(4, 1, 1, 3, 2, 1);
        assert_eq!(l.out_h, 2);
        let w = LayerWeights { w: vec![1.0; 9], b: vec![0.0] };
        let input = Tensor { h: 4, w: 4, c: 1, data: vec![1.0; 16] };
        let store = full_store(&l, input);
        let out = compute_region(&l, &w, &store, &Region::full(2, 2, 1)).t;
        assert_eq!(out.at(0, 0, 0), 4.0); // top-left window clipped to 2×2
        assert_eq!(out.at(1, 1, 0), 9.0);
    }

    #[test]
    fn partial_region_equals_slice_of_full() {
        // Computing a sub-region directly == slicing the full output.
        let l = layer(8, 3, 4, 3, 1, 1);
        let ws = WeightStore::for_model(
            &crate::model::Model::new("m", vec![l.clone()]),
            7,
        );
        let input = Tensor::random(8, 8, 3, 2);
        let store = full_store(&l, input);
        let full = compute_region(&l, &ws.layers[0], &store, &Region::full(8, 8, 4));
        let sub_r = Region::new(2, 5, 1, 7, 1, 3);
        let sub = compute_region(&l, &ws.layers[0], &store, &sub_r);
        for y in sub_r.h0..sub_r.h1 {
            for x in sub_r.w0..sub_r.w1 {
                for c in sub_r.c0..sub_r.c1 {
                    assert_eq!(
                        sub.t.at(y - sub_r.h0, x - sub_r.w0, c - sub_r.c0),
                        full.t.at(y, x, c)
                    );
                }
            }
        }
    }

    #[test]
    fn depthwise_channels_independent() {
        let l = LayerMeta::conv("dw", ConvType::Depthwise, 6, 6, 2, 2, 3, 1, 1);
        let m = crate::model::Model::new("m", vec![l.clone()]);
        let ws = WeightStore::for_model(&m, 3);
        let mut input = Tensor::random(6, 6, 2, 4);
        let store = full_store(&l, input.clone());
        let before = compute_region(&l, &ws.layers[0], &store, &Region::full(6, 6, 2)).t;
        // perturb channel 1 only; channel 0 output must not change
        for y in 0..6 {
            for x in 0..6 {
                *input.at_mut(y, x, 1) += 1.0;
            }
        }
        let store2 = full_store(&l, input);
        let after = compute_region(&l, &ws.layers[0], &store2, &Region::full(6, 6, 2)).t;
        for y in 0..6 {
            for x in 0..6 {
                assert_eq!(before.at(y, x, 0), after.at(y, x, 0));
                assert_ne!(before.at(y, x, 1), after.at(y, x, 1));
            }
        }
    }

    #[test]
    fn global_avg_pool() {
        let l = LayerMeta::pool("gap", 4, 4, 2, 4, 4);
        assert_eq!((l.out_h, l.out_w), (1, 1));
        let mut input = Tensor::zeros(4, 4, 2);
        for y in 0..4 {
            for x in 0..4 {
                *input.at_mut(y, x, 0) = 2.0;
                *input.at_mut(y, x, 1) = (y * 4 + x) as f32;
            }
        }
        let store = full_store(&l, input);
        let w = LayerWeights { w: vec![], b: vec![] };
        let out = compute_region(&l, &w, &store, &Region::full(1, 1, 2)).t;
        assert_eq!(out.at(0, 0, 0), 2.0);
        assert_eq!(out.at(0, 0, 1), 7.5);
    }

    #[test]
    fn dense_matches_manual_matmul() {
        let l = LayerMeta::dense("fc", 3, 4, 2);
        let m = crate::model::Model::new("m", vec![l.clone()]);
        let ws = WeightStore::for_model(&m, 5);
        let input = Tensor::random(3, 1, 4, 6);
        let store = full_store(&l, input.clone());
        let out = compute_region(&l, &ws.layers[0], &store, &Region::full(3, 1, 2)).t;
        for row in 0..3 {
            for oc in 0..2 {
                let mut acc = ws.layers[0].b[oc as usize];
                for ic in 0..4 {
                    acc += ws.layers[0].w[(ic * 2 + oc) as usize] * input.at(row, 0, ic);
                }
                assert!((out.at(row, 0, oc) - acc).abs() < 1e-6);
            }
        }
    }

    #[test]
    #[should_panic(expected = "coverage gap")]
    fn missing_halo_panics() {
        // A store holding only rows 0..2 cannot compute output rows 0..3 of
        // a 3×3 conv (row 2 needs input row 3).
        let l = layer(6, 1, 1, 3, 1, 1);
        let mut store = PatchStore::new();
        store.add(RegionTensor::new(
            Region::new(0, 2, 0, 6, 0, 1),
            Tensor::zeros(2, 6, 1),
        ));
        let w = LayerWeights { w: vec![1.0; 9], b: vec![0.0] };
        let _ = compute_region(&l, &w, &store, &Region::new(0, 3, 0, 6, 0, 1));
    }

    #[test]
    fn reference_runs_edgenet() {
        let model = zoo::edgenet(16);
        let ws = WeightStore::for_model(&model, 42);
        let input = Tensor::random(16, 16, 3, 1);
        let out = run_reference(&model, &ws, &input);
        assert_eq!((out.h, out.w, out.c), (1, 1, 10));
        assert!(out.data.iter().all(|v| v.is_finite()));
        // deterministic
        let out2 = run_reference(&model, &ws, &input);
        assert_eq!(out.data, out2.data);
    }
}
