//! `flexpie-ctl` — coordinator-side tooling for the wire transport.
//!
//! ```text
//! flexpie-ctl registry   [--bind tcp:127.0.0.1:0] [--ttl-ms 1000]
//! flexpie-ctl resolve    --registry <addr>
//! flexpie-ctl serve      --registry <addr> --nodes 3 [--model edgenet] \
//!                        [--scheme inh|inw|outc|grid] [--seed 5] [--requests 8]
//! flexpie-ctl trace-dump --registry <addr> [--json]
//! flexpie-ctl metrics    --registry <addr> [--json]
//! flexpie-ctl shutdown   --registry <addr>
//! ```
//!
//! `registry` hosts the TTL-leased discovery service in this process and
//! prints `REGISTRY <addr>` (supervisors wait for that line). `serve`
//! discovers the live daemons, installs a plan, drives inferences through
//! the cluster and — because the weights derive deterministically from the
//! seed — verifies every output against the in-process single-node
//! reference, bit for bit. `trace-dump` pulls every daemon's flight
//! recorder, merges the spans into per-request trees and prints the
//! queue/service/wire decomposition; `metrics` prints the unified named
//! counters (per-node RSS/CPU, span tallies). Both attach to daemons that
//! have no serving coordinator connected.

use std::sync::atomic::AtomicBool;
use std::time::Duration;

use flexpie::compute::{run_reference, Tensor, WeightStore};
use flexpie::metrics::Registry;
use flexpie::model::zoo;
use flexpie::partition::{Plan, Scheme};
use flexpie::trace::{merge_spans, SpanRecord, TraceSummary};
use flexpie::transport::coord::{InferOutcome, NodeTraceDump, ProcessCluster};
use flexpie::transport::{registry, tcp};
use flexpie::util::cli::Args;
use flexpie::util::json::Json;

fn main() {
    let args = Args::from_env();
    let code = match args.command.as_deref() {
        Some("registry") => cmd_registry(&args),
        Some("resolve") => cmd_resolve(&args),
        Some("serve") => cmd_serve(&args),
        Some("trace-dump") => cmd_trace_dump(&args),
        Some("metrics") => cmd_metrics(&args),
        Some("shutdown") => cmd_shutdown(&args),
        _ => {
            eprintln!(
                "flexpie-ctl — FlexPie wire-transport coordinator\n\
                 commands: registry | resolve | serve | trace-dump | metrics | shutdown\n\
                 see README.md (\"Wire transport\", \"Observability\") for usage"
            );
            2
        }
    };
    std::process::exit(code);
}

/// Host the registry in this process until a `Shutdown` frame arrives.
fn cmd_registry(args: &Args) -> i32 {
    let bind = args.get_or("bind", "tcp:127.0.0.1:0");
    let ttl = Duration::from_millis(args.u64_or("ttl-ms", 1000));
    let (listener, addr) = match tcp::listen(bind) {
        Ok(la) => la,
        Err(e) => {
            eprintln!("flexpie-ctl registry: bind {bind}: {e}");
            return 1;
        }
    };
    if let Err(e) = listener.set_nonblocking(true) {
        eprintln!("flexpie-ctl registry: {e}");
        return 1;
    }
    use std::io::Write as _;
    println!("REGISTRY {addr}");
    let _ = std::io::stdout().flush();
    let stop = AtomicBool::new(false);
    registry::serve(listener, ttl, &stop);
    0
}

fn cmd_resolve(args: &Args) -> i32 {
    let Some(reg) = args.get("registry") else {
        eprintln!("flexpie-ctl resolve: --registry required");
        return 2;
    };
    match registry::resolve(reg) {
        Ok(entries) => {
            for e in &entries {
                println!(
                    "node {} ctl={} data={} speed={}",
                    e.node, e.ctl_addr, e.data_addr, e.speed
                );
            }
            println!("{} live daemon(s)", entries.len());
            0
        }
        Err(e) => {
            eprintln!("flexpie-ctl resolve: {e}");
            1
        }
    }
}

fn cmd_serve(args: &Args) -> i32 {
    let Some(reg) = args.get("registry") else {
        eprintln!("flexpie-ctl serve: --registry required");
        return 2;
    };
    let min_nodes = args.usize_or("nodes", 3);
    let Some(model) = zoo::by_name(args.get_or("model", "edgenet")) else {
        eprintln!("flexpie-ctl serve: unknown model");
        return 2;
    };
    let scheme = match args.get_or("scheme", "inh") {
        "inw" => Scheme::InW,
        "outc" => Scheme::OutC,
        "grid" => Scheme::Grid2d,
        _ => Scheme::InH,
    };
    let seed = args.u64_or("seed", 5);
    let requests = args.u64_or("requests", 8);

    let plan = Plan::uniform(scheme, model.n_layers());
    let mut pc = match ProcessCluster::connect(reg, min_nodes, Duration::from_secs(30)) {
        Ok(pc) => pc,
        Err(e) => {
            eprintln!("flexpie-ctl serve: cluster bring-up: {e}");
            return 1;
        }
    };
    if let Err(e) = pc.install(&model, &plan, seed) {
        eprintln!("flexpie-ctl serve: plan install: {e}");
        return 1;
    }
    println!(
        "installed {} ({scheme:?}) on {} daemon(s), leader {}",
        model.name,
        pc.nodes(),
        pc.leader()
    );

    let ws = WeightStore::for_model(&model, seed);
    let l0 = &model.layers[0];
    let (mut ok, mut failed) = (0u64, 0u64);
    for i in 0..requests {
        let input = Tensor::random(l0.in_h, l0.in_w, l0.in_c, 0xC0DE + i);
        match pc.infer(&input) {
            Ok(InferOutcome::Done(run)) => {
                let reference = run_reference(&model, &ws, &input);
                let diff = reference.max_abs_diff(&run.output);
                if diff != 0.0 {
                    eprintln!("request {i}: output diverged from reference ({diff})");
                    return 1;
                }
                ok += 1;
                println!(
                    "request {i}: ok (seq {}, leader sent {} B in {} msgs)",
                    run.seq, run.bytes, run.msgs
                );
            }
            Ok(InferOutcome::Failed { dead, .. }) => {
                failed += 1;
                println!("request {i}: failed explicitly (dead={dead:?}); reinstalling");
                if let Err(e) = pc.reinstall(dead) {
                    eprintln!("flexpie-ctl serve: reinstall: {e}");
                    return 1;
                }
            }
            Err(e) => {
                eprintln!("flexpie-ctl serve: {e}");
                return 1;
            }
        }
    }
    println!("served {ok} ok, {failed} failed-and-reinstalled, 0 silently dropped");
    pc.shutdown();
    0
}

/// Attach to every live daemon (no plan install) and pull the flight
/// recorders + resource deltas. Shared by `trace-dump` and `metrics`.
fn pull_dumps(args: &Args, cmd: &str) -> Result<Vec<NodeTraceDump>, String> {
    let reg =
        args.get("registry").ok_or_else(|| format!("flexpie-ctl {cmd}: --registry required"))?;
    let mut pc = ProcessCluster::connect(reg, 1, Duration::from_secs(10))
        .map_err(|e| format!("flexpie-ctl {cmd}: cluster bring-up: {e}"))?;
    pc.infer_deadline = Duration::from_secs(10);
    pc.attach().map_err(|e| format!("flexpie-ctl {cmd}: attach: {e}"))?;
    Ok(pc.trace_dump())
}

fn cmd_trace_dump(args: &Args) -> i32 {
    let dumps = match pull_dumps(args, "trace-dump") {
        Ok(d) => d,
        Err(e) => {
            eprintln!("{e}");
            return if e.contains("--registry") { 2 } else { 1 };
        }
    };
    let spans: Vec<SpanRecord> =
        dumps.iter().flat_map(|d| d.spans.iter().copied()).collect();
    let trees = merge_spans(&spans);
    if args.has("json") {
        let v = Json::obj(vec![
            (
                "nodes",
                Json::Arr(
                    dumps
                        .iter()
                        .map(|d| {
                            Json::obj(vec![
                                ("node", Json::Num(d.node as f64)),
                                ("spans", Json::Num(d.spans.len() as f64)),
                                ("rss_bytes", Json::Num(d.rss_bytes as f64)),
                                ("cpu_ms", Json::Num(d.cpu_ms as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("trees", Json::Arr(trees.iter().map(|t| t.to_json()).collect())),
        ]);
        println!("{}", v.to_string());
        return 0;
    }
    for d in &dumps {
        println!(
            "node {}: {} span(s), rss {} KiB, cpu {} ms",
            d.node,
            d.spans.len(),
            d.rss_bytes / 1024,
            d.cpu_ms
        );
    }
    for t in &trees {
        println!(
            "trace {} gen {}: total {} µs = queue {} + service {} + wire {} µs, \
             {} stage span(s){}{}",
            t.trace_id,
            t.gen,
            t.total_ns / 1000,
            t.queue_ns / 1000,
            t.service_ns / 1000,
            t.wire_ns / 1000,
            t.stages.len(),
            if t.well_formed { "" } else { " [NOT WELL-FORMED]" },
            if t.truncated { " [TRUNCATED]" } else { "" },
        );
    }
    let summary = TraceSummary::from_trees(&trees);
    println!("{summary}");
    0
}

fn cmd_metrics(args: &Args) -> i32 {
    let dumps = match pull_dumps(args, "metrics") {
        Ok(d) => d,
        Err(e) => {
            eprintln!("{e}");
            return if e.contains("--registry") { 2 } else { 1 };
        }
    };
    let spans: Vec<SpanRecord> =
        dumps.iter().flat_map(|d| d.spans.iter().copied()).collect();
    let trees = merge_spans(&spans);
    let summary = TraceSummary::from_trees(&trees);
    let mut reg = Registry::new();
    for d in &dumps {
        reg.set(&format!("node{}.rss_bytes", d.node), d.rss_bytes);
        reg.set(&format!("node{}.cpu_ms", d.node), d.cpu_ms);
        reg.set(&format!("node{}.spans", d.node), d.spans.len() as u64);
    }
    reg.set("trace.traces", summary.traces);
    reg.set("trace.well_formed", summary.well_formed);
    reg.set("trace.truncated", summary.truncated);
    reg.set("trace.service_ns_sum", summary.service_ns_sum);
    reg.set("trace.wire_ns_sum", summary.wire_ns_sum);
    if args.has("json") {
        println!("{}", reg.to_json());
    } else {
        print!("{reg}");
    }
    0
}

fn cmd_shutdown(args: &Args) -> i32 {
    let Some(reg) = args.get("registry") else {
        eprintln!("flexpie-ctl shutdown: --registry required");
        return 2;
    };
    match registry::shutdown(reg) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("flexpie-ctl shutdown: {e}");
            1
        }
    }
}
