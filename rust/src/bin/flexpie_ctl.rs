//! `flexpie-ctl` — coordinator-side tooling for the wire transport.
//!
//! ```text
//! flexpie-ctl registry [--bind tcp:127.0.0.1:0] [--ttl-ms 1000]
//! flexpie-ctl resolve  --registry <addr>
//! flexpie-ctl serve    --registry <addr> --nodes 3 [--model edgenet] \
//!                      [--scheme inh|inw|outc|grid] [--seed 5] [--requests 8]
//! flexpie-ctl shutdown --registry <addr>
//! ```
//!
//! `registry` hosts the TTL-leased discovery service in this process and
//! prints `REGISTRY <addr>` (supervisors wait for that line). `serve`
//! discovers the live daemons, installs a plan, drives inferences through
//! the cluster and — because the weights derive deterministically from the
//! seed — verifies every output against the in-process single-node
//! reference, bit for bit.

use std::sync::atomic::AtomicBool;
use std::time::Duration;

use flexpie::compute::{run_reference, Tensor, WeightStore};
use flexpie::model::zoo;
use flexpie::partition::{Plan, Scheme};
use flexpie::transport::coord::{InferOutcome, ProcessCluster};
use flexpie::transport::{registry, tcp};
use flexpie::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let code = match args.command.as_deref() {
        Some("registry") => cmd_registry(&args),
        Some("resolve") => cmd_resolve(&args),
        Some("serve") => cmd_serve(&args),
        Some("shutdown") => cmd_shutdown(&args),
        _ => {
            eprintln!(
                "flexpie-ctl — FlexPie wire-transport coordinator\n\
                 commands: registry | resolve | serve | shutdown\n\
                 see README.md (\"Wire transport\") for usage"
            );
            2
        }
    };
    std::process::exit(code);
}

/// Host the registry in this process until a `Shutdown` frame arrives.
fn cmd_registry(args: &Args) -> i32 {
    let bind = args.get_or("bind", "tcp:127.0.0.1:0");
    let ttl = Duration::from_millis(args.u64_or("ttl-ms", 1000));
    let (listener, addr) = match tcp::listen(bind) {
        Ok(la) => la,
        Err(e) => {
            eprintln!("flexpie-ctl registry: bind {bind}: {e}");
            return 1;
        }
    };
    if let Err(e) = listener.set_nonblocking(true) {
        eprintln!("flexpie-ctl registry: {e}");
        return 1;
    }
    use std::io::Write as _;
    println!("REGISTRY {addr}");
    let _ = std::io::stdout().flush();
    let stop = AtomicBool::new(false);
    registry::serve(listener, ttl, &stop);
    0
}

fn cmd_resolve(args: &Args) -> i32 {
    let Some(reg) = args.get("registry") else {
        eprintln!("flexpie-ctl resolve: --registry required");
        return 2;
    };
    match registry::resolve(reg) {
        Ok(entries) => {
            for e in &entries {
                println!(
                    "node {} ctl={} data={} speed={}",
                    e.node, e.ctl_addr, e.data_addr, e.speed
                );
            }
            println!("{} live daemon(s)", entries.len());
            0
        }
        Err(e) => {
            eprintln!("flexpie-ctl resolve: {e}");
            1
        }
    }
}

fn cmd_serve(args: &Args) -> i32 {
    let Some(reg) = args.get("registry") else {
        eprintln!("flexpie-ctl serve: --registry required");
        return 2;
    };
    let min_nodes = args.usize_or("nodes", 3);
    let Some(model) = zoo::by_name(args.get_or("model", "edgenet")) else {
        eprintln!("flexpie-ctl serve: unknown model");
        return 2;
    };
    let scheme = match args.get_or("scheme", "inh") {
        "inw" => Scheme::InW,
        "outc" => Scheme::OutC,
        "grid" => Scheme::Grid2d,
        _ => Scheme::InH,
    };
    let seed = args.u64_or("seed", 5);
    let requests = args.u64_or("requests", 8);

    let plan = Plan::uniform(scheme, model.n_layers());
    let mut pc = match ProcessCluster::connect(reg, min_nodes, Duration::from_secs(30)) {
        Ok(pc) => pc,
        Err(e) => {
            eprintln!("flexpie-ctl serve: cluster bring-up: {e}");
            return 1;
        }
    };
    if let Err(e) = pc.install(&model, &plan, seed) {
        eprintln!("flexpie-ctl serve: plan install: {e}");
        return 1;
    }
    println!(
        "installed {} ({scheme:?}) on {} daemon(s), leader {}",
        model.name,
        pc.nodes(),
        pc.leader()
    );

    let ws = WeightStore::for_model(&model, seed);
    let l0 = &model.layers[0];
    let (mut ok, mut failed) = (0u64, 0u64);
    for i in 0..requests {
        let input = Tensor::random(l0.in_h, l0.in_w, l0.in_c, 0xC0DE + i);
        match pc.infer(&input) {
            Ok(InferOutcome::Done(run)) => {
                let reference = run_reference(&model, &ws, &input);
                let diff = reference.max_abs_diff(&run.output);
                if diff != 0.0 {
                    eprintln!("request {i}: output diverged from reference ({diff})");
                    return 1;
                }
                ok += 1;
                println!(
                    "request {i}: ok (seq {}, leader sent {} B in {} msgs)",
                    run.seq, run.bytes, run.msgs
                );
            }
            Ok(InferOutcome::Failed { dead, .. }) => {
                failed += 1;
                println!("request {i}: failed explicitly (dead={dead:?}); reinstalling");
                if let Err(e) = pc.reinstall(dead) {
                    eprintln!("flexpie-ctl serve: reinstall: {e}");
                    return 1;
                }
            }
            Err(e) => {
                eprintln!("flexpie-ctl serve: {e}");
                return 1;
            }
        }
    }
    println!("served {ok} ok, {failed} failed-and-reinstalled, 0 silently dropped");
    pc.shutdown();
    0
}

fn cmd_shutdown(args: &Args) -> i32 {
    let Some(reg) = args.get("registry") else {
        eprintln!("flexpie-ctl shutdown: --registry required");
        return 2;
    };
    match registry::shutdown(reg) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("flexpie-ctl shutdown: {e}");
            1
        }
    }
}
