//! `flexpie-load` — open-loop load agent and suite orchestrator.
//!
//! ```text
//! # one load-agent process (spawned by the harness, one per traffic source)
//! flexpie-load agent --addr tcp:127.0.0.1:4600 --id 0 --requests 32 \
//!                    --seed 11 --arrival poisson --rate 120 [--slo-ms 250] \
//!                    [--distinct 4] [--input-seed 711] [--reply-timeout-ms 30000] \
//!                    [--warmup 0.1]
//!
//! # the full suite ladder (A1–A4 deterministic, B1–B2 Poisson)
//! flexpie-load suite [--suite a1_baseline] [--node-bin PATH] [--out FILE] \
//!                    [--artifacts DIR]
//! ```
//!
//! `agent` paces a seeded schedule into a serving front door and prints one
//! `AGENT {json}` line (counts, latency histogram, `/proc` self-usage).
//! `suite` builds the server stack itself, fans agent subprocesses in, and
//! prints one `RESULT {json}` line per suite; `--out` also writes the
//! assembled trajectory JSON (the `BENCH_pr9.json` artifact).
//! `FLEXPIE_BENCH_FAST=1` shrinks every suite to CI-smoke scale.

use std::time::Duration;

use flexpie::bench::harness::{self, HarnessOpts};
use flexpie::loadgen::agent::{self, AgentOpts};
use flexpie::loadgen::{ArrivalProcess, ScheduleSpec};
use flexpie::util::bench::emit_result_json;
use flexpie::util::cli::Args;

fn usage() -> ! {
    eprintln!(
        "flexpie-load — FlexPie open-loop load harness\n\
         usage: flexpie-load agent --addr <addr> [--id N] [--requests N] [--seed N]\n\
         \x20                      [--arrival uniform|poisson|burst|step] [--rate HZ] …\n\
         \x20      flexpie-load suite [--suite NAME] [--node-bin PATH] [--out FILE]\n\
         \x20                         [--artifacts DIR]"
    );
    std::process::exit(2);
}

fn agent_main(args: &Args) {
    let Some(addr) = args.get("addr") else { usage() };
    let process = match ArrivalProcess::from_args(args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("flexpie-load agent: {e}");
            std::process::exit(2);
        }
    };
    let opts = AgentOpts {
        id: args.u64_or("id", 0) as u32,
        addr: addr.to_string(),
        spec: ScheduleSpec {
            process,
            requests: args.usize_or("requests", 32),
            seed: args.u64_or("seed", 1),
        },
        distinct: args.u64_or("distinct", 4),
        input_seed: args.u64_or("input-seed", 700),
        slo: Duration::from_secs_f64(args.f64_or("slo-ms", 250.0) / 1e3),
        connect_deadline: Duration::from_millis(args.u64_or("connect-deadline-ms", 10_000)),
        reply_timeout: Duration::from_millis(args.u64_or("reply-timeout-ms", 30_000)),
        warmup: args.f64_or("warmup", 0.0),
    };
    match agent::run(&opts) {
        Ok(report) => println!("{}", report.to_line()),
        Err(e) => {
            eprintln!("flexpie-load agent: {e}");
            std::process::exit(1);
        }
    }
}

fn suite_main(args: &Args) {
    let mut opts = match HarnessOpts::siblings_of_current_exe() {
        Ok(o) => o,
        Err(e) => {
            eprintln!("flexpie-load suite: {e}");
            std::process::exit(1);
        }
    };
    if let Some(nb) = args.get("node-bin") {
        opts.node_bin = nb.to_string();
    }
    if let Some(dir) = args.get("artifacts") {
        opts.artifact_dir = Some(dir.to_string());
    }
    let only = args.get("suite");
    let mut reports = Vec::new();
    for spec in harness::suites(opts.fast) {
        if only.is_some_and(|n| n != spec.name) {
            continue;
        }
        eprintln!("[flexpie-load] running suite {}", spec.name);
        match harness::run_suite(&spec, &opts) {
            Ok(report) => {
                emit_result_json(&report.to_json());
                reports.push(report);
            }
            Err(e) => {
                eprintln!("flexpie-load suite: {e}");
                std::process::exit(1);
            }
        }
    }
    if reports.is_empty() {
        eprintln!("flexpie-load suite: no suite matched");
        std::process::exit(2);
    }
    if let Some(out) = args.get("out") {
        if let Err(e) = harness::assemble(&reports).save(std::path::Path::new(out)) {
            eprintln!("flexpie-load suite: write {out}: {e}");
            std::process::exit(1);
        }
    }
}

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        usage();
    }
    let cmd = argv.remove(0);
    let args = Args::parse(argv);
    match cmd.as_str() {
        "agent" => agent_main(&args),
        "suite" => suite_main(&args),
        _ => usage(),
    }
}
