//! `flexpie-node` — one node daemon, one OS process.
//!
//! ```text
//! flexpie-node --node 0 --registry tcp:127.0.0.1:4500 \
//!              [--ctl-bind tcp:127.0.0.1:0] [--data-bind tcp:127.0.0.1:0] \
//!              [--speed 1.0] [--heartbeat-ms 100] [--heartbeat-timeout-ms 1200]
//! ```
//!
//! Boots, registers with the registry, prints `READY node=… ctl=… data=…`
//! (supervisors wait for that line), then serves plan installs and
//! inferences until a coordinator sends `Shutdown` — or until someone
//! `kill -9`s it, which is a supported and tested way to go: the lease
//! expires, the coordinator reinstalls on the survivors, and retried
//! inferences come out bit-identical.

use std::time::Duration;

use flexpie::transport::daemon::{run, DaemonOpts};
use flexpie::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let Some(registry) = args.get("registry") else {
        eprintln!(
            "flexpie-node — FlexPie wire-transport node daemon\n\
             usage: flexpie-node --node <id> --registry <addr> \
             [--ctl-bind <addr>] [--data-bind <addr>] [--speed <f>]\n\
             addresses: tcp:HOST:PORT (port 0 = ephemeral) or unix:/path/sock"
        );
        std::process::exit(2);
    };
    let mut opts = DaemonOpts::new(args.u64_or("node", 0) as u32, registry);
    opts.ctl_bind = args.get_or("ctl-bind", "tcp:127.0.0.1:0").to_string();
    opts.data_bind = args.get_or("data-bind", "tcp:127.0.0.1:0").to_string();
    opts.speed = args.f64_or("speed", 1.0);
    opts.tcp.heartbeat_interval = Duration::from_millis(args.u64_or("heartbeat-ms", 100));
    opts.tcp.heartbeat_timeout =
        Duration::from_millis(args.u64_or("heartbeat-timeout-ms", 1200));
    opts.announce = true;
    if let Err(e) = run(opts) {
        eprintln!("flexpie-node: {e}");
        std::process::exit(1);
    }
}
