//! Open-loop load harness: multi-process traffic against a serving stack,
//! with tail-latency SLO reporting and a deterministic test spine.
//!
//! The orchestrator (this module) builds a server — in-process
//! ([`Server::start_telemetry`]) or over the real wire fabric
//! ([`Server::start_process`] on `flexpie-node` daemon processes) — opens a
//! [`FrontDoor`], and fans N `flexpie-load agent` **processes** into it.
//! Each agent paces a precomputed seeded schedule and reports a single
//! `AGENT {json}` line: counts, an HDR-style latency histogram and its own
//! `/proc` usage. The orchestrator merges the histograms exactly
//! (bucket-wise, order-independent), samples the daemons' `/proc` around
//! the run, and folds everything into one [`SuiteReport`].
//!
//! Two suite families:
//!
//! * **A1–A4 (deterministic, CI-gated).** Rng-free arrival processes and an
//!   admission queue sized ≥ the total request count, so shedding is
//!   *structurally impossible*: fixed seed ⇒ fixed schedule ⇒ `ok == sent`,
//!   zero mismatches against the single-node reference, exact conservation
//!   `sent == ok + shed + failed`. Latency numbers are reported, never
//!   gated — that is what keeps the spine green on a noisy CI box.
//! * **B1–B2 (Poisson, honest).** Open-loop Poisson at 0.5×/0.8× of the
//!   capacity probed through the very same front door; B2 SIGKILLs the
//!   leader daemon mid-run and rides the replay path. Gates here are
//!   *structural* (conservation, monotone percentiles, B2 must observe
//!   ≥1 failover and ≥1 replay); p50/p99/p99.9, goodput and the
//!   SLO-violation fraction are the measured product.

use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::compute::WeightStore;
use crate::elastic::{ConditionTrace, ElasticConfig};
use crate::loadgen::agent::AgentReport;
use crate::loadgen::hist::Histogram;
use crate::loadgen::procfs::{self, ProcUsage};
use crate::loadgen::{workload, ArrivalProcess, ScheduleSpec};
use crate::metrics::Registry;
use crate::net::{Bandwidth, Testbed, Topology};
use crate::partition::{Plan, Scheme};
use crate::serve::frontdoor::FrontDoor;
use crate::serve::{RouterStats, ServeConfig, Server};
use crate::telemetry::TelemetryConfig;
use crate::trace::merge_spans;
use crate::transport::codec::{Frame, WireMsg};
use crate::transport::coord::ProcessCluster;
use crate::transport::registry::RegistryServer;
use crate::transport::tcp;
use crate::util::json::Json;

/// How the suite's server is built.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// In-process telemetry-path server with this pipeline depth.
    InProc { pipeline_depth: usize },
    /// Real `flexpie-node` daemon processes over TCP; with `kill_leader`
    /// the leader is SIGKILLed mid-run (the B2 chaos arc).
    Process { nodes: usize, kill_leader: bool },
}

/// The offered load, resolved at run time.
#[derive(Debug, Clone, PartialEq)]
pub enum Offered {
    /// A fixed (rng-free for the A-suites) arrival process per agent.
    Fixed(ArrivalProcess),
    /// Poisson at `frac` × the capacity probed through the front door,
    /// split evenly across agents.
    PoissonAtCapacity(f64),
}

/// One suite: everything needed to reproduce its traffic bit-for-bit.
#[derive(Debug, Clone)]
pub struct SuiteSpec {
    pub name: &'static str,
    pub mode: Mode,
    pub agents: u32,
    pub requests_per_agent: usize,
    pub offered: Offered,
    /// Base seed; agent `i` uses `seed + i` for its schedule.
    pub seed: u64,
    /// Latency SLO replies are judged against (reported, not gated).
    pub slo: Duration,
    /// Admission queue depth. `None` ⇒ sized to `total + agents`, which
    /// makes shedding structurally impossible — the A-suite determinism
    /// trick.
    pub queue_depth: Option<usize>,
    /// A-suite gate: every request must be served (`ok == sent`).
    pub deterministic: bool,
    /// Warm-up fraction: each agent trims this leading fraction of its
    /// arrivals from the latency histogram and SLO tally (cold caches and
    /// arena warm-up are not steady state). Conservation counts always
    /// cover the full schedule; the trim is flagged in the RESULT line.
    pub warmup: f64,
}

impl SuiteSpec {
    fn total(&self) -> usize {
        self.agents as usize * self.requests_per_agent
    }

    fn input_seed(&self) -> u64 {
        700 + self.seed
    }
}

/// The canonical suite list. `fast` shrinks request counts to CI-smoke
/// scale without changing any suite's structure.
pub fn suites(fast: bool) -> Vec<SuiteSpec> {
    let n = |full: usize, smoke: usize| if fast { smoke } else { full };
    vec![
        // A1 — one agent, uniform arrivals, batcher path: the baseline spine
        SuiteSpec {
            name: "a1_baseline",
            mode: Mode::InProc { pipeline_depth: 1 },
            agents: 1,
            requests_per_agent: n(32, 10),
            offered: Offered::Fixed(ArrivalProcess::Uniform { rate_hz: 200.0 }),
            seed: 11,
            slo: Duration::from_millis(250),
            queue_depth: None,
            deterministic: true,
            warmup: 0.0,
        },
        // A2 — four agents fanning into one queue under square-wave bursts
        SuiteSpec {
            name: "a2_fanin",
            mode: Mode::InProc { pipeline_depth: 1 },
            agents: 4,
            requests_per_agent: n(24, 6),
            offered: Offered::Fixed(ArrivalProcess::Burst {
                base_hz: 50.0,
                burst_hz: 400.0,
                period_s: 0.08,
                duty: 0.5,
            }),
            seed: 22,
            slo: Duration::from_millis(250),
            queue_depth: None,
            deterministic: true,
            warmup: 0.0,
        },
        // A3 — pipelined router under a rate step
        SuiteSpec {
            name: "a3_pipeline",
            mode: Mode::InProc { pipeline_depth: 4 },
            agents: 2,
            requests_per_agent: n(24, 6),
            offered: Offered::Fixed(ArrivalProcess::Step {
                before_hz: 100.0,
                after_hz: 300.0,
                at_s: 0.06,
            }),
            seed: 33,
            slo: Duration::from_millis(250),
            queue_depth: None,
            deterministic: true,
            warmup: 0.0,
        },
        // A4 — the full wire stack: 3 daemon processes, process-mode server
        SuiteSpec {
            name: "a4_process",
            mode: Mode::Process { nodes: 3, kill_leader: false },
            agents: 2,
            requests_per_agent: n(16, 5),
            offered: Offered::Fixed(ArrivalProcess::Uniform { rate_hz: 60.0 }),
            seed: 44,
            slo: Duration::from_millis(500),
            queue_depth: None,
            deterministic: true,
            warmup: 0.0,
        },
        // B1 — Poisson at half the probed capacity: the steady-tail number
        SuiteSpec {
            name: "b1_poisson_half",
            mode: Mode::InProc { pipeline_depth: 1 },
            agents: 2,
            requests_per_agent: n(48, 10),
            offered: Offered::PoissonAtCapacity(0.5),
            seed: 55,
            slo: Duration::from_millis(250),
            queue_depth: Some(32),
            deterministic: false,
            warmup: 0.1,
        },
        // B2 — Poisson at 0.8× capacity with a mid-run leader SIGKILL: the
        // tail *including* detection + reinstall + replay
        SuiteSpec {
            name: "b2_poisson_chaos",
            mode: Mode::Process { nodes: 3, kill_leader: true },
            agents: 2,
            requests_per_agent: n(32, 8),
            offered: Offered::PoissonAtCapacity(0.8),
            seed: 66,
            slo: Duration::from_millis(500),
            queue_depth: Some(32),
            deterministic: false,
            warmup: 0.1,
        },
    ]
}

/// Where the harness finds the binaries it spawns.
#[derive(Debug, Clone)]
pub struct HarnessOpts {
    /// Path to `flexpie-load` (agents are `flexpie-load agent …`).
    pub load_bin: String,
    /// Path to `flexpie-node` (daemons for the process suites).
    pub node_bin: String,
    /// Smoke-scale request counts (`FLEXPIE_BENCH_FAST`).
    pub fast: bool,
    /// When set, each suite writes its merged span trees
    /// (`trace_<suite>.json`) and unified counter snapshot
    /// (`metrics_<suite>.json`) into this directory — the CI artifacts
    /// `tools/check_trace.py` gates on.
    pub artifact_dir: Option<String>,
}

impl HarnessOpts {
    /// Resolve sibling binaries of the current executable — how the
    /// `flexpie-load suite` CLI finds them without env-var plumbing.
    pub fn siblings_of_current_exe() -> Result<HarnessOpts, String> {
        let me = std::env::current_exe().map_err(|e| format!("current_exe: {e}"))?;
        let dir = me.parent().ok_or("current_exe has no parent dir")?;
        let sibling = |name: &str| dir.join(name).to_string_lossy().into_owned();
        Ok(HarnessOpts {
            load_bin: me.to_string_lossy().into_owned(),
            node_bin: sibling("flexpie-node"),
            fast: std::env::var("FLEXPIE_BENCH_FAST").is_ok(),
            artifact_dir: None,
        })
    }
}

/// The merged, gated result of one suite run.
#[derive(Debug, Clone)]
pub struct SuiteReport {
    pub suite: String,
    pub mode: String,
    pub agents: u32,
    pub sent: u64,
    pub ok: u64,
    pub shed: u64,
    pub failed: u64,
    pub mismatches: u64,
    pub slo_ms: f64,
    /// Requests that got a reply within the SLO.
    pub slo_ok: u64,
    /// `1 − slo_ok/sent`: shed and failed requests count as violations.
    pub slo_violation_frac: f64,
    /// Total offered rate implied by the generated schedules.
    pub offered_rps: f64,
    /// Served requests per second of the slowest agent's span.
    pub goodput_rps: f64,
    pub p50_us: f64,
    pub p90_us: f64,
    pub p99_us: f64,
    pub p999_us: f64,
    pub mean_us: f64,
    pub max_us: f64,
    /// Merged across every agent process — exact, order-independent.
    pub hist: Histogram,
    /// Warm-up fraction the agents trimmed, and how many replies the trim
    /// removed from the histogram/SLO population (they still count in `ok`).
    pub warmup: f64,
    pub trimmed: u64,
    /// Per-request latency decomposition from the server's merged span
    /// trees: where each request's time went. Histogram units are
    /// nanoseconds, same as `hist`.
    pub queue_hist: Histogram,
    pub service_hist: Histogram,
    pub wire_hist: Histogram,
    /// Span trees merged from the server's flight recorder, and how many
    /// passed the merger's nesting + conservation checks.
    pub traces: u64,
    pub trace_well_formed: u64,
    pub queue_peak: usize,
    pub queue_wait_max_us: f64,
    /// Process mode: reinstall-and-retry rounds after a member death.
    pub failovers: u64,
    /// Process mode: total request re-executions on the replay path.
    pub replays: u64,
    /// Peak agent RSS / summed agent CPU over the run.
    pub agent_rss_peak: u64,
    pub agent_cpu_ms: u64,
    /// Peak daemon RSS / summed daemon CPU (0 for in-process suites).
    pub daemon_rss_peak: u64,
    pub daemon_cpu_ms: u64,
    /// Orchestrator (server + front door live here) CPU over the run.
    pub self_cpu_ms: u64,
    pub wall_s: f64,
}

impl SuiteReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("suite", Json::Str(self.suite.clone())),
            ("mode", Json::Str(self.mode.clone())),
            ("agents", Json::Num(self.agents as f64)),
            ("sent", Json::Num(self.sent as f64)),
            ("ok", Json::Num(self.ok as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("failed", Json::Num(self.failed as f64)),
            ("mismatches", Json::Num(self.mismatches as f64)),
            ("slo_ms", Json::Num(self.slo_ms)),
            ("slo_ok", Json::Num(self.slo_ok as f64)),
            ("slo_violation_frac", Json::Num(self.slo_violation_frac)),
            ("offered_rps", Json::Num(self.offered_rps)),
            ("goodput_rps", Json::Num(self.goodput_rps)),
            ("p50_us", Json::Num(self.p50_us)),
            ("p90_us", Json::Num(self.p90_us)),
            ("p99_us", Json::Num(self.p99_us)),
            ("p999_us", Json::Num(self.p999_us)),
            ("mean_us", Json::Num(self.mean_us)),
            ("max_us", Json::Num(self.max_us)),
            ("warmup", Json::Num(self.warmup)),
            ("trimmed", Json::Num(self.trimmed as f64)),
            ("queue_p50_us", Json::Num(self.queue_hist.percentile(0.50) as f64 / 1e3)),
            ("queue_p99_us", Json::Num(self.queue_hist.percentile(0.99) as f64 / 1e3)),
            ("service_p50_us", Json::Num(self.service_hist.percentile(0.50) as f64 / 1e3)),
            ("service_p99_us", Json::Num(self.service_hist.percentile(0.99) as f64 / 1e3)),
            ("wire_p50_us", Json::Num(self.wire_hist.percentile(0.50) as f64 / 1e3)),
            ("wire_p99_us", Json::Num(self.wire_hist.percentile(0.99) as f64 / 1e3)),
            ("traces", Json::Num(self.traces as f64)),
            ("trace_well_formed", Json::Num(self.trace_well_formed as f64)),
            ("queue_peak", Json::Num(self.queue_peak as f64)),
            ("queue_wait_max_us", Json::Num(self.queue_wait_max_us)),
            ("failovers", Json::Num(self.failovers as f64)),
            ("replays", Json::Num(self.replays as f64)),
            ("agent_rss_peak", Json::Num(self.agent_rss_peak as f64)),
            ("agent_cpu_ms", Json::Num(self.agent_cpu_ms as f64)),
            ("daemon_rss_peak", Json::Num(self.daemon_rss_peak as f64)),
            ("daemon_cpu_ms", Json::Num(self.daemon_cpu_ms as f64)),
            ("self_cpu_ms", Json::Num(self.self_cpu_ms as f64)),
            ("wall_s", Json::Num(self.wall_s)),
        ])
    }
}

/// Assemble suite reports into the committed bench-trajectory artifact.
pub fn assemble(reports: &[SuiteReport]) -> Json {
    Json::obj(vec![
        ("bench", Json::Str("load_harness".into())),
        ("pr", Json::Num(9.0)),
        ("suites", Json::Arr(reports.iter().map(SuiteReport::to_json).collect())),
    ])
}

// ---------------------------------------------------------------------------
// child processes
// ---------------------------------------------------------------------------

/// A child process SIGKILLed (and reaped) on drop.
struct Proc {
    child: Child,
}

impl Proc {
    fn sigkill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }

    fn pid(&self) -> u32 {
        self.child.id()
    }
}

impl Drop for Proc {
    fn drop(&mut self) {
        self.sigkill();
    }
}

/// Spawn a `flexpie-node` daemon and wait for its `READY` banner.
fn spawn_daemon(node_bin: &str, node: u32, registry: &str) -> Result<Proc, String> {
    let mut child = Command::new(node_bin)
        .args(["--node", &node.to_string(), "--registry", registry])
        .stdout(Stdio::piped())
        .spawn()
        .map_err(|e| format!("spawn {node_bin}: {e}"))?;
    let mut out = BufReader::new(child.stdout.take().expect("stdout piped"));
    let mut line = String::new();
    out.read_line(&mut line).map_err(|e| format!("daemon {node} banner: {e}"))?;
    if !line.starts_with("READY ") {
        let _ = child.kill();
        return Err(format!("daemon {node}: unexpected banner {line:?}"));
    }
    Ok(Proc { child })
}

/// Spawn one `flexpie-load agent` process against `addr`.
fn spawn_agent(
    opts: &HarnessOpts,
    spec: &SuiteSpec,
    arrival: &ArrivalProcess,
    id: u32,
    addr: &str,
) -> Result<Child, String> {
    let mut cmd = Command::new(&opts.load_bin);
    cmd.arg("agent")
        .args(["--id", &id.to_string()])
        .args(["--addr", addr])
        .args(["--requests", &spec.requests_per_agent.to_string()])
        .args(["--seed", &(spec.seed + id as u64).to_string()])
        .args(["--input-seed", &spec.input_seed().to_string()])
        .args(["--slo-ms", &format!("{}", spec.slo.as_secs_f64() * 1e3)])
        .args(["--warmup", &spec.warmup.to_string()])
        .args(arrival.to_cli())
        .stdout(Stdio::piped());
    cmd.spawn().map_err(|e| format!("spawn {}: {e}", opts.load_bin))
}

/// Collect an agent's single `AGENT` report line and reap the process.
fn reap_agent(suite: &str, id: u32, mut child: Child) -> Result<AgentReport, String> {
    let out = BufReader::new(child.stdout.take().expect("stdout piped"));
    let mut report = None;
    for line in out.lines() {
        let line = line.map_err(|e| format!("{suite}: agent {id} stdout: {e}"))?;
        if let Some(parsed) = AgentReport::parse_line(&line) {
            report = Some(parsed.map_err(|e| format!("{suite}: agent {id}: {e}"))?);
        }
    }
    let status = child.wait().map_err(|e| format!("{suite}: agent {id} wait: {e}"))?;
    if !status.success() {
        return Err(format!("{suite}: agent {id} exited with {status}"));
    }
    report.ok_or_else(|| format!("{suite}: agent {id} never printed its report"))
}

// ---------------------------------------------------------------------------
// suite runner
// ---------------------------------------------------------------------------

/// Sequential closed-loop capacity probe through the front door: the mean
/// service latency of a lone client, inverted into requests/second.
fn probe_capacity_rps(addr: &str, spec: &SuiteSpec, fast: bool) -> Result<(f64, u64), String> {
    let warmup = 2usize;
    let probes = if fast { 6 } else { 16 };
    let mut stream =
        tcp::connect_retry(addr, Duration::from_secs(5)).map_err(|e| format!("probe: {e}"))?;
    let input = workload::input(0, spec.input_seed(), 4);
    let mut total = Duration::ZERO;
    for k in 0..(warmup + probes) as u64 {
        let t = Instant::now();
        let msg = WireMsg::Submit { seq: k, input: input.clone() };
        let frame = Frame { node: u32::MAX, term: 0, msg };
        tcp::send_frame(&mut stream, &frame).map_err(|e| format!("probe send: {e}"))?;
        match tcp::read_frame(&mut stream).map_err(|e| format!("probe read: {e}"))?.msg {
            WireMsg::Reply { .. } => {}
            other => return Err(format!("probe: unexpected kind {}", other.kind())),
        }
        if k as usize >= warmup {
            total += t.elapsed();
        }
    }
    let mean = total.as_secs_f64() / probes as f64;
    Ok((1.0 / mean.max(1e-6), (warmup + probes) as u64))
}

/// The server and its supporting cast for one suite.
struct Stack {
    server: Option<Server>,
    door: Option<FrontDoor>,
    // Process mode: registry + daemons, in shutdown order.
    _registry: Option<RegistryServer>,
    daemons: Vec<Proc>,
    daemon_base: Vec<(u32, Option<ProcUsage>)>,
}

fn build_stack(spec: &SuiteSpec, opts: &HarnessOpts) -> Result<Stack, String> {
    let model = workload::model();
    let weights = WeightStore::for_model(&model, workload::WEIGHT_SEED);
    let cfg = ServeConfig {
        max_batch: 8,
        batch_window: Duration::from_millis(1),
        queue_depth: spec.queue_depth.unwrap_or(spec.total() + spec.agents as usize),
        pipeline_depth: match spec.mode {
            Mode::InProc { pipeline_depth } => pipeline_depth,
            Mode::Process { .. } => 1,
        },
        replay_budget: 4,
        ..ServeConfig::default()
    };
    let (server, registry, daemons, daemon_base) = match spec.mode {
        Mode::InProc { .. } => {
            let server = Server::start_telemetry(
                model,
                weights,
                Testbed::new(4, Topology::Ring, Bandwidth::gbps(5.0)),
                ConditionTrace::stable(4),
                TelemetryConfig::default(),
                cfg,
                ElasticConfig::default(),
            );
            (server, None, Vec::new(), Vec::new())
        }
        Mode::Process { nodes, .. } => {
            let reg = RegistryServer::spawn("tcp:127.0.0.1:0", Duration::from_millis(600))
                .map_err(|e| format!("{}: registry bind: {e}", spec.name))?;
            let daemons: Vec<Proc> = (0..nodes as u32)
                .map(|id| spawn_daemon(&opts.node_bin, id, reg.addr()))
                .collect::<Result<_, _>>()?;
            let base = daemons
                .iter()
                .map(|p| (p.pid(), procfs::usage_of(p.pid())))
                .collect();
            let mut pc = ProcessCluster::connect(reg.addr(), nodes, Duration::from_secs(30))
                .map_err(|e| format!("{}: cluster bring-up: {e:?}", spec.name))?;
            pc.infer_deadline = Duration::from_secs(10);
            let plan = Plan::uniform(Scheme::InH, model.n_layers());
            pc.install(&model, &plan, workload::WEIGHT_SEED)
                .map_err(|e| format!("{}: plan install: {e:?}", spec.name))?;
            (Server::start_process(pc, cfg), Some(reg), daemons, base)
        }
    };
    let door = FrontDoor::start(server.handle(), "tcp:127.0.0.1:0")
        .map_err(|e| format!("{}: front door bind: {e}", spec.name))?;
    Ok(Stack {
        server: Some(server),
        door: Some(door),
        _registry: registry,
        daemons,
        daemon_base,
    })
}

/// Run one suite end to end: build the stack, resolve the offered load,
/// fan the agents in, merge their reports, apply the structural gates.
pub fn run_suite(spec: &SuiteSpec, opts: &HarnessOpts) -> Result<SuiteReport, String> {
    let self0 = procfs::self_usage();
    let wall0 = Instant::now();
    let mut stack = build_stack(spec, opts)?;
    let addr = stack.door.as_ref().unwrap().addr().to_string();

    // Resolve the offered load — B-suites scale to measured capacity.
    let (arrival, probed) = match &spec.offered {
        Offered::Fixed(p) => (p.clone(), 0u64),
        Offered::PoissonAtCapacity(frac) => {
            let (cap, probed) = probe_capacity_rps(&addr, spec, opts.fast)?;
            (
                ArrivalProcess::Poisson { rate_hz: (frac * cap / spec.agents as f64).max(1.0) },
                probed,
            )
        }
    };

    // The longest agent schedule, regenerated here from the same specs the
    // agents will use — the harness knows the traffic before it starts.
    let span_ns = (0..spec.agents)
        .map(|i| {
            let s = ScheduleSpec {
                process: arrival.clone(),
                requests: spec.requests_per_agent,
                seed: spec.seed + i as u64,
            };
            s.generate().offsets_ns.last().copied().unwrap_or(0)
        })
        .max()
        .unwrap_or(0);
    let offered_rps = if span_ns == 0 {
        0.0
    } else {
        spec.total() as f64 / (span_ns as f64 / 1e9)
    };

    // B2: SIGKILL the leader daemon ~40% into the schedule span. The kill
    // point is seeded (a pure function of the schedule); the wall-clock
    // alignment is best-effort, as any real chaos is.
    let killer = match spec.mode {
        Mode::Process { kill_leader: true, .. } => {
            let mut leader = stack.daemons.remove(0);
            let delay = Duration::from_millis(300) + Duration::from_nanos(span_ns * 2 / 5);
            Some(std::thread::spawn(move || {
                std::thread::sleep(delay);
                leader.sigkill();
            }))
        }
        _ => None,
    };

    let children: Vec<Child> = (0..spec.agents)
        .map(|i| spawn_agent(opts, spec, &arrival, i, &addr))
        .collect::<Result<_, _>>()?;
    let reports: Vec<AgentReport> = children
        .into_iter()
        .enumerate()
        .map(|(i, c)| reap_agent(spec.name, i as u32, c))
        .collect::<Result<_, _>>()?;
    if let Some(k) = killer {
        let _ = k.join();
    }

    // Daemon usage deltas before teardown (the killed leader reads None).
    let (mut daemon_rss_peak, mut daemon_cpu_ms) = (0u64, 0u64);
    for (pid, base) in &stack.daemon_base {
        if let (Some(now), Some(base)) = (procfs::usage_of(*pid), base) {
            let d = now.since(base);
            daemon_rss_peak = daemon_rss_peak.max(d.rss_bytes);
            daemon_cpu_ms += d.cpu_ms;
        }
    }

    // Teardown order is load-bearing: the front door must release its
    // ServerHandle clones before shutdown() can drain the router. The
    // flight recorder outlives the server (Arc) so the span trees can be
    // merged after the router joined — every span is final by then.
    stack.door.take().unwrap().stop();
    let recorder = Arc::clone(stack.server.as_ref().unwrap().recorder());
    let stats: RouterStats = stack.server.take().unwrap().shutdown();
    drop(stack);
    let trees = merge_spans(&recorder.snapshot());

    let mut report = merge_reports(spec, &reports, &stats, offered_rps)?;
    report.traces = trees.len() as u64;
    for t in &trees {
        if t.well_formed {
            report.trace_well_formed += 1;
        }
        report.queue_hist.record(t.queue_ns);
        report.service_hist.record(t.service_ns);
        if t.wire_ns > 0 {
            report.wire_hist.record(t.wire_ns);
        }
    }
    let self_cpu_ms = match (self0, procfs::self_usage()) {
        (Some(a), Some(b)) => b.since(&a).cpu_ms,
        _ => 0,
    };
    let report = SuiteReport {
        self_cpu_ms,
        wall_s: wall0.elapsed().as_secs_f64(),
        daemon_rss_peak,
        daemon_cpu_ms,
        ..report
    };
    if let Some(dir) = &opts.artifact_dir {
        write_artifacts(dir, spec, &trees, &report, &stats)?;
    }
    gate(spec, &report, &stats, probed)?;
    Ok(report)
}

/// Write the per-suite trace and metrics artifacts `tools/check_trace.py`
/// gates on: the merged span trees and a flat named-counter snapshot.
fn write_artifacts(
    dir: &str,
    spec: &SuiteSpec,
    trees: &[crate::trace::TraceTree],
    r: &SuiteReport,
    stats: &RouterStats,
) -> Result<(), String> {
    std::fs::create_dir_all(dir).map_err(|e| format!("{}: mkdir {dir}: {e}", spec.name))?;
    let trace_json = Json::obj(vec![
        ("suite", Json::Str(spec.name.into())),
        ("mode", Json::Str(r.mode.clone())),
        ("warmup", Json::Num(spec.warmup)),
        ("trees", Json::Arr(trees.iter().map(crate::trace::TraceTree::to_json).collect())),
    ]);
    let tpath = format!("{dir}/trace_{}.json", spec.name);
    trace_json
        .save(std::path::Path::new(&tpath))
        .map_err(|e| format!("{}: write {tpath}: {e}", spec.name))?;

    let mut reg = Registry::new();
    reg.set("router.requests", stats.requests);
    reg.set("router.queue_peak", stats.queue_peak as u64);
    reg.set("router.shed.queue_full", stats.shed_queue_full);
    reg.set("router.shed.stopped", stats.shed_stopped);
    reg.set("router.shed.failed", stats.shed_failed);
    reg.set("router.failovers", stats.process_failovers);
    reg.set("router.replays", stats.replay_attempts);
    reg.set("trace.traces", r.traces);
    reg.set("trace.well_formed", r.trace_well_formed);
    reg.set("agents.sent", r.sent);
    reg.set("agents.ok", r.ok);
    reg.set("agents.shed", r.shed);
    reg.set("agents.failed", r.failed);
    reg.set("agents.trimmed", r.trimmed);
    reg.set("agents.rss_peak_bytes", r.agent_rss_peak);
    reg.set("agents.cpu_ms", r.agent_cpu_ms);
    reg.set("daemons.rss_peak_bytes", r.daemon_rss_peak);
    reg.set("daemons.cpu_ms", r.daemon_cpu_ms);
    if let Some(ts) = &stats.trace {
        reg.set("trace.queue_ns_sum", ts.queue_ns_sum);
        reg.set("trace.service_ns_sum", ts.service_ns_sum);
        reg.set("trace.wire_ns_sum", ts.wire_ns_sum);
        reg.set("trace.total_ns_sum", ts.total_ns_sum);
    }
    let mpath = format!("{dir}/metrics_{}.json", spec.name);
    std::fs::write(&mpath, reg.to_json())
        .map_err(|e| format!("{}: write {mpath}: {e}", spec.name))?;
    Ok(())
}

/// Merge per-agent reports into one suite report (histograms bucket-wise —
/// exact and order-independent — counters summed).
fn merge_reports(
    spec: &SuiteSpec,
    reports: &[AgentReport],
    stats: &RouterStats,
    offered_rps: f64,
) -> Result<SuiteReport, String> {
    let mut hist = Histogram::new();
    let (mut sent, mut ok, mut shed, mut failed) = (0u64, 0u64, 0u64, 0u64);
    let (mut mismatches, mut slo_ok, mut trimmed) = (0u64, 0u64, 0u64);
    let (mut agent_rss_peak, mut agent_cpu_ms) = (0u64, 0u64);
    let mut span = Duration::ZERO;
    for r in reports {
        if r.ok + r.shed + r.failed != r.sent {
            return Err(format!(
                "{}: agent {} accounting broken: {} + {} + {} != {}",
                spec.name, r.id, r.ok, r.shed, r.failed, r.sent
            ));
        }
        hist.merge(&r.hist);
        sent += r.sent;
        ok += r.ok;
        shed += r.shed;
        failed += r.failed;
        mismatches += r.mismatches;
        slo_ok += r.slo_ok;
        trimmed += r.trimmed;
        span = span.max(r.span);
        if let Some(u) = &r.usage {
            agent_rss_peak = agent_rss_peak.max(u.rss_bytes);
            agent_cpu_ms += u.cpu_ms;
        }
    }
    let p = |q: f64| hist.percentile(q) as f64 / 1e3;
    // warm-up replies were never judged against the SLO, so they leave the
    // violation denominator too (shed/failed still count as violations)
    let judged = sent.saturating_sub(trimmed);
    Ok(SuiteReport {
        suite: spec.name.into(),
        mode: match spec.mode {
            Mode::InProc { .. } => "inproc".into(),
            Mode::Process { .. } => "process".into(),
        },
        agents: spec.agents,
        sent,
        ok,
        shed,
        failed,
        mismatches,
        slo_ms: spec.slo.as_secs_f64() * 1e3,
        slo_ok,
        slo_violation_frac: if judged == 0 { 0.0 } else { 1.0 - slo_ok as f64 / judged as f64 },
        offered_rps,
        goodput_rps: if span.is_zero() { 0.0 } else { ok as f64 / span.as_secs_f64() },
        p50_us: p(0.50),
        p90_us: p(0.90),
        p99_us: p(0.99),
        p999_us: p(0.999),
        mean_us: hist.mean() / 1e3,
        max_us: hist.max() as f64 / 1e3,
        hist,
        warmup: spec.warmup,
        trimmed,
        queue_hist: Histogram::new(),
        service_hist: Histogram::new(),
        wire_hist: Histogram::new(),
        traces: 0,
        trace_well_formed: 0,
        queue_peak: stats.queue_peak,
        queue_wait_max_us: stats.queue_wait_max.as_secs_f64() * 1e6,
        failovers: stats.process_failovers,
        replays: stats.replay_attempts,
        agent_rss_peak,
        agent_cpu_ms,
        daemon_rss_peak: 0,
        daemon_cpu_ms: 0,
        self_cpu_ms: 0,
        wall_s: 0.0,
    })
}

/// The structural gates: what CI fails on. Latency magnitudes are never
/// gated; counts, conservation, bit-exactness and shape are.
fn gate(spec: &SuiteSpec, r: &SuiteReport, stats: &RouterStats, probed: u64) -> Result<(), String> {
    let check = |cond: bool, msg: String| if cond { Ok(()) } else { Err(msg) };
    check(
        r.sent == spec.total() as u64,
        format!("{}: sent {} != scheduled {}", spec.name, r.sent, spec.total()),
    )?;
    check(
        r.mismatches == 0,
        format!("{}: {} replies diverged from the reference", spec.name, r.mismatches),
    )?;
    // every admitted request is either a reply the agents saw, a probe
    // roundtrip, or an explicit post-admission failure — no silent drops
    check(
        stats.requests == r.ok + r.failed + probed,
        format!(
            "{}: router pulled {} requests but agents saw ok={} failed={} (+{probed} probes)",
            spec.name, stats.requests, r.ok, r.failed
        ),
    )?;
    let ps = [r.p50_us, r.p90_us, r.p99_us, r.p999_us];
    check(
        ps.windows(2).all(|w| w[0] <= w[1]),
        format!("{}: percentiles not monotone: {ps:?}", spec.name),
    )?;
    // per-reason shed conservation: the server's FrontDoor counters must
    // equal what the agents observed on the wire, reason by reason
    // (agents fold reasons 0 and 1 into `shed`, reason 2 is `failed`)
    check(
        stats.shed_queue_full + stats.shed_stopped == r.shed,
        format!(
            "{}: server shed {}+{} != agents' observed shed {}",
            spec.name, stats.shed_queue_full, stats.shed_stopped, r.shed
        ),
    )?;
    check(
        stats.shed_failed == r.failed,
        format!(
            "{}: server failed counter {} != agents' observed failed {}",
            spec.name, stats.shed_failed, r.failed
        ),
    )?;
    if spec.deterministic {
        check(
            r.ok == r.sent && r.shed == 0 && r.failed == 0,
            format!(
                "{}: deterministic suite shed/failed traffic: ok={} shed={} failed={} sent={}",
                spec.name, r.ok, r.shed, r.failed, r.sent
            ),
        )?;
        // every within-SLO reply is part of the recorded population, and
        // warm-up trimming removes replies from the histogram only — the
        // recorded + trimmed populations must still cover every reply
        check(
            r.slo_ok <= r.hist.count() && r.hist.count() + r.trimmed == r.ok,
            format!(
                "{}: histogram population {} (+{} trimmed) inconsistent with ok={} slo_ok={}",
                spec.name,
                r.hist.count(),
                r.trimmed,
                r.ok,
                r.slo_ok
            ),
        )?;
    }
    if let Mode::Process { kill_leader: true, .. } = spec.mode {
        check(
            r.failovers >= 1,
            format!("{}: leader SIGKILL never forced a failover", spec.name),
        )?;
        check(r.replays >= 1, format!("{}: no request rode the replay path", spec.name))?;
    }
    // Tracing is always on: a run that merged no span trees means the span
    // path regressed, not that tracing was "off".
    check(r.traces >= 1, format!("{}: no span trees recorded", spec.name))?;
    check(
        r.trace_well_formed <= r.traces,
        format!(
            "{}: well-formed {} exceeds trees {}",
            spec.name, r.trace_well_formed, r.traces
        ),
    )?;
    if spec.deterministic {
        // no chaos and no replays: every tree must pass the merger's
        // nesting + decomposition-conservation checks
        check(
            r.trace_well_formed == r.traces,
            format!(
                "{}: {} of {} span trees failed nesting/conservation",
                spec.name,
                r.traces - r.trace_well_formed,
                r.traces
            ),
        )?;
    }
    if let Mode::Process { .. } = spec.mode {
        check(
            r.wire_hist.count() >= 1,
            format!("{}: process mode recorded no wire spans", spec.name),
        )?;
    }
    Ok(())
}

/// Run every suite in order; stop at the first structural failure.
pub fn run_all(opts: &HarnessOpts) -> Result<Vec<SuiteReport>, String> {
    suites(opts.fast).iter().map(|s| run_suite(s, opts)).collect()
}
