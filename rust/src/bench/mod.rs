//! Paper-figure reproduction harness.
//!
//! One generator per table/figure in the paper's evaluation (§4); the
//! `rust/benches/*` targets and the `flexpie bench` CLI both call these.
//! All results are also dumped as JSON under `bench_results/` so
//! EXPERIMENTS.md entries are regenerable.
//!
//! | generator | paper artifact |
//! |---|---|
//! | [`fig2`] | Fig 2 — micro-bench: MobileNet L2/L5/L13 × schemes × {4,3}-node |
//! | [`fig7_9`] | Fig 7 (4-node) / Fig 9 (3-node) — 4 models × 6 solutions × bandwidths × topologies |
//! | [`fig8`] | Fig 8 — performance score per solution |
//! | [`search_time`] | §4 "DPP search time cost" + pruning ablation |
//! | [`ablation`] | design ablations: CE-vs-oracle regret, fusion-off, scheme-set restrictions |

pub mod harness;

use std::sync::Arc;

use crate::baselines::Solution;
use crate::cost::estimator::Estimators;
use crate::cost::gbdt::GbdtParams;
use crate::cost::tracegen::TraceConfig;
use crate::cost::CostSource;
use crate::engine;
use crate::model::{zoo, Model};
use crate::net::{Bandwidth, Testbed, Topology};
use crate::partition::{Plan, Scheme};
use crate::planner::{Dpp, DppConfig};
use crate::util::bench::Table;
use crate::util::json::Json;

/// Which cost source the *planners* consult (evaluation is always the
/// analytic simulator — that is the measured ground truth).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CostKind {
    /// Plan with the exact simulator costs (oracle CE).
    Analytic,
    /// Plan with the trained GBDT estimators (the paper's CE).
    Gbdt,
}

/// Bench options shared by all generators.
#[derive(Debug, Clone)]
pub struct BenchOpts {
    pub cost: CostKind,
    /// Truncate models to at most this many layers (0 = full models). Used
    /// by `FLEXPIE_BENCH_FAST` smoke runs.
    pub truncate: usize,
    /// Where trained estimators are cached.
    pub ce_dir: std::path::PathBuf,
    /// Trace samples when the CE must be trained from scratch.
    pub ce_samples: usize,
    /// Where JSON results are written (empty = skip).
    pub out_dir: std::path::PathBuf,
}

impl Default for BenchOpts {
    fn default() -> Self {
        let fast = std::env::var("FLEXPIE_BENCH_FAST").is_ok();
        BenchOpts {
            cost: CostKind::Gbdt,
            truncate: if fast { 12 } else { 0 },
            ce_dir: "artifacts/ce".into(),
            ce_samples: if fast { 4_000 } else { 20_000 },
            out_dir: "bench_results".into(),
        }
    }
}

impl BenchOpts {
    pub fn fast_analytic() -> BenchOpts {
        BenchOpts { cost: CostKind::Analytic, ..Default::default() }
    }

    fn model(&self, name: &str) -> Model {
        let m = zoo::by_name(name).unwrap_or_else(|| panic!("unknown model {name}"));
        if self.truncate > 0 && m.n_layers() > self.truncate {
            m.truncated(self.truncate)
        } else {
            m
        }
    }

    /// The planner-facing cost source for a testbed.
    pub fn cost_source(&self, tb: &Testbed) -> CostSource {
        match self.cost {
            CostKind::Analytic => CostSource::analytic(tb),
            CostKind::Gbdt => {
                let est = self.estimators();
                CostSource::gbdt(est, tb)
            }
        }
    }

    /// Load-or-train the estimator pair (cached on disk and in-process).
    pub fn estimators(&self) -> Arc<Estimators> {
        use std::sync::OnceLock;
        static CACHE: OnceLock<Arc<Estimators>> = OnceLock::new();
        CACHE
            .get_or_init(|| {
                let cfg = TraceConfig { samples: self.ce_samples, ..Default::default() };
                let params = GbdtParams { n_trees: 200, ..Default::default() };
                let (est, report) = Estimators::load_or_train(&self.ce_dir, &cfg, &params)
                    .expect("estimator training");
                if let Some(r) = report {
                    eprintln!(
                        "[flexpie] trained CE: i-Est r2={:.3} ρ={:.3}; s-Est r2={:.3} ρ={:.3}",
                        r.i_fit.r2, r.i_fit.spearman, r.s_fit.r2, r.s_fit.spearman
                    );
                }
                est
            })
            .clone()
    }

    fn save_json(&self, name: &str, v: &Json) {
        if self.out_dir.as_os_str().is_empty() {
            return;
        }
        let path = self.out_dir.join(name);
        if let Err(e) = v.save(&path) {
            eprintln!("[flexpie] warning: could not save {}: {e}", path.display());
        }
    }
}

// ---------------------------------------------------------------------------
// Fig 2 — micro-bench
// ---------------------------------------------------------------------------

/// One Fig-2 bar: per-layer inference time (compute + same-scheme halo sync)
/// for a single MobileNet layer under a fixed scheme.
#[derive(Debug, Clone)]
pub struct Fig2Row {
    pub group: String,
    pub scheme: Scheme,
    pub time_us: f64,
}

/// Reproduce Fig 2: MobileNet-stage layers L2/L5/L13 × {InH/InW, OutC,
/// 2D-grid} × {4-node, 3-node} at 5 Gb/s (SRIO-class), Ring.
///
/// The measured quantity is the *steady-state per-layer inference time* as
/// deployed in the engine: the boundary synchronization that delivers the
/// layer's input from a producer partitioned under the same scheme, plus the
/// (bottleneck-node) layer compute. This is what makes the schemes differ —
/// OutC pays the input all-gather but computes perfectly balanced; spatial
/// schemes pay only halos but inherit the integer-split imbalance
/// (4,4,3,3 rows at 14×14 on 4 nodes; a double-loaded node on 3-node grids).
pub fn fig2(opts: &BenchOpts) -> Vec<Fig2Row> {
    use crate::cost::query::{boundary_query, compute_query_tiles};
    use crate::model::{ConvType, LayerMeta};
    use crate::partition::geometry::out_tiles;
    use crate::partition::inflate::BlockGeometry;

    // 3×3 standard convolutions at the paper's L2/L5/L13 feature-map shapes.
    let layers: [(&str, LayerMeta); 3] = [
        ("L2", LayerMeta::conv("l2", ConvType::Standard, 112, 112, 32, 32, 3, 1, 1)),
        ("L5", LayerMeta::conv("l5", ConvType::Standard, 56, 56, 128, 128, 3, 1, 1)),
        ("L13", LayerMeta::conv("l13", ConvType::Standard, 14, 14, 512, 512, 3, 1, 1)),
    ];
    let mut rows = Vec::new();
    for nodes in [4usize, 3] {
        let tb = Testbed::new(nodes, Topology::Ring, Bandwidth::gbps(5.0));
        let cost = CostSource::analytic(&tb);
        for (label, layer) in &layers {
            // producer: an identically-shaped layer under the same scheme
            let producer = LayerMeta::conv(
                "prod",
                ConvType::Standard,
                layer.in_h,
                layer.in_w,
                layer.in_c,
                layer.in_c,
                3,
                1,
                1,
            );
            for scheme in [Scheme::InH, Scheme::OutC, Scheme::Grid2d] {
                let geo = BlockGeometry::new(std::slice::from_ref(layer), scheme, nodes);
                let bq =
                    boundary_query(&producer, scheme, layer, scheme, &geo.entry_need, &tb);
                let tiles = out_tiles(layer, scheme, nodes);
                let cq = compute_query_tiles(layer, &tiles, scheme, &tb);
                let time = cost.sync_time(&bq) + cost.compute_time(&cq);
                rows.push(Fig2Row {
                    group: format!("{nodes}-Node-{label}"),
                    scheme,
                    time_us: time * 1e6,
                });
            }
        }
    }
    let json = Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("group", Json::Str(r.group.clone())),
                    ("scheme", Json::Str(r.scheme.name().into())),
                    ("time_us", Json::Num(r.time_us)),
                ])
            })
            .collect(),
    );
    opts.save_json("fig2.json", &json);
    rows
}

/// Render Fig 2 as a table.
pub fn fig2_table(rows: &[Fig2Row]) -> Table {
    let mut t = Table::new(["group", "InH/InW", "OutC", "2D-grid", "best"]);
    let mut groups: Vec<String> = Vec::new();
    for r in rows {
        if !groups.contains(&r.group) {
            groups.push(r.group.clone());
        }
    }
    for g in groups {
        let find = |s: Scheme| {
            rows.iter().find(|r| r.group == g && r.scheme == s).map(|r| r.time_us).unwrap()
        };
        let (h, o, g2) = (find(Scheme::InH), find(Scheme::OutC), find(Scheme::Grid2d));
        let best = if h <= o && h <= g2 {
            "InH/InW"
        } else if o <= g2 {
            "OutC"
        } else {
            "2D-grid"
        };
        t.row([
            g,
            format!("{h:.1} µs"),
            format!("{o:.1} µs"),
            format!("{g2:.1} µs"),
            best.into(),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// Fig 7 / Fig 9 — end-to-end comparison
// ---------------------------------------------------------------------------

/// One cell of Fig 7/9: a (model, testbed, solution) inference time.
#[derive(Debug, Clone)]
pub struct Cell {
    pub model: String,
    pub nodes: usize,
    pub topology: Topology,
    pub bw_gbps: f64,
    pub solution: Solution,
    pub time_ms: f64,
    pub plan: Plan,
}

/// Reproduce Fig 7 (nodes = 4) or Fig 9 (nodes = 3): every model × testbed
/// (bandwidth × topology) × solution. Plans are produced with `opts.cost`;
/// every plan is *evaluated* on the analytic simulator.
pub fn fig7_9(nodes: usize, opts: &BenchOpts) -> Vec<Cell> {
    let grid = crate::config::ExperimentGrid::paper();
    let mut cells = Vec::new();
    for model_name in &grid.models {
        let model = opts.model(model_name);
        for &topology in &grid.topologies {
            for &bw in &grid.bandwidths_gbps {
                let tb = Testbed::new(nodes, topology, Bandwidth::gbps(bw));
                let plan_cost_src = opts.cost_source(&tb);
                for solution in Solution::ALL {
                    let plan = solution.plan(&model, &plan_cost_src);
                    let report = engine::evaluate(&model, &plan, &tb);
                    cells.push(Cell {
                        model: model_name.clone(),
                        nodes,
                        topology,
                        bw_gbps: bw,
                        solution,
                        time_ms: report.total_ms(),
                        plan,
                    });
                }
            }
        }
    }
    let json = Json::Arr(cells.iter().map(cell_json).collect());
    opts.save_json(&format!("fig{}.json", if nodes == 4 { 7 } else { 9 }), &json);
    cells
}

fn cell_json(c: &Cell) -> Json {
    Json::obj(vec![
        ("model", Json::Str(c.model.clone())),
        ("nodes", Json::Num(c.nodes as f64)),
        ("topology", Json::Str(c.topology.name().into())),
        ("bw_gbps", Json::Num(c.bw_gbps)),
        ("solution", Json::Str(c.solution.name().into())),
        ("time_ms", Json::Num(c.time_ms)),
        ("plan", Json::Str(c.plan.render())),
    ])
}

/// Render Fig 7/9 cells as one table per (topology, bandwidth).
pub fn fig7_9_tables(cells: &[Cell]) -> Vec<(String, Table)> {
    let mut keys: Vec<(Topology, f64)> = Vec::new();
    for c in cells {
        if !keys.iter().any(|&(t, b)| t == c.topology && b == c.bw_gbps) {
            keys.push((c.topology, c.bw_gbps));
        }
    }
    let mut out = Vec::new();
    for (topo, bw) in keys {
        let mut t = Table::new([
            "model",
            "One-dim(OutC)",
            "One-dim(InH/InW)",
            "2D-grid",
            "Layerwise",
            "Fused-layer",
            "FlexPie",
            "speedup (best..worst baseline)",
        ]);
        let mut models: Vec<String> = Vec::new();
        for c in cells {
            if c.topology == topo && c.bw_gbps == bw && !models.contains(&c.model) {
                models.push(c.model.clone());
            }
        }
        for m in models {
            let find = |s: Solution| {
                cells
                    .iter()
                    .find(|c| {
                        c.model == m && c.topology == topo && c.bw_gbps == bw && c.solution == s
                    })
                    .map(|c| c.time_ms)
                    .unwrap()
            };
            let times: Vec<f64> = Solution::ALL.iter().map(|&s| find(s)).collect();
            let flex = times[5];
            let best_baseline =
                times[..5].iter().cloned().fold(f64::INFINITY, f64::min);
            let worst_baseline = times[..5].iter().cloned().fold(0.0f64, f64::max);
            t.row([
                m,
                format!("{:.3}", times[0]),
                format!("{:.3}", times[1]),
                format!("{:.3}", times[2]),
                format!("{:.3}", times[3]),
                format!("{:.3}", times[4]),
                format!("{:.3}", flex),
                format!("{:.2}x..{:.2}x", best_baseline / flex, worst_baseline / flex),
            ]);
        }
        out.push((format!("{} @ {} Gb/s (times in ms)", topo.name(), bw), t));
    }
    out
}

// ---------------------------------------------------------------------------
// Fig 8 — performance score
// ---------------------------------------------------------------------------

/// Per-solution performance score over a set of cells:
/// `score = mean over test cases of min(t₁..t₆)/tᵢ` (paper §4 Metrics).
pub fn fig8(cells: &[Cell], opts: &BenchOpts) -> Vec<(Solution, f64)> {
    let mut case_keys: Vec<(String, usize, Topology, f64)> = Vec::new();
    for c in cells {
        let key = (c.model.clone(), c.nodes, c.topology, c.bw_gbps);
        if !case_keys.contains(&key) {
            case_keys.push(key);
        }
    }
    let mut scores: Vec<(Solution, f64)> =
        Solution::ALL.iter().map(|&s| (s, 0.0)).collect();
    for key in &case_keys {
        let case: Vec<&Cell> = cells
            .iter()
            .filter(|c| {
                (c.model.clone(), c.nodes, c.topology, c.bw_gbps) == *key
            })
            .collect();
        let best = case.iter().map(|c| c.time_ms).fold(f64::INFINITY, f64::min);
        for (sol, acc) in scores.iter_mut() {
            let t = case.iter().find(|c| c.solution == *sol).unwrap().time_ms;
            *acc += best / t;
        }
    }
    for (_, acc) in scores.iter_mut() {
        *acc /= case_keys.len() as f64;
    }
    let json = Json::Arr(
        scores
            .iter()
            .map(|(s, v)| {
                Json::obj(vec![
                    ("solution", Json::Str(s.name().into())),
                    ("score", Json::Num(*v)),
                ])
            })
            .collect(),
    );
    opts.save_json("fig8.json", &json);
    scores
}

pub fn fig8_table(scores_4: &[(Solution, f64)], scores_3: &[(Solution, f64)]) -> Table {
    let mut t = Table::new(["solution", "score (4-node)", "score (3-node)"]);
    for (i, (sol, s4)) in scores_4.iter().enumerate() {
        t.row([
            sol.name().to_string(),
            format!("{s4:.3}"),
            format!("{:.3}", scores_3[i].1),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// DPP search time + ablations
// ---------------------------------------------------------------------------

/// Search-cost report row.
#[derive(Debug, Clone)]
pub struct SearchRow {
    pub model: String,
    pub layers: usize,
    pub pruned_ms: f64,
    pub unpruned_ms: f64,
    pub pruned_syncs: usize,
    pub unpruned_syncs: usize,
    pub space_size: f64,
}

/// DPP search time per model, pruning on vs off, plus the raw combinatorial
/// space size DPP avoids enumerating.
pub fn search_time(opts: &BenchOpts) -> Vec<SearchRow> {
    let grid = crate::config::ExperimentGrid::paper();
    let tb = Testbed::new(4, Topology::Ring, Bandwidth::gbps(5.0));
    let cost = opts.cost_source(&tb);
    let mut rows = Vec::new();
    for name in &grid.models {
        let model = opts.model(name);
        let (_, with) = Dpp::with_config(
            &model,
            &cost,
            DppConfig { prune: true, ..Default::default() },
        )
        .plan_with_stats();
        let (_, without) = Dpp::with_config(
            &model,
            &cost,
            DppConfig { prune: false, ..Default::default() },
        )
        .plan_with_stats();
        rows.push(SearchRow {
            model: name.clone(),
            layers: model.n_layers(),
            pruned_ms: with.elapsed.as_secs_f64() * 1e3,
            unpruned_ms: without.elapsed.as_secs_f64() * 1e3,
            pruned_syncs: with.sync_queries,
            unpruned_syncs: without.sync_queries,
            space_size: crate::planner::exhaustive::search_space_size(model.n_layers(), 4),
        });
    }
    let json = Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("model", Json::Str(r.model.clone())),
                    ("layers", Json::Num(r.layers as f64)),
                    ("pruned_ms", Json::Num(r.pruned_ms)),
                    ("unpruned_ms", Json::Num(r.unpruned_ms)),
                    ("pruned_syncs", Json::Num(r.pruned_syncs as f64)),
                    ("unpruned_syncs", Json::Num(r.unpruned_syncs as f64)),
                    ("space_size", Json::Num(r.space_size)),
                ])
            })
            .collect(),
    );
    opts.save_json("search_time.json", &json);
    rows
}

pub fn search_time_table(rows: &[SearchRow]) -> Table {
    let mut t = Table::new([
        "model",
        "layers",
        "DPP (pruned)",
        "DPP (no prune)",
        "s-queries (pruned/full)",
        "naive space",
    ]);
    for r in rows {
        t.row([
            r.model.clone(),
            r.layers.to_string(),
            format!("{:.1} ms", r.pruned_ms),
            format!("{:.1} ms", r.unpruned_ms),
            format!("{}/{}", r.pruned_syncs, r.unpruned_syncs),
            format!("{:.2e}", r.space_size),
        ]);
    }
    t
}

/// Ablation rows: evaluated (analytic) time of FlexPie plans produced with
/// restricted planners, relative to the full planner with the oracle CE.
#[derive(Debug, Clone)]
pub struct AblationRow {
    pub model: String,
    pub variant: String,
    pub time_ms: f64,
    pub vs_full: f64,
}

/// Design ablations (DESIGN.md §6): GBDT-CE planning regret, fusion-off,
/// scheme-set restrictions.
pub fn ablation(opts: &BenchOpts) -> Vec<AblationRow> {
    let tb = Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0));
    let oracle = CostSource::analytic(&tb);
    let gbdt = CostSource::gbdt(opts.estimators(), &tb);
    let mut rows = Vec::new();
    for name in ["mobilenet", "resnet18"] {
        let model = opts.model(name);
        let full = Dpp::new(&model, &oracle).plan();
        let full_t = engine::evaluate(&model, &full, &tb).total_ms();
        let mut push = |variant: &str, plan: &Plan| {
            let t = engine::evaluate(&model, plan, &tb).total_ms();
            rows.push(AblationRow {
                model: name.into(),
                variant: variant.into(),
                time_ms: t,
                vs_full: t / full_t,
            });
        };
        push("full (oracle CE)", &full);
        push("GBDT CE", &Dpp::new(&model, &gbdt).plan());
        push(
            "no fusion (layerwise)",
            &Dpp::with_config(
                &model,
                &oracle,
                DppConfig { enable_fusion: false, ..Default::default() },
            )
            .plan(),
        );
        push(
            "spatial schemes only",
            &Dpp::with_config(
                &model,
                &oracle,
                DppConfig {
                    schemes: vec![Scheme::InH, Scheme::InW, Scheme::Grid2d],
                    ..Default::default()
                },
            )
            .plan(),
        );
        push(
            "block span ≤ 2",
            &Dpp::with_config(
                &model,
                &oracle,
                DppConfig { max_block_span: 2, ..Default::default() },
            )
            .plan(),
        );
    }
    let json = Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("model", Json::Str(r.model.clone())),
                    ("variant", Json::Str(r.variant.clone())),
                    ("time_ms", Json::Num(r.time_ms)),
                    ("vs_full", Json::Num(r.vs_full)),
                ])
            })
            .collect(),
    );
    opts.save_json("ablation.json", &json);
    rows
}

// ---------------------------------------------------------------------------
// Node-count scaling (the paper's 4~6-device deployment envelope)
// ---------------------------------------------------------------------------

/// One scaling row: FlexPie vs best fixed scheme at a node count.
#[derive(Debug, Clone)]
pub struct ScalingRow {
    pub model: String,
    pub nodes: usize,
    pub flexpie_ms: f64,
    pub best_fixed_ms: f64,
    pub single_node_ms: f64,
    pub nt_layers: usize,
}

/// Sweep cluster sizes 1–6 (the paper's "4~6 nodes" envelope plus the
/// degenerate ends): does FlexPie keep scaling where fixed schemes stall?
pub fn scaling(opts: &BenchOpts) -> Vec<ScalingRow> {
    let mut rows = Vec::new();
    for name in ["mobilenet", "resnet18"] {
        let model = opts.model(name);
        let single = {
            let tb = Testbed::new(1, Topology::Ring, Bandwidth::gbps(1.0));
            engine::evaluate(&model, &Plan::uniform(Scheme::InH, model.n_layers()), &tb)
                .total_ms()
        };
        for nodes in [2usize, 3, 4, 5, 6] {
            let tb = Testbed::new(nodes, Topology::Ring, Bandwidth::gbps(1.0));
            let cost = opts.cost_source(&tb);
            let plan = Dpp::new(&model, &cost).plan();
            let flex = engine::evaluate(&model, &plan, &tb).total_ms();
            let best_fixed = Scheme::ALL
                .iter()
                .map(|&s| {
                    engine::evaluate(&model, &Plan::uniform(s, model.n_layers()), &tb)
                        .total_ms()
                })
                .fold(f64::INFINITY, f64::min);
            rows.push(ScalingRow {
                model: name.into(),
                nodes,
                flexpie_ms: flex,
                best_fixed_ms: best_fixed,
                single_node_ms: single,
                nt_layers: plan.n_fused_layers(),
            });
        }
    }
    let json = Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("model", Json::Str(r.model.clone())),
                    ("nodes", Json::Num(r.nodes as f64)),
                    ("flexpie_ms", Json::Num(r.flexpie_ms)),
                    ("best_fixed_ms", Json::Num(r.best_fixed_ms)),
                    ("single_node_ms", Json::Num(r.single_node_ms)),
                    ("nt_layers", Json::Num(r.nt_layers as f64)),
                ])
            })
            .collect(),
    );
    opts.save_json("scaling.json", &json);
    rows
}

pub fn scaling_table(rows: &[ScalingRow]) -> Table {
    let mut t = Table::new([
        "model", "nodes", "FlexPie (ms)", "best fixed (ms)", "speedup vs 1 node", "NT layers",
    ]);
    for r in rows {
        t.row([
            r.model.clone(),
            r.nodes.to_string(),
            format!("{:.2}", r.flexpie_ms),
            format!("{:.2}", r.best_fixed_ms),
            format!("{:.2}x", r.single_node_ms / r.flexpie_ms),
            r.nt_layers.to_string(),
        ]);
    }
    t
}

pub fn ablation_table(rows: &[AblationRow]) -> Table {
    let mut t = Table::new(["model", "variant", "time (ms)", "vs full"]);
    for r in rows {
        t.row([
            r.model.clone(),
            r.variant.clone(),
            format!("{:.3}", r.time_ms),
            format!("{:.3}x", r.vs_full),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_opts() -> BenchOpts {
        BenchOpts {
            cost: CostKind::Analytic,
            truncate: 9,
            out_dir: "".into(),
            ..Default::default()
        }
    }

    #[test]
    fn fig2_shape_and_content() {
        let rows = fig2(&fast_opts());
        // 2 node-counts × 3 layers × 3 schemes
        assert_eq!(rows.len(), 18);
        assert!(rows.iter().all(|r| r.time_us > 0.0));
        let t = fig2_table(&rows);
        assert_eq!(t.render().lines().count(), 2 + 6);
    }

    #[test]
    fn fig2_no_one_size_fits_all() {
        // The paper's motivating observation: the best scheme differs across
        // (layer, testbed) cells.
        let rows = fig2(&fast_opts());
        let mut groups: Vec<String> = Vec::new();
        for r in &rows {
            if !groups.contains(&r.group) {
                groups.push(r.group.clone());
            }
        }
        let mut winners = std::collections::BTreeSet::new();
        for g in groups {
            let best = rows
                .iter()
                .filter(|r| r.group == g)
                .min_by(|a, b| a.time_us.partial_cmp(&b.time_us).unwrap())
                .unwrap();
            winners.insert(best.scheme.name());
        }
        assert!(winners.len() >= 2, "single scheme won everywhere: {winners:?}");
    }

    #[test]
    fn fig7_smoke_flexpie_wins() {
        let mut opts = fast_opts();
        opts.truncate = 7;
        let cells = fig7_9(4, &opts);
        // FlexPie never loses to a baseline on any cell (oracle CE).
        for chunk in cells.chunks(6) {
            let flex = chunk.iter().find(|c| c.solution == Solution::FlexPie).unwrap();
            for c in chunk {
                assert!(
                    flex.time_ms <= c.time_ms + 1e-9,
                    "{} beat FlexPie on {} {}@{}",
                    c.solution,
                    c.model,
                    c.topology,
                    c.bw_gbps
                );
            }
        }
        let scores = fig8(&cells, &opts);
        let flex_score = scores.iter().find(|(s, _)| *s == Solution::FlexPie).unwrap().1;
        assert!((flex_score - 1.0).abs() < 1e-9, "FlexPie score = {flex_score}");
    }

    #[test]
    fn scaling_rows_monotone_enough() {
        let mut opts = fast_opts();
        opts.truncate = 7;
        let rows = scaling(&opts);
        assert_eq!(rows.len(), 10); // 2 models × 5 node counts
        for r in &rows {
            // FlexPie never loses to the best fixed scheme
            assert!(r.flexpie_ms <= r.best_fixed_ms + 1e-9, "{r:?}");
            assert!(r.flexpie_ms > 0.0);
        }
        // 4 nodes must beat 2 nodes on a compute-bound truncated model
        let t2 = rows.iter().find(|r| r.model == "mobilenet" && r.nodes == 2).unwrap();
        let t4 = rows.iter().find(|r| r.model == "mobilenet" && r.nodes == 4).unwrap();
        assert!(t4.flexpie_ms < t2.flexpie_ms);
    }

    #[test]
    fn fig7_tables_render_speedup_range() {
        let mut opts = fast_opts();
        opts.truncate = 5;
        let cells = fig7_9(4, &opts);
        let tables = fig7_9_tables(&cells);
        // 2 topologies × 3 bandwidths
        assert_eq!(tables.len(), 6);
        for (title, t) in &tables {
            let rendered = t.render();
            assert!(rendered.contains("FlexPie"), "{title}");
            assert!(rendered.contains('x'), "speedup column missing in {title}");
        }
    }

    #[test]
    fn search_time_rows() {
        let mut opts = fast_opts();
        opts.truncate = 8;
        let rows = search_time(&opts);
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(r.pruned_syncs <= r.unpruned_syncs);
            assert!(r.space_size > 1e3);
        }
    }
}
