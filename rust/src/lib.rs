//! # FlexPie — flexible combinatorial optimization for distributed edge inference
//!
//! Reproduction of *"FlexPie: Accelerate Distributed Inference on Edge Devices
//! with Flexible Combinatorial Optimization"* (Zhang et al., 2025).
//!
//! FlexPie partitions a DNN's feature maps across a small cluster (3–6) of
//! edge devices and chooses, **per layer**, both a partition scheme
//! (`InH`, `InW`, `OutC`, `2D-grid`) and a transmission mode (`T` — exchange
//! boundary data after the layer, or `NT` — fuse into the next layer by doing
//! redundant computation). The choice is made by a dynamic-programming planner
//! ([`planner`]) driven by a data-driven cost estimator ([`cost`]): two GBDT
//! regressors (i-Estimator for compute, s-Estimator for synchronization)
//! trained on traces from the simulated testbed.
//!
//! ## Crate layout (Layer-3 of the three-layer stack)
//!
//! | module | role |
//! |--------|------|
//! | [`model`] | graph IR + model zoo (MobileNet, ResNet-18/101, BERT) + pre-optimization passes |
//! | [`partition`] | partition geometry: tiles, halos, NT inflation (the paper's §2.1/§2.3) |
//! | [`cost`] | feature extraction, from-scratch GBDT, i/s-Estimators, analytic ground truth, trace generator, shared query memo |
//! | [`planner`] | DPP — the paper's Algorithm 1 (reverse DP + pruning, optionally wavefront-parallel) + exhaustive reference for Thm 1 |
//! | [`baselines`] | OutC (Xenos), InH/InW (MoDNN/DeepSlicing), 2D-grid (DeepThings), layerwise (DINA), fused-layer (AOFL/EdgeCI) |
//! | [`net`] | network simulator: Ring / PS / Mesh topologies, bandwidth + latency |
//! | [`cluster`] | simulated edge cluster: leader/worker threads, message passing, virtual clock; block-pipelined streaming executor |
//! | [`elastic`] | runtime adaptation: condition traces, degradation monitor, plan cache, background replanner + speculative failover |
//! | [`telemetry`] | measured conditions: passive/active probes, ring-buffer sample store, EWMA+trend+seasonal forecasting, plan pre-warming |
//! | [`engine`] | plan executor: analytic evaluation + real-numerics distributed execution |
//! | [`compute`] | native Rust tensor kernels (conv/dwconv/pool/matmul) — fallback + oracle |
//! | [`runtime`] | PJRT client wrapper: loads `artifacts/*.hlo.txt` (AOT-compiled JAX/Pallas) |
//! | [`serve`] | serving front-end: request router + dynamic batcher + pipelined throughput mode |
//! | [`transport`] | real wire transport: versioned frame codec, TCP/UDS socket fabric, TTL-leased registry, node daemon + process coordinator |
//! | [`loadgen`] | open-loop traffic: seeded arrival schedules, HDR-style latency histograms, `/proc` sampling, the load-agent process |
//! | [`bench`] | generators for every paper table/figure (Fig 2, 7, 8, 9, search time, ablations) + the tail-latency load harness |
//!
//! Layers 1/2 (Pallas kernels + JAX model) live under `python/compile/` and
//! run **only at build time** (`make artifacts`); this crate is self-contained
//! at runtime.
//!
//! ## Quickstart
//!
//! ```no_run
//! use flexpie::prelude::*;
//!
//! let model = flexpie::model::zoo::mobilenet_v1(224, 1000);
//! let testbed = Testbed::new(4, Topology::Ring, Bandwidth::gbps(5.0));
//! let cost = CostSource::analytic(&testbed);
//! let plan = flexpie::planner::Dpp::new(&model, &cost).plan();
//! let report = flexpie::engine::evaluate(&model, &plan, &testbed);
//! println!("estimated inference time: {:.3} ms", report.total_ms());
//! ```

pub mod baselines;
pub mod bench;
pub mod cluster;
pub mod compute;
pub mod config;
pub mod cost;
pub mod elastic;
pub mod engine;
pub mod loadgen;
pub mod metrics;
pub mod model;
pub mod net;
pub mod partition;
pub mod planner;
pub mod runtime;
pub mod serve;
pub mod telemetry;
pub mod trace;
pub mod transport;
pub mod util;

/// Commonly used types, re-exported for ergonomic downstream use.
pub mod prelude {
    pub use crate::cost::{CostSource, Estimators};
    pub use crate::elastic::{ConditionTrace, ElasticController, PlanCache};
    pub use crate::engine::TimingReport;
    pub use crate::model::{ConvType, LayerMeta, Model, OpKind};
    pub use crate::net::{Bandwidth, Testbed, Topology};
    pub use crate::partition::{Mode, Plan, PlanStep, Scheme};
    pub use crate::planner::Dpp;
}

/// Bytes per element of the (single) runtime dtype. The paper's DSP testbed
/// runs f32 inference; we do the same end-to-end.
pub const DTYPE_BYTES: u64 = 4;
