//! NT-mode tile inflation — the redundant-computation geometry of §2.3/§3.3.
//!
//! A *fused block* is a maximal run of layers `i..=j` executed under one
//! scheme with no inter-node communication inside (`tᵢ..t_{j-1} = NT`,
//! `t_j = T`). Each node must therefore compute, at every interior layer, an
//! **inflated** output region: starting from its canonical tile at the block
//! end, the requirement is propagated backwards through the receptive field
//! (`req[l] = in_region(layer_{l+1}, req[l+1])`). The deeper the block and
//! the larger the kernels/strides, the more redundant work — the trade-off
//! the planner prices via the i-Estimator.

use super::geometry::{in_regions, out_tile};
use super::{union_volume, Scheme, Tile};
use crate::model::LayerMeta;

/// Geometry of one fused block for every node.
#[derive(Debug, Clone)]
pub struct BlockGeometry {
    /// `tiles[l][node]` — the (possibly inflated) output regions node `node`
    /// computes at block layer `l` (index 0 = first layer of the block).
    /// The last layer's tiles are always the canonical partition.
    pub tiles: Vec<Vec<Tile>>,
    /// `entry_need[node]` — the input region of the block's first layer that
    /// node `node` must hold before the block starts (delivered by the
    /// preceding T-boundary or the initial scatter).
    pub entry_need: Vec<Tile>,
    pub scheme: Scheme,
    pub nodes: usize,
}

impl BlockGeometry {
    /// Compute the geometry of block `layers` (a contiguous sub-slice of the
    /// model) under `scheme` with `nodes` devices.
    pub fn new(layers: &[LayerMeta], scheme: Scheme, nodes: usize) -> BlockGeometry {
        assert!(!layers.is_empty());
        let n = layers.len();
        let mut tiles: Vec<Vec<Tile>> = vec![Vec::new(); n];
        // Block end: canonical tiles.
        tiles[n - 1] = (0..nodes).map(|i| out_tile(&layers[n - 1], scheme, nodes, i)).collect();
        // Backward propagation through interior layers.
        for l in (0..n - 1).rev() {
            tiles[l] = (0..nodes)
                .map(|node| in_regions(&layers[l + 1], &tiles[l + 1][node]))
                .collect();
        }
        let entry_need: Vec<Tile> =
            (0..nodes).map(|node| in_regions(&layers[0], &tiles[0][node])).collect();
        BlockGeometry { tiles, entry_need, scheme, nodes }
    }

    /// FLOPs node `node` performs at block layer `l`.
    pub fn node_flops(&self, layers: &[LayerMeta], l: usize, node: usize) -> f64 {
        layers[l].flops_per_out_elem() * union_volume(&self.tiles[l][node]) as f64
    }

    /// Bottleneck (max-over-nodes) FLOPs at block layer `l` — layer
    /// completion is gated by the slowest node (barrier semantics).
    pub fn bottleneck_flops(&self, layers: &[LayerMeta], l: usize) -> f64 {
        (0..self.nodes)
            .map(|i| self.node_flops(layers, l, i))
            .fold(0.0, f64::max)
    }

    /// Total redundant FLOPs across the block: work beyond what a perfect
    /// non-redundant partition would do.
    pub fn redundant_flops(&self, layers: &[LayerMeta]) -> f64 {
        let mut extra = 0.0;
        for (l, layer) in layers.iter().enumerate() {
            let done: f64 =
                (0..self.nodes).map(|i| self.node_flops(layers, l, i)).sum();
            extra += done - layer.flops();
        }
        extra.max(0.0)
    }

    /// Inflation ratio of layer `l`: computed volume / canonical volume.
    /// 1.0 at the block end; grows towards the block entry.
    pub fn inflation(&self, layers: &[LayerMeta], l: usize) -> f64 {
        let computed: i64 =
            (0..self.nodes).map(|i| union_volume(&self.tiles[l][i])).sum();
        let canonical = layers[l].out_volume();
        if canonical == 0 {
            1.0
        } else {
            computed as f64 / canonical as f64
        }
    }

    /// Bottleneck in/out tile dimensions of layer `l` — the hull box of the
    /// busiest node's tile, used for cost-estimator features.
    pub fn bottleneck_tile_dims(&self, layers: &[LayerMeta], l: usize) -> TileDims {
        let busiest = (0..self.nodes)
            .max_by(|&a, &b| {
                union_volume(&self.tiles[l][a])
                    .cmp(&union_volume(&self.tiles[l][b]))
            })
            .unwrap_or(0);
        let out_hull = self.tiles[l][busiest]
            .iter()
            .fold(super::Region::empty(), |acc, r| acc.hull(r));
        let ins = in_regions(&layers[l], &self.tiles[l][busiest]);
        let in_hull = ins.iter().fold(super::Region::empty(), |acc, r| acc.hull(r));
        TileDims {
            in_h: in_hull.h1 - in_hull.h0,
            in_w: in_hull.w1 - in_hull.w0,
            in_c: in_hull.c1 - in_hull.c0,
            out_h: out_hull.h1 - out_hull.h0,
            out_w: out_hull.w1 - out_hull.w0,
            out_c: out_hull.c1 - out_hull.c0,
            out_volume: union_volume(&self.tiles[l][busiest]),
        }
    }
}

/// Hull dimensions of a node's tile (feature-vector input).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TileDims {
    pub in_h: i64,
    pub in_w: i64,
    pub in_c: i64,
    pub out_h: i64,
    pub out_w: i64,
    pub out_c: i64,
    pub out_volume: i64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ConvType, LayerMeta};

    fn conv(h: i64, c: i64, k: i64) -> LayerMeta {
        LayerMeta::conv("t", ConvType::Standard, h, h, c, c, k, 1, (k - 1) / 2)
    }

    #[test]
    fn single_layer_block_is_canonical() {
        let layers = vec![conv(16, 8, 3)];
        let g = BlockGeometry::new(&layers, Scheme::InH, 4);
        for node in 0..4 {
            assert_eq!(g.tiles[0][node], out_tile(&layers[0], Scheme::InH, 4, node));
        }
        assert!((g.inflation(&layers, 0) - 1.0).abs() < 1e-12);
        assert_eq!(g.redundant_flops(&layers), 0.0);
    }

    #[test]
    fn two_layer_block_inflates_interior_by_halo() {
        // Two same-padded 3×3 convs, InH over 4 nodes on a 16-row map:
        // interior nodes must compute 2 extra rows (one halo row each side)
        // at the first layer.
        let layers = vec![conv(16, 8, 3), conv(16, 8, 3)];
        let g = BlockGeometry::new(&layers, Scheme::InH, 4);
        // node 1 canonical rows at layer1: 4..8 → needs layer0 out rows 3..9.
        let t = &g.tiles[0][1];
        assert_eq!(t.len(), 1);
        assert_eq!((t[0].h0, t[0].h1), (3, 9));
        // block end is canonical
        assert_eq!((g.tiles[1][1][0].h0, g.tiles[1][1][0].h1), (4, 8));
        assert!(g.redundant_flops(&layers) > 0.0);
        assert!(g.inflation(&layers, 0) > 1.0);
    }

    #[test]
    fn inflation_grows_towards_block_entry() {
        let layers = vec![conv(32, 8, 3), conv(32, 8, 3), conv(32, 8, 3), conv(32, 8, 3)];
        let g = BlockGeometry::new(&layers, Scheme::InH, 4);
        let infl: Vec<f64> = (0..4).map(|l| g.inflation(&layers, l)).collect();
        assert!(infl[0] > infl[1] && infl[1] > infl[2] && infl[2] > infl[3]);
        assert!((infl[3] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn entry_need_covers_inflated_first_layer() {
        let layers = vec![conv(16, 8, 3), conv(16, 8, 3)];
        let g = BlockGeometry::new(&layers, Scheme::InH, 4);
        // entry_need = in_region of the inflated first-layer tile
        for node in 0..4 {
            let expect = in_regions(&layers[0], &g.tiles[0][node]);
            assert_eq!(g.entry_need[node], expect);
        }
        // node1 inflated rows 3..9 → input rows 2..10
        assert_eq!((g.entry_need[1][0].h0, g.entry_need[1][0].h1), (2, 10));
    }

    #[test]
    fn strided_block_inflation() {
        // stride-2 conv after a same conv: receptive field grows faster.
        let l0 = conv(32, 8, 3);
        let l1 = LayerMeta::conv("s2", ConvType::Standard, 32, 32, 8, 8, 3, 2, 1);
        let layers = vec![l0, l1];
        let g = BlockGeometry::new(&layers, Scheme::InH, 4);
        // layer1 out = 16 rows; node0 rows 0..4 → layer0 rows [0·2-1, 3·2-1+3)
        // clamped = [0, 8)
        let t = &g.tiles[0][0];
        assert_eq!((t[0].h0, t[0].h1), (0, 8));
    }

    #[test]
    fn grid_block_multi_rect_tiles() {
        let layers = vec![conv(14, 16, 3), conv(14, 16, 3)];
        let g = BlockGeometry::new(&layers, Scheme::Grid2d, 3);
        // 2×2 grid on 3 nodes: node0 owns two cells, so its inflated tile at
        // layer0 has two boxes.
        assert_eq!(g.tiles[0][0].len(), 2);
        assert!(g.bottleneck_flops(&layers, 0) > g.node_flops(&layers, 0, 1));
    }

    #[test]
    fn pointwise_block_no_spatial_inflation() {
        // 1×1 convs have no halo → NT costs nothing extra spatially.
        let l0 = LayerMeta::conv("pw0", ConvType::Pointwise, 16, 16, 8, 8, 1, 1, 0);
        let l1 = LayerMeta::conv("pw1", ConvType::Pointwise, 16, 16, 8, 8, 1, 1, 0);
        let layers = vec![l0, l1];
        let g = BlockGeometry::new(&layers, Scheme::InH, 4);
        assert_eq!(g.redundant_flops(&layers), 0.0);
    }

    #[test]
    fn outc_block_recomputes_everything() {
        // NT under OutC: the next layer needs all input channels, so each
        // node must recompute the *entire* previous layer — geometrically
        // legal, economically absurd; the planner prices it out.
        let l0 = LayerMeta::conv("pw0", ConvType::Pointwise, 8, 8, 16, 16, 1, 1, 0);
        let l1 = LayerMeta::conv("pw1", ConvType::Pointwise, 8, 8, 16, 16, 1, 1, 0);
        let layers = vec![l0, l1];
        let g = BlockGeometry::new(&layers, Scheme::OutC, 4);
        // each node's layer-0 tile = full map
        let full = 8 * 8 * 16;
        for node in 0..4 {
            assert_eq!(union_volume(&g.tiles[0][node]), full);
        }
        assert!((g.inflation(&layers, 0) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn bottleneck_tile_dims_sane() {
        let layers = vec![conv(16, 8, 3)];
        let g = BlockGeometry::new(&layers, Scheme::InH, 4);
        let d = g.bottleneck_tile_dims(&layers, 0);
        assert_eq!(d.out_h, 4);
        assert_eq!(d.out_w, 16);
        assert_eq!(d.out_c, 8);
        assert!(d.in_h >= 4 && d.in_h <= 6); // halo rows included
        assert_eq!(d.out_volume, 4 * 16 * 8);
    }
}
