//! Partition geometry — the paper's §2.1 (partition schemes), §2.3
//! (computation/communication trade-off) and the NT-mode redundant-compute
//! inflation that underlies layer fusion.
//!
//! Everything is expressed over half-open 3-D boxes ([`Region`]) in a layer's
//! `(h, w, c)` output coordinate space. A node's share of a layer is a
//! [`Tile`] — a set of disjoint boxes (a single box for One-dim schemes; up
//! to ⌈cells/nodes⌉ boxes for 2D-grid when the grid has more cells than
//! nodes, which is exactly how the paper's 3-node 2D-grid imbalance arises).

pub mod geometry;
pub mod inflate;

/// Partition scheme — the paper's Step-1 choice, `pᵢ ∈ {InH, InW, OutC,
/// 2D-grid}` (Fig 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scheme {
    /// Split along the feature-map height.
    InH,
    /// Split along the feature-map width.
    InW,
    /// Split along output channels.
    OutC,
    /// Split along both height and width (load-balance grid).
    Grid2d,
}

impl Scheme {
    pub const ALL: [Scheme; 4] = [Scheme::InH, Scheme::InW, Scheme::OutC, Scheme::Grid2d];

    /// Categorical code for the cost-estimator feature vector.
    pub fn code(self) -> f64 {
        match self {
            Scheme::InH => 0.0,
            Scheme::InW => 1.0,
            Scheme::OutC => 2.0,
            Scheme::Grid2d => 3.0,
        }
    }

    /// True for schemes that split spatial dimensions (candidates for cheap
    /// halo-only synchronization and NT fusion).
    pub fn is_spatial(self) -> bool {
        !matches!(self, Scheme::OutC)
    }

    pub fn name(self) -> &'static str {
        match self {
            Scheme::InH => "InH",
            Scheme::InW => "InW",
            Scheme::OutC => "OutC",
            Scheme::Grid2d => "2D-grid",
        }
    }
}

impl std::fmt::Display for Scheme {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for Scheme {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "inh" => Ok(Scheme::InH),
            "inw" => Ok(Scheme::InW),
            "outc" => Ok(Scheme::OutC),
            "grid" | "2d-grid" | "grid2d" | "2dgrid" => Ok(Scheme::Grid2d),
            other => Err(format!("unknown scheme {other:?}")),
        }
    }
}

/// Transmission mode — the paper's Step-2 choice, `tᵢ ∈ {T, NT}` (§3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Mode {
    /// Transmit: boundary data is exchanged between nodes after this layer.
    T,
    /// Non-Transmit: no exchange; earlier layers perform redundant
    /// computation so the local output already covers the next layer's needs.
    NT,
}

impl std::fmt::Display for Mode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Mode::T => "T",
            Mode::NT => "NT",
        })
    }
}

/// Per-layer decision: the pair `Pᵢ = (pᵢ, tᵢ)` of the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PlanStep {
    pub scheme: Scheme,
    pub mode: Mode,
}

/// A full partition plan: the sequence `S = [P₀ … Pₙ]`.
///
/// Invariant: the final step's mode is `T` (the last layer "must be
/// transmitted after computation" — its output is gathered at the leader),
/// and within a maximal run of `NT` steps followed by its terminating `T`
/// step (a *fused block*), every step uses the same scheme (cross-scheme
/// realignment without transmission is geometrically impossible).
#[derive(Debug, Clone, PartialEq)]
pub struct Plan {
    pub steps: Vec<PlanStep>,
    /// Cost estimated by the cost source that produced the plan (seconds).
    pub est_cost: f64,
}

impl Plan {
    /// A plan that uses a single scheme for every layer, all-T (the fixed
    /// baselines of the paper).
    pub fn uniform(scheme: Scheme, n_layers: usize) -> Plan {
        let mut steps = vec![PlanStep { scheme, mode: Mode::T }; n_layers];
        if let Some(last) = steps.last_mut() {
            last.mode = Mode::T;
        }
        Plan { steps, est_cost: f64::NAN }
    }

    /// Validate the structural invariants (see type docs).
    pub fn validate(&self) -> Result<(), String> {
        if self.steps.is_empty() {
            return Err("empty plan".into());
        }
        if self.steps.last().unwrap().mode != Mode::T {
            return Err("last layer must be T (gathered at leader)".into());
        }
        // Within each fused block [i..=j] (NT at i..j-1, T at j), schemes match.
        let mut block_scheme: Option<Scheme> = None;
        for (i, st) in self.steps.iter().enumerate() {
            if let Some(s) = block_scheme {
                if st.scheme != s {
                    return Err(format!(
                        "layer {i}: scheme {} differs from its fused block's scheme {}",
                        st.scheme, s
                    ));
                }
            }
            block_scheme = match st.mode {
                Mode::NT => Some(st.scheme),
                Mode::T => None,
            };
        }
        Ok(())
    }

    /// Iterate over the fused blocks of the plan: `(start, end_inclusive,
    /// scheme)`, where layers `start..end` are NT and layer `end` is T.
    pub fn blocks(&self) -> Vec<(usize, usize, Scheme)> {
        let mut out = Vec::new();
        let mut start = 0usize;
        for (i, st) in self.steps.iter().enumerate() {
            if st.mode == Mode::T {
                out.push((start, i, self.steps[start].scheme));
                start = i + 1;
            }
        }
        out
    }

    pub fn n_fused_layers(&self) -> usize {
        self.steps.iter().filter(|s| s.mode == Mode::NT).count()
    }

    /// Short human-readable rendering, e.g. `InH·NT InH·T OutC·T`.
    pub fn render(&self) -> String {
        self.steps
            .iter()
            .map(|s| format!("{}·{}", s.scheme, s.mode))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Half-open 3-D box `[h0,h1) × [w0,w1) × [c0,c1)` in a layer's output
/// coordinate space.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Region {
    pub h0: i64,
    pub h1: i64,
    pub w0: i64,
    pub w1: i64,
    pub c0: i64,
    pub c1: i64,
}

impl Region {
    pub fn new(h0: i64, h1: i64, w0: i64, w1: i64, c0: i64, c1: i64) -> Region {
        Region { h0, h1, w0, w1, c0, c1 }
    }

    pub fn full(h: i64, w: i64, c: i64) -> Region {
        Region::new(0, h, 0, w, 0, c)
    }

    pub fn empty() -> Region {
        Region::new(0, 0, 0, 0, 0, 0)
    }

    pub fn is_empty(&self) -> bool {
        self.h0 >= self.h1 || self.w0 >= self.w1 || self.c0 >= self.c1
    }

    pub fn volume(&self) -> i64 {
        if self.is_empty() {
            0
        } else {
            (self.h1 - self.h0) * (self.w1 - self.w0) * (self.c1 - self.c0)
        }
    }

    pub fn intersect(&self, o: &Region) -> Region {
        Region {
            h0: self.h0.max(o.h0),
            h1: self.h1.min(o.h1),
            w0: self.w0.max(o.w0),
            w1: self.w1.min(o.w1),
            c0: self.c0.max(o.c0),
            c1: self.c1.min(o.c1),
        }
    }

    pub fn contains(&self, o: &Region) -> bool {
        o.is_empty()
            || (self.h0 <= o.h0
                && o.h1 <= self.h1
                && self.w0 <= o.w0
                && o.w1 <= self.w1
                && self.c0 <= o.c0
                && o.c1 <= self.c1)
    }

    /// Smallest box covering both.
    pub fn hull(&self, o: &Region) -> Region {
        if self.is_empty() {
            return *o;
        }
        if o.is_empty() {
            return *self;
        }
        Region {
            h0: self.h0.min(o.h0),
            h1: self.h1.max(o.h1),
            w0: self.w0.min(o.w0),
            w1: self.w1.max(o.w1),
            c0: self.c0.min(o.c0),
            c1: self.c1.max(o.c1),
        }
    }
}

/// A node's share of one layer: a set of boxes. Disjoint for canonical tiles;
/// possibly overlapping after NT inflation (volume accounting always goes
/// through [`union_volume`]).
pub type Tile = Vec<Region>;

/// Exact volume of the union of a set of boxes, via coordinate compression.
/// Lists here are tiny (≤ a handful of boxes), so the O(n³·n) sweep is cheap.
pub fn union_volume(regions: &[Region]) -> i64 {
    let boxes: Vec<&Region> = regions.iter().filter(|r| !r.is_empty()).collect();
    match boxes.len() {
        0 => return 0,
        1 => return boxes[0].volume(),
        _ => {}
    }
    let mut hs: Vec<i64> = boxes.iter().flat_map(|r| [r.h0, r.h1]).collect();
    let mut ws: Vec<i64> = boxes.iter().flat_map(|r| [r.w0, r.w1]).collect();
    let mut cs: Vec<i64> = boxes.iter().flat_map(|r| [r.c0, r.c1]).collect();
    for v in [&mut hs, &mut ws, &mut cs] {
        v.sort_unstable();
        v.dedup();
    }
    let mut total = 0i64;
    for hi in 0..hs.len() - 1 {
        for wi in 0..ws.len() - 1 {
            for ci in 0..cs.len() - 1 {
                let probe = Region::new(hs[hi], hs[hi] + 1, ws[wi], ws[wi] + 1, cs[ci], cs[ci] + 1);
                if boxes.iter().any(|b| !b.intersect(&probe).is_empty()) {
                    total += (hs[hi + 1] - hs[hi]) * (ws[wi + 1] - ws[wi]) * (cs[ci + 1] - cs[ci]);
                }
            }
        }
    }
    total
}

/// Union volume of the pairwise intersections between two box sets — the
/// exact byte count one node must receive from another.
pub fn intersection_volume(a: &[Region], b: &[Region]) -> i64 {
    let mut parts: Vec<Region> = Vec::with_capacity(a.len() * b.len());
    for ra in a {
        for rb in b {
            let x = ra.intersect(rb);
            if !x.is_empty() {
                parts.push(x);
            }
        }
    }
    union_volume(&parts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn region_volume_and_empty() {
        let r = Region::new(0, 4, 0, 3, 0, 2);
        assert_eq!(r.volume(), 24);
        assert!(Region::new(2, 2, 0, 3, 0, 2).is_empty());
        assert_eq!(Region::new(3, 2, 0, 3, 0, 2).volume(), 0);
    }

    #[test]
    fn intersect_and_contains() {
        let a = Region::new(0, 10, 0, 10, 0, 4);
        let b = Region::new(5, 15, 2, 8, 0, 4);
        let x = a.intersect(&b);
        assert_eq!(x, Region::new(5, 10, 2, 8, 0, 4));
        assert!(a.contains(&x));
        assert!(!b.contains(&a));
        assert!(a.contains(&Region::empty()));
    }

    #[test]
    fn union_volume_disjoint_and_overlapping() {
        let a = Region::new(0, 2, 0, 2, 0, 1);
        let b = Region::new(2, 4, 0, 2, 0, 1);
        assert_eq!(union_volume(&[a, b]), 8);
        let c = Region::new(1, 3, 0, 2, 0, 1); // overlaps both
        assert_eq!(union_volume(&[a, b, c]), 8);
        let d = Region::new(0, 2, 5, 7, 0, 1);
        assert_eq!(union_volume(&[a, d]), 8);
    }

    #[test]
    fn union_volume_identical_boxes_counted_once() {
        let a = Region::new(0, 3, 0, 3, 0, 3);
        assert_eq!(union_volume(&[a, a, a]), 27);
    }

    #[test]
    fn intersection_volume_counts_overlap_once() {
        let have = vec![Region::new(0, 4, 0, 4, 0, 2)];
        // two needed boxes overlapping within `have`
        let need = vec![Region::new(0, 2, 0, 4, 0, 2), Region::new(1, 3, 0, 4, 0, 2)];
        assert_eq!(intersection_volume(&have, &need), 3 * 4 * 2);
    }

    #[test]
    fn plan_validate_rules() {
        let mut p = Plan::uniform(Scheme::InH, 3);
        p.validate().unwrap();
        p.steps[2].mode = Mode::NT;
        assert!(p.validate().is_err(), "last layer must be T");
        let mut q = Plan::uniform(Scheme::InH, 3);
        q.steps[0].mode = Mode::NT;
        q.steps[1].scheme = Scheme::InW; // scheme change inside fused block
        assert!(q.validate().is_err());
        let mut r = Plan::uniform(Scheme::InH, 3);
        r.steps[0].mode = Mode::NT; // block [0..=1] same scheme, ok
        r.validate().unwrap();
    }

    #[test]
    fn plan_blocks_decomposition() {
        let mut p = Plan::uniform(Scheme::InH, 5);
        p.steps[1].mode = Mode::NT;
        p.steps[2].mode = Mode::NT;
        // blocks: [0..=0], [1..=3], [4..=4]
        let blocks = p.blocks();
        assert_eq!(blocks, vec![(0, 0, Scheme::InH), (1, 3, Scheme::InH), (4, 4, Scheme::InH)]);
        assert_eq!(p.n_fused_layers(), 2);
    }

    #[test]
    fn scheme_parse_roundtrip() {
        for s in Scheme::ALL {
            let parsed: Scheme = s.name().parse().unwrap();
            assert_eq!(parsed, s);
        }
    }
}
