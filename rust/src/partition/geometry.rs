//! Tile geometry: canonical per-node output tiles for each scheme, input
//! region arithmetic (receptive fields), and boundary message matrices.
//!
//! These functions are the single source of truth for "who holds what" and
//! "who needs what" — the analytic cost model, the trace generator, the DPP
//! feature extraction and the real-numerics execution engine all consume the
//! same geometry, so a plan that is estimated is exactly the plan that is
//! executed.

use super::{Region, Scheme, Tile};
use crate::model::{ConvType, LayerMeta};

/// Split `len` into `n` near-even contiguous parts; parts `0..len%n` get one
/// extra element (so part sizes differ by at most 1). Returns the half-open
/// range of part `i`.
pub fn split_even(len: i64, n: i64, i: i64) -> (i64, i64) {
    debug_assert!(n > 0 && i >= 0 && i < n);
    let base = len / n;
    let rem = len % n;
    let start = i * base + i.min(rem);
    let extra = if i < rem { 1 } else { 0 };
    (start, start + base + extra)
}

/// Grid dimensions `(gh, gw)` for the 2D-grid scheme on `n` nodes.
///
/// `gw = ⌈√n⌉`, `gh = ⌈n/gw⌉`; the grid may have more cells than nodes
/// (3 nodes → 2×2 grid → one node owns two cells and does ~2× the work),
/// which is exactly the imbalance the paper observes on the 3-node testbed.
pub fn grid_dims(n: usize) -> (i64, i64) {
    let gw = (n as f64).sqrt().ceil() as i64;
    let gh = (n as i64 + gw - 1) / gw;
    (gh, gw)
}

/// Canonical output tile of `node` for `layer` under `scheme` with `nodes`
/// devices. Tiles across nodes are disjoint and partition the output space
/// (modulo empty tiles when a dimension is smaller than the split count).
pub fn out_tile(layer: &LayerMeta, scheme: Scheme, nodes: usize, node: usize) -> Tile {
    let n = nodes as i64;
    let i = node as i64;
    match scheme {
        Scheme::InH => {
            let (h0, h1) = split_even(layer.out_h, n, i);
            vec![Region::new(h0, h1, 0, layer.out_w, 0, layer.out_c)]
        }
        Scheme::InW => {
            let (w0, w1) = split_even(layer.out_w, n, i);
            vec![Region::new(0, layer.out_h, w0, w1, 0, layer.out_c)]
        }
        Scheme::OutC => {
            let (c0, c1) = split_even(layer.out_c, n, i);
            vec![Region::new(0, layer.out_h, 0, layer.out_w, c0, c1)]
        }
        Scheme::Grid2d => {
            let (gh, gw) = grid_dims(nodes);
            let mut tile = Tile::new();
            for cell in 0..(gh * gw) {
                if cell % n != i {
                    continue;
                }
                let (r, c) = (cell / gw, cell % gw);
                let (h0, h1) = split_even(layer.out_h, gh, r);
                let (w0, w1) = split_even(layer.out_w, gw, c);
                let reg = Region::new(h0, h1, w0, w1, 0, layer.out_c);
                if !reg.is_empty() {
                    tile.push(reg);
                }
            }
            tile
        }
    }
}

/// All nodes' canonical tiles for one layer.
pub fn out_tiles(layer: &LayerMeta, scheme: Scheme, nodes: usize) -> Vec<Tile> {
    (0..nodes).map(|i| out_tile(layer, scheme, nodes, i)).collect()
}

/// The input region `layer` needs in order to compute the output region `r`
/// (receptive-field arithmetic, clamped to the valid input extent — padding
/// contributes zeros, not transfers).
pub fn in_region(layer: &LayerMeta, r: &Region) -> Region {
    if r.is_empty() {
        return Region::empty();
    }
    if layer.conv_t == ConvType::Attention {
        // Every output row depends on all input rows (e.g. softmax(QKᵀ)V).
        return Region::full(layer.in_h, layer.in_w, layer.in_c);
    }
    let h0 = (r.h0 * layer.s - layer.p).max(0);
    let h1 = ((r.h1 - 1) * layer.s - layer.p + layer.k).min(layer.in_h);
    let w0 = (r.w0 * layer.s - layer.p).max(0);
    let w1 = ((r.w1 - 1) * layer.s - layer.p + layer.k).min(layer.in_w);
    let (c0, c1) = match layer.conv_t {
        // Channel-preserving ops: input channel range mirrors the output's.
        ConvType::Depthwise | ConvType::Pool => (r.c0, r.c1),
        // Dense / standard / pointwise: every output channel reads all input
        // channels.
        _ => (0, layer.in_c),
    };
    Region { h0, h1, w0, w1, c0, c1 }
}

/// Input regions needed for a whole tile.
pub fn in_regions(layer: &LayerMeta, tile: &Tile) -> Tile {
    tile.iter().map(|r| in_region(layer, r)).filter(|r| !r.is_empty()).collect()
}

/// Byte matrix `msgs[a*nodes + b]` = bytes node `a` must send node `b` so
/// that every node `b` obtains `need[b]`, given node `a` currently holds
/// `have[a]`. `have` tiles must be disjoint across nodes (canonical tiles
/// are); data a node already holds is never transferred.
pub fn boundary_messages(have: &[Tile], need: &[Tile], elem_bytes: u64) -> Vec<u64> {
    let nodes = have.len();
    debug_assert_eq!(need.len(), nodes);
    let mut msgs = vec![0u64; nodes * nodes];
    for b in 0..nodes {
        for a in 0..nodes {
            if a == b {
                continue;
            }
            let vol = super::intersection_volume(&have[a], &need[b]);
            msgs[a * nodes + b] = vol as u64 * elem_bytes;
        }
    }
    msgs
}

/// Message matrix for the initial input scatter: the leader (node 0) holds
/// the whole input; every other node receives the input region its first
/// tile requires.
pub fn scatter_messages(layer0: &LayerMeta, need: &[Tile], elem_bytes: u64) -> Vec<u64> {
    let nodes = need.len();
    let full = vec![Region::full(layer0.in_h, layer0.in_w, layer0.in_c)];
    let mut msgs = vec![0u64; nodes * nodes];
    for (b, nb) in need.iter().enumerate().skip(1) {
        msgs[b] = super::intersection_volume(&full, nb) as u64 * elem_bytes; // 0 -> b
    }
    msgs
}

/// Message matrix for the final gather: every node ships its output tile to
/// the leader.
pub fn gather_messages(tiles: &[Tile], elem_bytes: u64) -> Vec<u64> {
    let nodes = tiles.len();
    let mut msgs = vec![0u64; nodes * nodes];
    for (a, t) in tiles.iter().enumerate().skip(1) {
        msgs[a * nodes] = super::union_volume(t) as u64 * elem_bytes; // a -> 0
    }
    msgs
}

/// The bottleneck (maximum) per-node output volume under a scheme — drives
/// the compute imbalance effects of §4 (e.g. 14×14 maps on 4 nodes).
pub fn bottleneck_out_volume(layer: &LayerMeta, scheme: Scheme, nodes: usize) -> i64 {
    (0..nodes)
        .map(|i| super::union_volume(&out_tile(layer, scheme, nodes, i)))
        .max()
        .unwrap_or(0)
}

/// Compute imbalance factor: bottleneck volume / ideal even share.
pub fn imbalance(layer: &LayerMeta, scheme: Scheme, nodes: usize) -> f64 {
    let bottleneck = bottleneck_out_volume(layer, scheme, nodes) as f64;
    let ideal = layer.out_volume() as f64 / nodes as f64;
    if ideal == 0.0 {
        1.0
    } else {
        bottleneck / ideal
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ConvType, LayerMeta};
    use crate::partition::union_volume;

    fn conv(h: i64, c_in: i64, c_out: i64, k: i64, s: i64, p: i64) -> LayerMeta {
        LayerMeta::conv("t", ConvType::Standard, h, h, c_in, c_out, k, s, p)
    }

    #[test]
    fn split_even_covers_exactly() {
        for len in [1i64, 7, 14, 56, 224] {
            for n in 1..=6i64 {
                let mut covered = 0;
                let mut prev_end = 0;
                for i in 0..n {
                    let (s, e) = split_even(len, n, i);
                    assert_eq!(s, prev_end);
                    assert!(e - s >= len / n && e - s <= len / n + 1);
                    covered += e - s;
                    prev_end = e;
                }
                assert_eq!(covered, len);
            }
        }
    }

    #[test]
    fn grid_dims_match_paper() {
        assert_eq!(grid_dims(4), (2, 2));
        assert_eq!(grid_dims(3), (2, 2)); // 4 cells on 3 nodes → imbalance
        assert_eq!(grid_dims(6), (2, 3));
        assert_eq!(grid_dims(5), (2, 3));
        assert_eq!(grid_dims(2), (1, 2));
    }

    #[test]
    fn tiles_partition_output_space() {
        let l = conv(14, 512, 512, 3, 1, 1);
        for scheme in Scheme::ALL {
            for nodes in 2..=6 {
                let tiles = out_tiles(&l, scheme, nodes);
                let total: i64 = tiles.iter().map(|t| union_volume(t)).sum();
                assert_eq!(total, l.out_volume(), "{scheme} n={nodes}");
                // disjointness across nodes
                for a in 0..nodes {
                    for b in (a + 1)..nodes {
                        assert_eq!(
                            crate::partition::intersection_volume(&tiles[a], &tiles[b]),
                            0,
                            "{scheme} n={nodes} tiles {a},{b} overlap"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn grid_3node_has_double_loaded_node() {
        // Paper §4.2: with 3 nodes the 2D-grid gives one node twice the work.
        let l = conv(56, 64, 64, 3, 1, 1);
        let vols: Vec<i64> = (0..3)
            .map(|i| union_volume(&out_tile(&l, Scheme::Grid2d, 3, i)))
            .collect();
        let max = *vols.iter().max().unwrap() as f64;
        let min = *vols.iter().min().unwrap() as f64;
        assert!(max / min > 1.9, "vols = {vols:?}");
    }

    #[test]
    fn imbalance_14x14_on_4_nodes() {
        // 14 rows on 4 nodes → 4,4,3,3: bottleneck 4/3.5 ≈ 1.14 for InH;
        // 2D-grid 7×7 cells are exact → 1.0.
        let l = conv(14, 512, 512, 3, 1, 1);
        assert!((imbalance(&l, Scheme::InH, 4) - 4.0 / 3.5).abs() < 1e-9);
        assert!((imbalance(&l, Scheme::Grid2d, 4) - 1.0).abs() < 1e-9);
        // OutC: 512 channels split 128 each → perfectly balanced.
        assert!((imbalance(&l, Scheme::OutC, 4) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn in_region_same_padding() {
        let l = conv(16, 8, 8, 3, 1, 1);
        // interior rows need one halo row each side
        let r = Region::new(4, 8, 0, 16, 0, 8);
        let ir = in_region(&l, &r);
        assert_eq!((ir.h0, ir.h1), (3, 9));
        assert_eq!((ir.w0, ir.w1), (0, 16));
        assert_eq!((ir.c0, ir.c1), (0, 8));
        // border rows clamp at the feature-map edge
        let r0 = Region::new(0, 4, 0, 16, 0, 8);
        let ir0 = in_region(&l, &r0);
        assert_eq!((ir0.h0, ir0.h1), (0, 5));
    }

    #[test]
    fn in_region_strided() {
        let l = conv(16, 8, 8, 3, 2, 1);
        assert_eq!(l.out_h, 8);
        let r = Region::new(2, 4, 0, 8, 0, 8);
        let ir = in_region(&l, &r);
        // rows 2..4 of out need input rows 2*2-1 .. 3*2-1+3 = 3..8
        assert_eq!((ir.h0, ir.h1), (3, 8));
    }

    #[test]
    fn in_region_depthwise_preserves_channels() {
        let l = LayerMeta::conv("dw", ConvType::Depthwise, 16, 16, 8, 8, 3, 1, 1);
        let r = Region::new(0, 16, 0, 16, 2, 6);
        let ir = in_region(&l, &r);
        assert_eq!((ir.c0, ir.c1), (2, 6));
    }

    #[test]
    fn in_region_attention_needs_all_rows() {
        let l = LayerMeta::attention("att", 128, 768, 128);
        let r = Region::new(0, 32, 0, 1, 0, 128);
        let ir = in_region(&l, &r);
        assert_eq!((ir.h0, ir.h1), (0, 128));
        assert_eq!((ir.c0, ir.c1), (0, 768));
    }

    #[test]
    fn boundary_messages_inh_halo_only() {
        // Same-scheme InH boundary on a same-padded conv: each node needs one
        // halo row from each spatial neighbour.
        let l = conv(16, 8, 8, 3, 1, 1);
        let nodes = 4;
        let have = out_tiles(&l, Scheme::InH, nodes);
        let next = conv(16, 8, 8, 3, 1, 1);
        let need: Vec<Tile> = (0..nodes)
            .map(|b| in_regions(&next, &out_tile(&next, Scheme::InH, nodes, b)))
            .collect();
        let msgs = boundary_messages(&have, &need, 4);
        // node1 needs row 3 from node0 and row 8 from node2: 16*8*4 bytes each
        let row_bytes = 16 * 8 * 4u64;
        assert_eq!(msgs[0 * nodes + 1], row_bytes);
        assert_eq!(msgs[2 * nodes + 1], row_bytes);
        assert_eq!(msgs[3 * nodes + 1], 0);
        // symmetric: corner nodes receive one halo row only
        assert_eq!(msgs[1 * nodes + 0], row_bytes);
        assert_eq!(msgs[2 * nodes + 0], 0);
    }

    #[test]
    fn boundary_messages_outc_allgather() {
        // OutC→anything: each node holds a channel slice of the previous
        // output; a standard conv next layer needs all channels everywhere.
        let l = conv(8, 16, 16, 1, 1, 0);
        let nodes = 4;
        let have = out_tiles(&l, Scheme::OutC, nodes);
        let next = LayerMeta::conv("n", ConvType::Pointwise, 8, 8, 16, 32, 1, 1, 0);
        let need: Vec<Tile> = (0..nodes)
            .map(|b| in_regions(&next, &out_tile(&next, Scheme::OutC, nodes, b)))
            .collect();
        let msgs = boundary_messages(&have, &need, 4);
        // every node must receive 3/4 of the full map: from each other node,
        // its full channel slice = 8*8*4 elems
        for a in 0..nodes {
            for b in 0..nodes {
                if a != b {
                    assert_eq!(msgs[a * nodes + b], 8 * 8 * 4 * 4);
                }
            }
        }
    }

    #[test]
    fn same_scheme_matmul_rows_no_traffic() {
        // Row-split dense chains need zero sync (no receptive-field overlap):
        // BERT's "easy parallelism" (paper §4.1 Limitation).
        let l = LayerMeta::dense("fc1", 128, 768, 768);
        let next = LayerMeta::dense("fc2", 128, 768, 768);
        let nodes = 4;
        let have = out_tiles(&l, Scheme::InH, nodes);
        let need: Vec<Tile> = (0..nodes)
            .map(|b| in_regions(&next, &out_tile(&next, Scheme::InH, nodes, b)))
            .collect();
        let msgs = boundary_messages(&have, &need, 4);
        assert!(msgs.iter().all(|&m| m == 0));
    }

    #[test]
    fn scatter_and_gather_shapes() {
        let l = conv(16, 3, 8, 3, 1, 1);
        let nodes = 4;
        let need: Vec<Tile> = (0..nodes)
            .map(|b| in_regions(&l, &out_tile(&l, Scheme::InH, nodes, b)))
            .collect();
        let sc = scatter_messages(&l, &need, 4);
        assert_eq!(sc[0], 0); // leader keeps its part
        assert!(sc[1] > 0 && sc[2] > 0 && sc[3] > 0);
        let tiles = out_tiles(&l, Scheme::InH, nodes);
        let ga = gather_messages(&tiles, 4);
        assert_eq!(ga[1 * nodes], (16 / 4) * 16 * 8 * 4);
        assert_eq!(ga[0], 0);
    }
}
