//! Serving front-end: request router + dynamic batcher + block pipeline.
//!
//! The paper's engine serves one inference at a time; a deployable system
//! needs admission, queueing, batching and — under load — pipelining in
//! front of the cluster. The [`Server`] owns a router thread: requests are
//! admitted into a bounded queue, the batcher drains up to `max_batch`
//! requests (or waits out `batch_window` for stragglers), and the batch is
//! executed on the simulated cluster. Python is nowhere on this path.
//!
//! Two execution modes ([`ServeConfig::pipeline_depth`]):
//!
//! * **Lockstep** (`pipeline_depth <= 1`): the router runs each batch to
//!   completion before forming the next — the latency-serving shape, and
//!   the paper's assumption.
//! * **Pipelined** (`pipeline_depth > 1`): the router *feeds* a
//!   [`BlockPipeline`] — one persistent stage thread per plan block — and
//!   completes requests as they stream out, so consecutive batches overlap
//!   across plan blocks and steady-state throughput is set by the
//!   bottleneck stage. Per-stage occupancy and drain accounting ride back
//!   on [`RouterStats::pipeline`].
//!
//! Two plan sources drive either mode:
//!
//! * [`Server::start`] — the static path: one frozen plan for one frozen
//!   testbed, forever.
//! * [`Server::start_elastic`] — the condition-aware path. In lockstep the
//!   [`ElasticFrontend`] is consulted at every batch boundary (a single
//!   atomic epoch load in the steady state; swaps land between batches).
//!   In pipelined mode a plan swap becomes a **drain-and-flush**: a cheap
//!   per-batch probe ([`ElasticFrontend::needs_flush`]) watches the
//!   liveness mask and the background planner's publication epoch, and
//!   only when one moves does the router drain the in-flight generation,
//!   consult the frontend once for the new generation, and rebuild the
//!   pipeline on the new plan/node set — so the frontend is consulted per
//!   drained generation rather than per batch, and no request is ever lost
//!   across a swap.
//! * [`Server::start_telemetry`] — the *measured* condition-aware path:
//!   the same elastic frontend, but its snapshots come from
//!   [`crate::telemetry`] probes instead of trace reads — each executed
//!   batch's boundary traffic feeds back as a passive bandwidth sample,
//!   and (with [`ElasticConfig::forecast`]) the background planner
//!   pre-warms the plan cache for the conditions the forecaster projects.
//!
//! No node is immortal — the leader included. Each generation is bound to
//! an elected leader (lowest surviving rank,
//! [`crate::cluster::election::elect_leader`]); when the *leader* dies the
//! flush becomes an abort instead of a drain: in-flight inferences — whose
//! outputs lived on the dead gather owner — are **captured with their
//! admission order and re-executed on the rebuilt generation** (replay
//! recovery, [`RouterStats::replayed_on_leader_loss`]). Replayed responses
//! are bit-identical to what the dead generation would have produced
//! (numerics are node-count- and leader-invariant) and stay in submission
//! order, because orphans re-enter the new pipeline ahead of newly
//! collected requests. Each request carries a bounded replay budget
//! ([`ServeConfig::replay_budget`]); an orphan past its budget degrades to
//! the pre-replay contract — failed explicitly and counted in
//! [`RouterStats::failed_on_leader_loss`] (its response channel
//! disconnects; nothing hangs and nothing is silently dropped). Queued
//! requests re-admit under the new leader either way. In lockstep mode a
//! leader loss costs nothing: batch boundaries never leave work in flight,
//! so the next batch simply executes with the new leader at logical
//! node 0.
//!
//! [`Server::shutdown`] stops the router after the batch in flight:
//! requests still sitting in the admission queue are drained and failed
//! explicitly (their response channels drop, so `submit()` callers observe
//! a clean disconnect instead of hanging), counted in
//! [`RouterStats::failed_on_shutdown`].
//!
//! A fourth plan source, [`Server::start_process`], swaps the execution
//! substrate instead of the plan source: batches route to a
//! [`crate::transport::coord::ProcessCluster`] — real node daemons over
//! TCP/UDS — rather than in-process threads. Because the wire protocol
//! runs the identical lockstep exchange, the outputs are bit-identical to
//! the in-process paths; a daemon death mid-batch surfaces as an explicit
//! failed inference, the router reinstalls on the survivors
//! ([`RouterStats::process_failovers`]) and **replays the same input** on
//! the rebuilt cluster ([`RouterStats::replayed_on_dead_cluster`], bounded
//! by the same [`ServeConfig::replay_budget`]); only an exhausted budget
//! or an unrecoverable cluster fails requests
//! ([`RouterStats::failed_on_dead_cluster`]) — the same
//! zero-silent-drop contract as every other path.
//!
//! Every admitted request is **traced**: admission assigns a process-unique
//! trace id ([`crate::trace`]) and the router records queue / service /
//! wire / end-to-end spans into a server-owned lock-free
//! [`FlightRecorder`] as each response completes (pipelined stages add
//! per-stage busy spans; the process router derives the wire span as its
//! measured round trip minus the daemon-reported compute time). Recording
//! is allocation-free on the serving path; [`Server::shutdown`] merges the
//! recorder into [`RouterStats::trace`], and the open-loop harness drains
//! it for percentile-level latency decomposition.

pub mod frontdoor;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError};
use std::sync::{mpsc::sync_channel, Arc};
use std::time::{Duration, Instant};

use crate::cluster::pipeline::{BlockPipeline, Completion, PipelineStats};
use crate::compute::{ComputeConfig, Tensor, WeightStore};
use crate::elastic::{ConditionTrace, ElasticConfig, ElasticFrontend};
use crate::engine;
use crate::metrics::{AdaptationMetrics, PipelineSummary, Summary};
use crate::model::Model;
use crate::net::Testbed;
use crate::partition::Plan;
use crate::telemetry::{TelemetryConfig, TelemetrySource};
use crate::trace::{
    merge_spans, FlightRecorder, SpanRecord, TraceSummary, CTL_NODE, KIND_QUEUE, KIND_SERVICE,
    KIND_TOTAL, KIND_WIRE,
};

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Maximum requests per batch.
    pub max_batch: usize,
    /// How long the batcher waits for more requests after the first.
    pub batch_window: Duration,
    /// Bounded admission queue depth (backpressure beyond this).
    pub queue_depth: usize,
    /// In-flight batch budget of the block pipeline: `<= 1` serves in
    /// lockstep (batch at a time); `> 1` feeds the per-block pipeline with
    /// up to this many submissions queued at its entry (each stage holds
    /// one more in flight).
    pub pipeline_depth: usize,
    /// How many times one request may be re-executed after its inference
    /// was aborted by a leader loss (pipelined path) or a member death
    /// (process path). `0` restores the pre-replay behavior: every abort
    /// is an explicit client-visible failure.
    pub replay_budget: u32,
    /// Node-compute tuning (tile worker pool, parallelism threshold,
    /// buffer-arena reuse), threaded into both the lockstep and pipelined
    /// executors.
    pub compute: ComputeConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 8,
            batch_window: Duration::from_millis(2),
            queue_depth: 128,
            pipeline_depth: 1,
            replay_budget: 3,
            compute: ComputeConfig::default(),
        }
    }
}

/// A completed inference.
#[derive(Debug)]
pub struct Response {
    pub output: Tensor,
    /// Time spent queued before the batch formed (lockstep) or before the
    /// request entered the pipeline (pipelined).
    pub queued: Duration,
    /// Host wall-clock service time: the whole batch's execution in
    /// lockstep, submission-to-completion through the pipeline otherwise.
    pub service: Duration,
    /// Virtual-clock (simulated-testbed) inference time per item, under the
    /// conditions the batch actually ran in.
    pub virtual_time: f64,
    /// Size of the batch this request rode in.
    pub batch_size: usize,
    /// Number of cluster nodes the batch executed on (drops below the
    /// baseline when the elastic path fails over).
    pub nodes: usize,
    /// Original rank of the leader (scatter/ingress + gather owner) that
    /// served this request — moves off rank 0 after a leader failover.
    pub leader: usize,
    /// Router-assigned completion sequence number, strictly increasing in
    /// delivery order. Because the router serves FIFO (lockstep batches in
    /// admission order; the pipeline completes in submission order), a
    /// client that submits in order must observe increasing `seq` across
    /// its responses — the chaos harness asserts exactly that.
    pub seq: u64,
}

struct Request {
    input: Tensor,
    enqueued: Instant,
    /// Trace id assigned at admission (never 0 — every request is traced;
    /// the recorder is lock-free and allocation-free, so tracing is on by
    /// default).
    trace: u64,
    resp: Sender<Response>,
}

/// Admission error: queue full (backpressure) or server stopped.
#[derive(Debug, PartialEq, Eq)]
pub enum AdmitError {
    QueueFull,
    Stopped,
}

/// Per-reason shed counters, shared between every [`ServerHandle`] clone
/// (the front door increments them as it denies submissions) and folded
/// into [`RouterStats`] at shutdown. Reason codes mirror the wire denial
/// codes: [`frontdoor::DENY_QUEUE_FULL`], [`frontdoor::DENY_STOPPED`],
/// [`frontdoor::DENY_FAILED`] — the load harness asserts conservation
/// against the agents' own per-reason tallies.
#[derive(Debug, Default)]
pub struct ShedCounters {
    queue_full: AtomicU64,
    stopped: AtomicU64,
    failed: AtomicU64,
}

impl ShedCounters {
    /// Count one denial under its wire reason code. Unknown codes count as
    /// `failed` — a denial is never silently dropped from the books.
    pub fn note(&self, reason: u8) {
        let c = match reason {
            frontdoor::DENY_QUEUE_FULL => &self.queue_full,
            frontdoor::DENY_STOPPED => &self.stopped,
            _ => &self.failed,
        };
        c.fetch_add(1, Ordering::Relaxed);
    }

    pub fn queue_full(&self) -> u64 {
        self.queue_full.load(Ordering::Relaxed)
    }

    pub fn stopped(&self) -> u64 {
        self.stopped.load(Ordering::Relaxed)
    }

    pub fn failed(&self) -> u64 {
        self.failed.load(Ordering::Relaxed)
    }
}

/// Admission-queue occupancy shared between the submit side and the
/// router: submits increment, the router decrements as it pulls requests
/// into a batch, and the high-water mark rides back on
/// [`RouterStats::queue_peak`]. Plain counters, no locks — the open-loop
/// harness reads the gauge while load is in flight.
#[derive(Debug, Default)]
pub struct QueueGauge {
    depth: AtomicUsize,
    peak: AtomicUsize,
}

impl QueueGauge {
    fn admitted(&self) {
        let d = self.depth.fetch_add(1, Ordering::SeqCst) + 1;
        self.peak.fetch_max(d, Ordering::SeqCst);
    }

    fn dequeued(&self) {
        self.depth.fetch_sub(1, Ordering::SeqCst);
    }

    /// Requests currently sitting in the admission queue.
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::SeqCst)
    }

    /// Deepest the queue has ever been.
    pub fn peak(&self) -> usize {
        self.peak.load(Ordering::SeqCst)
    }
}

/// A cloneable submit-side handle: the open-loop front door
/// ([`frontdoor::FrontDoor`]) fans wire connections into one of these from
/// its own threads. Holding a handle keeps the admission queue open —
/// [`Server::shutdown`] can only drain once every handle is dropped, so
/// stop the front door first.
#[derive(Clone)]
pub struct ServerHandle {
    tx: SyncSender<Request>,
    gauge: Arc<QueueGauge>,
    recorder: Arc<FlightRecorder>,
    shed: Arc<ShedCounters>,
}

impl ServerHandle {
    /// Submit without waiting; returns the response channel. Identical
    /// admission contract to [`Server::submit`].
    pub fn submit(&self, input: Tensor) -> Result<Receiver<Response>, AdmitError> {
        submit_via(&self.tx, &self.gauge, &self.recorder, input)
    }

    /// The shared queue-occupancy gauge.
    pub fn gauge(&self) -> &QueueGauge {
        &self.gauge
    }

    /// The server's flight recorder (span source for trace dumps).
    pub fn recorder(&self) -> &FlightRecorder {
        &self.recorder
    }

    /// The shared per-reason shed counters.
    pub fn shed(&self) -> &ShedCounters {
        &self.shed
    }

    /// An owning clone of the shed counters for threads that outlive this
    /// handle (the front door's per-connection writers).
    pub fn shed_arc(&self) -> Arc<ShedCounters> {
        Arc::clone(&self.shed)
    }
}

fn submit_via(
    tx: &SyncSender<Request>,
    gauge: &QueueGauge,
    recorder: &FlightRecorder,
    input: Tensor,
) -> Result<Receiver<Response>, AdmitError> {
    let (resp_tx, resp_rx) = channel();
    let req = Request {
        input,
        enqueued: Instant::now(),
        trace: recorder.next_trace_id(),
        resp: resp_tx,
    };
    match tx.try_send(req) {
        Ok(()) => {
            gauge.admitted();
            Ok(resp_rx)
        }
        Err(TrySendError::Full(_)) => Err(AdmitError::QueueFull),
        Err(TrySendError::Disconnected(_)) => Err(AdmitError::Stopped),
    }
}

/// The serving handle. Dropping the server (or calling
/// [`Server::shutdown`]) stops the router.
pub struct Server {
    tx: SyncSender<Request>,
    stop: Arc<AtomicBool>,
    gauge: Arc<QueueGauge>,
    recorder: Arc<FlightRecorder>,
    shed: Arc<ShedCounters>,
    router: Option<std::thread::JoinHandle<RouterStats>>,
}

/// Router counters.
#[derive(Debug, Default, Clone)]
pub struct RouterStats {
    pub requests: u64,
    pub batches: u64,
    pub max_batch_seen: usize,
    /// Admitted requests failed (response channel dropped) because
    /// [`Server::shutdown`] stopped the router before they were served.
    pub failed_on_shutdown: u64,
    /// Requests failed because the leader died with their inference in
    /// flight **and** their replay budget was already spent: the pipeline
    /// generation aborts and their response channels disconnect. Requests
    /// within budget are replayed instead (see
    /// [`RouterStats::replayed_on_leader_loss`]); requests still in the
    /// admission queue (or the batch being formed) are never failed — they
    /// re-admit under the new leader. Zero on the lockstep path, where
    /// batch boundaries never leave work in flight.
    pub failed_on_leader_loss: u64,
    /// Requests whose in-flight inference was aborted by a leader loss and
    /// re-executed on the rebuilt pipeline generation (counted once per
    /// request) — the client sees nothing but added latency.
    pub replayed_on_leader_loss: u64,
    /// Process mode: requests that completed only after at least one
    /// replay on a reinstalled cluster (a member died mid-inference).
    pub replayed_on_dead_cluster: u64,
    /// Total re-executions performed across all requests (a request
    /// replayed twice counts twice) — the replay path's work, off the
    /// client's books.
    pub replay_attempts: u64,
    /// Present on the elastic path: replan/cache/failover counters. On the
    /// pipelined path `checks` counts frontend consultations, which happen
    /// once per drained generation rather than per batch.
    pub adaptation: Option<AdaptationMetrics>,
    /// Present on the elastic path: how long batch boundaries spent
    /// acquiring their plan (the stall the background replanner is meant to
    /// eliminate — steady state is one atomic load).
    pub boundary_stall: Option<Summary>,
    /// Present on the pipelined path: per-stage occupancy, bottleneck stage
    /// and drain-and-flush generation counts.
    pub pipeline: Option<PipelineSummary>,
    /// Process mode only: how many times a member death forced a
    /// reinstall-and-retry (the wire counterpart of elastic failover).
    pub process_failovers: u64,
    /// Process mode only: requests failed explicitly because the cluster
    /// could not be rebuilt (no survivors / reinstall kept failing). Their
    /// response channels disconnect — never a hang, never a silent drop.
    pub failed_on_dead_cluster: u64,
    /// Deepest the admission queue ever got (from the shared
    /// [`QueueGauge`]) — the open-loop harness's headroom signal.
    pub queue_peak: usize,
    /// Total time requests spent in the admission queue before the router
    /// pulled them (queue age, summed over requests; divide by
    /// [`RouterStats::requests`] for the mean).
    pub queue_wait_total: Duration,
    /// Worst single admission-queue wait.
    pub queue_wait_max: Duration,
    /// Front-door denials for a full admission queue (wire reason 0) —
    /// from the shared [`ShedCounters`], zero when no front door ran.
    pub shed_queue_full: u64,
    /// Front-door denials because the server had stopped (wire reason 1).
    pub shed_stopped: u64,
    /// Admitted-but-failed denials (wire reason 2): shutdown drain or an
    /// exhausted replay budget, observed by the front door as a response
    /// channel disconnecting.
    pub shed_failed: u64,
    /// Merged span trees from the server's flight recorder: what tracing
    /// saw, aggregated ([`crate::trace::TraceSummary`]). `None` only when
    /// nothing was ever recorded.
    pub trace: Option<TraceSummary>,
}

/// Where the router gets the plan for the next batch.
enum PlanSource {
    Static {
        plan: Arc<Plan>,
        nodes: usize,
        virtual_time: f64,
    },
    Elastic {
        fe: ElasticFrontend,
        /// Virtual clock: cumulative predicted inference seconds served.
        vt: f64,
    },
}

impl Server {
    /// Start serving `model` with a frozen `plan` on the simulated `testbed`.
    pub fn start(
        model: Model,
        plan: Plan,
        weights: WeightStore,
        testbed: Testbed,
        cfg: ServeConfig,
    ) -> Server {
        plan.validate().expect("invalid plan");
        let virtual_time = engine::evaluate(&model, &plan, &testbed).total;
        let source = PlanSource::Static {
            plan: Arc::new(plan),
            nodes: testbed.nodes,
            virtual_time,
        };
        Self::spawn(model, weights, cfg, source)
    }

    /// Start the condition-aware serving path: plan for the trace's `t = 0`
    /// conditions, then monitor/replan/swap on the background planner
    /// thread, consulted (wait-free in the steady state) at every batch
    /// boundary — or once per drained generation in pipelined mode.
    pub fn start_elastic(
        model: Model,
        weights: WeightStore,
        base: Testbed,
        trace: ConditionTrace,
        cfg: ServeConfig,
        ecfg: ElasticConfig,
    ) -> Server {
        let fe = ElasticFrontend::start(model.clone(), base, trace, ecfg);
        Self::spawn(model, weights, cfg, PlanSource::Elastic { fe, vt: 0.0 })
    }

    /// Start the *measured*-conditions serving path: identical to
    /// [`Server::start_elastic`] except the controller never reads `world`
    /// directly — a [`TelemetrySource`] measures it through passive probes
    /// on the traffic this server moves (in lockstep mode each executed
    /// batch's boundary bytes feed back as bandwidth samples; the pipelined
    /// router's per-batch probes tick the rate-limited active prober
    /// instead), plus heartbeat and compute sweeps. Enable
    /// [`ElasticConfig::forecast`] to also pre-warm the plan cache for the
    /// conditions the forecaster projects.
    pub fn start_telemetry(
        model: Model,
        weights: WeightStore,
        base: Testbed,
        world: ConditionTrace,
        tcfg: TelemetryConfig,
        cfg: ServeConfig,
        ecfg: ElasticConfig,
    ) -> Server {
        let source = TelemetrySource::new(world, &base, tcfg);
        let fe = ElasticFrontend::start_with_source(model.clone(), base, Box::new(source), ecfg);
        Self::spawn(model, weights, cfg, PlanSource::Elastic { fe, vt: 0.0 })
    }

    /// Start serving on a **process cluster**: real node daemons over
    /// TCP/UDS, discovered through the registry and already holding an
    /// installed plan (see [`crate::transport::coord::ProcessCluster`]).
    /// Serves in lockstep (the wire protocol is batch-at-a-time;
    /// `pipeline_depth` is ignored). Outputs are bit-identical to the
    /// in-process paths; member deaths trigger reinstall-and-retry on the
    /// survivors, and [`Server::shutdown`] also shuts the daemons down.
    pub fn start_process(cluster: crate::transport::coord::ProcessCluster, cfg: ServeConfig) -> Server {
        let (tx, rx) = sync_channel::<Request>(cfg.queue_depth);
        let stop = Arc::new(AtomicBool::new(false));
        let gauge = Arc::new(QueueGauge::default());
        let recorder = Arc::new(FlightRecorder::new());
        let shed = Arc::new(ShedCounters::default());
        let router_stop = stop.clone();
        let router_gauge = gauge.clone();
        let router_recorder = recorder.clone();
        let router = std::thread::spawn(move || {
            router_process(rx, &cfg, cluster, &router_stop, &router_gauge, &router_recorder)
        });
        Server { tx, stop, gauge, recorder, shed, router: Some(router) }
    }

    fn spawn(model: Model, weights: WeightStore, cfg: ServeConfig, source: PlanSource) -> Server {
        let (tx, rx) = sync_channel::<Request>(cfg.queue_depth);
        let stop = Arc::new(AtomicBool::new(false));
        let gauge = Arc::new(QueueGauge::default());
        let recorder = Arc::new(FlightRecorder::new());
        let shed = Arc::new(ShedCounters::default());
        let router_stop = stop.clone();
        let router_gauge = gauge.clone();
        let router_recorder = recorder.clone();
        let router = std::thread::spawn(move || {
            let weights = Arc::new(weights);
            router_main(
                rx,
                &model,
                &weights,
                &cfg,
                source,
                &router_stop,
                &router_gauge,
                &router_recorder,
            )
        });
        Server { tx, stop, gauge, recorder, shed, router: Some(router) }
    }

    /// Submit one inference and wait for its completion.
    pub fn infer(&self, input: Tensor) -> Result<Response, AdmitError> {
        let rx = self.submit(input)?;
        rx.recv().map_err(|_| AdmitError::Stopped)
    }

    /// Submit without waiting; returns the response channel.
    pub fn submit(&self, input: Tensor) -> Result<Receiver<Response>, AdmitError> {
        submit_via(&self.tx, &self.gauge, &self.recorder, input)
    }

    /// The server's flight recorder: drain it ([`FlightRecorder::snapshot`])
    /// and feed [`merge_spans`] for per-request latency decomposition while
    /// the server is still running.
    pub fn recorder(&self) -> &Arc<FlightRecorder> {
        &self.recorder
    }

    /// A cloneable submit-side handle for threads that fan requests in —
    /// the wire front door, load agents, anything that must not own the
    /// server. Drop every handle before [`Server::shutdown`] so the
    /// router's final drain can observe the queue closing.
    pub fn handle(&self) -> ServerHandle {
        ServerHandle {
            tx: self.tx.clone(),
            gauge: self.gauge.clone(),
            recorder: self.recorder.clone(),
            shed: self.shed.clone(),
        }
    }

    /// Stop the router and return its counters. The batch (and pipeline
    /// generation) in flight completes; requests still waiting in the
    /// admission queue are drained and failed explicitly — their response
    /// channels disconnect, so no `submit()` caller ever hangs on a dead
    /// receiver.
    pub fn shutdown(mut self) -> RouterStats {
        let handle = self.router.take().unwrap();
        let shed = Arc::clone(&self.shed);
        let recorder = Arc::clone(&self.recorder);
        self.stop.store(true, Ordering::Release);
        drop(self); // drops the queue sender → the router's drain terminates
        let mut stats = handle.join().expect("router panicked");
        stats.shed_queue_full = shed.queue_full();
        stats.shed_stopped = shed.stopped();
        stats.shed_failed = shed.failed();
        if recorder.recorded() > 0 {
            stats.trace = Some(TraceSummary::from_trees(&merge_spans(&recorder.snapshot())));
        }
        stats
    }
}

// No custom Drop: dropping the Server closes the admission queue (tx) and
// detaches the router thread, which exits once the queue drains.

#[allow(clippy::too_many_arguments)]
fn router_main(
    rx: Receiver<Request>,
    model: &Model,
    weights: &Arc<WeightStore>,
    cfg: &ServeConfig,
    source: PlanSource,
    stop: &AtomicBool,
    gauge: &QueueGauge,
    recorder: &Arc<FlightRecorder>,
) -> RouterStats {
    if cfg.pipeline_depth > 1 {
        router_pipelined(rx, model, weights, cfg, source, stop, gauge, recorder)
    } else {
        router_lockstep(rx, model, weights, cfg, source, stop, gauge, recorder)
    }
}

/// Record the router-side spans for one completed request — the end-to-end
/// interval plus its queue / service / (process-mode) wire components, all
/// on the router's clock, laid out back to back from the admission instant
/// so the merger's nesting and conservation checks are meaningful. The
/// total is measured independently (admission → now); the components are
/// whatever each path measured for them.
fn record_request_spans(
    recorder: &FlightRecorder,
    trace: u64,
    gen: u64,
    enqueued: Instant,
    queue_ns: u64,
    service_ns: u64,
    wire_ns: u64,
) {
    if trace == 0 {
        return;
    }
    let now_ns = recorder.now_ns();
    let total_ns = enqueued.elapsed().as_nanos() as u64;
    let start = now_ns.saturating_sub(total_ns);
    let span = |kind: u8, start_ns: u64, dur_ns: u64| SpanRecord {
        trace_id: trace,
        gen,
        kind,
        node: CTL_NODE,
        start_ns,
        dur_ns,
    };
    recorder.record(span(KIND_TOTAL, start, total_ns));
    recorder.record(span(KIND_QUEUE, start, queue_ns));
    recorder.record(span(KIND_SERVICE, start + queue_ns, service_ns));
    if wire_ns > 0 {
        recorder.record(span(KIND_WIRE, start + queue_ns + service_ns, wire_ns));
    }
}

/// Account a freshly collected batch leaving the admission queue: decrement
/// the occupancy gauge and fold each request's queue age into the stats.
fn note_dequeued(batch: &[Request], gauge: &QueueGauge, stats: &mut RouterStats) {
    let now = Instant::now();
    for req in batch {
        gauge.dequeued();
        let wait = now.saturating_duration_since(req.enqueued);
        stats.queue_wait_total += wait;
        stats.queue_wait_max = stats.queue_wait_max.max(wait);
    }
}

/// Collect one batch: block for the first request, then wait out the window.
fn collect_batch(rx: &Receiver<Request>, cfg: &ServeConfig) -> Option<Vec<Request>> {
    let first = rx.recv().ok()?;
    let mut batch = vec![first];
    fill_batch(rx, cfg, &mut batch);
    Some(batch)
}

/// Top a started batch up to `max_batch`, waiting out the batch window.
fn fill_batch(rx: &Receiver<Request>, cfg: &ServeConfig, batch: &mut Vec<Request>) {
    fill_batch_until(rx, cfg.max_batch, Instant::now() + cfg.batch_window, batch)
}

/// Top a started batch up to `max_batch` until `deadline` — which may
/// already lie in the past (saturating duration math: `deadline - now`
/// panics when `now` has passed it, and a router must never die to a
/// scheduling hiccup between the clock reads).
fn fill_batch_until(
    rx: &Receiver<Request>,
    max_batch: usize,
    deadline: Instant,
    batch: &mut Vec<Request>,
) {
    while batch.len() < max_batch {
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            break;
        }
        match rx.recv_timeout(remaining) {
            Ok(r) => batch.push(r),
            Err(_) => break,
        }
    }
}

/// How often the pipelined router wakes from the admission queue to reap
/// completions while inferences are in flight. Responses are therefore
/// delivered at most this long after their completion even when no new
/// request arrives to drive the loop.
const REAP_TICK: Duration = Duration::from_micros(500);

/// Wait for the next request while the pipeline works: completions are
/// reaped continuously, so a response is never withheld behind an idle
/// admission queue (a client doing submit-then-recv must not deadlock the
/// router). Blocks outright only when nothing is in flight. Returns `None`
/// once the queue has disconnected.
fn next_request_reaping(
    rx: &Receiver<Request>,
    pipe: &mut Option<BlockPipeline>,
    pending: &mut VecDeque<Pending>,
    next_seq: &mut u64,
    recorder: &FlightRecorder,
) -> Option<Request> {
    loop {
        if let Some(p) = pipe.as_mut() {
            while let Some(c) = p.try_complete() {
                complete_front(pending, c, next_seq, recorder);
            }
        }
        if pending.is_empty() {
            // pipeline idle — nothing to reap, block cheaply on the queue
            return rx.recv().ok();
        }
        match rx.recv_timeout(REAP_TICK) {
            Ok(r) => return Some(r),
            Err(RecvTimeoutError::Timeout) => continue,
            // disconnected: the final drain below the loop completes the
            // in-flight work
            Err(RecvTimeoutError::Disconnected) => return None,
        }
    }
}

/// Fail every request still sitting in the admission queue: dropping a
/// request drops its response sender, so the submitter's receiver
/// disconnects instead of hanging. Blocks until the queue sender is gone
/// ([`Server::shutdown`] drops it right after setting the stop flag), so
/// the accounting also covers a submit racing the shutdown.
fn fail_queued(rx: Receiver<Request>, gauge: &QueueGauge, stats: &mut RouterStats) {
    for _req in rx.iter() {
        gauge.dequeued();
        stats.failed_on_shutdown += 1;
    }
}

#[allow(clippy::too_many_arguments)]
fn router_lockstep(
    rx: Receiver<Request>,
    model: &Model,
    weights: &Arc<WeightStore>,
    cfg: &ServeConfig,
    mut source: PlanSource,
    stop: &AtomicBool,
    gauge: &QueueGauge,
    recorder: &FlightRecorder,
) -> RouterStats {
    let mut stats = RouterStats::default();
    let mut next_seq = 0u64;

    while let Some(batch) = collect_batch(&rx, cfg) {
        note_dequeued(&batch, gauge, &mut stats);
        stats.batches += 1;
        stats.requests += batch.len() as u64;
        stats.max_batch_seen = stats.max_batch_seen.max(batch.len());

        // Batch boundary: consult the plan source. On the elastic path this
        // is a wait-free acquisition from the background planner's slot;
        // swaps land here, never mid-batch. A leader loss costs nothing in
        // lockstep — nothing is in flight at a boundary, so the batch just
        // executes with the newly elected leader at logical node 0.
        let (plan, alive, nodes, leader, virtual_time) = match &mut source {
            PlanSource::Static { plan, nodes, virtual_time } => {
                (plan.clone(), None, *nodes, 0, *virtual_time)
            }
            PlanSource::Elastic { fe, vt } => {
                let decision = fe.acquire(*vt);
                *vt += decision.cost_per_item * batch.len() as f64;
                (
                    decision.plan,
                    Some(decision.alive),
                    decision.nodes,
                    decision.leader,
                    decision.cost_per_item,
                )
            }
        };

        let service_start = Instant::now();
        let mut moved_bytes = 0u64;
        let mut moved_msgs = 0u64;
        let outputs: Vec<Tensor> = batch
            .iter()
            .map(|req| {
                let run = match &alive {
                    // elastic path: execute on the surviving sub-cluster
                    Some(mask) => crate::cluster::run_degraded_cfg(
                        model,
                        &plan,
                        weights,
                        &req.input,
                        mask,
                        &cfg.compute,
                    ),
                    None => crate::cluster::run_distributed_cfg(
                        model,
                        &plan,
                        weights,
                        &req.input,
                        nodes,
                        &cfg.compute,
                    ),
                };
                moved_bytes += run.bytes_exchanged;
                moved_msgs += run.messages as u64;
                run.output
            })
            .collect();
        let service = service_start.elapsed();
        if let PlanSource::Elastic { fe, vt } = &mut source {
            // the batch's own boundary exchanges are the passive bandwidth
            // probe of the measured-conditions path (no-op on traces)
            fe.observe_traffic(*vt, moved_bytes, moved_msgs);
        }

        let batch_size = batch.len();
        for (req, output) in batch.into_iter().zip(outputs) {
            let seq = next_seq;
            next_seq += 1;
            let queued = service_start.duration_since(req.enqueued);
            let _ = req.resp.send(Response {
                output,
                queued,
                service,
                virtual_time,
                batch_size,
                nodes,
                leader,
                seq,
            });
            record_request_spans(
                recorder,
                req.trace,
                0,
                req.enqueued,
                queued.as_nanos() as u64,
                service.as_nanos() as u64,
                0,
            );
        }
        if stop.load(Ordering::Acquire) {
            break;
        }
    }

    // shutdown: fail whatever the stop flag stranded in the queue, then
    // stop the background planner (draining its queued asks) and fold its
    // counters into the router stats
    fail_queued(rx, gauge, &mut stats);
    stats.queue_peak = gauge.peak();
    if let PlanSource::Elastic { fe, .. } = source {
        let (adaptation, stall) = fe.finish();
        stats.adaptation = Some(adaptation);
        stats.boundary_stall = Some(stall);
    }
    stats
}

/// Lockstep router over a wire-attached daemon cluster. Per request: run
/// it with replay recovery
/// ([`crate::transport::coord::ProcessCluster::infer_with_recovery`]) — an
/// explicit failure (daemon death, deadline) bans the culprit, reinstalls
/// the plan on the survivors and re-executes the same input, up to
/// [`ServeConfig::replay_budget`] replays. The replay is bit-identical,
/// because the numerics are node-count-invariant. Requests fail (channels
/// disconnect) only when the budget is exhausted or the cluster itself is
/// unrecoverable.
fn router_process(
    rx: Receiver<Request>,
    cfg: &ServeConfig,
    mut cluster: crate::transport::coord::ProcessCluster,
    stop: &AtomicBool,
    gauge: &QueueGauge,
    recorder: &FlightRecorder,
) -> RouterStats {
    use crate::transport::coord::RecoveryOutcome;
    let mut stats = RouterStats::default();
    let mut next_seq = 0u64;
    let mut cluster_dead = false;

    while let Some(batch) = collect_batch(&rx, cfg) {
        note_dequeued(&batch, gauge, &mut stats);
        stats.batches += 1;
        stats.requests += batch.len() as u64;
        stats.max_batch_seen = stats.max_batch_seen.max(batch.len());
        let batch_size = batch.len();
        let service_start = Instant::now();

        for req in batch {
            if cluster_dead {
                // dropping `req` drops its response sender: an explicit,
                // observable failure
                stats.failed_on_dead_cluster += 1;
                continue;
            }
            // this request's own dispatch instant: everything before it is
            // queue wait (including earlier requests of the same batch)
            let dispatched = Instant::now();
            let report =
                cluster.infer_with_recovery_traced(&req.input, cfg.replay_budget, req.trace);
            stats.process_failovers += report.failovers as u64;
            stats.replay_attempts += report.replays as u64;
            match report.outcome {
                RecoveryOutcome::Done(run) => {
                    if report.replays > 0 {
                        stats.replayed_on_dead_cluster += 1;
                    }
                    let seq = next_seq;
                    next_seq += 1;
                    // Wire time is derived — coordinator round trip minus
                    // daemon-reported compute, both measured on their own
                    // clock. The daemon's service span for the successful
                    // attempt merges in by (trace, term) from trace dumps.
                    let wire_ns = run.roundtrip_ns.saturating_sub(run.service_ns);
                    let queue_ns =
                        dispatched.saturating_duration_since(req.enqueued).as_nanos() as u64;
                    let _ = req.resp.send(Response {
                        output: run.output,
                        queued: service_start.duration_since(req.enqueued),
                        service: service_start.elapsed(),
                        // no simulated testbed under this path
                        virtual_time: 0.0,
                        batch_size,
                        nodes: cluster.nodes(),
                        leader: cluster.leader() as usize,
                        seq,
                    });
                    record_request_spans(
                        recorder,
                        req.trace,
                        run.term,
                        req.enqueued,
                        queue_ns,
                        run.service_ns,
                        wire_ns,
                    );
                }
                // budget spent: the cluster is rebuilt and healthy, but
                // this request degrades to the explicit-failure contract
                RecoveryOutcome::Exhausted => stats.failed_on_dead_cluster += 1,
                RecoveryOutcome::Dead => {
                    cluster_dead = true; // no survivors — fail the rest
                    stats.failed_on_dead_cluster += 1;
                }
            }
        }
        if stop.load(Ordering::Acquire) {
            break;
        }
    }
    fail_queued(rx, gauge, &mut stats);
    stats.queue_peak = gauge.peak();
    cluster.shutdown();
    stats
}

/// Bookkeeping for one request inside the pipeline, completed in FIFO
/// order as completions stream out. Carries its input so an inference
/// aborted by a leader loss can be re-executed on the rebuilt generation.
struct Pending {
    input: Tensor,
    resp: Sender<Response>,
    enqueued: Instant,
    submitted: Instant,
    batch_size: usize,
    nodes: usize,
    leader: usize,
    virtual_time: f64,
    /// Re-executions already spent on this request.
    replays: u32,
    /// Admission-assigned trace id, carried through replays.
    trace: u64,
}

fn complete_front(
    pending: &mut VecDeque<Pending>,
    c: Completion,
    next_seq: &mut u64,
    recorder: &FlightRecorder,
) {
    let p = pending.pop_front().expect("completion without a pending request");
    let seq = *next_seq;
    *next_seq += 1;
    let queued = p.submitted.duration_since(p.enqueued);
    let service = p.submitted.elapsed();
    let _ = p.resp.send(Response {
        output: c.output,
        queued,
        service,
        virtual_time: p.virtual_time,
        batch_size: p.batch_size,
        nodes: p.nodes,
        leader: p.leader,
        seq,
    });
    record_request_spans(
        recorder,
        p.trace,
        0,
        p.enqueued,
        queued.as_nanos() as u64,
        service.as_nanos() as u64,
        0,
    );
}

/// Fold one finished generation's stage statistics into the summary —
/// occupancy snapshot plus the arena-reuse counters the metrics registry
/// reports.
fn absorb_pipeline(summary: &mut PipelineSummary, pstats: &PipelineStats) {
    summary.absorb(
        pstats.stages.len(),
        pstats.items,
        pstats.occupancy(),
        pstats.bottleneck_stage(),
    );
    summary.buf_reuses += pstats.stages.iter().map(|s| s.buf_reuses).sum::<u64>();
    summary.buf_allocs += pstats.stages.iter().map(|s| s.buf_allocs).sum::<u64>();
}

/// Drain one pipeline generation: complete everything in flight, then fold
/// the stage statistics into the summary.
fn drain_generation(
    pipe: BlockPipeline,
    pending: &mut VecDeque<Pending>,
    summary: &mut PipelineSummary,
    next_seq: &mut u64,
    recorder: &FlightRecorder,
) {
    let (rest, pstats) = pipe.finish();
    for c in rest {
        complete_front(pending, c, next_seq, recorder);
    }
    debug_assert!(pending.is_empty(), "drained generation left requests pending");
    absorb_pipeline(summary, &pstats);
}

/// Abort one pipeline generation whose leader died: in-flight completions
/// are discarded (their outputs lived on the dead gather owner) and the
/// requests behind them **captured in admission order** for replay on the
/// rebuilt generation — the router re-submits them ahead of new work, so
/// their responses stay in submission order. Nothing is failed here;
/// budget enforcement happens at re-submission. `stats.items` in the
/// summary counts only the completions this generation actually delivered.
fn abort_generation(
    pipe: BlockPipeline,
    pending: &mut VecDeque<Pending>,
    summary: &mut PipelineSummary,
) -> VecDeque<Pending> {
    let (aborted, pstats) = pipe.abort();
    debug_assert_eq!(
        aborted as usize,
        pending.len(),
        "abort accounting diverged from the pending queue"
    );
    let orphans = std::mem::take(pending);
    absorb_pipeline(summary, &pstats);
    orphans
}

#[allow(clippy::too_many_arguments)]
fn router_pipelined(
    rx: Receiver<Request>,
    model: &Model,
    weights: &Arc<WeightStore>,
    cfg: &ServeConfig,
    mut source: PlanSource,
    stop: &AtomicBool,
    gauge: &QueueGauge,
    recorder: &Arc<FlightRecorder>,
) -> RouterStats {
    let mut stats = RouterStats::default();
    let mut summary = PipelineSummary::default();
    let mut pending: VecDeque<Pending> = VecDeque::new();
    let mut pipe: Option<BlockPipeline> = None;
    let mut next_seq = 0u64;
    // current generation's execution parameters
    let mut gen_nodes = 0usize;
    let mut gen_cost = 0.0f64;
    let mut gen_leader = 0usize;

    while let Some(first) =
        next_request_reaping(&rx, &mut pipe, &mut pending, &mut next_seq, recorder)
    {
        let mut batch = vec![first];
        fill_batch(&rx, cfg, &mut batch);
        note_dequeued(&batch, gauge, &mut stats);
        stats.batches += 1;
        stats.max_batch_seen = stats.max_batch_seen.max(batch.len());

        // In-flight requests orphaned by a leader-loss abort this
        // boundary, waiting to be replayed on the rebuilt generation.
        let mut orphans: VecDeque<Pending> = VecDeque::new();

        // Generation boundary: start (or drain-and-flush) the pipeline.
        match &mut source {
            PlanSource::Static { plan, nodes, virtual_time } => {
                if pipe.is_none() {
                    gen_nodes = *nodes;
                    gen_cost = *virtual_time;
                    gen_leader = 0;
                    pipe = Some(BlockPipeline::start_traced(
                        model,
                        plan,
                        weights,
                        *nodes,
                        cfg.pipeline_depth,
                        0,
                        cfg.compute,
                        Some(Arc::clone(recorder)),
                    ));
                }
            }
            PlanSource::Elastic { fe, vt } => {
                if let Some(running) = pipe.take() {
                    if fe.needs_flush(*vt) {
                        if fe.leader_lost(*vt, gen_leader) {
                            // The generation's leader died: the gather owner
                            // holding every in-flight output is gone, so
                            // those inferences cannot complete *here*.
                            // Capture them for replay on the generation
                            // rebuilt under the new leader below; the batch
                            // just collected — and everything still in the
                            // admission queue — re-admits untouched.
                            orphans = abort_generation(running, &mut pending, &mut summary);
                        } else {
                            // Ordinary drain-and-flush: finish every
                            // in-flight inference under the old plan, then
                            // consult the frontend for the new generation.
                            drain_generation(
                                running,
                                &mut pending,
                                &mut summary,
                                &mut next_seq,
                                recorder,
                            );
                        }
                    } else {
                        pipe = Some(running);
                    }
                }
                if pipe.is_none() {
                    let decision = fe.acquire(*vt);
                    gen_nodes = decision.nodes;
                    gen_cost = decision.cost_per_item;
                    gen_leader = decision.leader;
                    pipe = Some(BlockPipeline::start_traced(
                        model,
                        &decision.plan,
                        weights,
                        decision.nodes,
                        cfg.pipeline_depth,
                        decision.leader,
                        cfg.compute,
                        Some(Arc::clone(recorder)),
                    ));
                }
                *vt += gen_cost * batch.len() as f64;
            }
        }

        let p = pipe.as_mut().expect("generation pipeline running");

        // Replay recovery: re-execute the aborted generation's in-flight
        // requests on the rebuilt one — oldest first, ahead of the batch
        // just collected, so responses keep submission order and stay
        // bit-identical (numerics are node-count- and leader-invariant).
        // An orphan past its budget degrades to the pre-replay contract:
        // dropping it disconnects its response channel, an explicit
        // client-visible failure.
        for orphan in orphans {
            if orphan.replays >= cfg.replay_budget {
                stats.failed_on_leader_loss += 1;
                continue;
            }
            p.submit_traced(orphan.input.clone(), orphan.trace);
            stats.replay_attempts += 1;
            if orphan.replays == 0 {
                stats.replayed_on_leader_loss += 1; // count requests once
            }
            pending.push_back(Pending {
                submitted: Instant::now(),
                nodes: gen_nodes,
                leader: gen_leader,
                virtual_time: gen_cost,
                replays: orphan.replays + 1,
                ..orphan
            });
        }

        let batch_size = batch.len();
        let submitted = Instant::now();
        for req in batch {
            // blocks on backpressure past pipeline_depth
            p.submit_traced(req.input.clone(), req.trace);
            pending.push_back(Pending {
                input: req.input,
                resp: req.resp,
                enqueued: req.enqueued,
                submitted,
                batch_size,
                nodes: gen_nodes,
                leader: gen_leader,
                virtual_time: gen_cost,
                replays: 0,
                trace: req.trace,
            });
            stats.requests += 1;
        }
        // Reap whatever has streamed out while feeding.
        while let Some(c) = p.try_complete() {
            complete_front(&mut pending, c, &mut next_seq, recorder);
        }
        if stop.load(Ordering::Acquire) {
            break;
        }
    }

    // Final drain: everything admitted into the pipeline completes; only
    // requests still in the admission queue are failed.
    if let Some(running) = pipe.take() {
        drain_generation(running, &mut pending, &mut summary, &mut next_seq, recorder);
    }
    fail_queued(rx, gauge, &mut stats);
    stats.queue_peak = gauge.peak();
    if summary.generations > 0 {
        stats.pipeline = Some(summary);
    }
    if let PlanSource::Elastic { fe, .. } = source {
        let (adaptation, stall) = fe.finish();
        stats.adaptation = Some(adaptation);
        stats.boundary_stall = Some(stall);
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::net::{Bandwidth, Topology};
    use crate::partition::Scheme;

    fn setup(cfg: ServeConfig) -> (Server, Model) {
        let model = zoo::edgenet(16);
        let plan = Plan::uniform(Scheme::InH, model.n_layers());
        let weights = WeightStore::for_model(&model, 5);
        let testbed = Testbed::new(4, Topology::Ring, Bandwidth::gbps(5.0));
        (Server::start(model.clone(), plan, weights, testbed, cfg), model)
    }

    #[test]
    fn serves_single_request() {
        let (server, _model) = setup(ServeConfig::default());
        let resp = server.infer(Tensor::random(16, 16, 3, 1)).unwrap();
        assert_eq!((resp.output.h, resp.output.w, resp.output.c), (1, 1, 10));
        assert!(resp.virtual_time > 0.0);
        assert_eq!(resp.nodes, 4);
        assert_eq!(resp.leader, 0, "static path serves under the baseline leader");
        assert_eq!(resp.seq, 0, "first delivered response takes sequence 0");
        let stats = server.shutdown();
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.failed_on_leader_loss, 0);
        assert!(stats.adaptation.is_none(), "static path reports no adaptation");
        assert!(stats.pipeline.is_none(), "lockstep path reports no pipeline");
    }

    #[test]
    fn serving_output_matches_reference() {
        let (server, model) = setup(ServeConfig::default());
        let input = Tensor::random(16, 16, 3, 7);
        let ws = WeightStore::for_model(&model, 5);
        let reference = crate::compute::run_reference(&model, &ws, &input);
        let resp = server.infer(input).unwrap();
        assert_eq!(reference.max_abs_diff(&resp.output), 0.0);
    }

    #[test]
    fn batches_multiple_requests() {
        let cfg = ServeConfig {
            max_batch: 4,
            batch_window: Duration::from_millis(200),
            queue_depth: 16,
            ..ServeConfig::default()
        };
        let (server, _) = setup(cfg);
        let rxs: Vec<_> = (0..4)
            .map(|i| server.submit(Tensor::random(16, 16, 3, i)).unwrap())
            .collect();
        let resps: Vec<Response> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        // all four should ride in few batches (most likely one)
        assert!(resps.iter().any(|r| r.batch_size >= 2), "no batching happened");
        let stats = server.shutdown();
        assert_eq!(stats.requests, 4);
        assert!(stats.batches <= 3);
    }

    #[test]
    fn batch_window_is_honored() {
        // a lone request must wait out the batching window before service
        let cfg = ServeConfig {
            max_batch: 8,
            batch_window: Duration::from_millis(150),
            queue_depth: 16,
            ..ServeConfig::default()
        };
        let (server, _) = setup(cfg);
        let resp = server.infer(Tensor::random(16, 16, 3, 9)).unwrap();
        assert!(
            resp.queued >= Duration::from_millis(100),
            "batcher serviced a lone request before the window elapsed ({:?})",
            resp.queued
        );
        assert_eq!(resp.batch_size, 1);
        let stats = server.shutdown();
        assert_eq!(stats.batches, 1);
    }

    #[test]
    fn expired_batch_deadline_stops_the_fill_without_panicking() {
        // regression: the fill used `deadline - now`, which panics when the
        // router thread is scheduled past the deadline between the two
        // clock reads; saturating math must just stop the fill instead —
        // leaving the waiting request for the next batch, not crashing
        let (tx, rx) = channel::<Request>();
        let (resp, _keep) = channel();
        let stale = Instant::now();
        std::thread::sleep(Duration::from_millis(5));
        tx.send(Request {
            input: Tensor::random(2, 2, 1, 1),
            enqueued: Instant::now(),
            trace: 1,
            resp,
        })
        .unwrap();
        let mut batch = Vec::new();
        fill_batch_until(&rx, 8, stale, &mut batch);
        assert!(batch.is_empty(), "an expired window must admit nothing");
        assert!(rx.try_recv().is_ok(), "the queued request stays admitted for the next batch");
    }

    #[test]
    fn queue_counters_track_depth_and_wait() {
        // four requests held by a long batch window must register on the
        // occupancy gauge and accumulate queue age, and the gauge must
        // read empty again once the router has drained everything
        let cfg = ServeConfig {
            max_batch: 8,
            batch_window: Duration::from_millis(120),
            queue_depth: 16,
            ..ServeConfig::default()
        };
        let (server, _) = setup(cfg);
        let handle = server.handle();
        let rxs: Vec<_> = (0..4)
            .map(|i| handle.submit(Tensor::random(16, 16, 3, i)).unwrap())
            .collect();
        assert!(handle.gauge().peak() >= 1, "admissions never registered");
        for rx in rxs {
            rx.recv().unwrap();
        }
        assert_eq!(handle.gauge().depth(), 0, "gauge must drain to zero");
        let stats = server.shutdown();
        assert!(stats.queue_peak >= 1, "peak not recorded: {stats:?}");
        assert!(
            stats.queue_wait_max >= Duration::from_millis(60),
            "first request waited out the batch window: {:?}",
            stats.queue_wait_max
        );
        assert!(stats.queue_wait_total >= stats.queue_wait_max);
    }

    #[test]
    fn backpressure_when_queue_full() {
        let cfg = ServeConfig {
            max_batch: 1,
            batch_window: Duration::ZERO,
            queue_depth: 1,
            ..ServeConfig::default()
        };
        let (server, _) = setup(cfg);
        // flood: at least one should hit QueueFull (router can't drain fast
        // enough under a burst of instant submissions)
        let mut full_seen = false;
        let mut pending = Vec::new();
        for i in 0..64 {
            match server.submit(Tensor::random(16, 16, 3, i)) {
                Ok(rx) => pending.push(rx),
                Err(AdmitError::QueueFull) => {
                    full_seen = true;
                    break;
                }
                Err(e) => panic!("unexpected {e:?}"),
            }
        }
        for rx in pending {
            let _ = rx.recv();
        }
        assert!(full_seen, "queue never filled");
        server.shutdown();
    }

    #[test]
    fn backpressure_retry_loses_nothing() {
        // QueueFull is a clean retryable signal: retrying every rejected
        // submit must eventually land all requests, with none lost
        let cfg = ServeConfig {
            max_batch: 1,
            batch_window: Duration::ZERO,
            queue_depth: 1,
            ..ServeConfig::default()
        };
        let (server, _) = setup(cfg);
        let mut rxs = Vec::new();
        for i in 0..20 {
            loop {
                match server.submit(Tensor::random(16, 16, 3, i)) {
                    Ok(rx) => {
                        rxs.push(rx);
                        break;
                    }
                    Err(AdmitError::QueueFull) => std::thread::yield_now(),
                    Err(e) => panic!("unexpected {e:?}"),
                }
            }
        }
        for rx in rxs {
            rx.recv().expect("response lost");
        }
        let stats = server.shutdown();
        assert_eq!(stats.requests, 20);
    }

    #[test]
    fn shutdown_fails_queued_requests_without_hanging() {
        // Fill the admission queue, shut down immediately, and account for
        // every request: served ones respond, stranded ones disconnect —
        // nobody hangs on a dead receiver.
        let cfg = ServeConfig {
            max_batch: 1,
            batch_window: Duration::ZERO,
            queue_depth: 32,
            ..ServeConfig::default()
        };
        let (server, _) = setup(cfg);
        let total = 24u64;
        let mut rxs = Vec::new();
        for i in 0..total {
            match server.submit(Tensor::random(16, 16, 3, i)) {
                Ok(rx) => rxs.push(rx),
                Err(e) => panic!("queue_depth covers the burst: {e:?}"),
            }
        }
        let stats = server.shutdown();
        let served = rxs.iter().filter(|rx| rx.recv().is_ok()).count() as u64;
        assert_eq!(stats.requests, served);
        assert_eq!(
            stats.requests + stats.failed_on_shutdown,
            total,
            "every admitted request must be served or explicitly failed: {stats:?}"
        );
    }

    #[test]
    fn pipelined_static_serving_matches_reference() {
        let cfg = ServeConfig {
            max_batch: 2,
            batch_window: Duration::ZERO,
            queue_depth: 32,
            pipeline_depth: 4,
            ..ServeConfig::default()
        };
        let (server, model) = setup(cfg);
        let ws = WeightStore::for_model(&model, 5);
        let inputs: Vec<Tensor> =
            (0..8u64).map(|i| Tensor::random(16, 16, 3, 40 + i)).collect();
        // submit asynchronously so batches genuinely overlap in the pipeline
        let rxs: Vec<_> =
            inputs.iter().map(|t| server.submit(t.clone()).unwrap()).collect();
        for (i, (input, rx)) in inputs.iter().zip(rxs).enumerate() {
            let resp = rx.recv().expect("request lost in the pipeline");
            let reference = crate::compute::run_reference(&model, &ws, input);
            assert_eq!(reference.max_abs_diff(&resp.output), 0.0);
            assert_eq!(resp.nodes, 4);
            assert_eq!(resp.leader, 0);
            assert_eq!(resp.seq, i as u64, "completion order must match submission order");
            assert!(resp.virtual_time > 0.0);
        }
        let stats = server.shutdown();
        assert_eq!(stats.requests, 8);
        let p = stats.pipeline.expect("pipelined path reports stage stats");
        assert_eq!(p.generations, 1, "static path never flushes");
        assert_eq!(p.items, 8);
        // uniform InH over edgenet: one stage per all-T block
        assert_eq!(p.stages, zoo::edgenet(16).n_layers());
        assert!(p.bottleneck_stage < p.stages);
        assert_eq!(p.occupancy.len(), p.stages);
    }

    #[test]
    fn pipelined_elastic_stable_trace_is_one_generation() {
        let model = zoo::edgenet(16);
        let base = Testbed::new(4, Topology::Ring, Bandwidth::gbps(5.0));
        let cfg = ServeConfig {
            max_batch: 1,
            batch_window: Duration::ZERO,
            queue_depth: 32,
            pipeline_depth: 3,
            ..ServeConfig::default()
        };
        let server = Server::start_elastic(
            model.clone(),
            WeightStore::for_model(&model, 5),
            base,
            ConditionTrace::stable(4),
            cfg,
            ElasticConfig::default(),
        );
        let ws = WeightStore::for_model(&model, 5);
        let inputs: Vec<Tensor> =
            (0..6u64).map(|i| Tensor::random(16, 16, 3, 90 + i)).collect();
        let rxs: Vec<_> =
            inputs.iter().map(|t| server.submit(t.clone()).unwrap()).collect();
        for (input, rx) in inputs.iter().zip(rxs) {
            let resp = rx.recv().expect("request lost");
            let reference = crate::compute::run_reference(&model, &ws, input);
            assert_eq!(reference.max_abs_diff(&resp.output), 0.0);
            assert_eq!(resp.nodes, 4);
        }
        let stats = server.shutdown();
        assert_eq!(stats.requests, 6);
        let p = stats.pipeline.expect("pipeline stats present");
        assert_eq!(p.generations, 1, "stable conditions must never flush");
        let m = stats.adaptation.expect("elastic path reports adaptation");
        assert_eq!(
            m.checks, 1,
            "pipelined mode consults the frontend once per generation: {m}"
        );
        assert_eq!(m.plan_swaps, 0);
        assert_eq!(m.failovers, 0);
    }

    #[test]
    fn elastic_on_stable_trace_matches_static_server() {
        // identical inputs through the static and elastic paths must yield
        // bit-identical outputs, and a stable trace must never swap
        let model = zoo::edgenet(16);
        let base = Testbed::new(4, Topology::Ring, Bandwidth::gbps(5.0));
        let cfg = ServeConfig {
            max_batch: 1,
            batch_window: Duration::ZERO,
            queue_depth: 16,
            ..ServeConfig::default()
        };
        let plan = crate::planner::plan_for_testbed(&model, &base);
        let static_srv = Server::start(
            model.clone(),
            plan,
            WeightStore::for_model(&model, 5),
            base.clone(),
            cfg.clone(),
        );
        let elastic_srv = Server::start_elastic(
            model.clone(),
            WeightStore::for_model(&model, 5),
            base,
            ConditionTrace::stable(4),
            cfg,
            ElasticConfig::default(),
        );
        for i in 0..4u64 {
            let input = Tensor::random(16, 16, 3, 100 + i);
            let a = static_srv.infer(input.clone()).unwrap();
            let b = elastic_srv.infer(input).unwrap();
            assert_eq!(a.output.max_abs_diff(&b.output), 0.0);
            assert_eq!(b.nodes, 4);
        }
        static_srv.shutdown();
        let stats = elastic_srv.shutdown();
        let m = stats.adaptation.expect("elastic path must report adaptation");
        assert_eq!(m.checks, 4);
        assert_eq!(m.plan_swaps, 0);
        assert_eq!(m.failovers, 0);
    }

    #[test]
    fn process_mode_serving_matches_reference() {
        // the same server front-end over real sockets: registry + three
        // in-thread daemons; responses must be bit-identical to reference
        use crate::transport::coord::ProcessCluster;
        use crate::transport::daemon::{self, DaemonOpts};
        use crate::transport::registry::RegistryServer;

        let reg = RegistryServer::spawn("tcp:127.0.0.1:0", Duration::from_secs(3)).unwrap();
        for id in [0u32, 1, 2] {
            let opts = DaemonOpts::new(id, reg.addr());
            std::thread::spawn(move || {
                let _ = daemon::run(opts);
            });
        }
        let model = zoo::edgenet(16);
        let plan = Plan::uniform(Scheme::InH, model.n_layers());
        let mut pc = ProcessCluster::connect(reg.addr(), 3, Duration::from_secs(10)).unwrap();
        pc.install(&model, &plan, 5).unwrap();
        let server = Server::start_process(pc, ServeConfig::default());
        let ws = WeightStore::for_model(&model, 5);
        for i in 0..3u64 {
            let input = Tensor::random(16, 16, 3, 300 + i);
            let reference = crate::compute::run_reference(&model, &ws, &input);
            let resp = server.infer(input).unwrap();
            assert_eq!(reference.max_abs_diff(&resp.output), 0.0, "request {i}");
            assert_eq!(resp.nodes, 3);
            assert_eq!(resp.seq, i);
        }
        let stats = server.shutdown();
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.process_failovers, 0);
        assert_eq!(stats.failed_on_dead_cluster, 0);
        let s = stats.trace.expect("process-mode requests are traced");
        assert_eq!(s.traces, 3);
        assert_eq!(s.well_formed, 3, "{s}");
        assert!(s.wire_ns_sum > 0, "wire component must be attributed: {s}");
    }

    #[test]
    fn lockstep_traces_decompose_within_tolerance() {
        // sim-fabric conservation property: every served request's merged
        // span tree must be well-formed — queue + service accounts for the
        // end-to-end interval within the merger's tolerance
        let (server, _) = setup(ServeConfig::default());
        let n = 5u64;
        for i in 0..n {
            server.infer(Tensor::random(16, 16, 3, i)).unwrap();
        }
        let trees = crate::trace::merge_spans(&server.recorder().snapshot());
        assert_eq!(trees.len() as u64, n, "one tree per request");
        for t in &trees {
            assert!(t.well_formed, "decomposition must validate: {t:?}");
            assert!(!t.truncated);
            assert!(t.total_ns > 0);
            assert!(
                t.queue_ns + t.service_ns <= t.total_ns + crate::trace::TOL_ABS_NS,
                "components exceed the total beyond tolerance: {t:?}"
            );
        }
        let stats = server.shutdown();
        let s = stats.trace.expect("every request is traced");
        assert_eq!(s.traces, n);
        assert_eq!(s.well_formed, n);
        assert_eq!(s.truncated, 0);
        assert_eq!(stats.shed_queue_full, 0, "no front door ran");
    }

    #[test]
    fn pipelined_traces_carry_per_stage_spans() {
        let cfg = ServeConfig {
            max_batch: 2,
            batch_window: Duration::ZERO,
            queue_depth: 32,
            pipeline_depth: 3,
            ..ServeConfig::default()
        };
        let (server, model) = setup(cfg);
        let rxs: Vec<_> = (0..6u64)
            .map(|i| server.submit(Tensor::random(16, 16, 3, 40 + i)).unwrap())
            .collect();
        for rx in rxs {
            rx.recv().expect("request lost");
        }
        let trees = crate::trace::merge_spans(&server.recorder().snapshot());
        assert_eq!(trees.len(), 6);
        let stages = model.n_layers(); // uniform InH: one stage per layer
        for t in &trees {
            assert!(t.well_formed, "{t:?}");
            assert_eq!(t.stages.len(), stages, "per-stage spans missing: {t:?}");
            assert!(t.stages.iter().all(|&(_, ns)| ns > 0));
        }
        let stats = server.shutdown();
        let p = stats.pipeline.expect("pipelined path reports stage stats");
        assert!(p.buf_reuses > 0, "steady-state stages must recycle buffers");
        let s = stats.trace.expect("trace summary present");
        assert_eq!(s.well_formed, 6);
    }

    #[test]
    fn elastic_swap_mid_stream_preserves_outputs() {
        // a mid-stream bandwidth collapse may swap the plan; outputs must
        // stay bit-identical to the static plan's (numerics are
        // plan-invariant), and the monitor must have seen the degradation
        let model = zoo::edgenet(16);
        let base = Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0));
        let plan0 = crate::planner::plan_for_testbed(&model, &base);
        let c0 = engine::evaluate(&model, &plan0, &base).total;
        // collapse shortly after the second batch's boundary check
        let trace = ConditionTrace::stable(4).with_bandwidth_dip(1.5 * c0, f64::INFINITY, 0.1);
        let cfg = ServeConfig {
            max_batch: 1,
            batch_window: Duration::ZERO,
            queue_depth: 16,
            ..ServeConfig::default()
        };
        let server = Server::start_elastic(
            model.clone(),
            WeightStore::for_model(&model, 5),
            base,
            trace,
            cfg,
            ElasticConfig::default(),
        );
        let ws = WeightStore::for_model(&model, 5);
        for i in 0..6u64 {
            let input = Tensor::random(16, 16, 3, 200 + i);
            let reference = crate::compute::run_reference(&model, &ws, &input);
            let resp = server.infer(input).unwrap();
            assert_eq!(reference.max_abs_diff(&resp.output), 0.0, "request {i}");
        }
        let stats = server.shutdown();
        assert_eq!(stats.requests, 6);
        let m = stats.adaptation.unwrap();
        assert_eq!(m.checks, 6);
        assert!(m.degraded_checks >= 1, "collapse never detected: {m}");
    }
}
