//! Serving front-end: request router + dynamic batcher (vLLM-router style).
//!
//! The paper's engine serves one inference at a time; a deployable system
//! needs admission, queueing and batching in front of the cluster. The
//! [`Server`] owns a router thread: requests are admitted into a bounded
//! queue, the batcher drains up to `max_batch` requests (or waits out
//! `batch_window` for stragglers), executes the batch on the simulated
//! cluster, and completes each request with its output plus queueing/service
//! timing. Python is nowhere on this path.

use std::sync::mpsc::{channel, Receiver, Sender, TrySendError};
use std::sync::{mpsc::sync_channel, Arc};
use std::time::{Duration, Instant};

use crate::compute::{Tensor, WeightStore};
use crate::engine;
use crate::model::Model;
use crate::net::Testbed;
use crate::partition::Plan;

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Maximum requests per batch.
    pub max_batch: usize,
    /// How long the batcher waits for more requests after the first.
    pub batch_window: Duration,
    /// Bounded admission queue depth (backpressure beyond this).
    pub queue_depth: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 8,
            batch_window: Duration::from_millis(2),
            queue_depth: 128,
        }
    }
}

/// A completed inference.
#[derive(Debug)]
pub struct Response {
    pub output: Tensor,
    /// Time spent queued before the batch formed.
    pub queued: Duration,
    /// Host wall-clock service time of the batch that carried this request.
    pub service: Duration,
    /// Virtual-clock (simulated-testbed) inference time per item.
    pub virtual_time: f64,
    /// Size of the batch this request rode in.
    pub batch_size: usize,
}

struct Request {
    input: Tensor,
    enqueued: Instant,
    resp: Sender<Response>,
}

/// Admission error: queue full (backpressure) or server stopped.
#[derive(Debug, PartialEq, Eq)]
pub enum AdmitError {
    QueueFull,
    Stopped,
}

/// The serving handle. Cloneable handles submit requests; dropping the last
/// handle and calling [`Server::shutdown`] stops the router.
pub struct Server {
    tx: std::sync::mpsc::SyncSender<Request>,
    router: Option<std::thread::JoinHandle<RouterStats>>,
}

/// Router counters.
#[derive(Debug, Default, Clone, Copy)]
pub struct RouterStats {
    pub requests: u64,
    pub batches: u64,
    pub max_batch_seen: usize,
}

impl Server {
    /// Start serving `model` with `plan` on the simulated `testbed`.
    pub fn start(
        model: Model,
        plan: Plan,
        weights: WeightStore,
        testbed: Testbed,
        cfg: ServeConfig,
    ) -> Server {
        plan.validate().expect("invalid plan");
        let (tx, rx) = sync_channel::<Request>(cfg.queue_depth);
        let router = std::thread::spawn(move || {
            router_main(rx, &model, &plan, &weights, &testbed, &cfg)
        });
        Server { tx, router: Some(router) }
    }

    /// Submit one inference and wait for its completion.
    pub fn infer(&self, input: Tensor) -> Result<Response, AdmitError> {
        let rx = self.submit(input)?;
        rx.recv().map_err(|_| AdmitError::Stopped)
    }

    /// Submit without waiting; returns the response channel.
    pub fn submit(&self, input: Tensor) -> Result<Receiver<Response>, AdmitError> {
        let (resp_tx, resp_rx) = channel();
        let req = Request { input, enqueued: Instant::now(), resp: resp_tx };
        match self.tx.try_send(req) {
            Ok(()) => Ok(resp_rx),
            Err(TrySendError::Full(_)) => Err(AdmitError::QueueFull),
            Err(TrySendError::Disconnected(_)) => Err(AdmitError::Stopped),
        }
    }

    /// Stop the router and return its counters.
    pub fn shutdown(mut self) -> RouterStats {
        let handle = self.router.take().unwrap();
        drop(self); // drops the queue sender → router drains and exits
        handle.join().expect("router panicked")
    }
}

// No custom Drop: dropping the Server closes the admission queue (tx) and
// detaches the router thread, which exits once the queue drains.

fn router_main(
    rx: Receiver<Request>,
    model: &Model,
    plan: &Plan,
    weights: &WeightStore,
    testbed: &Testbed,
    cfg: &ServeConfig,
) -> RouterStats {
    let mut stats = RouterStats::default();
    // per-item virtual time is plan-static; compute once
    let virtual_time = engine::evaluate(model, plan, testbed).total;
    let weights = Arc::new(weights.clone());

    loop {
        // block for the first request of the batch
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return stats, // all senders gone
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + cfg.batch_window;
        while batch.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(_) => break,
            }
        }

        stats.batches += 1;
        stats.requests += batch.len() as u64;
        stats.max_batch_seen = stats.max_batch_seen.max(batch.len());

        let service_start = Instant::now();
        let outputs: Vec<Tensor> = batch
            .iter()
            .map(|req| {
                crate::cluster::run_distributed(model, plan, &weights, &req.input, testbed.nodes)
                    .output
            })
            .collect();
        let service = service_start.elapsed();

        let batch_size = batch.len();
        for (req, output) in batch.into_iter().zip(outputs) {
            let _ = req.resp.send(Response {
                output,
                queued: service_start.duration_since(req.enqueued),
                service,
                virtual_time,
                batch_size,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::net::{Bandwidth, Topology};
    use crate::partition::Scheme;

    fn setup(cfg: ServeConfig) -> (Server, Model) {
        let model = zoo::edgenet(16);
        let plan = Plan::uniform(Scheme::InH, model.n_layers());
        let weights = WeightStore::for_model(&model, 5);
        let testbed = Testbed::new(4, Topology::Ring, Bandwidth::gbps(5.0));
        (Server::start(model.clone(), plan, weights, testbed, cfg), model)
    }

    #[test]
    fn serves_single_request() {
        let (server, _model) = setup(ServeConfig::default());
        let resp = server.infer(Tensor::random(16, 16, 3, 1)).unwrap();
        assert_eq!((resp.output.h, resp.output.w, resp.output.c), (1, 1, 10));
        assert!(resp.virtual_time > 0.0);
        let stats = server.shutdown();
        assert_eq!(stats.requests, 1);
    }

    #[test]
    fn serving_output_matches_reference() {
        let (server, model) = setup(ServeConfig::default());
        let input = Tensor::random(16, 16, 3, 7);
        let ws = WeightStore::for_model(&model, 5);
        let reference = crate::compute::run_reference(&model, &ws, &input);
        let resp = server.infer(input).unwrap();
        assert_eq!(reference.max_abs_diff(&resp.output), 0.0);
    }

    #[test]
    fn batches_multiple_requests() {
        let cfg = ServeConfig {
            max_batch: 4,
            batch_window: Duration::from_millis(200),
            queue_depth: 16,
        };
        let (server, _) = setup(cfg);
        let rxs: Vec<_> = (0..4)
            .map(|i| server.submit(Tensor::random(16, 16, 3, i)).unwrap())
            .collect();
        let resps: Vec<Response> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        // all four should ride in few batches (most likely one)
        assert!(resps.iter().any(|r| r.batch_size >= 2), "no batching happened");
        let stats = server.shutdown();
        assert_eq!(stats.requests, 4);
        assert!(stats.batches <= 3);
    }

    #[test]
    fn backpressure_when_queue_full() {
        let cfg = ServeConfig {
            max_batch: 1,
            batch_window: Duration::ZERO,
            queue_depth: 1,
        };
        let (server, _) = setup(cfg);
        // flood: at least one should hit QueueFull (router can't drain fast
        // enough under a burst of instant submissions)
        let mut full_seen = false;
        let mut pending = Vec::new();
        for i in 0..64 {
            match server.submit(Tensor::random(16, 16, 3, i)) {
                Ok(rx) => pending.push(rx),
                Err(AdmitError::QueueFull) => {
                    full_seen = true;
                    break;
                }
                Err(e) => panic!("unexpected {e:?}"),
            }
        }
        for rx in pending {
            let _ = rx.recv();
        }
        assert!(full_seen, "queue never filled");
        server.shutdown();
    }
}
