//! Serving front-end: request router + dynamic batcher (vLLM-router style).
//!
//! The paper's engine serves one inference at a time; a deployable system
//! needs admission, queueing and batching in front of the cluster. The
//! [`Server`] owns a router thread: requests are admitted into a bounded
//! queue, the batcher drains up to `max_batch` requests (or waits out
//! `batch_window` for stragglers), executes the batch on the simulated
//! cluster, and completes each request with its output plus queueing/service
//! timing. Python is nowhere on this path.
//!
//! Two plan sources drive the router:
//!
//! * [`Server::start`] — the static path: one frozen plan for one frozen
//!   testbed, forever (the paper's assumption).
//! * [`Server::start_elastic`] — the condition-aware path: an
//!   [`ElasticFrontend`] is consulted at every batch boundary. The frontend
//!   samples the condition trace on a virtual clock (advanced by the
//!   predicted per-item cost of each executed batch) and acquires the
//!   current plan from the background replanner's atomic plan slot — a
//!   single atomic epoch load in the steady state. All monitoring,
//!   replanning and speculative n−1 failover planning happen on the
//!   dedicated planner thread, so a batch boundary never executes a DPP
//!   search inline; plan swaps still land only *between* batches.
//!   Adaptation counters plus the boundary-stall distribution ride back on
//!   [`RouterStats`] at shutdown.

use std::sync::mpsc::{channel, Receiver, Sender, TrySendError};
use std::sync::{mpsc::sync_channel, Arc};
use std::time::{Duration, Instant};

use crate::compute::{Tensor, WeightStore};
use crate::elastic::{ConditionTrace, ElasticConfig, ElasticFrontend};
use crate::engine;
use crate::metrics::{AdaptationMetrics, Summary};
use crate::model::Model;
use crate::net::Testbed;
use crate::partition::Plan;

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Maximum requests per batch.
    pub max_batch: usize,
    /// How long the batcher waits for more requests after the first.
    pub batch_window: Duration,
    /// Bounded admission queue depth (backpressure beyond this).
    pub queue_depth: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 8,
            batch_window: Duration::from_millis(2),
            queue_depth: 128,
        }
    }
}

/// A completed inference.
#[derive(Debug)]
pub struct Response {
    pub output: Tensor,
    /// Time spent queued before the batch formed.
    pub queued: Duration,
    /// Host wall-clock service time of the batch that carried this request.
    pub service: Duration,
    /// Virtual-clock (simulated-testbed) inference time per item, under the
    /// conditions the batch actually ran in.
    pub virtual_time: f64,
    /// Size of the batch this request rode in.
    pub batch_size: usize,
    /// Number of cluster nodes the batch executed on (drops below the
    /// baseline when the elastic path fails over).
    pub nodes: usize,
}

struct Request {
    input: Tensor,
    enqueued: Instant,
    resp: Sender<Response>,
}

/// Admission error: queue full (backpressure) or server stopped.
#[derive(Debug, PartialEq, Eq)]
pub enum AdmitError {
    QueueFull,
    Stopped,
}

/// The serving handle. Cloneable handles submit requests; dropping the last
/// handle and calling [`Server::shutdown`] stops the router.
pub struct Server {
    tx: std::sync::mpsc::SyncSender<Request>,
    router: Option<std::thread::JoinHandle<RouterStats>>,
}

/// Router counters.
#[derive(Debug, Default, Clone, Copy)]
pub struct RouterStats {
    pub requests: u64,
    pub batches: u64,
    pub max_batch_seen: usize,
    /// Present on the elastic path: replan/cache/failover counters.
    pub adaptation: Option<AdaptationMetrics>,
    /// Present on the elastic path: how long batch boundaries spent
    /// acquiring their plan (the stall the background replanner is meant to
    /// eliminate — steady state is one atomic load).
    pub boundary_stall: Option<Summary>,
}

/// Where the router gets the plan for the next batch.
enum PlanSource {
    Static {
        plan: Arc<Plan>,
        nodes: usize,
        virtual_time: f64,
    },
    Elastic {
        fe: ElasticFrontend,
        /// Virtual clock: cumulative predicted inference seconds served.
        vt: f64,
    },
}

impl Server {
    /// Start serving `model` with a frozen `plan` on the simulated `testbed`.
    pub fn start(
        model: Model,
        plan: Plan,
        weights: WeightStore,
        testbed: Testbed,
        cfg: ServeConfig,
    ) -> Server {
        plan.validate().expect("invalid plan");
        let virtual_time = engine::evaluate(&model, &plan, &testbed).total;
        let source = PlanSource::Static {
            plan: Arc::new(plan),
            nodes: testbed.nodes,
            virtual_time,
        };
        Self::spawn(model, weights, cfg, source)
    }

    /// Start the condition-aware serving path: plan for the trace's `t = 0`
    /// conditions, then monitor/replan/swap on the background planner
    /// thread, consulted (wait-free in the steady state) at every batch
    /// boundary.
    pub fn start_elastic(
        model: Model,
        weights: WeightStore,
        base: Testbed,
        trace: ConditionTrace,
        cfg: ServeConfig,
        ecfg: ElasticConfig,
    ) -> Server {
        let fe = ElasticFrontend::start(model.clone(), base, trace, ecfg);
        Self::spawn(model, weights, cfg, PlanSource::Elastic { fe, vt: 0.0 })
    }

    fn spawn(model: Model, weights: WeightStore, cfg: ServeConfig, source: PlanSource) -> Server {
        let (tx, rx) = sync_channel::<Request>(cfg.queue_depth);
        let router = std::thread::spawn(move || {
            let weights = Arc::new(weights);
            router_main(rx, &model, &weights, &cfg, source)
        });
        Server { tx, router: Some(router) }
    }

    /// Submit one inference and wait for its completion.
    pub fn infer(&self, input: Tensor) -> Result<Response, AdmitError> {
        let rx = self.submit(input)?;
        rx.recv().map_err(|_| AdmitError::Stopped)
    }

    /// Submit without waiting; returns the response channel.
    pub fn submit(&self, input: Tensor) -> Result<Receiver<Response>, AdmitError> {
        let (resp_tx, resp_rx) = channel();
        let req = Request { input, enqueued: Instant::now(), resp: resp_tx };
        match self.tx.try_send(req) {
            Ok(()) => Ok(resp_rx),
            Err(TrySendError::Full(_)) => Err(AdmitError::QueueFull),
            Err(TrySendError::Disconnected(_)) => Err(AdmitError::Stopped),
        }
    }

    /// Stop the router and return its counters.
    pub fn shutdown(mut self) -> RouterStats {
        let handle = self.router.take().unwrap();
        drop(self); // drops the queue sender → router drains and exits
        handle.join().expect("router panicked")
    }
}

// No custom Drop: dropping the Server closes the admission queue (tx) and
// detaches the router thread, which exits once the queue drains.

fn router_main(
    rx: Receiver<Request>,
    model: &Model,
    weights: &Arc<WeightStore>,
    cfg: &ServeConfig,
    mut source: PlanSource,
) -> RouterStats {
    let mut stats = RouterStats::default();

    loop {
        // block for the first request of the batch
        let first = match rx.recv() {
            Ok(r) => r,
            // all senders gone — drain the planner and report below
            Err(_) => break,
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + cfg.batch_window;
        while batch.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(_) => break,
            }
        }

        stats.batches += 1;
        stats.requests += batch.len() as u64;
        stats.max_batch_seen = stats.max_batch_seen.max(batch.len());

        // Batch boundary: consult the plan source. On the elastic path this
        // is a wait-free acquisition from the background planner's slot;
        // swaps land here, never mid-batch.
        let (plan, alive, nodes, virtual_time) = match &mut source {
            PlanSource::Static { plan, nodes, virtual_time } => {
                (plan.clone(), None, *nodes, *virtual_time)
            }
            PlanSource::Elastic { fe, vt } => {
                let decision = fe.acquire(*vt);
                *vt += decision.cost_per_item * batch.len() as f64;
                (decision.plan, Some(decision.alive), decision.nodes, decision.cost_per_item)
            }
        };

        let service_start = Instant::now();
        let outputs: Vec<Tensor> = batch
            .iter()
            .map(|req| match &alive {
                // elastic path: execute on the surviving sub-cluster
                Some(mask) => {
                    crate::cluster::run_degraded(model, &plan, weights, &req.input, mask).output
                }
                None => {
                    crate::cluster::run_distributed(model, &plan, weights, &req.input, nodes)
                        .output
                }
            })
            .collect();
        let service = service_start.elapsed();

        let batch_size = batch.len();
        for (req, output) in batch.into_iter().zip(outputs) {
            let _ = req.resp.send(Response {
                output,
                queued: service_start.duration_since(req.enqueued),
                service,
                virtual_time,
                batch_size,
                nodes,
            });
        }
    }

    // shutdown: stop the background planner (draining its queued asks) and
    // fold its counters into the router stats
    if let PlanSource::Elastic { fe, .. } = source {
        let (adaptation, stall) = fe.finish();
        stats.adaptation = Some(adaptation);
        stats.boundary_stall = Some(stall);
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::net::{Bandwidth, Topology};
    use crate::partition::Scheme;

    fn setup(cfg: ServeConfig) -> (Server, Model) {
        let model = zoo::edgenet(16);
        let plan = Plan::uniform(Scheme::InH, model.n_layers());
        let weights = WeightStore::for_model(&model, 5);
        let testbed = Testbed::new(4, Topology::Ring, Bandwidth::gbps(5.0));
        (Server::start(model.clone(), plan, weights, testbed, cfg), model)
    }

    #[test]
    fn serves_single_request() {
        let (server, _model) = setup(ServeConfig::default());
        let resp = server.infer(Tensor::random(16, 16, 3, 1)).unwrap();
        assert_eq!((resp.output.h, resp.output.w, resp.output.c), (1, 1, 10));
        assert!(resp.virtual_time > 0.0);
        assert_eq!(resp.nodes, 4);
        let stats = server.shutdown();
        assert_eq!(stats.requests, 1);
        assert!(stats.adaptation.is_none(), "static path reports no adaptation");
    }

    #[test]
    fn serving_output_matches_reference() {
        let (server, model) = setup(ServeConfig::default());
        let input = Tensor::random(16, 16, 3, 7);
        let ws = WeightStore::for_model(&model, 5);
        let reference = crate::compute::run_reference(&model, &ws, &input);
        let resp = server.infer(input).unwrap();
        assert_eq!(reference.max_abs_diff(&resp.output), 0.0);
    }

    #[test]
    fn batches_multiple_requests() {
        let cfg = ServeConfig {
            max_batch: 4,
            batch_window: Duration::from_millis(200),
            queue_depth: 16,
        };
        let (server, _) = setup(cfg);
        let rxs: Vec<_> = (0..4)
            .map(|i| server.submit(Tensor::random(16, 16, 3, i)).unwrap())
            .collect();
        let resps: Vec<Response> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        // all four should ride in few batches (most likely one)
        assert!(resps.iter().any(|r| r.batch_size >= 2), "no batching happened");
        let stats = server.shutdown();
        assert_eq!(stats.requests, 4);
        assert!(stats.batches <= 3);
    }

    #[test]
    fn batch_window_is_honored() {
        // a lone request must wait out the batching window before service
        let cfg = ServeConfig {
            max_batch: 8,
            batch_window: Duration::from_millis(150),
            queue_depth: 16,
        };
        let (server, _) = setup(cfg);
        let resp = server.infer(Tensor::random(16, 16, 3, 9)).unwrap();
        assert!(
            resp.queued >= Duration::from_millis(100),
            "batcher serviced a lone request before the window elapsed ({:?})",
            resp.queued
        );
        assert_eq!(resp.batch_size, 1);
        let stats = server.shutdown();
        assert_eq!(stats.batches, 1);
    }

    #[test]
    fn backpressure_when_queue_full() {
        let cfg = ServeConfig {
            max_batch: 1,
            batch_window: Duration::ZERO,
            queue_depth: 1,
        };
        let (server, _) = setup(cfg);
        // flood: at least one should hit QueueFull (router can't drain fast
        // enough under a burst of instant submissions)
        let mut full_seen = false;
        let mut pending = Vec::new();
        for i in 0..64 {
            match server.submit(Tensor::random(16, 16, 3, i)) {
                Ok(rx) => pending.push(rx),
                Err(AdmitError::QueueFull) => {
                    full_seen = true;
                    break;
                }
                Err(e) => panic!("unexpected {e:?}"),
            }
        }
        for rx in pending {
            let _ = rx.recv();
        }
        assert!(full_seen, "queue never filled");
        server.shutdown();
    }

    #[test]
    fn backpressure_retry_loses_nothing() {
        // QueueFull is a clean retryable signal: retrying every rejected
        // submit must eventually land all requests, with none lost
        let cfg = ServeConfig {
            max_batch: 1,
            batch_window: Duration::ZERO,
            queue_depth: 1,
        };
        let (server, _) = setup(cfg);
        let mut rxs = Vec::new();
        for i in 0..20 {
            loop {
                match server.submit(Tensor::random(16, 16, 3, i)) {
                    Ok(rx) => {
                        rxs.push(rx);
                        break;
                    }
                    Err(AdmitError::QueueFull) => std::thread::yield_now(),
                    Err(e) => panic!("unexpected {e:?}"),
                }
            }
        }
        for rx in rxs {
            rx.recv().expect("response lost");
        }
        let stats = server.shutdown();
        assert_eq!(stats.requests, 20);
    }

    #[test]
    fn elastic_on_stable_trace_matches_static_server() {
        // identical inputs through the static and elastic paths must yield
        // bit-identical outputs, and a stable trace must never swap
        let model = zoo::edgenet(16);
        let base = Testbed::new(4, Topology::Ring, Bandwidth::gbps(5.0));
        let cfg = ServeConfig {
            max_batch: 1,
            batch_window: Duration::ZERO,
            queue_depth: 16,
        };
        let plan = crate::planner::plan_for_testbed(&model, &base);
        let static_srv = Server::start(
            model.clone(),
            plan,
            WeightStore::for_model(&model, 5),
            base.clone(),
            cfg.clone(),
        );
        let elastic_srv = Server::start_elastic(
            model.clone(),
            WeightStore::for_model(&model, 5),
            base,
            ConditionTrace::stable(4),
            cfg,
            ElasticConfig::default(),
        );
        for i in 0..4u64 {
            let input = Tensor::random(16, 16, 3, 100 + i);
            let a = static_srv.infer(input.clone()).unwrap();
            let b = elastic_srv.infer(input).unwrap();
            assert_eq!(a.output.max_abs_diff(&b.output), 0.0);
            assert_eq!(b.nodes, 4);
        }
        static_srv.shutdown();
        let stats = elastic_srv.shutdown();
        let m = stats.adaptation.expect("elastic path must report adaptation");
        assert_eq!(m.checks, 4);
        assert_eq!(m.plan_swaps, 0);
        assert_eq!(m.failovers, 0);
    }

    #[test]
    fn elastic_swap_mid_stream_preserves_outputs() {
        // a mid-stream bandwidth collapse may swap the plan; outputs must
        // stay bit-identical to the static plan's (numerics are
        // plan-invariant), and the monitor must have seen the degradation
        let model = zoo::edgenet(16);
        let base = Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0));
        let plan0 = crate::planner::plan_for_testbed(&model, &base);
        let c0 = engine::evaluate(&model, &plan0, &base).total;
        // collapse shortly after the second batch's boundary check
        let trace = ConditionTrace::stable(4).with_bandwidth_dip(1.5 * c0, f64::INFINITY, 0.1);
        let cfg = ServeConfig {
            max_batch: 1,
            batch_window: Duration::ZERO,
            queue_depth: 16,
        };
        let server = Server::start_elastic(
            model.clone(),
            WeightStore::for_model(&model, 5),
            base,
            trace,
            cfg,
            ElasticConfig::default(),
        );
        let ws = WeightStore::for_model(&model, 5);
        for i in 0..6u64 {
            let input = Tensor::random(16, 16, 3, 200 + i);
            let reference = crate::compute::run_reference(&model, &ws, &input);
            let resp = server.infer(input).unwrap();
            assert_eq!(reference.max_abs_diff(&resp.output), 0.0, "request {i}");
        }
        let stats = server.shutdown();
        assert_eq!(stats.requests, 6);
        let m = stats.adaptation.unwrap();
        assert_eq!(m.checks, 6);
        assert!(m.degraded_checks >= 1, "collapse never detected: {m}");
    }
}
