//! Wire front door: open-loop request admission over TCP/UDS.
//!
//! Load agents are separate OS processes; this is the socket they fan
//! requests into. One accept thread owns the listener; each connection gets
//! a **reader** (decodes [`WireMsg::Submit`] frames and admits them through
//! a [`ServerHandle`] — `try_send`, never blocking, so backpressure stays a
//! protocol-visible [`WireMsg::Denied`] instead of TCP-buffer pushback) and
//! a **writer** (completes admissions in FIFO order — safe because the
//! router serves FIFO, so one connection's responses arrive in its own
//! submission order — and owns the socket's write half, so replies and
//! denials never interleave mid-frame).
//!
//! Every submission gets exactly one terminal frame: `Reply{seq}` with the
//! output, or `Denied{seq, reason}` (0 = queue full, 1 = server stopped,
//! 2 = failed after admission — shutdown drain, exhausted replay budget).
//! That accounting conservation (`sent == ok + shed + failed`) is what the
//! load harness audits. Every denial is also tallied per reason into the
//! server's shared [`crate::serve::ShedCounters`], so
//! [`crate::serve::RouterStats`] reports the same split the agents observe
//! on the wire — the harness asserts the two views agree.
//!
//! Shutdown order matters: a connection's reader holds a [`ServerHandle`]
//! clone, which keeps the server's admission queue open. [`FrontDoor::stop`]
//! forces every connection closed and joins its threads — call it *before*
//! [`super::Server::shutdown`], or the router's final drain waits forever
//! for the queue to disconnect.

use std::io::ErrorKind;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::serve::{AdmitError, Response, ServerHandle};
use crate::transport::codec::{Frame, WireMsg, CTL_NODE};
use crate::transport::tcp::{self, Stream};

/// Denial reason codes on the wire.
pub const DENY_QUEUE_FULL: u8 = 0;
pub const DENY_STOPPED: u8 = 1;
pub const DENY_FAILED: u8 = 2;

/// How often the accept loop polls for new connections / the stop flag.
const ACCEPT_TICK: Duration = Duration::from_millis(2);

/// One admitted-or-shed submission handed from a connection's reader to
/// its writer, completed strictly in arrival order.
enum Outcome {
    /// Admitted: await the router's response.
    Pending(u64, Receiver<Response>),
    /// Refused at admission with this reason code.
    Shed(u64, u8),
}

/// The running front door. Dropping it without [`FrontDoor::stop`] leaks
/// the accept thread (and its server handles) until the process exits —
/// always stop it.
pub struct FrontDoor {
    addr: String,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<(Stream, JoinHandle<()>)>>>,
}

impl FrontDoor {
    /// Bind `bind` (`tcp:host:port`, port 0 for ephemeral, or
    /// `unix:/path`) and start accepting load connections into `handle`.
    pub fn start(handle: ServerHandle, bind: &str) -> std::io::Result<FrontDoor> {
        let (listener, addr) = tcp::listen(bind)?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<(Stream, JoinHandle<()>)>>> = Arc::new(Mutex::new(Vec::new()));
        let accept_stop = stop.clone();
        let accept_conns = conns.clone();
        let accept = std::thread::spawn(move || {
            while !accept_stop.load(Ordering::Acquire) {
                match listener.accept_nonblocking() {
                    Ok(stream) => {
                        let peer = match stream.try_clone() {
                            Ok(c) => c,
                            Err(_) => continue, // connection died at accept
                        };
                        let h = handle.clone();
                        let t = std::thread::spawn(move || serve_conn(stream, h));
                        accept_conns.lock().unwrap().push((peer, t));
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => {
                        std::thread::sleep(ACCEPT_TICK);
                    }
                    Err(_) => std::thread::sleep(ACCEPT_TICK),
                }
            }
            // the accept thread owned the last long-lived ServerHandle
            // clone (`handle` moves in here); dropping it on exit lets the
            // server's admission queue disconnect once the connection
            // threads are gone too
        });
        Ok(FrontDoor { addr, stop, accept: Some(accept), conns })
    }

    /// Canonical dial address (`tcp:host:port` with the real port).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Stop accepting, force every open connection closed, and join all
    /// threads. After this returns no [`ServerHandle`] clone survives in
    /// the front door, so [`super::Server::shutdown`] can drain.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(t) = self.accept.take() {
            let _ = t.join();
        }
        let conns = std::mem::take(&mut *self.conns.lock().unwrap());
        for (stream, thread) in conns {
            // unblocks a reader parked in read_frame on an idle connection
            stream.shutdown_both();
            let _ = thread.join();
        }
    }
}

/// Reader half of one connection: decode submissions, admit, hand the
/// outcome to the writer. Exits on EOF / reset / forced shutdown.
fn serve_conn(mut stream: Stream, handle: ServerHandle) {
    let Ok(mut wstream) = stream.try_clone() else {
        return;
    };
    let (tx, rx): (Sender<Outcome>, Receiver<Outcome>) = channel();
    let shed = handle.shed_arc();
    let writer = std::thread::spawn(move || write_outcomes(&mut wstream, rx, &shed));
    loop {
        match tcp::read_frame(&mut stream) {
            Ok(Frame { msg: WireMsg::Submit { seq, input }, .. }) => {
                let outcome = match handle.submit(input) {
                    Ok(resp) => Outcome::Pending(seq, resp),
                    Err(AdmitError::QueueFull) => {
                        handle.shed().note(DENY_QUEUE_FULL);
                        Outcome::Shed(seq, DENY_QUEUE_FULL)
                    }
                    Err(AdmitError::Stopped) => {
                        handle.shed().note(DENY_STOPPED);
                        Outcome::Shed(seq, DENY_STOPPED)
                    }
                };
                if tx.send(outcome).is_err() {
                    break; // writer died (client unreachable): stop reading
                }
            }
            // tolerate but ignore anything else well-formed (e.g. Hello)
            Ok(_) => {}
            Err(_) => break,
        }
    }
    drop(tx); // writer drains the in-flight tail, then exits
    let _ = writer.join();
}

/// Writer half: one terminal frame per submission, FIFO. Blocking on
/// `resp.recv()` is head-of-line only for *this* connection, and the
/// router completes FIFO anyway. Post-admission failures are counted here
/// — the writer is the first to observe the response channel disconnect.
fn write_outcomes(stream: &mut Stream, rx: Receiver<Outcome>, shed: &crate::serve::ShedCounters) {
    for outcome in rx.iter() {
        let msg = match outcome {
            Outcome::Pending(seq, resp) => match resp.recv() {
                Ok(r) => WireMsg::Reply { seq, output: r.output },
                // admitted but failed: shutdown drain or exhausted replays
                Err(_) => {
                    shed.note(DENY_FAILED);
                    WireMsg::Denied { seq, reason: DENY_FAILED }
                }
            },
            Outcome::Shed(seq, reason) => WireMsg::Denied { seq, reason },
        };
        let frame = Frame { node: CTL_NODE, term: 0, msg };
        if tcp::send_frame(stream, &frame).is_err() {
            break; // client gone — pending receivers drop, nothing hangs
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::{Tensor, WeightStore};
    use crate::model::zoo;
    use crate::net::{Bandwidth, Testbed, Topology};
    use crate::partition::{Plan, Scheme};
    use crate::serve::{ServeConfig, Server};

    fn wire_server(cfg: ServeConfig) -> (Server, FrontDoor) {
        let model = zoo::edgenet(16);
        let plan = Plan::uniform(Scheme::InH, model.n_layers());
        let weights = WeightStore::for_model(&model, 5);
        let testbed = Testbed::new(4, Topology::Ring, Bandwidth::gbps(5.0));
        let server = Server::start(model, plan, weights, testbed, cfg);
        let door = FrontDoor::start(server.handle(), "tcp:127.0.0.1:0").unwrap();
        (server, door)
    }

    fn submit(stream: &mut Stream, seq: u64, input: Tensor) {
        let frame = Frame { node: 1, term: 0, msg: WireMsg::Submit { seq, input } };
        tcp::send_frame(stream, &frame).unwrap();
    }

    #[test]
    fn replies_match_reference_and_quote_seq() {
        let (server, door) = wire_server(ServeConfig::default());
        let model = zoo::edgenet(16);
        let ws = WeightStore::for_model(&model, 5);
        let mut stream = tcp::connect(door.addr()).unwrap();
        for seq in 0..3u64 {
            let input = Tensor::random(16, 16, 3, 700 + seq);
            let reference = crate::compute::run_reference(&model, &ws, &input);
            submit(&mut stream, seq, input);
            match tcp::read_frame(&mut stream).unwrap().msg {
                WireMsg::Reply { seq: got, output } => {
                    assert_eq!(got, seq);
                    assert_eq!(reference.max_abs_diff(&output), 0.0);
                }
                other => panic!("expected Reply, got kind {}", other.kind()),
            }
        }
        drop(stream);
        door.stop();
        let stats = server.shutdown();
        assert_eq!(stats.requests, 3);
    }

    #[test]
    fn overload_is_denied_not_buffered() {
        // queue_depth 1 and a slammed front door: at least one submission
        // must come back Denied(queue full), and every submission gets
        // exactly one terminal frame
        let cfg = ServeConfig {
            max_batch: 1,
            batch_window: Duration::ZERO,
            queue_depth: 1,
            ..ServeConfig::default()
        };
        let (server, door) = wire_server(cfg);
        let mut stream = tcp::connect(door.addr()).unwrap();
        let total = 24u64;
        for seq in 0..total {
            submit(&mut stream, seq, Tensor::random(16, 16, 3, seq));
        }
        let mut ok = 0u64;
        let mut shed = 0u64;
        let mut seen = Vec::new();
        for _ in 0..total {
            match tcp::read_frame(&mut stream).unwrap().msg {
                WireMsg::Reply { seq, .. } => {
                    ok += 1;
                    seen.push(seq);
                }
                WireMsg::Denied { seq, reason } => {
                    assert_eq!(reason, DENY_QUEUE_FULL);
                    shed += 1;
                    seen.push(seq);
                }
                other => panic!("unexpected kind {}", other.kind()),
            }
        }
        assert_eq!(ok + shed, total, "one terminal frame per submission");
        assert!(ok >= 1, "nothing served");
        assert!(shed >= 1, "queue_depth 1 never backpressured");
        seen.sort_unstable();
        assert_eq!(seen, (0..total).collect::<Vec<_>>(), "a seq went unanswered");
        drop(stream);
        door.stop();
        let stats = server.shutdown();
        assert_eq!(stats.requests, ok);
        // per-reason shed conservation: the server's counters must equal
        // what the client observed on the wire
        assert_eq!(stats.shed_queue_full, shed, "per-reason shed counter diverged");
        assert_eq!(stats.shed_stopped, 0);
        assert_eq!(stats.shed_failed, 0);
    }

    #[test]
    fn stop_with_idle_connection_does_not_hang() {
        // an agent that connected but never disconnects must not wedge
        // stop(): the forced shutdown unblocks its reader
        let (server, door) = wire_server(ServeConfig::default());
        let mut stream = tcp::connect(door.addr()).unwrap();
        submit(&mut stream, 0, Tensor::random(16, 16, 3, 1));
        assert!(matches!(
            tcp::read_frame(&mut stream).unwrap().msg,
            WireMsg::Reply { seq: 0, .. }
        ));
        // keep the connection open across stop()
        door.stop();
        let stats = server.shutdown();
        assert_eq!(stats.requests, 1);
        drop(stream);
    }
}
