//! Ring-buffered telemetry sample store.
//!
//! The ingestion layer's single point of truth: per-link bandwidth samples,
//! per-node compute-speed samples and the latest liveness sweep, each in a
//! bounded ring so a server that measures for days holds constant memory.
//! The store is written by probes on whatever thread observed the traffic
//! and read by the condition source at batch boundaries, so every method is
//! `&self` behind one uncontended mutex (writes are a few words; reads copy
//! out a handful of recent samples).
//!
//! Estimation policy: the per-series estimate is the **median of the last
//! three in-window samples** — responsive to a regime shift within two
//! samples while a single corrupted measurement can never move the
//! quantized condition cell on its own. A series with no sample inside the
//! window falls back to its most recent sample ever (stale beats invented),
//! and a series that was never measured reports the baseline factor `1.0`.

use std::collections::VecDeque;
use std::sync::Mutex;

use crate::elastic::ClusterSnapshot;

/// One measured value at a point of virtual time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Sample {
    pub t: f64,
    pub value: f64,
}

/// Fixed-capacity chronological sample ring.
#[derive(Debug, Clone)]
pub struct Ring {
    cap: usize,
    buf: VecDeque<Sample>,
}

impl Ring {
    pub fn new(cap: usize) -> Ring {
        assert!(cap >= 1, "ring capacity must be >= 1");
        Ring { cap, buf: VecDeque::with_capacity(cap) }
    }

    pub fn push(&mut self, s: Sample) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
        }
        self.buf.push_back(s);
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn latest(&self) -> Option<Sample> {
        self.buf.back().copied()
    }

    /// Median of the last (up to) `k` samples with `t >= since`; falls back
    /// to the latest sample when nothing is that recent.
    pub fn recent_median(&self, since: f64, k: usize) -> Option<f64> {
        let mut vals: Vec<f64> = self
            .buf
            .iter()
            .rev()
            .filter(|s| s.t >= since)
            .take(k.max(1))
            .map(|s| s.value)
            .collect();
        if vals.is_empty() {
            return self.latest().map(|s| s.value);
        }
        vals.sort_by(|a, b| a.partial_cmp(b).expect("non-finite telemetry sample"));
        Some(vals[vals.len() / 2])
    }
}

/// Ingestion counters, for stats lines and tests.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct TelemetryStats {
    /// Passive bandwidth samples recorded (traffic the cluster moved anyway).
    pub bandwidth_samples: u64,
    /// Of those, samples produced by the active low-rate prober.
    pub active_probes: u64,
    /// Per-node compute-speed samples recorded.
    pub compute_samples: u64,
    /// Liveness sweeps recorded.
    pub liveness_sweeps: u64,
}

impl std::fmt::Display for TelemetryStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "bw={} (active {}) compute={} liveness={}",
            self.bandwidth_samples, self.active_probes, self.compute_samples, self.liveness_sweeps
        )
    }
}

struct StoreInner {
    /// Per-link effective-bandwidth factor rings (factor 1.0 = baseline).
    links: Vec<Ring>,
    /// Per-node speed-factor rings.
    speed: Vec<Ring>,
    /// Latest liveness sweep (all-alive until the first heartbeat).
    alive: Vec<bool>,
    stats: TelemetryStats,
}

/// The per-link / per-node telemetry store behind the measured
/// [`crate::elastic::ConditionSource`].
pub struct TelemetryStore {
    nodes: usize,
    /// Samples older than this (virtual seconds) are out of the estimation
    /// window.
    window: f64,
    inner: Mutex<StoreInner>,
}

/// Samples folded into each estimate (see the module docs).
const MEDIAN_K: usize = 3;

impl TelemetryStore {
    pub fn new(nodes: usize, links: usize, capacity: usize, window: f64) -> TelemetryStore {
        assert!(nodes >= 1, "empty cluster");
        assert!(links >= 1, "at least one link series");
        assert!(window > 0.0, "estimation window must be positive");
        TelemetryStore {
            nodes,
            window,
            inner: Mutex::new(StoreInner {
                links: vec![Ring::new(capacity); links],
                speed: vec![Ring::new(capacity); nodes],
                alive: vec![true; nodes],
                stats: TelemetryStats::default(),
            }),
        }
    }

    pub fn nodes(&self) -> usize {
        self.nodes
    }

    pub fn stats(&self) -> TelemetryStats {
        self.inner.lock().unwrap().stats
    }

    /// Record a measured effective-bandwidth factor for `link` at `t`.
    pub fn record_bandwidth(&self, link: usize, t: f64, factor: f64, active: bool) {
        assert!(factor.is_finite() && factor > 0.0, "bad bandwidth factor {factor}");
        let mut inner = self.inner.lock().unwrap();
        assert!(link < inner.links.len(), "link {link} out of range");
        inner.links[link].push(Sample { t, value: factor });
        inner.stats.bandwidth_samples += 1;
        if active {
            inner.stats.active_probes += 1;
        }
    }

    /// Record a measured compute-speed factor for `node` at `t`.
    pub fn record_speed(&self, node: usize, t: f64, factor: f64) {
        assert!(factor.is_finite() && factor > 0.0, "bad speed factor {factor}");
        let mut inner = self.inner.lock().unwrap();
        assert!(node < self.nodes, "node {node} out of range");
        inner.speed[node].push(Sample { t, value: factor });
        inner.stats.compute_samples += 1;
    }

    /// Record a liveness sweep (heartbeat result) at `t`.
    pub fn record_liveness(&self, _t: f64, alive: &[bool]) {
        assert_eq!(alive.len(), self.nodes, "liveness mask length != nodes");
        let mut inner = self.inner.lock().unwrap();
        inner.alive.copy_from_slice(alive);
        inner.stats.liveness_sweeps += 1;
    }

    /// Virtual seconds since the newest bandwidth sample on any link
    /// (`f64::INFINITY` before the first) — the active prober's idle check.
    pub fn bandwidth_age(&self, now: f64) -> f64 {
        let inner = self.inner.lock().unwrap();
        inner
            .links
            .iter()
            .filter_map(|r| r.latest())
            .map(|s| now - s.t)
            .fold(f64::INFINITY, f64::min)
    }

    /// The store's current best view of cluster conditions at `t` — what the
    /// elastic stack consumes in place of a scripted trace sample. The
    /// bandwidth factor aggregates links by taking the **minimum** estimate
    /// (the bottleneck link governs an exchange); unmeasured series report
    /// the baseline `1.0`.
    pub fn snapshot(&self, t: f64) -> ClusterSnapshot {
        let inner = self.inner.lock().unwrap();
        let since = t - self.window;
        let mut bandwidth_factor = inner
            .links
            .iter()
            .filter_map(|r| r.recent_median(since, MEDIAN_K))
            .fold(f64::INFINITY, f64::min);
        if !bandwidth_factor.is_finite() {
            bandwidth_factor = 1.0; // no link ever measured: baseline
        }
        let speed_factors: Vec<f64> = inner
            .speed
            .iter()
            .map(|r| r.recent_median(since, MEDIAN_K).unwrap_or(1.0))
            .collect();
        ClusterSnapshot { t, alive: inner.alive.clone(), bandwidth_factor, speed_factors }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_evicts_oldest_and_keeps_order() {
        let mut r = Ring::new(3);
        assert!(r.is_empty());
        for i in 0..5 {
            r.push(Sample { t: i as f64, value: i as f64 * 10.0 });
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.latest().unwrap().value, 40.0);
        // only t in {2, 3, 4} survive
        assert_eq!(r.recent_median(0.0, 10), Some(30.0));
    }

    #[test]
    fn recent_median_resists_one_outlier_and_falls_back_when_stale() {
        let mut r = Ring::new(8);
        r.push(Sample { t: 1.0, value: 1.0 });
        r.push(Sample { t: 2.0, value: 1.0 });
        r.push(Sample { t: 3.0, value: 0.1 }); // one corrupted measurement
        assert_eq!(r.recent_median(0.0, 3), Some(1.0), "single outlier moved the estimate");
        // two consecutive low samples do shift it (a real regime change)
        r.push(Sample { t: 4.0, value: 0.1 });
        assert_eq!(r.recent_median(0.0, 3), Some(0.1));
        // nothing in-window: fall back to the latest sample ever
        assert_eq!(r.recent_median(100.0, 3), Some(0.1));
        assert_eq!(Ring::new(2).recent_median(0.0, 3), None);
    }

    #[test]
    fn empty_store_reports_baseline_conditions() {
        let store = TelemetryStore::new(4, 1, 16, 2.0);
        let snap = store.snapshot(5.0);
        assert_eq!(snap.t, 5.0);
        assert_eq!(snap.alive, vec![true; 4]);
        assert_eq!(snap.bandwidth_factor, 1.0);
        assert_eq!(snap.speed_factors, vec![1.0; 4]);
        assert_eq!(store.bandwidth_age(5.0), f64::INFINITY);
    }

    #[test]
    fn snapshot_reflects_recorded_samples_and_bottleneck_link() {
        let store = TelemetryStore::new(3, 2, 16, 2.0);
        for t in [1.0, 1.5, 2.0] {
            store.record_bandwidth(0, t, 0.9, false);
            store.record_bandwidth(1, t, 0.5, true);
        }
        store.record_speed(1, 2.0, 0.75);
        store.record_liveness(2.0, &[true, true, false]);
        let snap = store.snapshot(2.0);
        assert_eq!(snap.bandwidth_factor, 0.5, "bottleneck link must govern");
        assert_eq!(snap.speed_factors, vec![1.0, 0.75, 1.0]);
        assert_eq!(snap.alive, vec![true, true, false]);
        let stats = store.stats();
        assert_eq!(stats.bandwidth_samples, 6);
        assert_eq!(stats.active_probes, 3);
        assert_eq!(stats.compute_samples, 1);
        assert_eq!(stats.liveness_sweeps, 1);
        assert!((store.bandwidth_age(2.5) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn stale_samples_fall_out_of_the_window_but_not_out_of_memory() {
        let store = TelemetryStore::new(2, 1, 16, 1.0);
        store.record_bandwidth(0, 0.0, 0.4, false);
        // inside the window the dip sample is the estimate
        assert_eq!(store.snapshot(0.5).bandwidth_factor, 0.4);
        // far outside the window: stale fallback still beats inventing 1.0
        assert_eq!(store.snapshot(100.0).bandwidth_factor, 0.4);
        // a fresh sample takes over immediately (median of in-window set)
        store.record_bandwidth(0, 100.0, 0.8, false);
        assert_eq!(store.snapshot(100.0).bandwidth_factor, 0.8);
    }
}
