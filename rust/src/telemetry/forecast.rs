//! Short-window condition forecasting: EWMA level + trend + optional
//! seasonal component, fitted online, deterministic — no RNG anywhere.
//!
//! The monitor built in PRs 1–4 is reactive: it replans *after* a dip
//! lands. The [`ForecastEngine`] closes the loop the other way: it observes
//! the condition snapshots the frontend already samples (scripted or
//! probe-measured — provenance doesn't matter), fits a per-series
//! [`Holt`] model (level + per-second trend, time-aware updates so
//! irregular boundary spacing is handled exactly), optionally a
//! [`Seasonal`] bin table for periodic worlds (the diurnal day), and
//! projects the whole cluster snapshot `H` batch-boundaries ahead. The
//! projected snapshot quantizes into the **existing** cache-key space
//! ([`crate::elastic::ClusterSnapshot::quantize`]), so "pre-warm the
//! forecast cell" is an ordinary cache fill the serving path already knows
//! how to hit.
//!
//! Confidence: each series tracks an EWMA of its absolute one-step error;
//! [`Forecast::lo`]/[`Forecast::hi`] bracket the projection by twice that
//! error — wide while the series is noisy or turning, collapsing toward
//! the point estimate when the model tracks well.

use crate::elastic::ClusterSnapshot;

/// Forecasting knobs (see [`crate::elastic::ElasticConfig::forecast`] for
/// how the serving path enables them).
#[derive(Debug, Clone)]
pub struct ForecastConfig {
    /// How many batch boundaries ahead to project (the horizon `H`); the
    /// engine converts to seconds via the observed boundary spacing.
    pub horizon_boundaries: usize,
    /// Level smoothing (0 < alpha <= 1): larger follows the series faster.
    pub alpha: f64,
    /// Trend smoothing.
    pub beta: f64,
    /// Seasonal smoothing (only used with `seasonal_period`).
    pub gamma: f64,
    /// Optional seasonal period, virtual seconds (e.g. the 60 s compressed
    /// diurnal day). `None` = pure level + trend.
    pub seasonal_period: Option<f64>,
    /// Seasonal bins across one period.
    pub season_bins: usize,
    /// Observations required before the first projection is offered.
    pub min_observations: u64,
}

impl Default for ForecastConfig {
    fn default() -> Self {
        ForecastConfig {
            horizon_boundaries: 4,
            alpha: 0.5,
            beta: 0.4,
            gamma: 0.3,
            seasonal_period: None,
            season_bins: 24,
            min_observations: 3,
        }
    }
}

/// A projected value with its confidence bracket.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Forecast {
    pub value: f64,
    pub lo: f64,
    pub hi: f64,
}

/// Holt's linear model over irregularly-spaced observations: an EWMA level
/// plus a per-second trend, updated against the time-extrapolated
/// prediction so uneven sampling cannot bias the slope.
#[derive(Debug, Clone)]
pub struct Holt {
    alpha: f64,
    beta: f64,
    level: f64,
    trend: f64,
    /// EWMA of the absolute one-step-ahead error.
    err: f64,
    last_t: f64,
    n: u64,
}

/// Smoothing applied to the one-step error EWMA.
const ERR_BLEND: f64 = 0.3;

impl Holt {
    pub fn new(alpha: f64, beta: f64) -> Holt {
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha out of range");
        assert!((0.0..=1.0).contains(&beta), "beta out of range");
        Holt { alpha, beta, level: 0.0, trend: 0.0, err: 0.0, last_t: 0.0, n: 0 }
    }

    pub fn observe(&mut self, t: f64, v: f64) {
        assert!(v.is_finite(), "non-finite observation");
        if self.n == 0 {
            self.level = v;
            self.last_t = t;
            self.n = 1;
            return;
        }
        let dt = t - self.last_t;
        if dt <= 0.0 {
            // repeated timestamp: refresh the level only — no slope evidence
            self.level = self.alpha * v + (1.0 - self.alpha) * self.level;
            return;
        }
        let predicted = self.level + self.trend * dt;
        self.err = ERR_BLEND * (v - predicted).abs() + (1.0 - ERR_BLEND) * self.err;
        let prev_level = self.level;
        self.level = self.alpha * v + (1.0 - self.alpha) * predicted;
        self.trend = self.beta * ((self.level - prev_level) / dt) + (1.0 - self.beta) * self.trend;
        self.last_t = t;
        self.n += 1;
    }

    /// Projection `horizon` seconds past the last observation.
    pub fn forecast(&self, horizon: f64) -> f64 {
        self.level + self.trend * horizon
    }

    pub fn error(&self) -> f64 {
        self.err
    }

    pub fn is_warm(&self) -> bool {
        self.n > 0
    }

    pub fn last_t(&self) -> f64 {
        self.last_t
    }
}

/// Online seasonal residual table: one EWMA bin per phase slice of the
/// period. Bins that were never visited contribute nothing.
#[derive(Debug, Clone)]
pub struct Seasonal {
    period: f64,
    gamma: f64,
    bins: Vec<f64>,
    seen: Vec<u32>,
}

impl Seasonal {
    pub fn new(period: f64, bins: usize, gamma: f64) -> Seasonal {
        assert!(period > 0.0, "seasonal period must be positive");
        assert!(bins >= 2, "need at least two seasonal bins");
        Seasonal { period, gamma, bins: vec![0.0; bins], seen: vec![0; bins] }
    }

    fn bin(&self, t: f64) -> usize {
        let frac = (t / self.period).rem_euclid(1.0);
        ((frac * self.bins.len() as f64) as usize).min(self.bins.len() - 1)
    }

    /// Fold the residual (observation minus level) at `t` into its bin.
    pub fn observe(&mut self, t: f64, residual: f64) {
        let b = self.bin(t);
        self.bins[b] = if self.seen[b] == 0 {
            residual
        } else {
            self.gamma * residual + (1.0 - self.gamma) * self.bins[b]
        };
        self.seen[b] = self.seen[b].saturating_add(1);
    }

    /// The seasonal component at `t` (0.0 for unvisited bins).
    pub fn component(&self, t: f64) -> f64 {
        let b = self.bin(t);
        if self.seen[b] == 0 {
            0.0
        } else {
            self.bins[b]
        }
    }
}

/// One forecast series: Holt on the deseasonalized signal plus the optional
/// seasonal table.
#[derive(Debug, Clone)]
pub struct Forecaster {
    holt: Holt,
    seasonal: Option<Seasonal>,
}

impl Forecaster {
    pub fn new(cfg: &ForecastConfig) -> Forecaster {
        Forecaster {
            holt: Holt::new(cfg.alpha, cfg.beta),
            seasonal: cfg.seasonal_period.map(|p| Seasonal::new(p, cfg.season_bins, cfg.gamma)),
        }
    }

    pub fn observe(&mut self, t: f64, v: f64) {
        let s = self.seasonal.as_ref().map_or(0.0, |m| m.component(t));
        self.holt.observe(t, v - s);
        if let Some(m) = &mut self.seasonal {
            m.observe(t, v - self.holt.forecast(0.0));
        }
    }

    /// Projection `horizon` seconds past the last observation, seasonal
    /// component included, with the confidence bracket.
    pub fn forecast(&self, horizon: f64) -> Forecast {
        let t_target = self.holt.last_t() + horizon;
        let s = self.seasonal.as_ref().map_or(0.0, |m| m.component(t_target));
        let value = self.holt.forecast(horizon) + s;
        let spread = 2.0 * self.holt.error();
        Forecast { value, lo: value - spread, hi: value + spread }
    }

    pub fn is_warm(&self) -> bool {
        self.holt.is_warm()
    }
}

/// Clamp bounds for projected factors: forecasts may extrapolate, but a
/// projected snapshot must stay a physically meaningful condition cell.
const MIN_FACTOR: f64 = 0.05;
const MAX_FACTOR: f64 = 2.0;

/// The whole-cluster forecaster: one [`Forecaster`] for the shared-fabric
/// bandwidth factor and one per node for the compute-speed factor, plus the
/// observed boundary spacing that converts the horizon from boundaries to
/// seconds. Liveness is **carried, never extrapolated** — predicting a
/// death the heartbeat hasn't seen would fail requests on a hunch; the
/// n−1 speculation at the forecast bandwidth covers that risk instead.
pub struct ForecastEngine {
    cfg: ForecastConfig,
    bw: Forecaster,
    speed: Vec<Forecaster>,
    /// EWMA of the boundary spacing, virtual seconds.
    dt: f64,
    last_t: f64,
    observations: u64,
    alive: Vec<bool>,
}

impl ForecastEngine {
    pub fn new(nodes: usize, cfg: ForecastConfig) -> ForecastEngine {
        assert!(nodes >= 1, "empty cluster");
        assert!(cfg.horizon_boundaries >= 1, "horizon must be at least one boundary");
        ForecastEngine {
            bw: Forecaster::new(&cfg),
            speed: (0..nodes).map(|_| Forecaster::new(&cfg)).collect(),
            dt: 0.0,
            last_t: 0.0,
            observations: 0,
            alive: vec![true; nodes],
            cfg,
        }
    }

    /// Feed one boundary's snapshot (scripted or measured — the engine
    /// doesn't care which).
    pub fn observe(&mut self, snap: &ClusterSnapshot) {
        assert_eq!(snap.alive.len(), self.speed.len(), "snapshot/engine node mismatch");
        if self.observations > 0 {
            let dt = snap.t - self.last_t;
            if dt > 0.0 {
                self.dt = if self.dt == 0.0 {
                    dt
                } else {
                    0.3 * dt + 0.7 * self.dt
                };
            }
        }
        self.last_t = snap.t;
        self.observations += 1;
        self.alive.copy_from_slice(&snap.alive);
        self.bw.observe(snap.t, snap.bandwidth_factor);
        for (node, f) in self.speed.iter_mut().enumerate() {
            if snap.alive[node] {
                f.observe(snap.t, snap.speed_factors[node]);
            }
        }
    }

    /// The horizon in virtual seconds: `H` boundaries at the observed
    /// spacing (0.0 until two boundaries have been seen).
    pub fn horizon_seconds(&self) -> f64 {
        self.cfg.horizon_boundaries as f64 * self.dt
    }

    /// The projected bandwidth factor at the horizon, with its bracket.
    pub fn bandwidth_forecast(&self) -> Forecast {
        self.bw.forecast(self.horizon_seconds())
    }

    /// The projected cluster snapshot `H` boundaries ahead — `None` until
    /// enough history exists to say anything. Quantizing the result yields
    /// the cache cell the background replanner pre-warms.
    pub fn projected(&self) -> Option<ClusterSnapshot> {
        if self.observations < self.cfg.min_observations || self.dt <= 0.0 {
            return None;
        }
        let h = self.horizon_seconds();
        let bandwidth_factor = self.bw.forecast(h).value.clamp(MIN_FACTOR, MAX_FACTOR);
        let speed_factors: Vec<f64> = self
            .speed
            .iter()
            .map(|f| {
                if f.is_warm() {
                    f.forecast(h).value.clamp(MIN_FACTOR, MAX_FACTOR)
                } else {
                    1.0
                }
            })
            .collect();
        Some(ClusterSnapshot {
            t: self.last_t + h,
            alive: self.alive.clone(),
            bandwidth_factor,
            speed_factors,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn holt_tracks_a_constant_exactly() {
        let mut h = Holt::new(0.5, 0.4);
        for k in 0..20 {
            h.observe(k as f64, 0.8);
        }
        assert!((h.forecast(5.0) - 0.8).abs() < 1e-9);
        assert!(h.error() < 1e-9, "constant series must converge to zero error");
    }

    #[test]
    fn holt_extrapolates_a_linear_ramp() {
        // v(t) = 1 − 0.05·t: the projection 4 s ahead must land close to
        // the true future value once the trend has converged
        let mut h = Holt::new(0.5, 0.4);
        for k in 0..40 {
            let t = k as f64 * 0.5;
            h.observe(t, 1.0 - 0.05 * t);
        }
        let t_last = 39.0 * 0.5;
        let truth = 1.0 - 0.05 * (t_last + 4.0);
        assert!(
            (h.forecast(4.0) - truth).abs() < 0.02,
            "ramp projection {} vs truth {truth}",
            h.forecast(4.0)
        );
    }

    #[test]
    fn holt_handles_irregular_spacing_and_repeats() {
        let mut h = Holt::new(0.5, 0.4);
        h.observe(0.0, 1.0);
        h.observe(0.0, 1.0); // repeated timestamp must not divide by zero
        h.observe(0.1, 0.99);
        h.observe(2.0, 0.80);
        h.observe(2.25, 0.775);
        // slope is ~−0.1/s regardless of spacing
        let slope = (h.forecast(1.0) - h.forecast(0.0)).abs();
        assert!((0.02..0.3).contains(&slope), "slope estimate {slope}");
    }

    #[test]
    fn seasonal_learns_a_periodic_dip() {
        // square-ish wave, period 10: low in [5, 10). After three periods
        // the seasonal forecaster must predict the dip bin ahead of time,
        // while the trend-only model (which sees a flat mean) cannot.
        let cfg = ForecastConfig {
            seasonal_period: Some(10.0),
            season_bins: 10,
            ..ForecastConfig::default()
        };
        let mut with_season = Forecaster::new(&cfg);
        let mut level_only = Forecaster::new(&ForecastConfig::default());
        let wave = |t: f64| if t.rem_euclid(10.0) < 5.0 { 1.0 } else { 0.4 };
        let mut t = 0.0;
        while t < 30.0 {
            with_season.observe(t, wave(t));
            level_only.observe(t, wave(t));
            t += 0.5;
        }
        // last observation at t = 29.5 (high phase); the dip starts at 35
        let horizon = 6.0;
        let truth = wave(29.5 + horizon);
        let seasonal_err = (with_season.forecast(horizon).value - truth).abs();
        let level_err = (level_only.forecast(horizon).value - truth).abs();
        assert!(
            seasonal_err < level_err,
            "seasonal {seasonal_err} must beat level-only {level_err}"
        );
        assert!(seasonal_err < 0.25, "seasonal projection off by {seasonal_err}");
    }

    #[test]
    fn confidence_brackets_widen_with_error() {
        let mut f = Forecaster::new(&ForecastConfig::default());
        // alternating series: the one-step error cannot converge to zero
        for k in 0..30 {
            f.observe(k as f64, if k % 2 == 0 { 1.0 } else { 0.5 });
        }
        let fc = f.forecast(2.0);
        assert!(fc.hi > fc.value && fc.lo < fc.value, "bracket collapsed: {fc:?}");
        assert!(fc.hi - fc.lo > 0.1, "noisy series must report a wide bracket");
    }

    #[test]
    fn engine_projects_the_next_condition_cell_on_a_ramp() {
        // descending bandwidth staircase: the projected snapshot must reach
        // the next quantized cell before the actual conditions do
        let cfg = ForecastConfig { horizon_boundaries: 4, ..ForecastConfig::default() };
        let mut eng = ForecastEngine::new(4, cfg);
        assert!(eng.projected().is_none(), "no projection before min history");
        let mut cur_bucket = 0;
        let mut projected_led = false;
        for k in 0..40 {
            let t = k as f64 * 0.5;
            let factor = (1.0 - 0.02 * t).max(0.4);
            let snap = ClusterSnapshot {
                t,
                alive: vec![true; 4],
                bandwidth_factor: factor,
                speed_factors: vec![1.0; 4],
            };
            eng.observe(&snap);
            cur_bucket = snap.quantize().bw_bucket;
            if let Some(proj) = eng.projected() {
                assert_eq!(proj.alive, vec![true; 4]);
                assert!((proj.t - (t + eng.horizon_seconds())).abs() < 1e-9);
                if proj.quantize().bw_bucket < cur_bucket {
                    projected_led = true;
                }
            }
        }
        assert!(cur_bucket < 8, "the ramp never left the baseline cell");
        assert!(projected_led, "projection never led the actual cell transition");
    }

    #[test]
    fn engine_carries_liveness_and_defaults_unmeasured_speeds() {
        let mut eng = ForecastEngine::new(3, ForecastConfig::default());
        for k in 0..5 {
            let snap = ClusterSnapshot {
                t: k as f64,
                alive: vec![true, false, true],
                bandwidth_factor: 0.9,
                speed_factors: vec![1.0, 1.0, 0.8],
            };
            eng.observe(&snap);
        }
        let proj = eng.projected().expect("history is sufficient");
        assert_eq!(proj.alive, vec![true, false, true], "liveness must be carried");
        assert_eq!(proj.speed_factors[1], 1.0, "dead node keeps the baseline placeholder");
        assert!((proj.speed_factors[2] - 0.8).abs() < 1e-6);
        assert!((proj.bandwidth_factor - 0.9).abs() < 1e-6);
    }
}
