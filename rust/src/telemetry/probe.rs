//! Condition probes: how measured telemetry is produced.
//!
//! In a deployment, probes time real traffic; in this reproduction the
//! "wire" is the simulated testbed, so the [`ProbeHarness`] holds the
//! ground-truth [`ConditionTrace`] *privately* and exposes only physical
//! observables derived from it — the elapsed time of a byte transfer, the
//! runtime of a calibration kernel, whether a peer answered a heartbeat.
//! Everything downstream (store, forecaster, controller) sees samples, not
//! the trace: the measured path cannot cheat.
//!
//! Three probe kinds feed the [`TelemetryStore`]:
//!
//! * **Passive exchange measurement** — the scatter/realignment/gather
//!   traffic the cluster already moves. Each observed transfer of `bytes`
//!   in `msgs` messages took `bytes·8 / bw_eff + latency·msgs` seconds on
//!   the wire; the per-message setup cost is a known hardware constant
//!   (SRIO doorbell + DMA descriptor), so the probe subtracts it and
//!   recovers the effective link bandwidth from the payload time. Free —
//!   no probe traffic is ever added while the cluster is serving.
//! * **Active prober** — a low-rate fallback for idle links: if no
//!   bandwidth sample is newer than `probe_interval`, it pays
//!   `probe_bytes` on the link and measures that transfer instead. Rate
//!   limiting keeps it negligible next to serving traffic.
//! * **Compute / liveness sweep** — each alive node times a fixed
//!   calibration kernel against its profiled nominal runtime (the
//!   busy-time observable the pipeline stages report anyway), and a
//!   heartbeat sweep records which peers answered at all.
//!
//! Deterministic end to end: the same trace and tick sequence produce the
//! same sample stream, bit for bit — no RNG anywhere on the measured path.

use std::sync::Arc;

use super::store::TelemetryStore;
use super::TelemetryConfig;
use crate::elastic::ConditionTrace;
use crate::model::ConvType;
use crate::net::Testbed;

/// Link index the shared-fabric probes record under: the simulated SRIO
/// interconnect scales every link by one factor, so one series carries it.
pub const FABRIC_LINK: usize = 0;

/// FLOPs of the calibration kernel the compute sweep times on each device.
const CALIB_FLOPS: f64 = 1e8;

/// The measurement apparatus over a hidden condition world.
pub struct ProbeHarness {
    /// The ground truth being measured — private by design (see module
    /// docs): only observables derived from it ever leave this struct.
    world: ConditionTrace,
    base: Testbed,
    store: Arc<TelemetryStore>,
    cfg: TelemetryConfig,
    /// Virtual time of the last compute sweep (`NEG_INFINITY` = never).
    last_compute: f64,
}

impl ProbeHarness {
    pub fn new(
        world: ConditionTrace,
        base: Testbed,
        store: Arc<TelemetryStore>,
        cfg: TelemetryConfig,
    ) -> ProbeHarness {
        assert_eq!(world.nodes, base.nodes, "world/testbed node mismatch");
        assert_eq!(store.nodes(), base.nodes, "store/testbed node mismatch");
        ProbeHarness { world, base, store, cfg, last_compute: f64::NEG_INFINITY }
    }

    /// One probe tick at virtual time `t`: heartbeat sweep, rate-limited
    /// compute sweep, and the active bandwidth prober if the link has been
    /// idle past `probe_interval`. The condition source calls this once per
    /// batch-boundary sample.
    pub fn tick(&mut self, t: f64) {
        self.heartbeat(t);
        if t - self.last_compute >= self.cfg.compute_interval {
            self.compute_sweep(t);
            self.last_compute = t;
        }
        if self.store.bandwidth_age(t) > self.cfg.probe_interval {
            self.measure_transfer(t, self.cfg.probe_bytes, /* active = */ true);
        }
    }

    /// Passive observation of serving traffic: `bytes` of boundary payload
    /// moved in `_msgs` messages, finishing at `t`. The message count rides
    /// along for accounting symmetry with the router hook; only the payload
    /// enters the bandwidth estimate (see [`Self::measure_transfer`]).
    pub fn observe_exchange(&mut self, t: f64, bytes: u64, _msgs: u64) {
        self.measure_transfer(t, bytes, /* active = */ false);
    }

    /// Time a transfer on the wire and recover the effective bandwidth:
    /// the observable is the payload time (the per-message doorbell/DMA
    /// setup cost is a known hardware constant the probe accounts for
    /// separately, so it never pollutes the bandwidth estimate), and the
    /// recovered factor is nominal-over-measured payload time. The
    /// simulator's wire is noise-free, so the recovery is exact — the
    /// median-of-3 store estimate and quantized cells are what absorb
    /// measurement noise in a deployment.
    fn measure_transfer(&mut self, t: f64, bytes: u64, active: bool) {
        if bytes == 0 {
            // single-node plans (and degenerate probe configs) move
            // nothing: no transfer was timed, so nothing was learned
            return;
        }
        let truth = self.world.sample(t);
        let payload = self
            .base
            .bandwidth
            .scaled(truth.bandwidth_factor)
            .transfer_time(bytes)
            .max(1e-12);
        let factor = self.base.bandwidth.transfer_time(bytes) / payload;
        self.store.record_bandwidth(FABRIC_LINK, t, factor, active);
    }

    /// Heartbeat sweep: a peer that answers is alive; one that doesn't is
    /// down. A hard observable — no estimation involved.
    fn heartbeat(&mut self, t: f64) {
        let truth = self.world.sample(t);
        self.store.record_liveness(t, &truth.alive);
    }

    /// Time the fixed calibration kernel on every alive device and divide
    /// the profiled nominal runtime by the measurement — the per-node
    /// speed-factor observable.
    fn compute_sweep(&mut self, t: f64) {
        let truth = self.world.sample(t);
        let nominal = self.base.device.compute_time(CALIB_FLOPS, ConvType::Standard);
        for node in 0..self.base.nodes {
            if !truth.alive[node] {
                continue; // a dead device runs nothing
            }
            let measured = nominal / truth.speed_factors[node].max(1e-6);
            self.store.record_speed(node, t, nominal / measured);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{Bandwidth, Topology};

    fn setup(world: ConditionTrace) -> (ProbeHarness, Arc<TelemetryStore>) {
        let base = Testbed::new(world.nodes, Topology::Ring, Bandwidth::gbps(1.0));
        let cfg = TelemetryConfig::default();
        let store = Arc::new(TelemetryStore::new(base.nodes, 1, cfg.ring_capacity, cfg.window));
        (ProbeHarness::new(world, base, store.clone(), cfg), store)
    }

    #[test]
    fn passive_exchange_recovers_the_scripted_dip() {
        let (mut h, store) = setup(ConditionTrace::stable(4).with_bandwidth_dip(5.0, 9.0, 0.25));
        h.observe_exchange(1.0, 1 << 20, 16);
        let clean = store.snapshot(1.0).bandwidth_factor;
        assert!((clean - 1.0).abs() < 1e-9, "clean link measured at {clean}");
        for t in [6.0, 6.5, 7.0] {
            h.observe_exchange(t, 1 << 20, 16);
        }
        let dipped = store.snapshot(7.0).bandwidth_factor;
        assert!((dipped - 0.25).abs() < 1e-9, "dip measured at {dipped}");
        assert_eq!(store.stats().active_probes, 0, "passive path ran the prober");
    }

    #[test]
    fn active_prober_is_rate_limited_and_fills_idle_links() {
        let (mut h, store) = setup(ConditionTrace::stable(4));
        h.tick(0.0); // idle link: probe fires
        assert_eq!(store.stats().active_probes, 1);
        h.tick(0.05); // within probe_interval of the last sample: no probe
        assert_eq!(store.stats().active_probes, 1);
        h.tick(10.0); // long idle again
        assert_eq!(store.stats().active_probes, 2);
        // recent passive traffic suppresses the prober entirely
        h.observe_exchange(10.1, 1 << 18, 4);
        h.tick(10.2);
        assert_eq!(store.stats().active_probes, 2);
    }

    #[test]
    fn heartbeat_sees_outages_and_recoveries() {
        let (mut h, store) = setup(ConditionTrace::stable(3).with_outage(1, 2.0, 4.0));
        h.tick(1.0);
        assert_eq!(store.snapshot(1.0).alive, vec![true; 3]);
        h.tick(2.5);
        assert_eq!(store.snapshot(2.5).alive, vec![true, false, true]);
        h.tick(4.5);
        assert_eq!(store.snapshot(4.5).alive, vec![true; 3]);
    }

    #[test]
    fn compute_sweep_recovers_per_node_speed_factors() {
        // diurnal drift wobbles per-node speeds; the sweep must recover the
        // true factors through the timing observable, for alive nodes only
        let world = ConditionTrace::diurnal_drift(4, 7).with_outage(3, 0.0, f64::INFINITY);
        let truth = world.sample(12.0);
        let (mut h, store) = setup(world);
        h.tick(12.0);
        let snap = store.snapshot(12.0);
        for node in 0..3 {
            assert!(
                (snap.speed_factors[node] - truth.speed_factors[node]).abs() < 1e-9,
                "node {node}: measured {} vs true {}",
                snap.speed_factors[node],
                truth.speed_factors[node]
            );
        }
        // the dead node was never measured: baseline placeholder
        assert_eq!(snap.speed_factors[3], 1.0);
        assert!(!snap.alive[3]);
    }
}
