//! Telemetry & forecasting — measured conditions in, pre-warmed plans out.
//!
//! PRs 1–4 built a fully *reactive* elastic stack over a fully *simulated*
//! world: [`crate::elastic::ConditionTrace`] scripts bandwidth drift and
//! outages, and the monitor replans only after a shift lands. This
//! subsystem closes both gaps (the ROADMAP's **Real condition ingestion**
//! and **Learned condition forecasting** items) with three layers:
//!
//! 1. **Ingestion** ([`probe`], [`store`]) — passive probes on the
//!    scatter/realignment/gather traffic the cluster already moves
//!    (observed bytes over elapsed wire time → effective bandwidth), an
//!    active low-rate prober for idle links, per-node compute timing and a
//!    liveness heartbeat, all flowing into the ring-buffered
//!    [`TelemetryStore`].
//! 2. **Source** ([`TelemetrySource`]) — the measured implementation of
//!    [`crate::elastic::ConditionSource`]: the elastic/chaos stack runs
//!    unchanged whether its snapshots come from a scripted trace or from
//!    the store. The ground-truth trace lives *inside* the probe harness
//!    and never leaks: downstream consumers see samples only.
//! 3. **Forecasting** ([`forecast`]) — deterministic EWMA level + trend
//!    (+ optional seasonal) models project each series `H` batch
//!    boundaries ahead and classify the projected snapshot into the
//!    existing quantized plan-cache key space, so the background replanner
//!    can pre-warm the coming regime's plan — and pre-speculate its
//!    n−1/leader-loss cells at the *forecast* bandwidth — before the shift
//!    arrives.
//!
//! Wiring: [`crate::serve::Server::start_telemetry`] serves against a
//! measured source; [`crate::elastic::ElasticConfig::forecast`] turns on
//! pre-warming for any source, measured or scripted.

pub mod forecast;
pub mod probe;
pub mod store;

pub use forecast::{Forecast, ForecastConfig, ForecastEngine, Forecaster, Holt, Seasonal};
pub use probe::{ProbeHarness, FABRIC_LINK};
pub use store::{Ring, Sample, TelemetryStats, TelemetryStore};

use std::sync::Arc;

use crate::elastic::{ClusterSnapshot, ConditionSource, ConditionTrace};
use crate::net::Testbed;

/// Ingestion knobs. Intervals are in *virtual* seconds — the clock the
/// serving router advances by predicted per-item cost, the same one
/// condition traces run on.
#[derive(Debug, Clone)]
pub struct TelemetryConfig {
    /// Ring-buffer capacity per link/node series.
    pub ring_capacity: usize,
    /// Active-probe spacing: if no bandwidth sample is newer than this at a
    /// tick, the prober pays `probe_bytes` on the idle link.
    pub probe_interval: f64,
    /// Active-probe payload bytes — the cost the prober pays on the link
    /// per measurement (kept small next to a boundary exchange).
    pub probe_bytes: u64,
    /// Per-node compute-measurement spacing.
    pub compute_interval: f64,
    /// Estimation window: samples older than this are stale (the store
    /// falls back to the newest sample rather than inventing baseline).
    pub window: f64,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig {
            ring_capacity: 256,
            probe_interval: 0.25,
            probe_bytes: 64 * 1024,
            compute_interval: 0.25,
            window: 2.0,
        }
    }
}

/// The measured [`ConditionSource`]: probes in, snapshots out. Every
/// [`ConditionSource::sample`] runs one probe tick (heartbeat, rate-limited
/// compute sweep, active prober when the link is idle) and then reads the
/// store's current estimate; [`ConditionSource::observe_traffic`] feeds the
/// serving path's own exchanges in as passive bandwidth samples.
pub struct TelemetrySource {
    harness: ProbeHarness,
    store: Arc<TelemetryStore>,
    nodes: usize,
}

impl TelemetrySource {
    /// Measure `world` (the hidden ground truth) as seen from `base`'s
    /// hardware. The store is shared — keep a clone of
    /// [`TelemetrySource::store`] to inspect samples or print stats.
    pub fn new(world: ConditionTrace, base: &Testbed, cfg: TelemetryConfig) -> TelemetrySource {
        assert_eq!(world.nodes, base.nodes, "world/testbed node mismatch");
        let store = Arc::new(TelemetryStore::new(
            base.nodes,
            /* links = */ 1,
            cfg.ring_capacity,
            cfg.window,
        ));
        TelemetrySource {
            nodes: base.nodes,
            harness: ProbeHarness::new(world, base.clone(), store.clone(), cfg),
            store,
        }
    }

    /// The shared sample store (for stats lines, tests and dashboards).
    pub fn store(&self) -> Arc<TelemetryStore> {
        self.store.clone()
    }
}

impl ConditionSource for TelemetrySource {
    fn node_count(&self) -> usize {
        self.nodes
    }

    fn sample(&mut self, t: f64) -> ClusterSnapshot {
        self.harness.tick(t);
        self.store.snapshot(t)
    }

    fn observe_traffic(&mut self, t: f64, bytes: u64, msgs: u64) {
        self.harness.observe_exchange(t, bytes, msgs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{Bandwidth, Topology};

    fn base(nodes: usize) -> Testbed {
        Testbed::new(nodes, Topology::Ring, Bandwidth::gbps(1.0))
    }

    #[test]
    fn measured_source_tracks_a_scripted_world_within_a_bucket() {
        // dip + outage, observed purely through probes: the measured
        // snapshot must land in the same quantized condition cell as the
        // ground truth once the estimation window has caught up
        let world = ConditionTrace::stable(4)
            .with_bandwidth_dip(5.0, 20.0, 0.5)
            .with_outage(2, 8.0, 12.0);
        let mut src = TelemetrySource::new(world.clone(), &base(4), TelemetryConfig::default());
        let mut t = 0.0;
        while t <= 25.0 {
            let measured = src.sample(t);
            assert_eq!(measured.alive, world.sample(t).alive, "heartbeat diverged at t={t}");
            t += 0.5;
        }
        // after the run the estimate sits at the recovered baseline
        let final_snap = src.sample(25.0);
        assert_eq!(
            final_snap.quantize(),
            world.sample(25.0).quantize(),
            "measured cell diverged from the world's cell"
        );
        // and mid-dip sampling had measured the dip cell (re-drive to check)
        let mut src2 = TelemetrySource::new(world.clone(), &base(4), TelemetryConfig::default());
        let mut hit_dip_cell = false;
        let mut t = 0.0;
        while t <= 15.0 {
            if src2.sample(t).quantize() == world.sample(10.0).quantize() {
                hit_dip_cell = true;
            }
            t += 0.5;
        }
        assert!(hit_dip_cell, "the dip never reached the measured cell space");
    }

    #[test]
    fn passive_traffic_suppresses_the_active_prober() {
        let mut src =
            TelemetrySource::new(ConditionTrace::stable(4), &base(4), TelemetryConfig::default());
        // serving traffic arrives continuously: the prober never fires
        let mut t = 0.0;
        while t < 5.0 {
            src.observe_traffic(t, 1 << 18, 8);
            let _ = src.sample(t + 0.01);
            t += 0.1;
        }
        let stats = src.store().stats();
        assert_eq!(stats.active_probes, 0, "prober ran alongside live traffic: {stats}");
        assert!(stats.bandwidth_samples > 40, "passive samples missing: {stats}");
    }

    #[test]
    fn source_is_deterministic() {
        let make = || {
            TelemetrySource::new(
                ConditionTrace::diurnal_drift(4, 7),
                &base(4),
                TelemetryConfig::default(),
            )
        };
        let (mut a, mut b) = (make(), make());
        for k in 0..50 {
            let t = k as f64 * 0.3;
            assert_eq!(a.sample(t), b.sample(t), "divergence at t={t}");
        }
        assert_eq!(a.store().stats(), b.store().stats());
    }
}
