//! Trace generation — the substitute for the paper's 330 K measured samples.
//!
//! The paper runs inference workloads on the DSP testbed under varied
//! settings and records (feature vector, time) pairs. Our traces come from
//! the same place the evaluation ground truth does: the analytic simulator,
//! perturbed with multiplicative lognormal measurement noise. Sampling
//! covers the distribution the DPP will actually query: zoo-model layers and
//! random synthetic layers × schemes × node counts × bandwidths ×
//! topologies × fused-block spans (so NT inflation appears in the i-traces
//! and inflated entry requirements in the s-traces).

use super::query::{boundary_query, compute_query, gather_query, scatter_query};
use super::{analytic, Features, NF};
use crate::model::{zoo, ConvType, LayerMeta};
use crate::net::{Bandwidth, Testbed, Topology};
use crate::partition::inflate::BlockGeometry;
use crate::partition::Scheme;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Trace-generation configuration.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Number of (feature, label) samples per estimator.
    pub samples: usize,
    /// Lognormal noise sigma applied to labels (0 disables).
    pub noise_sigma: f64,
    pub seed: u64,
    /// Max fused-block span sampled (inflation depth coverage).
    pub max_block: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { samples: 60_000, noise_sigma: 0.04, seed: 0x7ace, max_block: 5 }
    }
}

/// A labelled training set for one estimator: row-major features + targets.
#[derive(Debug, Clone, Default)]
pub struct TraceSet {
    pub x: Vec<f64>,
    pub y: Vec<f64>,
}

impl TraceSet {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn push(&mut self, f: &Features, label: f64) {
        self.x.extend_from_slice(&f.0);
        self.y.push(label);
    }

    /// Split off the last `frac` fraction as a held-out set.
    pub fn split(&self, frac: f64) -> (TraceSet, TraceSet) {
        let n = self.len();
        let cut = ((n as f64) * (1.0 - frac)) as usize;
        let train = TraceSet { x: self.x[..cut * NF].to_vec(), y: self.y[..cut].to_vec() };
        let test = TraceSet { x: self.x[cut * NF..].to_vec(), y: self.y[cut..].to_vec() };
        (train, test)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![("x", Json::num_arr(&self.x)), ("y", Json::num_arr(&self.y))])
    }

    fn from_json(v: &Json) -> Result<TraceSet, String> {
        Ok(TraceSet {
            x: v.req("x")?.as_f64_vec().ok_or("x")?,
            y: v.req("y")?.as_f64_vec().ok_or("y")?,
        })
    }
}

/// Both estimators' training data.
#[derive(Debug, Clone, Default)]
pub struct Traces {
    pub compute: TraceSet,
    pub sync: TraceSet,
}

impl Traces {
    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        Json::obj(vec![
            ("compute", self.compute.to_json()),
            ("sync", self.sync.to_json()),
        ])
        .save(path)
    }

    pub fn load(path: &std::path::Path) -> std::io::Result<Traces> {
        let v = Json::load(path)?;
        let parse = || -> Result<Traces, String> {
            Ok(Traces {
                compute: TraceSet::from_json(v.req("compute")?)?,
                sync: TraceSet::from_json(v.req("sync")?)?,
            })
        };
        parse().map_err(std::io::Error::other)
    }
}

/// The testbed grid the paper sweeps (§4): 3/4 nodes are the headline
/// configurations; 2/5/6 appear for generalization.
fn sample_testbed(rng: &mut Rng) -> Testbed {
    let nodes = *rng.pick(&[3usize, 4, 4, 3, 2, 5, 6]);
    let topology = *rng.pick(&Topology::ALL);
    let bw = match rng.below(4) {
        0 => Bandwidth::gbps(5.0),
        1 => Bandwidth::gbps(1.0),
        2 => Bandwidth::mbps(500.0),
        _ => Bandwidth::gbps(rng.range_f64(0.2, 8.0)),
    };
    Testbed::new(nodes, topology, bw)
}

/// Random synthetic layer, covering shapes outside the zoo.
fn sample_synthetic_layer(rng: &mut Rng) -> LayerMeta {
    let conv_t = match rng.below(10) {
        0..=3 => ConvType::Standard,
        4..=5 => ConvType::Depthwise,
        6..=7 => ConvType::Pointwise,
        8 => ConvType::Dense,
        _ => ConvType::Pool,
    };
    match conv_t {
        ConvType::Dense => {
            let rows = *rng.pick(&[1i64, 64, 128, 256]);
            let in_f = *rng.pick(&[128i64, 256, 512, 768, 1024]);
            let out_f = *rng.pick(&[128i64, 256, 512, 768, 3072]);
            LayerMeta::dense("syn_fc", rows, in_f, out_f)
        }
        _ => {
            let h = *rng.pick(&[7i64, 14, 28, 56, 112, 224]);
            let c_in = *rng.pick(&[3i64, 16, 32, 64, 128, 256, 512]);
            let (k, p) = match conv_t {
                ConvType::Pointwise => (1, 0),
                _ => *rng.pick(&[(3i64, 1i64), (5, 2), (7, 3)]),
            };
            let s = if rng.bool(0.25) && h > k { 2 } else { 1 };
            let c_out = match conv_t {
                ConvType::Depthwise | ConvType::Pool => c_in,
                _ => *rng.pick(&[16i64, 32, 64, 128, 256, 512]),
            };
            LayerMeta::conv("syn", conv_t, h, h, c_in, c_out, k, s, p)
        }
    }
}

/// Draw a contiguous layer run from a zoo model (or a synthetic chain).
fn sample_block(rng: &mut Rng, pool: &[crate::model::Model], max_block: usize) -> Vec<LayerMeta> {
    if rng.bool(0.3) {
        // synthetic single layer or small same-shape chain
        let l = sample_synthetic_layer(rng);
        if rng.bool(0.5) || l.out_h != l.in_h || l.out_c != l.in_c {
            return vec![l];
        }
        let span = rng.range_incl(1, max_block.min(3));
        return vec![l; span];
    }
    let m = rng.pick(pool);
    let span = rng.range_incl(1, max_block.min(m.n_layers()));
    let start = rng.below(m.n_layers() - span + 1);
    m.layers[start..start + span].to_vec()
}

fn noise(rng: &mut Rng, sigma: f64) -> f64 {
    if sigma <= 0.0 {
        return 1.0;
    }
    rng.normal(0.0, sigma).exp()
}

/// Generate the full training corpus.
pub fn generate(cfg: &TraceConfig) -> Traces {
    let mut rng = Rng::new(cfg.seed);
    let pool = zoo::paper_benchmarks();
    let mut traces = Traces::default();

    while traces.compute.len() < cfg.samples {
        let tb = sample_testbed(&mut rng);
        let layers = sample_block(&mut rng, &pool, cfg.max_block);
        let scheme = *rng.pick(&Scheme::ALL);
        let geo = BlockGeometry::new(&layers, scheme, tb.nodes);
        for l in 0..layers.len() {
            if traces.compute.len() >= cfg.samples {
                break;
            }
            let q = compute_query(&layers, &geo, l, &tb);
            let label = analytic::compute_time(&tb, &q) * noise(&mut rng, cfg.noise_sigma);
            traces.compute.push(&q.features, label);
        }
    }

    while traces.sync.len() < cfg.samples {
        let tb = sample_testbed(&mut rng);
        match rng.below(10) {
            // scatter boundary
            0 => {
                let layers = sample_block(&mut rng, &pool, cfg.max_block);
                let scheme = *rng.pick(&Scheme::ALL);
                let geo = BlockGeometry::new(&layers, scheme, tb.nodes);
                let q = scatter_query(&layers[0], scheme, &geo.entry_need, &tb);
                let label = analytic::sync_time(&tb, &q) * noise(&mut rng, cfg.noise_sigma);
                traces.sync.push(&q.features, label);
            }
            // gather boundary
            1 => {
                let l = sample_synthetic_layer(&mut rng);
                let scheme = *rng.pick(&Scheme::ALL);
                let q = gather_query(&l, scheme, &tb);
                let label = analytic::sync_time(&tb, &q) * noise(&mut rng, cfg.noise_sigma);
                traces.sync.push(&q.features, label);
            }
            // inter-block boundary (the common case)
            _ => {
                let m = rng.pick(&pool);
                if m.n_layers() < 2 {
                    continue;
                }
                let j = rng.below(m.n_layers() - 1);
                let producer = &m.layers[j];
                let p_from = *rng.pick(&Scheme::ALL);
                let p_to = *rng.pick(&Scheme::ALL);
                let span = rng.range_incl(1, cfg.max_block.min(m.n_layers() - (j + 1)).max(1));
                let next_block = &m.layers[j + 1..j + 1 + span];
                let geo = BlockGeometry::new(next_block, p_to, tb.nodes);
                let q =
                    boundary_query(producer, p_from, &next_block[0], p_to, &geo.entry_need, &tb);
                let label = analytic::sync_time(&tb, &q) * noise(&mut rng, cfg.noise_sigma);
                traces.sync.push(&q.features, label);
            }
        }
    }

    traces
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_counts() {
        let cfg = TraceConfig { samples: 500, ..Default::default() };
        let t = generate(&cfg);
        assert_eq!(t.compute.len(), 500);
        assert_eq!(t.sync.len(), 500);
        assert_eq!(t.compute.x.len(), 500 * NF);
    }

    #[test]
    fn labels_positive_and_finite() {
        let cfg = TraceConfig { samples: 300, ..Default::default() };
        let t = generate(&cfg);
        assert!(t.compute.y.iter().all(|&v| v.is_finite() && v >= 0.0));
        assert!(t.sync.y.iter().all(|&v| v.is_finite() && v >= 0.0));
        // compute labels are strictly positive (every layer does work)
        assert!(t.compute.y.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = TraceConfig { samples: 200, ..Default::default() };
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.compute.y, b.compute.y);
        assert_eq!(a.sync.x, b.sync.x);
    }

    #[test]
    fn split_fractions() {
        let cfg = TraceConfig { samples: 100, ..Default::default() };
        let t = generate(&cfg);
        let (train, test) = t.compute.split(0.2);
        assert_eq!(train.len(), 80);
        assert_eq!(test.len(), 20);
    }

    #[test]
    fn save_load_roundtrip() {
        let cfg = TraceConfig { samples: 50, ..Default::default() };
        let t = generate(&cfg);
        let dir = crate::util::tmp::TempDir::new("traces");
        let p = dir.path().join("traces.json");
        t.save(&p).unwrap();
        let t2 = Traces::load(&p).unwrap();
        assert_eq!(t.compute.y, t2.compute.y);
        assert_eq!(t.sync.x, t2.sync.x);
    }
}
