//! The i-Estimator and s-Estimator (paper §3.2): two GBDT regressors that
//! answer the DPP's compute and synchronization cost questions, plus their
//! training/persistence pipeline.

use std::path::Path;
use std::sync::Arc;

use super::gbdt::{evaluate, FitReport, Gbdt, GbdtParams};
use super::tracegen::{generate, TraceConfig, Traces};
use super::NF;

/// The trained estimator pair.
#[derive(Debug, Clone)]
pub struct Estimators {
    /// Inference-time estimator (per-layer partitioned compute).
    pub i_est: Gbdt,
    /// Synchronization-time estimator (per-boundary exchange).
    pub s_est: Gbdt,
}

/// Held-out diagnostics for both estimators.
#[derive(Debug, Clone, Copy)]
pub struct TrainReport {
    pub i_fit: FitReport,
    pub s_fit: FitReport,
}

impl Estimators {
    /// Train both estimators from a trace corpus, holding out 10% for the
    /// returned fit report.
    pub fn train(traces: &Traces, params: &GbdtParams) -> (Estimators, TrainReport) {
        let (i_train, i_test) = traces.compute.split(0.1);
        let (s_train, s_test) = traces.sync.split(0.1);
        let i_est = Gbdt::train(&i_train.x, &i_train.y, NF, params);
        let s_est = Gbdt::train(&s_train.x, &s_train.y, NF, params);
        let report = TrainReport {
            i_fit: evaluate(&i_est, &i_test.x, &i_test.y),
            s_fit: evaluate(&s_est, &s_test.x, &s_test.y),
        };
        (Estimators { i_est, s_est }, report)
    }

    /// Generate traces and train in one step.
    pub fn train_from_scratch(
        trace_cfg: &TraceConfig,
        params: &GbdtParams,
    ) -> (Estimators, TrainReport) {
        let traces = generate(trace_cfg);
        Self::train(&traces, params)
    }

    /// Persist both models under `dir` (`i_est.json`, `s_est.json`).
    pub fn save(&self, dir: &Path) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        self.i_est.save(&dir.join("i_est.json"))?;
        self.s_est.save(&dir.join("s_est.json"))
    }

    pub fn load(dir: &Path) -> std::io::Result<Estimators> {
        Ok(Estimators {
            i_est: Gbdt::load(&dir.join("i_est.json"))?,
            s_est: Gbdt::load(&dir.join("s_est.json"))?,
        })
    }

    /// Load from `dir` if present, else train (with `trace_cfg`/`params`) and
    /// persist. The bench harness and CLI default path.
    pub fn load_or_train(
        dir: &Path,
        trace_cfg: &TraceConfig,
        params: &GbdtParams,
    ) -> std::io::Result<(Arc<Estimators>, Option<TrainReport>)> {
        if dir.join("i_est.json").exists() && dir.join("s_est.json").exists() {
            return Ok((Arc::new(Self::load(dir)?), None));
        }
        let (est, report) = Self::train_from_scratch(trace_cfg, params);
        est.save(dir)?;
        Ok((Arc::new(est), Some(report)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::features::idx;
    use crate::cost::query::compute_query;
    use crate::cost::{analytic, CostSource};
    use crate::model::{ConvType, LayerMeta};
    use crate::net::{Bandwidth, Testbed, Topology};
    use crate::partition::inflate::BlockGeometry;
    use crate::partition::Scheme;

    fn quick_estimators() -> (Estimators, TrainReport) {
        let cfg = TraceConfig { samples: 6_000, ..Default::default() };
        let params = GbdtParams { n_trees: 120, ..Default::default() };
        Estimators::train_from_scratch(&cfg, &params)
    }

    #[test]
    fn estimators_fit_the_simulator() {
        let (_est, report) = quick_estimators();
        assert!(report.i_fit.r2 > 0.80, "i r2 = {:?}", report.i_fit);
        assert!(report.i_fit.mare < 0.10, "i mare = {:?}", report.i_fit);
        assert!(report.i_fit.spearman > 0.97, "i spearman = {:?}", report.i_fit);
        assert!(report.s_fit.spearman > 0.90, "s spearman = {:?}", report.s_fit);
    }

    #[test]
    fn estimator_ranks_layers_like_oracle() {
        // The planner only needs the CE to *order* candidates correctly.
        // Across a diverse batch of (layer, scheme, nodes) candidates the
        // i-Estimator's ordering must track the oracle's (schemes often tie
        // exactly on balanced layers, so exact-argmin is not the right test).
        let (est, _) = quick_estimators();
        let tb = Testbed::new(4, Topology::Ring, Bandwidth::gbps(5.0));
        let mut pred = Vec::new();
        let mut truth = Vec::new();
        for (h, c, k) in [(112, 32, 3), (56, 128, 3), (28, 256, 3), (14, 512, 3), (7, 512, 1)] {
            let p = (k - 1) / 2;
            let layer = LayerMeta::conv("t", ConvType::Standard, h, h, c, c, k, 1, p);
            let layers = vec![layer];
            for scheme in Scheme::ALL {
                let geo = BlockGeometry::new(&layers, scheme, 4);
                let q = compute_query(&layers, &geo, 0, &tb);
                pred.push(est.i_est.predict(&q.features.0));
                truth.push(analytic::compute_time(&tb, &q));
            }
        }
        // Balanced schemes tie *exactly* in truth, which makes rank
        // correlation ill-posed; what the DP needs is small relative error
        // so that genuinely-different candidates order correctly.
        let mare = truth
            .iter()
            .zip(&pred)
            .map(|(&t, &p)| ((t - p) / t).abs())
            .sum::<f64>()
            / truth.len() as f64;
        assert!(mare < 0.15, "mare = {mare}; pred={pred:?} truth={truth:?}");
        // and the big ordering (cheap 7x7 pointwise << expensive 56x56 conv)
        // must hold strictly:
        let max_cheap = pred[16..].iter().cloned().fold(0.0f64, f64::max);
        let min_costly = pred[4..16].iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max_cheap < min_costly);
    }

    #[test]
    fn persistence_roundtrip() {
        let (est, _) = quick_estimators();
        let dir = crate::util::tmp::TempDir::new("est");
        est.save(dir.path()).unwrap();
        let est2 = Estimators::load(dir.path()).unwrap();
        let probe = {
            let mut f = [0.0; NF];
            f[idx::IN_H] = 56.0;
            f[idx::MAGNITUDE] = 0.1;
            f
        };
        assert_eq!(est.i_est.predict(&probe), est2.i_est.predict(&probe));
        // load_or_train hits the cached path
        let (est3, report) = Estimators::load_or_train(
            dir.path(),
            &TraceConfig { samples: 10, ..Default::default() },
            &GbdtParams::default(),
        )
        .unwrap();
        assert!(report.is_none());
        assert_eq!(est3.i_est.predict(&probe), est.i_est.predict(&probe));
    }

    #[test]
    fn cost_source_gbdt_vs_analytic_close() {
        let (est, _) = quick_estimators();
        let tb = Testbed::new(4, Topology::Ring, Bandwidth::gbps(5.0));
        let layers =
            vec![LayerMeta::conv("t", ConvType::Standard, 56, 56, 128, 128, 3, 1, 1)];
        let geo = BlockGeometry::new(&layers, Scheme::InH, 4);
        let q = compute_query(&layers, &geo, 0, &tb);
        let oracle = CostSource::analytic(&tb).compute_time(&q);
        let learned =
            CostSource::gbdt(Arc::new(est), &tb).compute_time(&q);
        let ratio = learned / oracle;
        assert!((0.5..2.0).contains(&ratio), "ratio = {ratio}");
    }
}
