//! From-scratch Gradient Boosting Decision Trees — the XGBoost substitute
//! (paper §3.2: "We implement the GBDT based on XGBoost").
//!
//! Histogram-based gradient boosting for squared-error regression:
//! features are quantile-binned to `u8` bins once, each tree is grown
//! depth-first with greedy variance-gain splits over per-bin gradient
//! histograms, and leaves take the shrunk mean residual. Targets are
//! log-transformed by default (time costs span five orders of magnitude
//! between a pointwise tile and a ResNet conv; relative error is what
//! matters for ranking partition schemes).
//!
//! Deliberately minimal relative to XGBoost: no second-order gradients, no
//! regularized leaf weights — squared loss makes first-order boosting exact
//! enough, and the estimators' job is *ranking* candidate schemes.

use crate::util::json::{parse, Json};
use crate::util::rng::Rng;

/// Training hyper-parameters.
#[derive(Debug, Clone)]
pub struct GbdtParams {
    pub n_trees: usize,
    pub learning_rate: f64,
    pub max_depth: usize,
    /// Minimum samples per leaf.
    pub min_leaf: usize,
    /// Row subsample fraction per tree.
    pub subsample: f64,
    /// Feature subsample fraction per tree.
    pub colsample: f64,
    /// Number of histogram bins (≤ 256).
    pub n_bins: usize,
    /// Fit on `ln(y)` and exponentiate at prediction time.
    pub log_target: bool,
    pub seed: u64,
}

impl Default for GbdtParams {
    fn default() -> Self {
        GbdtParams {
            n_trees: 300,
            learning_rate: 0.08,
            max_depth: 7,
            min_leaf: 8,
            subsample: 0.8,
            colsample: 0.9,
            n_bins: 256,
            log_target: true,
            seed: 0xf1e2_d3c4,
        }
    }
}

/// One tree node, used during growth; trees are flattened to
/// struct-of-arrays form ([`Tree`]) for cache-friendly prediction (§Perf:
/// the DPP issues tens of thousands of predictions per plan).
#[derive(Debug, Clone)]
pub enum Node {
    /// Go left when `x[feature] <= threshold`.
    Split { feature: u16, threshold: f64, left: u32, right: u32 },
    Leaf { value: f64 },
}

/// Sentinel feature id marking a leaf in the flattened layout.
const LEAF: u16 = u16::MAX;

/// One packed node: 16 bytes, one cache line per 4 nodes — a tree walk
/// touches exactly one line per visited node (§Perf). Thresholds are f64
/// values that happen to round-trip through the JSON format; leaf values
/// live in `thr` with `feat == LEAF`.
#[derive(Debug, Clone, Copy)]
pub struct PackedNode {
    pub thr: f64,
    pub feat: u16,
    pub left: u16,
    pub right: u16,
    pub _pad: u16,
}

/// A flattened tree of packed nodes. Child indices are u16 — a depth-7 tree
/// has < 256 nodes, far under the limit (asserted at build).
#[derive(Debug, Clone)]
pub struct Tree {
    pub nodes: Vec<PackedNode>,
}

impl Tree {
    fn from_nodes(nodes: &[Node]) -> Tree {
        assert!(nodes.len() < u16::MAX as usize, "tree too large for u16 indices");
        let packed = nodes
            .iter()
            .map(|nd| match nd {
                Node::Split { feature, threshold, left, right } => PackedNode {
                    thr: *threshold,
                    feat: *feature,
                    left: *left as u16,
                    right: *right as u16,
                    _pad: 0,
                },
                Node::Leaf { value } => {
                    PackedNode { thr: *value, feat: LEAF, left: 0, right: 0, _pad: 0 }
                }
            })
            .collect();
        Tree { nodes: packed }
    }

    #[inline]
    pub fn predict(&self, x: &[f64]) -> f64 {
        let mut i = 0usize;
        loop {
            let nd = unsafe { self.nodes.get_unchecked(i) };
            if nd.feat == LEAF {
                return nd.thr;
            }
            i = if x[nd.feat as usize] <= nd.thr { nd.left as usize } else { nd.right as usize };
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }
}

/// A trained GBDT regressor.
#[derive(Debug, Clone)]
pub struct Gbdt {
    pub params: GbdtParams,
    pub base: f64,
    pub trees: Vec<Tree>,
    /// Per-feature quantile bin edges used at training time (kept for
    /// diagnostics; prediction uses raw thresholds).
    pub bin_edges: Vec<Vec<f64>>,
    pub n_features: usize,
}

impl Gbdt {
    /// Train on row-major `x` (`n × n_features`) against `y`.
    pub fn train(x: &[f64], y: &[f64], n_features: usize, params: &GbdtParams) -> Gbdt {
        let n = y.len();
        assert!(n > 0 && x.len() == n * n_features, "bad training matrix");
        assert!(params.n_bins >= 2 && params.n_bins <= 256);

        let target: Vec<f64> = if params.log_target {
            y.iter().map(|&v| v.max(1e-12).ln()).collect()
        } else {
            y.to_vec()
        };

        // --- quantile binning -------------------------------------------------
        let mut bin_edges: Vec<Vec<f64>> = Vec::with_capacity(n_features);
        let mut binned = vec![0u8; n * n_features];
        for f in 0..n_features {
            let mut vals: Vec<f64> = (0..n).map(|r| x[r * n_features + f]).collect();
            vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            vals.dedup();
            let edges: Vec<f64> = if vals.len() <= params.n_bins {
                // midpoints between distinct values
                vals.windows(2).map(|w| 0.5 * (w[0] + w[1])).collect()
            } else {
                (1..params.n_bins)
                    .map(|b| {
                        let q = b as f64 / params.n_bins as f64;
                        vals[((vals.len() - 1) as f64 * q) as usize]
                    })
                    .collect()
            };
            for r in 0..n {
                let v = x[r * n_features + f];
                // first edge >= v  →  bin = count of edges < v
                let bin = edges.partition_point(|&e| e < v);
                binned[r * n_features + f] = bin as u8;
            }
            bin_edges.push(edges);
        }

        let base = target.iter().sum::<f64>() / n as f64;
        let mut pred = vec![base; n];
        let mut trees = Vec::with_capacity(params.n_trees);
        let mut rng = Rng::new(params.seed);
        let mut residual = vec![0.0f64; n];

        for _ in 0..params.n_trees {
            for r in 0..n {
                residual[r] = target[r] - pred[r];
            }
            // row subsample
            let mut rows: Vec<u32> = (0..n as u32).collect();
            if params.subsample < 1.0 {
                rng.shuffle(&mut rows);
                rows.truncate(((n as f64) * params.subsample).max(1.0) as usize);
            }
            // feature subsample
            let mut feats: Vec<u16> = (0..n_features as u16).collect();
            if params.colsample < 1.0 {
                rng.shuffle(&mut feats);
                feats.truncate(((n_features as f64) * params.colsample).ceil().max(1.0) as usize);
            }
            let tree = grow_tree(
                &binned,
                &bin_edges,
                &residual,
                n_features,
                rows,
                &feats,
                params,
                &mut rng,
            );
            // update predictions on ALL rows (x is row-major: no copies)
            for r in 0..n {
                pred[r] += tree.predict(&x[r * n_features..(r + 1) * n_features]);
            }
            trees.push(tree);
        }

        Gbdt { params: params.clone(), base, trees, bin_edges, n_features }
    }

    /// Predict a single row.
    #[inline]
    pub fn predict(&self, x: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), self.n_features);
        let mut v = self.base;
        for t in &self.trees {
            v += t.predict(x);
        }
        if self.params.log_target {
            v.exp()
        } else {
            v
        }
    }

    /// Encode to JSON. Trees are stored as flat parallel arrays
    /// `[kind, feature/0, threshold/value, left/0, right/0]` per node.
    pub fn to_json(&self) -> Json {
        let tree_json = |t: &Tree| {
            Json::Arr(
                t.nodes
                    .iter()
                    .map(|nd| {
                        if nd.feat == LEAF {
                            Json::num_arr(&[1.0, 0.0, nd.thr, 0.0, 0.0])
                        } else {
                            Json::num_arr(&[
                                0.0,
                                nd.feat as f64,
                                nd.thr,
                                nd.left as f64,
                                nd.right as f64,
                            ])
                        }
                    })
                    .collect::<Vec<_>>(),
            )
        };
        Json::obj(vec![
            ("base", Json::Num(self.base)),
            ("n_features", Json::Num(self.n_features as f64)),
            ("log_target", Json::Bool(self.params.log_target)),
            ("n_trees", Json::Num(self.params.n_trees as f64)),
            ("learning_rate", Json::Num(self.params.learning_rate)),
            ("max_depth", Json::Num(self.params.max_depth as f64)),
            ("min_leaf", Json::Num(self.params.min_leaf as f64)),
            ("subsample", Json::Num(self.params.subsample)),
            ("colsample", Json::Num(self.params.colsample)),
            ("n_bins", Json::Num(self.params.n_bins as f64)),
            ("seed", Json::Num(self.params.seed as f64)),
            ("trees", Json::Arr(self.trees.iter().map(tree_json).collect())),
            (
                "bin_edges",
                Json::Arr(self.bin_edges.iter().map(|e| Json::num_arr(e)).collect()),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Gbdt, String> {
        let params = GbdtParams {
            n_trees: v.req("n_trees")?.as_usize().ok_or("n_trees")?,
            learning_rate: v.req("learning_rate")?.as_f64().ok_or("learning_rate")?,
            max_depth: v.req("max_depth")?.as_usize().ok_or("max_depth")?,
            min_leaf: v.req("min_leaf")?.as_usize().ok_or("min_leaf")?,
            subsample: v.req("subsample")?.as_f64().ok_or("subsample")?,
            colsample: v.req("colsample")?.as_f64().ok_or("colsample")?,
            n_bins: v.req("n_bins")?.as_usize().ok_or("n_bins")?,
            log_target: v.req("log_target")?.as_bool().ok_or("log_target")?,
            seed: v.req("seed")?.as_f64().ok_or("seed")? as u64,
        };
        let mut trees = Vec::new();
        for t in v.req("trees")?.as_arr().ok_or("trees")? {
            let mut nodes = Vec::new();
            for nd in t.as_arr().ok_or("tree")? {
                let row = nd.as_f64_vec().ok_or("node")?;
                if row.len() != 5 {
                    return Err("bad node row".into());
                }
                nodes.push(if row[0] == 0.0 {
                    Node::Split {
                        feature: row[1] as u16,
                        threshold: row[2],
                        left: row[3] as u32,
                        right: row[4] as u32,
                    }
                } else {
                    Node::Leaf { value: row[2] }
                });
            }
            trees.push(Tree::from_nodes(&nodes));
        }
        let bin_edges = v
            .req("bin_edges")?
            .as_arr()
            .ok_or("bin_edges")?
            .iter()
            .map(|e| e.as_f64_vec().ok_or_else(|| "bin_edges row".to_string()))
            .collect::<Result<Vec<_>, _>>()?;
        Ok(Gbdt {
            base: v.req("base")?.as_f64().ok_or("base")?,
            n_features: v.req("n_features")?.as_usize().ok_or("n_features")?,
            params,
            trees,
            bin_edges,
        })
    }

    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        self.to_json().save(path)
    }

    pub fn load(path: &std::path::Path) -> std::io::Result<Gbdt> {
        let text = std::fs::read_to_string(path)?;
        let v = parse(&text).map_err(std::io::Error::other)?;
        Gbdt::from_json(&v).map_err(std::io::Error::other)
    }

    /// Split-count feature importance (how often each feature is chosen).
    pub fn feature_importance(&self) -> Vec<f64> {
        let mut counts = vec![0.0f64; self.n_features];
        for t in &self.trees {
            for nd in &t.nodes {
                if nd.feat != LEAF {
                    counts[nd.feat as usize] += 1.0;
                }
            }
        }
        let total: f64 = counts.iter().sum::<f64>().max(1.0);
        counts.iter_mut().for_each(|c| *c /= total);
        counts
    }
}

/// Grow one regression tree over the binned matrix.
#[allow(clippy::too_many_arguments)]
fn grow_tree(
    binned: &[u8],
    bin_edges: &[Vec<f64>],
    residual: &[f64],
    n_features: usize,
    rows: Vec<u32>,
    feats: &[u16],
    params: &GbdtParams,
    _rng: &mut Rng,
) -> Tree {
    struct Work {
        node_id: usize,
        rows: Vec<u32>,
        depth: usize,
    }
    let mut nodes: Vec<Node> = vec![Node::Leaf { value: 0.0 }];
    let mut stack = vec![Work { node_id: 0, rows, depth: 0 }];

    while let Some(w) = stack.pop() {
        let sum: f64 = w.rows.iter().map(|&r| residual[r as usize]).sum();
        let cnt = w.rows.len() as f64;
        let leaf_value = params.learning_rate * sum / cnt.max(1.0);

        if w.depth >= params.max_depth || w.rows.len() < 2 * params.min_leaf {
            nodes[w.node_id] = Node::Leaf { value: leaf_value };
            continue;
        }

        // best split over sampled features via per-bin histograms
        let mut best: Option<(u16, u8, f64)> = None; // (feature, bin, gain)
        let parent_score = sum * sum / cnt;
        let mut hist_sum = [0.0f64; 256];
        let mut hist_cnt = [0u32; 256];
        for &f in feats {
            let fu = f as usize;
            let nb = bin_edges[fu].len() + 1;
            hist_sum[..nb].fill(0.0);
            hist_cnt[..nb].fill(0);
            for &r in &w.rows {
                let b = binned[r as usize * n_features + fu] as usize;
                hist_sum[b] += residual[r as usize];
                hist_cnt[b] += 1;
            }
            let mut left_sum = 0.0f64;
            let mut left_cnt = 0u32;
            for b in 0..nb.saturating_sub(1) {
                left_sum += hist_sum[b];
                left_cnt += hist_cnt[b];
                let right_cnt = w.rows.len() as u32 - left_cnt;
                if (left_cnt as usize) < params.min_leaf || (right_cnt as usize) < params.min_leaf
                {
                    continue;
                }
                let right_sum = sum - left_sum;
                let gain = left_sum * left_sum / left_cnt as f64
                    + right_sum * right_sum / right_cnt as f64
                    - parent_score;
                if gain > best.map(|(_, _, g)| g).unwrap_or(1e-12) {
                    best = Some((f, b as u8, gain));
                }
            }
        }

        match best {
            None => nodes[w.node_id] = Node::Leaf { value: leaf_value },
            Some((f, bin, _gain)) => {
                let threshold = bin_edges[f as usize][bin as usize];
                let (mut lrows, mut rrows) = (Vec::new(), Vec::new());
                for &r in &w.rows {
                    if binned[r as usize * n_features + f as usize] <= bin {
                        lrows.push(r);
                    } else {
                        rrows.push(r);
                    }
                }
                let left = nodes.len() as u32;
                nodes.push(Node::Leaf { value: 0.0 });
                let right = nodes.len() as u32;
                nodes.push(Node::Leaf { value: 0.0 });
                nodes[w.node_id] = Node::Split { feature: f, threshold, left, right };
                stack.push(Work { node_id: left as usize, rows: lrows, depth: w.depth + 1 });
                stack.push(Work { node_id: right as usize, rows: rrows, depth: w.depth + 1 });
            }
        }
    }
    Tree::from_nodes(&nodes)
}

/// Goodness-of-fit diagnostics.
#[derive(Debug, Clone, Copy)]
pub struct FitReport {
    pub r2: f64,
    pub mae: f64,
    /// Mean absolute *relative* error — the metric that matters for ranking.
    pub mare: f64,
    /// Spearman rank correlation between predicted and true costs.
    pub spearman: f64,
    pub n: usize,
}

/// Evaluate a model on a held-out set.
pub fn evaluate(model: &Gbdt, x: &[f64], y: &[f64]) -> FitReport {
    let nf = model.n_features;
    let n = y.len();
    let preds: Vec<f64> = (0..n).map(|r| model.predict(&x[r * nf..(r + 1) * nf])).collect();
    let mean = y.iter().sum::<f64>() / n as f64;
    let ss_tot: f64 = y.iter().map(|&v| (v - mean).powi(2)).sum();
    let ss_res: f64 = y.iter().zip(&preds).map(|(&t, &p)| (t - p).powi(2)).sum();
    let mae = y.iter().zip(&preds).map(|(&t, &p)| (t - p).abs()).sum::<f64>() / n as f64;
    let mare = y
        .iter()
        .zip(&preds)
        .map(|(&t, &p)| ((t - p) / t.max(1e-12)).abs())
        .sum::<f64>()
        / n as f64;
    FitReport {
        r2: 1.0 - ss_res / ss_tot.max(1e-300),
        mae,
        mare,
        spearman: spearman(y, &preds),
        n,
    }
}

/// Spearman rank correlation.
pub fn spearman(a: &[f64], b: &[f64]) -> f64 {
    let ra = ranks(a);
    let rb = ranks(b);
    pearson(&ra, &rb)
}

fn ranks(v: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..v.len()).collect();
    idx.sort_by(|&i, &j| v[i].partial_cmp(&v[j]).unwrap());
    let mut out = vec![0.0; v.len()];
    for (rank, &i) in idx.iter().enumerate() {
        out[i] = rank as f64;
    }
    out
}

fn pearson(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len() as f64;
    let (ma, mb) = (a.iter().sum::<f64>() / n, b.iter().sum::<f64>() / n);
    let mut cov = 0.0;
    let (mut va, mut vb) = (0.0, 0.0);
    for i in 0..a.len() {
        let (da, db) = (a[i] - ma, b[i] - mb);
        cov += da * db;
        va += da * da;
        vb += db * db;
    }
    cov / (va.sqrt() * vb.sqrt()).max(1e-300)
}

/// Deterministic synthetic regression set for self-tests.
pub fn synthetic_dataset(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>, usize) {
    let nf = 5;
    let mut rng = Rng::new(seed);
    let mut x = Vec::with_capacity(n * nf);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let row: Vec<f64> = (0..nf).map(|_| rng.range_f64(0.0, 4.0)).collect();
        // nonlinear target with interactions
        let t = (row[0] * row[1]).exp().min(50.0) * 0.01
            + row[2].powi(2)
            + if row[3] > 2.0 { 3.0 } else { 0.5 }
            + 0.2 * row[4];
        x.extend_from_slice(&row);
        y.push(t);
    }
    (x, y, nf)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn learns_nonlinear_function() {
        let (x, y, nf) = synthetic_dataset(4000, 7);
        let (xt, yt, _) = synthetic_dataset(1000, 8);
        let params = GbdtParams { n_trees: 120, log_target: false, ..Default::default() };
        let model = Gbdt::train(&x, &y, nf, &params);
        let rep = evaluate(&model, &xt, &yt);
        assert!(rep.r2 > 0.95, "r2 = {}", rep.r2);
        assert!(rep.spearman > 0.97, "spearman = {}", rep.spearman);
    }

    #[test]
    fn log_target_handles_wide_dynamic_range() {
        // y spans 6 orders of magnitude; log-target keeps relative error low.
        let n = 3000;
        let mut rng = Rng::new(42);
        let nf = 3;
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let a: f64 = rng.range_f64(0.0, 6.0);
            let b: f64 = rng.range_f64(0.5, 2.0);
            let c: f64 = rng.range_f64(0.0, 1.0);
            x.extend_from_slice(&[a, b, c]);
            y.push(10f64.powf(a) * b);
        }
        let params = GbdtParams { n_trees: 150, log_target: true, ..Default::default() };
        let model = Gbdt::train(&x, &y, nf, &params);
        let rep = evaluate(&model, &x, &y);
        assert!(rep.mare < 0.2, "mare = {}", rep.mare);
        assert!(rep.spearman > 0.99);
    }

    #[test]
    fn deterministic_given_seed() {
        let (x, y, nf) = synthetic_dataset(500, 3);
        let params = GbdtParams { n_trees: 20, ..Default::default() };
        let m1 = Gbdt::train(&x, &y, nf, &params);
        let m2 = Gbdt::train(&x, &y, nf, &params);
        let probe = &x[..nf];
        assert_eq!(m1.predict(probe), m2.predict(probe));
    }

    #[test]
    fn persistence_roundtrip() {
        let (x, y, nf) = synthetic_dataset(500, 3);
        let params = GbdtParams { n_trees: 10, ..Default::default() };
        let m = Gbdt::train(&x, &y, nf, &params);
        let dir = crate::util::tmp::TempDir::new("gbdt");
        let path = dir.path().join("m.json");
        m.save(&path).unwrap();
        let m2 = Gbdt::load(&path).unwrap();
        for r in 0..20 {
            let row = &x[r * nf..(r + 1) * nf];
            assert_eq!(m.predict(row), m2.predict(row));
        }
    }

    #[test]
    fn constant_target_gives_constant_prediction() {
        let n = 200;
        let nf = 2;
        let x: Vec<f64> = (0..n * nf).map(|i| (i % 7) as f64).collect();
        let y = vec![3.5f64; n];
        let params = GbdtParams { n_trees: 10, log_target: false, ..Default::default() };
        let m = Gbdt::train(&x, &y, nf, &params);
        assert!((m.predict(&[1.0, 2.0]) - 3.5).abs() < 1e-9);
    }

    #[test]
    fn feature_importance_finds_signal() {
        // only feature 0 matters
        let n = 2000;
        let nf = 4;
        let mut rng = Rng::new(11);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let row: Vec<f64> = (0..nf).map(|_| rng.range_f64(0.0, 1.0)).collect();
            y.push(row[0] * 10.0);
            x.extend_from_slice(&row);
        }
        let params =
            GbdtParams { n_trees: 50, log_target: false, colsample: 1.0, ..Default::default() };
        let m = Gbdt::train(&x, &y, nf, &params);
        let imp = m.feature_importance();
        assert!(imp[0] > 0.5, "importance = {imp:?}");
    }

    #[test]
    fn spearman_sanity() {
        assert!((spearman(&[1.0, 2.0, 3.0], &[10.0, 20.0, 30.0]) - 1.0).abs() < 1e-9);
        assert!((spearman(&[1.0, 2.0, 3.0], &[30.0, 20.0, 10.0]) + 1.0).abs() < 1e-9);
    }
}
