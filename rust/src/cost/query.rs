//! Query builders — the single place where partition geometry is turned into
//! cost questions. The DPP, the baselines, the evaluation engine and the
//! trace generator all build queries through these functions, so an
//! estimated plan and an executed plan are costed identically.

use super::features::{idx, Features, LEADER_SCHEME_CODE};
use super::{ComputeQuery, SyncQuery, MAX_NODES};
use crate::model::LayerMeta;
use crate::net::Testbed;
use crate::partition::geometry::{boundary_messages, gather_messages, out_tiles, scatter_messages};
use crate::partition::inflate::BlockGeometry;
use crate::partition::{union_volume, Region, Scheme, Tile};
use crate::DTYPE_BYTES;

/// Build the compute query for block layer `l` of a fused block.
pub fn compute_query(
    layers: &[LayerMeta],
    geo: &BlockGeometry,
    l: usize,
    tb: &Testbed,
) -> ComputeQuery {
    compute_query_tiles(&layers[l], &geo.tiles[l], geo.scheme, tb)
}

/// Build the compute query for one layer given each node's (possibly
/// inflated) output tiles — the planner's incremental hot path. Feature
/// shape dims are the **bottleneck node's hull tile** (the paper's estimator
/// sees the per-device workload, which is the partitioned tile, not the full
/// layer).
pub fn compute_query_tiles(
    layer: &LayerMeta,
    tiles: &[Tile],
    scheme: Scheme,
    tb: &Testbed,
) -> ComputeQuery {
    let nodes = tiles.len();
    debug_assert_eq!(nodes, tb.nodes);
    let mut per_node_flops = [0.0; MAX_NODES];
    let mut bottleneck = 0.0f64;
    let mut busiest = 0usize;
    let mut busiest_vol = -1i64;
    let fpe = layer.flops_per_out_elem();
    for (node, t) in tiles.iter().enumerate() {
        let vol = union_volume(t);
        let f = fpe * vol as f64 / tb.speed[node];
        per_node_flops[node] = f;
        if f > bottleneck {
            bottleneck = f;
        }
        if vol > busiest_vol {
            busiest_vol = vol;
            busiest = node;
        }
    }
    let out_hull = tiles[busiest].iter().fold(Region::empty(), |acc, r| acc.hull(r));
    let ins = crate::partition::geometry::in_regions(layer, &tiles[busiest]);
    let in_hull = ins.iter().fold(Region::empty(), |acc, r| acc.hull(r));

    let mut f = Features::zeros();
    f[idx::IN_H] = (in_hull.h1 - in_hull.h0) as f64;
    f[idx::IN_W] = (in_hull.w1 - in_hull.w0) as f64;
    f[idx::IN_C] = (in_hull.c1 - in_hull.c0) as f64;
    f[idx::OUT_H] = (out_hull.h1 - out_hull.h0) as f64;
    f[idx::OUT_W] = (out_hull.w1 - out_hull.w0) as f64;
    f[idx::OUT_C] = (out_hull.c1 - out_hull.c0) as f64;
    f[idx::K] = layer.k as f64;
    f[idx::S] = layer.s as f64;
    f[idx::P] = layer.p as f64;
    f[idx::CONV_T] = layer.conv_t.code();
    f[idx::BW_GBPS] = tb.bandwidth.as_gbps();
    f[idx::ARCH] = tb.topology.code();
    f[idx::SCHEME_FROM] = scheme.code();
    f[idx::SCHEME_TO] = scheme.code();
    f[idx::NODES] = nodes as f64;
    f[idx::MAGNITUDE] = bottleneck / 1e9; // GFLOPs

    ComputeQuery { features: f, per_node_flops, nodes, conv_t: layer.conv_t }
}

/// Build the sync query for the T boundary after `producer` (partitioned
/// under `p_from`), delivering `entry_need` — the input requirement of the
/// next block (whose first layer is `consumer`, scheme `p_to`).
pub fn boundary_query(
    producer: &LayerMeta,
    p_from: Scheme,
    consumer: &LayerMeta,
    p_to: Scheme,
    entry_need: &[Tile],
    tb: &Testbed,
) -> SyncQuery {
    let have = out_tiles(producer, p_from, tb.nodes);
    let msgs = boundary_messages(&have, entry_need, DTYPE_BYTES);
    let features = sync_features(
        producer,
        Some(consumer),
        p_from.code(),
        p_to.code(),
        tb,
        &msgs,
    );
    SyncQuery { features, msgs }
}

/// Sync query for the initial scatter: leader holds the model input; every
/// node receives the input region required by the first block.
pub fn scatter_query(
    first: &LayerMeta,
    p_to: Scheme,
    entry_need: &[Tile],
    tb: &Testbed,
) -> SyncQuery {
    let msgs = scatter_messages(first, entry_need, DTYPE_BYTES);
    let features =
        sync_features(first, Some(first), LEADER_SCHEME_CODE, p_to.code(), tb, &msgs);
    SyncQuery { features, msgs }
}

/// Sync query for the final gather of the last layer's tiles to the leader.
pub fn gather_query(last: &LayerMeta, p_from: Scheme, tb: &Testbed) -> SyncQuery {
    let tiles = out_tiles(last, p_from, tb.nodes);
    let msgs = gather_messages(&tiles, DTYPE_BYTES);
    let features =
        sync_features(last, None, p_from.code(), LEADER_SCHEME_CODE, tb, &msgs);
    SyncQuery { features, msgs }
}

/// Shared s-Estimator feature layout: producer output shape in the IN dims,
/// consumer kernel geometry in the K/S/P dims, transfer magnitude last.
fn sync_features(
    producer: &LayerMeta,
    consumer: Option<&LayerMeta>,
    from_code: f64,
    to_code: f64,
    tb: &Testbed,
    msgs: &[u64],
) -> Features {
    let n = tb.nodes;
    let total: u64 = msgs.iter().sum();
    let mut f = Features::zeros();
    f[idx::IN_H] = producer.out_h as f64;
    f[idx::IN_W] = producer.out_w as f64;
    f[idx::IN_C] = producer.out_c as f64;
    if let Some(c) = consumer {
        f[idx::OUT_H] = c.out_h as f64;
        f[idx::OUT_W] = c.out_w as f64;
        f[idx::OUT_C] = c.out_c as f64;
        f[idx::K] = c.k as f64;
        f[idx::S] = c.s as f64;
        f[idx::P] = c.p as f64;
        f[idx::CONV_T] = c.conv_t.code();
    }
    f[idx::BW_GBPS] = tb.bandwidth.as_gbps();
    f[idx::ARCH] = tb.topology.code();
    f[idx::SCHEME_FROM] = from_code;
    f[idx::SCHEME_TO] = to_code;
    f[idx::NODES] = n as f64;
    f[idx::MAGNITUDE] = total as f64 / 1e6; // MB
    f
}

/// Convenience: the canonical entry requirement of a block starting at
/// `layers[0]` under `scheme` (used by single-layer boundaries and tests).
pub fn block_entry_need(layers: &[LayerMeta], scheme: Scheme, nodes: usize) -> Vec<Tile> {
    BlockGeometry::new(layers, scheme, nodes).entry_need
}

/// Total bytes a plan's boundary would move (diagnostic).
pub fn boundary_bytes(q: &SyncQuery) -> u64 {
    q.total_bytes()
}

/// Bottleneck-node output volume share of a compute query (diagnostic):
/// max per-node flops / total flops.
pub fn compute_imbalance(q: &ComputeQuery) -> f64 {
    let total: f64 = q.per_node_flops[..q.nodes].iter().sum();
    let max = q.per_node_flops[..q.nodes].iter().fold(0.0f64, |a, &b| a.max(b));
    if total == 0.0 {
        1.0
    } else {
        max * q.nodes as f64 / total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ConvType;
    use crate::net::{Bandwidth, Topology};

    fn tb4() -> Testbed {
        Testbed::new(4, Topology::Ring, Bandwidth::gbps(5.0))
    }

    fn conv(h: i64, c: i64) -> LayerMeta {
        LayerMeta::conv("t", ConvType::Standard, h, h, c, c, 3, 1, 1)
    }

    #[test]
    fn compute_query_features_track_tile() {
        let layers = vec![conv(16, 8)];
        let geo = BlockGeometry::new(&layers, Scheme::InH, 4);
        let q = compute_query(&layers, &geo, 0, &tb4());
        assert_eq!(q.features[idx::OUT_H], 4.0);
        assert_eq!(q.features[idx::OUT_C], 8.0);
        assert_eq!(q.features[idx::NODES], 4.0);
        assert_eq!(q.nodes, 4);
        // all nodes do equal work on a 16-row map
        let f = q.per_node_flops;
        assert!((f[0] - f[3]).abs() < 1.0);
    }

    #[test]
    fn boundary_query_same_scheme_halo() {
        let a = conv(16, 8);
        let b = conv(16, 8);
        let tb = tb4();
        let need = block_entry_need(std::slice::from_ref(&b), Scheme::InH, 4);
        let q = boundary_query(&a, Scheme::InH, &b, Scheme::InH, &need, &tb);
        // halo rows only: 6 messages of one 16×8 row
        assert_eq!(q.total_bytes(), 6 * 16 * 8 * 4);
        assert_eq!(q.features[idx::SCHEME_FROM], Scheme::InH.code());
    }

    #[test]
    fn scheme_change_boundary_costs_more_than_same() {
        let a = conv(16, 8);
        let b = conv(16, 8);
        let tb = tb4();
        let need_same = block_entry_need(std::slice::from_ref(&b), Scheme::InH, 4);
        let same = boundary_query(&a, Scheme::InH, &b, Scheme::InH, &need_same, &tb);
        let need_x = block_entry_need(std::slice::from_ref(&b), Scheme::InW, 4);
        let cross = boundary_query(&a, Scheme::InH, &b, Scheme::InW, &need_x, &tb);
        assert!(cross.total_bytes() > same.total_bytes());
    }

    #[test]
    fn scatter_gather_queries() {
        let l = conv(16, 8);
        let tb = tb4();
        let need = block_entry_need(std::slice::from_ref(&l), Scheme::InH, 4);
        let sq = scatter_query(&l, Scheme::InH, &need, &tb);
        assert!(sq.total_bytes() > 0);
        assert_eq!(sq.features[idx::SCHEME_FROM], LEADER_SCHEME_CODE);
        let gq = gather_query(&l, Scheme::InH, &tb);
        // 3 non-leader tiles of 4 rows each
        assert_eq!(gq.total_bytes(), 3 * 4 * 16 * 8 * 4);
    }

    #[test]
    fn imbalance_diagnostic() {
        let layers = vec![conv(14, 8)];
        let geo = BlockGeometry::new(&layers, Scheme::InH, 4);
        let q = compute_query(&layers, &geo, 0, &tb4());
        // 14 rows over 4 nodes: 4/3.5
        assert!((compute_imbalance(&q) - 4.0 / 3.5).abs() < 1e-9);
    }

    #[test]
    fn heterogeneous_speed_shifts_bottleneck() {
        let layers = vec![conv(16, 8)];
        let geo = BlockGeometry::new(&layers, Scheme::InH, 4);
        let tb = tb4().with_speed(vec![1.0, 0.5, 1.0, 1.0]);
        let q = compute_query(&layers, &geo, 0, &tb);
        let max = q.per_node_flops[..4].iter().cloned().fold(0.0f64, f64::max);
        assert!((max - q.per_node_flops[1]).abs() < 1e-9);
    }
}
