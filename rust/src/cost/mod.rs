//! Cost estimation — the paper's §3.2 (data-driven cost estimator) plus the
//! analytic ground-truth model it learns from.
//!
//! The planner asks two questions, each phrased as a *query*:
//!
//! * [`ComputeQuery`] — "how long does one layer's (possibly inflated)
//!   partitioned computation take?" — answered by the **i-Estimator**.
//! * [`SyncQuery`] — "how long does the boundary synchronization between two
//!   partition schemes take?" — answered by the **s-Estimator**.
//!
//! A query carries both the exact geometric facts (per-node FLOPs, the byte
//! matrix) and the learned-estimator feature vector, so the same query can be
//! answered by either cost source:
//!
//! * [`CostSource::Analytic`] — the simulator's ground truth (device profile
//!   + topology link schedule). This is what the execution engine charges,
//!   and what the trace generator labels training data with.
//! * [`CostSource::Gbdt`] — the paper's data-driven estimators: two GBDT
//!   regressors trained on traces ([`tracegen`]). Planning with GBDT and
//!   evaluating on the simulator measures the *planning regret* of the
//!   learned model (an ablation in the benches).

pub mod analytic;
pub mod estimator;
pub mod features;
pub mod gbdt;
pub mod memo;
pub mod query;
pub mod tracegen;

pub use estimator::Estimators;
pub use features::{Features, NF};
pub use memo::{MemoCostSource, MemoStats, MemoStore};

use crate::model::ConvType;
use crate::net::Testbed;

/// Maximum cluster size supported by the fixed-size per-node arrays on the
/// planner hot path (edge clusters are 3–6 nodes; 16 is generous headroom).
pub const MAX_NODES: usize = 16;

/// What the planner minimizes over the same search space and cost queries.
///
/// Both objectives decompose a plan into *pipeline stages*: the fused block
/// `b` paired with its entry synchronization (scatter for the first block, a
/// realignment boundary otherwise), plus the final gather as its own stage.
/// [`Objective::Latency`] sums the stages (one inference end to end — the
/// paper's metric); [`Objective::Throughput`] takes their maximum, the
/// steady-state per-item cost of the block-pipelined executor
/// ([`crate::cluster::pipeline`]), where every stage works on a different
/// in-flight inference and the slowest stage sets the service rate.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, Hash)]
pub enum Objective {
    /// End-to-end latency of one inference (sum of all stages).
    #[default]
    Latency,
    /// Bottleneck (max) pipeline-stage time — the reciprocal of the
    /// pipelined executor's steady-state throughput.
    Throughput,
}

impl Objective {
    pub const ALL: [Objective; 2] = [Objective::Latency, Objective::Throughput];

    pub fn name(self) -> &'static str {
        match self {
            Objective::Latency => "latency",
            Objective::Throughput => "throughput",
        }
    }
}

impl std::fmt::Display for Objective {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for Objective {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "latency" => Ok(Objective::Latency),
            "throughput" | "bottleneck" => Ok(Objective::Throughput),
            other => Err(format!("unknown objective {other:?}")),
        }
    }
}

/// A compute-cost question: one layer, one scheme, possibly NT-inflated.
#[derive(Debug, Clone)]
pub struct ComputeQuery {
    /// Feature vector for the i-Estimator.
    pub features: Features,
    /// Exact per-node FLOPs (already divided by per-node speed factors), for
    /// the analytic answer. Indices `nodes..` are zero.
    pub per_node_flops: [f64; MAX_NODES],
    pub nodes: usize,
    pub conv_t: ConvType,
}

/// A synchronization-cost question: one T boundary (or scatter/gather).
#[derive(Debug, Clone)]
pub struct SyncQuery {
    /// Feature vector for the s-Estimator.
    pub features: Features,
    /// Exact byte matrix `msgs[a*nodes+b]`, for the analytic answer.
    pub msgs: Vec<u64>,
}

impl SyncQuery {
    pub fn total_bytes(&self) -> u64 {
        self.msgs.iter().sum()
    }
}

/// The cost oracle the planner consults. Mirrors the paper's CE interface:
/// "DPP contacts CE to get an estimated time cost for the partition scheme in
/// its consideration".
#[derive(Debug, Clone)]
pub enum CostSource {
    /// Exact simulator costs (device profile + topology schedule).
    Analytic(Testbed),
    /// Learned i/s-Estimators (GBDT), as in the paper.
    Gbdt { estimators: std::sync::Arc<Estimators>, testbed: Testbed },
    /// Any of the above behind a shared query cache ([`memo`]) with an
    /// analytic bandwidth re-pricing fast path.
    Memo(MemoCostSource),
}

impl CostSource {
    pub fn analytic(testbed: &Testbed) -> CostSource {
        CostSource::Analytic(testbed.clone())
    }

    pub fn gbdt(estimators: std::sync::Arc<Estimators>, testbed: &Testbed) -> CostSource {
        CostSource::Gbdt { estimators, testbed: testbed.clone() }
    }

    /// This source behind `store`'s query cache (memo-of-memo flattens).
    pub fn memoized(self, store: &std::sync::Arc<MemoStore>) -> CostSource {
        CostSource::Memo(MemoCostSource::new(self, store.clone()))
    }

    /// The memo counters, when this source is memoized (zeros otherwise).
    pub fn memo_stats(&self) -> MemoStats {
        match self {
            CostSource::Memo(m) => m.store().stats(),
            _ => MemoStats::default(),
        }
    }

    pub fn testbed(&self) -> &Testbed {
        match self {
            CostSource::Analytic(tb) => tb,
            CostSource::Gbdt { testbed, .. } => testbed,
            CostSource::Memo(m) => m.testbed(),
        }
    }

    /// Estimated seconds for the layer computation described by `q`
    /// (max over nodes — layers synchronize at barriers).
    pub fn compute_time(&self, q: &ComputeQuery) -> f64 {
        match self {
            CostSource::Analytic(tb) => analytic::compute_time(tb, q),
            CostSource::Gbdt { estimators, .. } => estimators.i_est.predict(&q.features.0),
            CostSource::Memo(m) => m.compute_time(q),
        }
    }

    /// Estimated seconds for the synchronization described by `q`.
    pub fn sync_time(&self, q: &SyncQuery) -> f64 {
        match self {
            CostSource::Analytic(tb) => analytic::sync_time(tb, q),
            CostSource::Gbdt { estimators, .. } => estimators.s_est.predict(&q.features.0),
            CostSource::Memo(m) => m.sync_time(q),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            CostSource::Analytic(_) => "analytic",
            CostSource::Gbdt { .. } => "gbdt",
            CostSource::Memo(m) => m.name(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{Bandwidth, Topology};

    #[test]
    fn analytic_source_answers_queries() {
        let tb = Testbed::new(4, Topology::Ring, Bandwidth::gbps(5.0));
        let src = CostSource::analytic(&tb);
        let mut per_node = [0.0; MAX_NODES];
        per_node[..4].copy_from_slice(&[1e6, 2e6, 1e6, 1e6]);
        let q = ComputeQuery {
            features: Features::zeros(),
            per_node_flops: per_node,
            nodes: 4,
            conv_t: ConvType::Standard,
        };
        let t = src.compute_time(&q);
        // bottleneck node: 2e6 flops at 128e9*0.55 + 20us overhead
        let expect = 2e6 / (128e9 * 0.55) + 20e-6;
        assert!((t - expect).abs() < 1e-12);
    }

    #[test]
    fn sync_query_total_bytes() {
        let q = SyncQuery { features: Features::zeros(), msgs: vec![0, 5, 7, 0] };
        assert_eq!(q.total_bytes(), 12);
    }

    #[test]
    fn objective_names_round_trip() {
        for o in Objective::ALL {
            assert_eq!(o.name().parse::<Objective>().unwrap(), o);
            assert_eq!(o.to_string(), o.name());
        }
        assert!("speed".parse::<Objective>().is_err());
        assert_eq!(Objective::default(), Objective::Latency);
    }
}
