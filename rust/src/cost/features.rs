//! Feature expression for the cost estimators (paper Fig 4).
//!
//! The paper feeds three groups of features: (1) layer shape parameters —
//! InH/OutH, InW/OutW, InC/OutC, K, S, P, ConvT; (2) inter-device bandwidth;
//! (3) the communication architecture, "etc.". We materialize that "etc." as
//! the partition context the DPP varies (scheme, node count, NT inflation)
//! plus two derived magnitudes (bottleneck GFLOPs for the i-Estimator,
//! transfer megabytes for the s-Estimator) — all functions of the paper's
//! inputs, included so the tree model spends its splits on *behaviour*
//! (efficiency cliffs, topology serialization) rather than re-deriving
//! arithmetic. The deviation is recorded in DESIGN.md §2.

/// Number of feature dimensions.
pub const NF: usize = 16;

/// Named indices into the feature vector. The first 12 match the paper's
/// Fig 4 schema; 12..16 are the partition context / derived magnitudes.
pub mod idx {
    pub const IN_H: usize = 0;
    pub const IN_W: usize = 1;
    pub const IN_C: usize = 2;
    pub const OUT_H: usize = 3;
    pub const OUT_W: usize = 4;
    pub const OUT_C: usize = 5;
    pub const K: usize = 6;
    pub const S: usize = 7;
    pub const P: usize = 8;
    pub const CONV_T: usize = 9;
    pub const BW_GBPS: usize = 10;
    pub const ARCH: usize = 11;
    pub const SCHEME_FROM: usize = 12;
    pub const SCHEME_TO: usize = 13;
    pub const NODES: usize = 14;
    /// i-Estimator: bottleneck GFLOPs of the (inflated) tile.
    /// s-Estimator: total transfer megabytes.
    pub const MAGNITUDE: usize = 15;
}

/// Pseudo-scheme code for the leader in scatter/gather boundaries (real
/// schemes use codes 0..4, see [`crate::partition::Scheme::code`]).
pub const LEADER_SCHEME_CODE: f64 = 4.0;

/// A fixed-size feature vector (no heap allocation on the planner hot path).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Features(pub [f64; NF]);

impl Features {
    pub fn zeros() -> Features {
        Features([0.0; NF])
    }

    pub fn get(&self, i: usize) -> f64 {
        self.0[i]
    }
}

impl std::ops::Index<usize> for Features {
    type Output = f64;
    fn index(&self, i: usize) -> &f64 {
        &self.0[i]
    }
}

impl std::ops::IndexMut<usize> for Features {
    fn index_mut(&mut self, i: usize) -> &mut f64 {
        &mut self.0[i]
    }
}

/// Human-readable names, for estimator diagnostics and feature-importance
/// reports.
pub const FEATURE_NAMES: [&str; NF] = [
    "in_h", "in_w", "in_c", "out_h", "out_w", "out_c", "k", "s", "p", "conv_t", "bw_gbps",
    "arch", "scheme_from", "scheme_to", "nodes", "magnitude",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_cover_all_dims() {
        assert_eq!(FEATURE_NAMES.len(), NF);
        assert_eq!(idx::MAGNITUDE, NF - 1);
    }

    #[test]
    fn index_ops() {
        let mut f = Features::zeros();
        f[idx::K] = 3.0;
        assert_eq!(f[idx::K], 3.0);
        assert_eq!(f.get(idx::K), 3.0);
    }
}
