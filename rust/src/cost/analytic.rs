//! Analytic ground-truth cost model — the simulator's physics.
//!
//! The paper measures real hardware; our substitute is this model: per-node
//! compute time from the device profile (peak throughput × per-op-family
//! efficiency + launch overhead), boundary time from the topology's link
//! schedule. The execution engine charges these costs to its virtual clock,
//! the trace generator labels CE training data with them (plus measurement
//! noise), and `CostSource::Analytic` exposes them to the planner as the
//! oracle used in the Thm-1 optimality tests.

use super::{ComputeQuery, SyncQuery};
use crate::net::Testbed;

/// Layer compute time: barrier semantics — the layer completes when the
/// slowest node finishes its (speed-adjusted) share.
pub fn compute_time(tb: &Testbed, q: &ComputeQuery) -> f64 {
    let mut worst = 0.0f64;
    for node in 0..q.nodes {
        let t = tb.device.compute_time(q.per_node_flops[node], q.conv_t);
        worst = worst.max(t);
    }
    worst
}

/// Boundary synchronization time: the topology's schedule of the byte
/// matrix.
pub fn sync_time(tb: &Testbed, q: &SyncQuery) -> f64 {
    tb.exchange_time(&q.msgs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::features::Features;
    use crate::cost::MAX_NODES;
    use crate::model::ConvType;
    use crate::net::{Bandwidth, Topology};

    #[test]
    fn compute_is_bottleneck_bound() {
        let tb = Testbed::new(4, Topology::Ring, Bandwidth::gbps(5.0));
        let mut per_node = [0.0; MAX_NODES];
        per_node[..4].copy_from_slice(&[1e9, 1e9, 4e9, 1e9]);
        let q = ComputeQuery {
            features: Features::zeros(),
            per_node_flops: per_node,
            nodes: 4,
            conv_t: ConvType::Standard,
        };
        let t = compute_time(&tb, &q);
        let expect = 4e9 / (128e9 * 0.55) + 20e-6;
        assert!((t - expect).abs() < 1e-9);
    }

    #[test]
    fn sync_zero_matrix_is_free() {
        let tb = Testbed::new(3, Topology::Ps, Bandwidth::gbps(1.0));
        let q = SyncQuery { features: Features::zeros(), msgs: vec![0; 9] };
        assert_eq!(sync_time(&tb, &q), 0.0);
    }
}
