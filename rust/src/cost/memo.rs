//! Memoized cost queries — the planner-side query cache that makes online
//! replanning cheap.
//!
//! ## Why memoization is sound
//!
//! Both estimator queries are *pure functions* of data the query itself
//! carries, so caching them can never change a planner result:
//!
//! * A [`ComputeQuery`] is answered, for the analytic oracle, from
//!   `(per_node_flops, conv_t)` and the device profile alone — the speed
//!   factors are already folded into `per_node_flops` by the query builder —
//!   and for the GBDT oracle from the feature vector alone. Neither depends
//!   on any planner state.
//! * A [`SyncQuery`] is answered, for the analytic oracle, from the byte
//!   matrix `msgs` plus the topology's schedule, and for the GBDT oracle
//!   from the feature vector. The byte matrix is pure partition *geometry*
//!   (layer shapes × schemes × node count): bandwidth never changes which
//!   bytes move where, only how long they take.
//!
//! Keys are therefore the exact bit patterns of those inputs (no lossy
//! hashing — equal keys imply equal answers by construction), namespaced by
//! a [`SourceSig`] capturing everything else the answer depends on
//! (topology, per-message latency, device profile, and — for learned
//! estimators — the estimator instance).
//!
//! ## The re-pricing fast path
//!
//! For the analytic oracle the *bandwidth scalar is deliberately excluded
//! from the sync key*: an entry stores the bandwidth-independent
//! [`ExchangeProfile`] (which link/port carries which bytes), and every
//! lookup prices that profile under the querying testbed's current
//! bandwidth via [`Testbed::price_exchange`]. A replan after pure bandwidth
//! drift — the common diurnal case — therefore performs **zero** inner sync
//! queries: every boundary cost is an analytic rescale of cached geometry,
//! bit-identical to what a fresh query would return. The
//! [`MemoStats::sync_rescales`] counter tracks exactly these re-pricings
//! (lookups served at a bandwidth other than the one the entry was built
//! under).
//!
//! The store is thread-safe (`RwLock` maps + atomic counters) and shared
//! via `Arc`, so one warm store serves the parallel DPP workers, the
//! background replanner, and its speculative n−1 planning concurrently.
//! Both maps are bounded (`MAX_ENTRIES_PER_MAP`): because the memo is a
//! pure cache, overflow simply flushes the map and lets the working set
//! refill — memory stays O(1) even when continuously drifting device
//! speeds mint fresh compute keys at every consulted batch boundary.

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use super::{ComputeQuery, CostSource, Estimators, SyncQuery};
use crate::net::{ExchangeProfile, PortLoad, Testbed, Topology};
use crate::util::json::Json;

/// Per-map entry cap. The memo is a pure cache, so overflowing simply
/// flushes the map and lets it refill: compute keys embed speed-adjusted
/// per-node flops, and under continuously drifting device speeds (the
/// diurnal profile) every consulted boundary mints fresh bit patterns — an
/// unbounded map would grow for the lifetime of a long-running server. A
/// full search universe is a few thousand entries, so the cap leaves ample
/// headroom across many models and condition cells while bounding memory.
const MAX_ENTRIES_PER_MAP: usize = 65_536;

/// Hit/miss/rescale counters of a [`MemoStore`] (monotone; diff two
/// snapshots with [`MemoStats::delta_since`] for per-search numbers).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct MemoStats {
    /// Compute queries answered from the cache.
    pub compute_hits: u64,
    /// Compute queries that consulted the inner estimator.
    pub compute_misses: u64,
    /// Sync queries answered from the cache at the entry's own bandwidth.
    pub sync_hits: u64,
    /// Sync queries answered by re-pricing cached geometry under a
    /// *different* bandwidth (the analytic rescale fast path).
    pub sync_rescales: u64,
    /// Sync queries that consulted the inner estimator.
    pub sync_misses: u64,
}

impl MemoStats {
    /// Counter increments since an `earlier` snapshot of the same store.
    pub fn delta_since(self, earlier: MemoStats) -> MemoStats {
        MemoStats {
            compute_hits: self.compute_hits.saturating_sub(earlier.compute_hits),
            compute_misses: self.compute_misses.saturating_sub(earlier.compute_misses),
            sync_hits: self.sync_hits.saturating_sub(earlier.sync_hits),
            sync_rescales: self.sync_rescales.saturating_sub(earlier.sync_rescales),
            sync_misses: self.sync_misses.saturating_sub(earlier.sync_misses),
        }
    }

    /// Fraction of compute queries served without the inner estimator.
    pub fn compute_hit_rate(&self) -> f64 {
        crate::metrics::hit_ratio(self.compute_hits, self.compute_misses)
    }

    /// Fraction of sync queries served without the inner estimator (exact
    /// hits and rescales both count as warm).
    pub fn sync_warm_rate(&self) -> f64 {
        crate::metrics::hit_ratio(self.sync_hits + self.sync_rescales, self.sync_misses)
    }
}

impl std::fmt::Display for MemoStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "compute={}h/{}m sync={}h/{}r/{}m",
            self.compute_hits,
            self.compute_misses,
            self.sync_hits,
            self.sync_rescales,
            self.sync_misses
        )
    }
}

/// Everything a cached answer depends on besides the per-query key and (for
/// analytic sync entries) the bandwidth: interned once per distinct source
/// so keys carry a compact id instead of the full signature.
#[derive(Clone)]
struct SourceSig {
    /// 0 = analytic oracle, 1 = learned (GBDT) estimators.
    kind: u8,
    topology: Topology,
    /// Per-message latency bits (priced live for sync, but namespaced so
    /// latency-differing testbeds never share compute entries either).
    latency: u64,
    /// Device profile bits: peak, efficiency[0..6], layer overhead.
    device: [u64; 8],
    /// The learned estimator instance this namespace belongs to (`None`
    /// for the analytic oracle). Holding the `Arc` keeps the allocation
    /// alive for the store's lifetime, so pointer identity can never be
    /// recycled onto a different estimator while its entries still exist.
    estimators: Option<Arc<Estimators>>,
}

impl PartialEq for SourceSig {
    fn eq(&self, other: &SourceSig) -> bool {
        let same_est = match (&self.estimators, &other.estimators) {
            (None, None) => true,
            (Some(a), Some(b)) => Arc::ptr_eq(a, b),
            _ => false,
        };
        self.kind == other.kind
            && self.topology == other.topology
            && self.latency == other.latency
            && self.device == other.device
            && same_est
    }
}

impl std::fmt::Debug for SourceSig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "SourceSig {{ kind: {}, topology: {}, estimators: {:?} }}",
            self.kind,
            self.topology,
            self.estimators.as_ref().map(Arc::as_ptr)
        )
    }
}

impl SourceSig {
    fn of(inner: &CostSource) -> SourceSig {
        let tb = inner.testbed();
        let mut device = [0u64; 8];
        device[0] = tb.device.peak_flops.to_bits();
        for (i, e) in tb.device.efficiency.iter().enumerate() {
            device[1 + i] = e.to_bits();
        }
        device[7] = tb.device.layer_overhead.to_bits();
        let (kind, estimators) = match inner {
            CostSource::Analytic(_) => (0u8, None),
            CostSource::Gbdt { estimators, .. } => (1u8, Some(estimators.clone())),
            CostSource::Memo(_) => unreachable!("memo layers are flattened on construction"),
        };
        SourceSig {
            kind,
            topology: tb.topology,
            latency: tb.latency.to_bits(),
            device,
            estimators,
        }
    }
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
enum ComputeKey {
    /// Analytic answer: bottleneck over speed-adjusted per-node flops.
    Analytic { sig: u32, conv: u8, flops: Box<[u64]> },
    /// Learned answer: a pure function of the feature vector.
    Learned { sig: u32, features: Box<[u64]> },
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
enum SyncKey {
    /// Analytic answer: schedule of the byte matrix (bandwidth excluded —
    /// entries re-price under the current bandwidth on every lookup).
    Analytic { sig: u32, msgs: Box<[u64]> },
    /// Learned answer: a pure function of the feature vector (which
    /// includes the bandwidth feature, so no rescale path exists).
    Learned { sig: u32, features: Box<[u64]> },
}

#[derive(Debug, Clone)]
enum SyncEntry {
    /// Cached schedule + the bandwidth it was first priced under (the
    /// bandwidth only classifies hit vs. rescale; pricing is always live).
    Analytic { bw_bits: u64, profile: ExchangeProfile },
    Learned { value: f64 },
}

/// Shared, thread-safe memo of estimator answers. One store can serve any
/// number of [`MemoCostSource`]s — across testbeds, bandwidths and even
/// oracles — because every entry is namespaced by its [`SourceSig`].
pub struct MemoStore {
    sigs: RwLock<Vec<SourceSig>>,
    compute: RwLock<HashMap<ComputeKey, f64>>,
    sync: RwLock<HashMap<SyncKey, SyncEntry>>,
    compute_hits: AtomicU64,
    compute_misses: AtomicU64,
    sync_hits: AtomicU64,
    sync_rescales: AtomicU64,
    sync_misses: AtomicU64,
}

impl std::fmt::Debug for MemoStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let (nc, ns) = self.len();
        write!(f, "MemoStore {{ compute: {}, sync: {}, stats: {} }}", nc, ns, self.stats())
    }
}

impl Default for MemoStore {
    fn default() -> Self {
        Self::new()
    }
}

impl MemoStore {
    pub fn new() -> MemoStore {
        MemoStore {
            sigs: RwLock::new(Vec::new()),
            compute: RwLock::new(HashMap::new()),
            sync: RwLock::new(HashMap::new()),
            compute_hits: AtomicU64::new(0),
            compute_misses: AtomicU64::new(0),
            sync_hits: AtomicU64::new(0),
            sync_rescales: AtomicU64::new(0),
            sync_misses: AtomicU64::new(0),
        }
    }

    /// A fresh store behind the `Arc` every consumer shares.
    pub fn shared() -> Arc<MemoStore> {
        Arc::new(MemoStore::new())
    }

    /// Counter snapshot.
    pub fn stats(&self) -> MemoStats {
        MemoStats {
            compute_hits: self.compute_hits.load(Ordering::Relaxed),
            compute_misses: self.compute_misses.load(Ordering::Relaxed),
            sync_hits: self.sync_hits.load(Ordering::Relaxed),
            sync_rescales: self.sync_rescales.load(Ordering::Relaxed),
            sync_misses: self.sync_misses.load(Ordering::Relaxed),
        }
    }

    /// `(compute entries, sync entries)` currently cached.
    pub fn len(&self) -> (usize, usize) {
        (self.compute.read().unwrap().len(), self.sync.read().unwrap().len())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == (0, 0)
    }

    /// Serialize every *analytic* entry to `path` as JSON (via
    /// [`crate::util::json`]). Learned (GBDT) entries are namespaced by a
    /// live estimator instance (pointer identity) and cannot survive a
    /// process boundary, so they are skipped. The JSON float encoding is
    /// shortest-round-trip, so a save → load cycle reproduces every key and
    /// cached value bit for bit — a reloaded store answers exactly the
    /// queries the original would have answered warm.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let sigs = self.sigs.read().unwrap();
        // analytic namespaces only, with a dense remap store-id → file index
        let mut remap: HashMap<u32, usize> = HashMap::new();
        let mut saved_sigs = Vec::new();
        for (i, sig) in sigs.iter().enumerate() {
            if sig.kind != 0 {
                continue;
            }
            remap.insert(i as u32, saved_sigs.len());
            let device: Vec<f64> = sig.device.iter().map(|&b| f64::from_bits(b)).collect();
            saved_sigs.push(Json::obj(vec![
                ("topology", Json::Str(sig.topology.name().to_string())),
                ("latency", Json::Num(f64::from_bits(sig.latency))),
                ("device", Json::num_arr(&device)),
            ]));
        }
        drop(sigs);

        let mut compute_entries = Vec::new();
        for (key, &value) in self.compute.read().unwrap().iter() {
            if let ComputeKey::Analytic { sig, conv, flops } = key {
                let Some(&si) = remap.get(sig) else { continue };
                let fl: Vec<f64> = flops.iter().map(|&b| f64::from_bits(b)).collect();
                compute_entries.push(Json::obj(vec![
                    ("sig", Json::Num(si as f64)),
                    ("conv", Json::Num(*conv as f64)),
                    ("flops", Json::num_arr(&fl)),
                    ("value", Json::Num(value)),
                ]));
            }
        }

        let mut sync_entries = Vec::new();
        for (key, entry) in self.sync.read().unwrap().iter() {
            if let (
                SyncKey::Analytic { sig, msgs },
                SyncEntry::Analytic { bw_bits, profile },
            ) = (key, entry)
            {
                let Some(&si) = remap.get(sig) else { continue };
                let loads: Vec<Json> = profile
                    .loads
                    .iter()
                    .map(|l| Json::Arr(vec![Json::Num(l.bytes as f64), Json::Num(l.msgs as f64)]))
                    .collect();
                sync_entries.push(Json::obj(vec![
                    ("sig", Json::Num(si as f64)),
                    ("msgs", Json::Arr(msgs.iter().map(|&m| Json::Num(m as f64)).collect())),
                    ("bw", Json::Num(f64::from_bits(*bw_bits))),
                    ("loads", Json::Arr(loads)),
                ]));
            }
        }

        Json::obj(vec![
            ("version", Json::Num(1.0)),
            ("sigs", Json::Arr(saved_sigs)),
            ("compute", Json::Arr(compute_entries)),
            ("sync", Json::Arr(sync_entries)),
        ])
        .save(path)
    }

    /// Absorb a previously [`Self::save`]d store: every saved analytic
    /// entry becomes a warm entry of this store (keys re-interned into this
    /// store's signature table, so the file composes with whatever is
    /// already cached). Hit/miss counters are untouched — loading is
    /// neither. Returns the `(compute, sync)` entry counts absorbed.
    pub fn load_into(&self, path: &Path) -> std::io::Result<(usize, usize)> {
        let v = Json::load(path)?;
        let bad = |what: &str| {
            std::io::Error::other(format!("memo store {}: bad {what}", path.display()))
        };
        // Strict numeric-array parsing: `Json::as_f64_vec` silently *drops*
        // non-numeric elements, so a NaN-bearing entry (NaN serializes as
        // `null`) would shrink its array and be absorbed under a wrong key.
        // Keys are trusted bit-for-bit — reject instead.
        let strict_nums = |j: Option<&Json>, what: &str| -> std::io::Result<Vec<f64>> {
            let arr = j.and_then(Json::as_arr).ok_or_else(|| bad(what))?;
            let mut out = Vec::with_capacity(arr.len());
            for x in arr {
                out.push(x.as_f64().ok_or_else(|| bad(what))?);
            }
            Ok(out)
        };
        // cached values are trusted bit-for-bit, so refuse formats this
        // code does not understand rather than misinterpret their fields
        if v.get("version").and_then(Json::as_f64) != Some(1.0) {
            return Err(bad("version (expected 1)"));
        }
        let sigs = v.get("sigs").and_then(Json::as_arr).ok_or_else(|| bad("sigs"))?;
        let mut ids = Vec::with_capacity(sigs.len());
        for s in sigs {
            let topology = s
                .get("topology")
                .and_then(Json::as_str)
                .ok_or_else(|| bad("topology"))?
                .parse::<Topology>()
                .map_err(std::io::Error::other)?;
            let latency =
                s.get("latency").and_then(Json::as_f64).ok_or_else(|| bad("latency"))?;
            let device_vals = strict_nums(s.get("device"), "device")?;
            if device_vals.len() != 8 {
                return Err(bad("device length"));
            }
            let mut device = [0u64; 8];
            for (d, val) in device.iter_mut().zip(&device_vals) {
                *d = val.to_bits();
            }
            ids.push(self.intern(SourceSig {
                kind: 0,
                topology,
                latency: latency.to_bits(),
                device,
                estimators: None,
            }));
        }
        let sig_of = |e: &Json| -> std::io::Result<u32> {
            let i = e.get("sig").and_then(Json::as_usize).ok_or_else(|| bad("sig"))?;
            ids.get(i).copied().ok_or_else(|| bad("sig index"))
        };

        let centries =
            v.get("compute").and_then(Json::as_arr).ok_or_else(|| bad("compute"))?;
        {
            let mut map = self.compute.write().unwrap();
            for e in centries {
                let sig = sig_of(e)?;
                let conv =
                    e.get("conv").and_then(Json::as_usize).ok_or_else(|| bad("conv"))? as u8;
                let flops = strict_nums(e.get("flops"), "flops")?;
                let value =
                    e.get("value").and_then(Json::as_f64).ok_or_else(|| bad("value"))?;
                if !value.is_finite() {
                    // a NaN/Inf cost would poison every plan comparison
                    return Err(bad("value (non-finite)"));
                }
                let key = ComputeKey::Analytic {
                    sig,
                    conv,
                    flops: flops.iter().map(|f| f.to_bits()).collect(),
                };
                if map.len() >= MAX_ENTRIES_PER_MAP {
                    map.clear();
                }
                map.insert(key, value);
            }
        }

        let sentries = v.get("sync").and_then(Json::as_arr).ok_or_else(|| bad("sync"))?;
        {
            let mut map = self.sync.write().unwrap();
            for e in sentries {
                let sig = sig_of(e)?;
                let msgs_json =
                    e.get("msgs").and_then(Json::as_arr).ok_or_else(|| bad("msgs"))?;
                let mut msgs = Vec::with_capacity(msgs_json.len());
                for m in msgs_json {
                    msgs.push(m.as_f64().ok_or_else(|| bad("msgs element"))? as u64);
                }
                let bw = e.get("bw").and_then(Json::as_f64).ok_or_else(|| bad("bw"))?;
                if !bw.is_finite() {
                    // only classifies hit vs rescale, but keep the format
                    // uniformly finite rather than absorb a junk entry
                    return Err(bad("bw (non-finite)"));
                }
                let loads_json =
                    e.get("loads").and_then(Json::as_arr).ok_or_else(|| bad("loads"))?;
                let mut loads = Vec::with_capacity(loads_json.len());
                for l in loads_json {
                    let pair = l.as_arr().ok_or_else(|| bad("load"))?;
                    if pair.len() != 2 {
                        return Err(bad("load pair"));
                    }
                    loads.push(PortLoad {
                        bytes: pair[0].as_f64().ok_or_else(|| bad("load bytes"))? as u64,
                        msgs: pair[1].as_f64().ok_or_else(|| bad("load msgs"))? as u64,
                    });
                }
                let key = SyncKey::Analytic { sig, msgs: msgs.into_boxed_slice() };
                if map.len() >= MAX_ENTRIES_PER_MAP {
                    map.clear();
                }
                map.insert(
                    key,
                    SyncEntry::Analytic {
                        bw_bits: bw.to_bits(),
                        profile: ExchangeProfile { loads },
                    },
                );
            }
        }
        Ok((centries.len(), sentries.len()))
    }

    /// A fresh shared store absorbed from `path`.
    pub fn load(path: &Path) -> std::io::Result<Arc<MemoStore>> {
        let store = MemoStore::shared();
        store.load_into(path)?;
        Ok(store)
    }

    fn intern(&self, sig: SourceSig) -> u32 {
        if let Some(i) = self.sigs.read().unwrap().iter().position(|s| *s == sig) {
            return i as u32;
        }
        let mut sigs = self.sigs.write().unwrap();
        // re-check under the write lock: another source may have raced us
        if let Some(i) = sigs.iter().position(|s| *s == sig) {
            return i as u32;
        }
        sigs.push(sig);
        (sigs.len() - 1) as u32
    }
}

/// A [`CostSource`] wrapper that answers repeated queries from a shared
/// [`MemoStore`] — see the module docs for the purity argument and the
/// bandwidth re-pricing fast path.
#[derive(Clone)]
pub struct MemoCostSource {
    inner: Box<CostSource>,
    store: Arc<MemoStore>,
    sig: u32,
}

impl std::fmt::Debug for MemoCostSource {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "MemoCostSource {{ inner: {}, store: {:?} }}", self.inner.name(), self.store)
    }
}

impl MemoCostSource {
    /// Wrap `inner` over `store`. A memo-of-memo is flattened so the cache
    /// is consulted exactly once per query.
    pub fn new(inner: CostSource, store: Arc<MemoStore>) -> MemoCostSource {
        let inner = match inner {
            CostSource::Memo(m) => m.inner,
            other => Box::new(other),
        };
        let sig = store.intern(SourceSig::of(&inner));
        MemoCostSource { inner, store, sig }
    }

    pub fn inner(&self) -> &CostSource {
        &self.inner
    }

    pub fn store(&self) -> &Arc<MemoStore> {
        &self.store
    }

    pub fn testbed(&self) -> &Testbed {
        self.inner.testbed()
    }

    pub fn name(&self) -> &'static str {
        match &*self.inner {
            CostSource::Analytic(_) => "memo+analytic",
            CostSource::Gbdt { .. } => "memo+gbdt",
            CostSource::Memo(_) => unreachable!("memo layers are flattened on construction"),
        }
    }

    pub fn compute_time(&self, q: &ComputeQuery) -> f64 {
        let key = match &*self.inner {
            CostSource::Analytic(_) => ComputeKey::Analytic {
                sig: self.sig,
                conv: q.conv_t.code() as u8,
                flops: q.per_node_flops[..q.nodes].iter().map(|f| f.to_bits()).collect(),
            },
            CostSource::Gbdt { .. } => ComputeKey::Learned {
                sig: self.sig,
                features: q.features.0.iter().map(|f| f.to_bits()).collect(),
            },
            CostSource::Memo(_) => unreachable!("memo layers are flattened on construction"),
        };
        if let Some(&v) = self.store.compute.read().unwrap().get(&key) {
            self.store.compute_hits.fetch_add(1, Ordering::Relaxed);
            return v;
        }
        let v = self.inner.compute_time(q);
        self.store.compute_misses.fetch_add(1, Ordering::Relaxed);
        // concurrent fills of the same key write the same pure value
        let mut map = self.store.compute.write().unwrap();
        if map.len() >= MAX_ENTRIES_PER_MAP {
            map.clear();
        }
        map.insert(key, v);
        v
    }

    pub fn sync_time(&self, q: &SyncQuery) -> f64 {
        match &*self.inner {
            CostSource::Analytic(tb) => {
                let key = SyncKey::Analytic {
                    sig: self.sig,
                    msgs: q.msgs.clone().into_boxed_slice(),
                };
                let bw_bits = tb.bandwidth.as_gbps().to_bits();
                if let Some(SyncEntry::Analytic { bw_bits: entry_bw, profile }) =
                    self.store.sync.read().unwrap().get(&key)
                {
                    if *entry_bw == bw_bits {
                        self.store.sync_hits.fetch_add(1, Ordering::Relaxed);
                    } else {
                        self.store.sync_rescales.fetch_add(1, Ordering::Relaxed);
                    }
                    // always price live: bit-identical to a fresh query at
                    // the current bandwidth and latency
                    return tb.price_exchange(profile);
                }
                let profile = tb.exchange_profile(&q.msgs);
                let v = tb.price_exchange(&profile);
                self.store.sync_misses.fetch_add(1, Ordering::Relaxed);
                let mut map = self.store.sync.write().unwrap();
                if map.len() >= MAX_ENTRIES_PER_MAP {
                    map.clear();
                }
                map.insert(key, SyncEntry::Analytic { bw_bits, profile });
                v
            }
            CostSource::Gbdt { .. } => {
                let key = SyncKey::Learned {
                    sig: self.sig,
                    features: q.features.0.iter().map(|f| f.to_bits()).collect(),
                };
                if let Some(SyncEntry::Learned { value }) =
                    self.store.sync.read().unwrap().get(&key)
                {
                    self.store.sync_hits.fetch_add(1, Ordering::Relaxed);
                    return *value;
                }
                let v = self.inner.sync_time(q);
                self.store.sync_misses.fetch_add(1, Ordering::Relaxed);
                let mut map = self.store.sync.write().unwrap();
                if map.len() >= MAX_ENTRIES_PER_MAP {
                    map.clear();
                }
                map.insert(key, SyncEntry::Learned { value: v });
                v
            }
            CostSource::Memo(_) => unreachable!("memo layers are flattened on construction"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::query::{block_entry_need, boundary_query, compute_query};
    use crate::model::{ConvType, LayerMeta};
    use crate::net::{Bandwidth, Topology};
    use crate::partition::inflate::BlockGeometry;
    use crate::partition::Scheme;

    fn tb(gbps: f64) -> Testbed {
        Testbed::new(4, Topology::Ring, Bandwidth::gbps(gbps))
    }

    fn conv(h: i64, c: i64) -> LayerMeta {
        LayerMeta::conv("t", ConvType::Standard, h, h, c, c, 3, 1, 1)
    }

    fn queries(testbed: &Testbed) -> (ComputeQuery, SyncQuery) {
        let a = conv(16, 8);
        let b = conv(16, 8);
        let layers = vec![a.clone()];
        let geo = BlockGeometry::new(&layers, Scheme::InH, 4);
        let cq = compute_query(&layers, &geo, 0, testbed);
        let need = block_entry_need(std::slice::from_ref(&b), Scheme::InH, 4);
        let sq = boundary_query(&a, Scheme::InH, &b, Scheme::InH, &need, testbed);
        (cq, sq)
    }

    #[test]
    fn memoized_answers_match_inner_bit_for_bit() {
        let testbed = tb(1.0);
        let inner = CostSource::analytic(&testbed);
        let store = MemoStore::shared();
        let memo = inner.clone().memoized(&store);
        let (cq, sq) = queries(&testbed);
        for _ in 0..3 {
            assert_eq!(memo.compute_time(&cq).to_bits(), inner.compute_time(&cq).to_bits());
            assert_eq!(memo.sync_time(&sq).to_bits(), inner.sync_time(&sq).to_bits());
        }
        let s = store.stats();
        assert_eq!((s.compute_misses, s.sync_misses), (1, 1));
        assert_eq!((s.compute_hits, s.sync_hits), (2, 2));
        assert_eq!(s.sync_rescales, 0);
    }

    #[test]
    fn bandwidth_drift_is_served_by_rescaling_not_requerying() {
        let fast = tb(1.0);
        let slow = fast.with_bandwidth_factor(0.25);
        let store = MemoStore::shared();
        let memo_fast = CostSource::analytic(&fast).memoized(&store);
        let (cq, sq) = queries(&fast);
        memo_fast.compute_time(&cq);
        memo_fast.sync_time(&sq);
        let warm = store.stats();

        // same geometry under a collapsed link: zero inner queries
        let memo_slow = CostSource::analytic(&slow).memoized(&store);
        let (cq2, sq2) = queries(&slow);
        let got_c = memo_slow.compute_time(&cq2);
        let got_s = memo_slow.sync_time(&sq2);
        let delta = store.stats().delta_since(warm);
        assert_eq!(delta.compute_misses, 0, "compute is bandwidth-independent");
        assert_eq!(delta.sync_misses, 0, "drift must not re-query the estimator");
        assert_eq!(delta.sync_rescales, 1, "drift lookups are rescales");

        // and the rescaled answers are bit-identical to fresh queries
        let fresh = CostSource::analytic(&slow);
        assert_eq!(got_c.to_bits(), fresh.compute_time(&cq2).to_bits());
        assert_eq!(got_s.to_bits(), fresh.sync_time(&sq2).to_bits());
    }

    #[test]
    fn distinct_topologies_never_share_entries() {
        let ring = Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0));
        let ps = Testbed::new(4, Topology::Ps, Bandwidth::gbps(1.0));
        let store = MemoStore::shared();
        let memo_ring = CostSource::analytic(&ring).memoized(&store);
        let memo_ps = CostSource::analytic(&ps).memoized(&store);
        let (_, sq_ring) = queries(&ring);
        let (_, sq_ps) = queries(&ps);
        let a = memo_ring.sync_time(&sq_ring);
        let b = memo_ps.sync_time(&sq_ps);
        assert_eq!(store.stats().sync_misses, 2, "each topology fills its own entry");
        assert_eq!(a.to_bits(), CostSource::analytic(&ring).sync_time(&sq_ring).to_bits());
        assert_eq!(b.to_bits(), CostSource::analytic(&ps).sync_time(&sq_ps).to_bits());
    }

    #[test]
    fn save_load_roundtrip_preserves_entries_bit_for_bit() {
        let testbed = tb(1.0);
        let store = MemoStore::shared();
        let memo = CostSource::analytic(&testbed).memoized(&store);
        let (cq, sq) = queries(&testbed);
        let vc = memo.compute_time(&cq);
        let vs = memo.sync_time(&sq);

        let dir = crate::util::tmp::TempDir::new("memo_store");
        let p = dir.path().join("memo.json");
        store.save(&p).unwrap();
        let loaded = MemoStore::load(&p).unwrap();
        assert_eq!(loaded.len(), store.len());

        // identical queries against the reloaded store are pure hits with
        // bit-identical answers
        let memo2 = CostSource::analytic(&testbed).memoized(&loaded);
        let before = loaded.stats();
        assert_eq!(memo2.compute_time(&cq).to_bits(), vc.to_bits());
        assert_eq!(memo2.sync_time(&sq).to_bits(), vs.to_bits());
        let delta = loaded.stats().delta_since(before);
        assert_eq!(delta.compute_misses, 0, "reloaded store missed: {delta}");
        assert_eq!(delta.sync_misses, 0, "reloaded store missed: {delta}");
        assert_eq!((delta.compute_hits, delta.sync_hits), (1, 1));

        // the bandwidth re-pricing fast path survives the round trip too
        let slow = testbed.with_bandwidth_factor(0.25);
        let memo_slow = CostSource::analytic(&slow).memoized(&loaded);
        let (_, sq_slow) = queries(&slow);
        let got = memo_slow.sync_time(&sq_slow);
        let delta = loaded.stats().delta_since(before);
        assert_eq!(delta.sync_misses, 0, "drift after reload re-queried: {delta}");
        assert_eq!(delta.sync_rescales, 1);
        assert_eq!(
            got.to_bits(),
            CostSource::analytic(&slow).sync_time(&sq_slow).to_bits()
        );
    }

    #[test]
    fn load_into_composes_with_existing_entries() {
        // a saved ring-testbed store absorbed into a store already holding
        // star-testbed entries leaves both namespaces answerable warm
        let ring = Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0));
        let ps = Testbed::new(4, Topology::Ps, Bandwidth::gbps(1.0));
        let ring_store = MemoStore::shared();
        let memo_ring = CostSource::analytic(&ring).memoized(&ring_store);
        let (cq_ring, sq_ring) = queries(&ring);
        memo_ring.compute_time(&cq_ring);
        memo_ring.sync_time(&sq_ring);
        let dir = crate::util::tmp::TempDir::new("memo_compose");
        let p = dir.path().join("ring.json");
        ring_store.save(&p).unwrap();

        let combined = MemoStore::shared();
        let memo_ps = CostSource::analytic(&ps).memoized(&combined);
        let (cq_ps, sq_ps) = queries(&ps);
        memo_ps.compute_time(&cq_ps);
        memo_ps.sync_time(&sq_ps);
        let (nc, ns) = combined.load_into(&p).unwrap();
        assert_eq!((nc, ns), (1, 1));
        let before = combined.stats();
        let memo_ring2 = CostSource::analytic(&ring).memoized(&combined);
        memo_ring2.compute_time(&cq_ring);
        memo_ring2.sync_time(&sq_ring);
        memo_ps.compute_time(&cq_ps);
        memo_ps.sync_time(&sq_ps);
        let delta = combined.stats().delta_since(before);
        assert_eq!(delta.compute_misses + delta.sync_misses, 0, "{delta}");
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = crate::util::tmp::TempDir::new("memo_bad");
        let p = dir.path().join("bad.json");
        std::fs::write(&p, "{\"sigs\": 7}").unwrap();
        assert!(MemoStore::load(&p).is_err());
        assert!(MemoStore::load(&dir.path().join("absent.json")).is_err());
    }

    /// A real saved store's text, for the corruption tests below.
    fn saved_store_text(dir: &crate::util::tmp::TempDir) -> String {
        let testbed = tb(1.0);
        let store = MemoStore::shared();
        let memo = CostSource::analytic(&testbed).memoized(&store);
        let (cq, sq) = queries(&testbed);
        memo.compute_time(&cq);
        memo.sync_time(&sq);
        let p = dir.path().join("good.json");
        store.save(&p).unwrap();
        std::fs::read_to_string(&p).unwrap()
    }

    fn expect_load_err(dir: &crate::util::tmp::TempDir, name: &str, text: &str, hint: &str) {
        let p = dir.path().join(name);
        std::fs::write(&p, text).unwrap();
        let err = MemoStore::load(&p).expect_err(name);
        assert!(
            err.to_string().contains(hint),
            "{name}: error {err} does not mention {hint:?}"
        );
    }

    #[test]
    fn load_rejects_truncated_file() {
        let dir = crate::util::tmp::TempDir::new("memo_trunc");
        let text = saved_store_text(&dir);
        let p = dir.path().join("trunc.json");
        std::fs::write(&p, &text[..text.len() - 10]).unwrap();
        assert!(MemoStore::load(&p).is_err(), "truncated store must not load");
    }

    #[test]
    fn load_rejects_version_mismatch() {
        let dir = crate::util::tmp::TempDir::new("memo_ver");
        let text = saved_store_text(&dir);
        assert!(text.contains("\"version\":1"), "fixture drifted: {text}");
        let newer = text.replace("\"version\":1", "\"version\":2");
        expect_load_err(&dir, "v2.json", &newer, "version");
    }

    #[test]
    fn load_rejects_nan_bearing_entries() {
        // NaN serializes as `null`; the lenient vec accessor would silently
        // drop it and shrink the key — load_into must reject instead
        let dir = crate::util::tmp::TempDir::new("memo_nan");
        let text = saved_store_text(&dir);

        // a NaN compute value
        let i = text.find("\"value\":").expect("fixture has a compute value");
        let j = text[i..].find('}').unwrap() + i;
        let nan_value = format!("{}\"value\":null{}", &text[..i], &text[j..]);
        expect_load_err(&dir, "nan_value.json", &nan_value, "value");

        // an infinite compute value (parses, but is not a usable cost)
        let inf_value = format!("{}\"value\":1e999{}", &text[..i], &text[j..]);
        expect_load_err(&dir, "inf_value.json", &inf_value, "value");

        // a NaN inside the flops key vector
        let k = text.find("\"flops\":[").expect("fixture has flops") + "\"flops\":[".len();
        let e = text[k..].find(|c| c == ',' || c == ']').unwrap() + k;
        let nan_flops = format!("{}null{}", &text[..k], &text[e..]);
        expect_load_err(&dir, "nan_flops.json", &nan_flops, "flops");
    }

    #[test]
    fn failed_load_leaves_store_usable() {
        // a rejected file must not poison the store: queries after the
        // failed absorb still answer and memoize normally
        let dir = crate::util::tmp::TempDir::new("memo_usable");
        let text = saved_store_text(&dir);
        let p = dir.path().join("bad_version.json");
        std::fs::write(&p, text.replace("\"version\":1", "\"version\":3")).unwrap();
        let store = MemoStore::shared();
        assert!(store.load_into(&p).is_err());
        let testbed = tb(1.0);
        let memo = CostSource::analytic(&testbed).memoized(&store);
        let (cq, sq) = queries(&testbed);
        let inner = CostSource::analytic(&testbed);
        assert_eq!(memo.compute_time(&cq).to_bits(), inner.compute_time(&cq).to_bits());
        assert_eq!(memo.sync_time(&sq).to_bits(), inner.sync_time(&sq).to_bits());
        assert_eq!(store.stats().compute_misses, 1);
        assert_eq!(memo.compute_time(&cq).to_bits(), inner.compute_time(&cq).to_bits());
        assert_eq!(store.stats().compute_hits, 1);
    }

    #[test]
    fn memo_of_memo_flattens() {
        let testbed = tb(1.0);
        let store = MemoStore::shared();
        let once = CostSource::analytic(&testbed).memoized(&store);
        let twice = once.memoized(&store);
        match &twice {
            CostSource::Memo(m) => {
                assert!(matches!(&*m.inner, CostSource::Analytic(_)), "inner must be flattened")
            }
            other => panic!("expected memo source, got {}", other.name()),
        }
        assert_eq!(twice.name(), "memo+analytic");
    }
}
