//! Open-loop load generation: seeded arrival schedules, mergeable latency
//! histograms, per-process resource sampling and the load-agent loop.
//!
//! The paper evaluates closed-loop batch latency; the ROADMAP's north star
//! is heavy open traffic, where the currency is p99/p99.9 under Poisson
//! arrivals. The pieces here are built so a multi-process harness
//! ([`crate::bench::harness`]) can be **deterministic where it matters and
//! honest where it can't be**:
//!
//! * [`Schedule`]s are generated ahead of time from a [`ScheduleSpec`] —
//!   same seed, same spec ⇒ byte-identical offsets, no wall clock in the
//!   generator. The agent then *paces* the precomputed offsets, so the
//!   arrival process is fixed before the first request leaves.
//! * [`hist::Histogram`] is an HDR-style log-bucketed histogram whose merge
//!   is exact (bucket-wise addition, order-independent): N agent processes
//!   each report their own histogram as JSON and the orchestrator's merged
//!   percentiles are identical to what one process recording every sample
//!   would have reported.
//! * [`procfs`] samples `/proc/<pid>/{statm,stat,io}` around a run — RSS,
//!   CPU time and real I/O per process, `None` off Linux rather than wrong.
//! * [`agent`] is the open-loop client: it never waits for a response
//!   before sending the next request (a writer thread paces the schedule, a
//!   reader thread matches replies by sequence number), which is what makes
//!   the measured tail an *arrival-process* tail instead of a closed-loop
//!   artifact.

pub mod agent;
pub mod hist;
pub mod procfs;

use crate::util::cli::Args;
use crate::util::rng::Rng;

/// The arrival process a schedule is drawn from. Rates are requests per
/// second of *offered* load (open loop: arrivals don't wait for service).
///
/// `Uniform`, `Burst` and `Step` are rng-free — their schedules depend only
/// on the rate parameters, which is exactly what the deterministic A-suites
/// want. `Poisson` consumes the spec's seed (exponential inter-arrivals via
/// inverse-CDF), the regime the B-suites measure tails under.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// Fixed inter-arrival gap `1/rate_hz`.
    Uniform { rate_hz: f64 },
    /// Exponential inter-arrivals with mean `1/rate_hz`.
    Poisson { rate_hz: f64 },
    /// Square-wave modulation: `burst_hz` for the first `duty` fraction of
    /// every `period_s`, `base_hz` for the rest.
    Burst { base_hz: f64, burst_hz: f64, period_s: f64, duty: f64 },
    /// Rate change at an absolute offset: `before_hz` until `at_s`,
    /// `after_hz` after.
    Step { before_hz: f64, after_hz: f64, at_s: f64 },
}

impl ArrivalProcess {
    /// CLI flags understood by [`ArrivalProcess::from_args`] — the harness
    /// hands a spec to an agent *process* through these.
    pub fn to_cli(&self) -> Vec<String> {
        let f = |v: f64| format!("{v}");
        match self {
            ArrivalProcess::Uniform { rate_hz } => {
                vec!["--arrival".into(), "uniform".into(), "--rate".into(), f(*rate_hz)]
            }
            ArrivalProcess::Poisson { rate_hz } => {
                vec!["--arrival".into(), "poisson".into(), "--rate".into(), f(*rate_hz)]
            }
            ArrivalProcess::Burst { base_hz, burst_hz, period_s, duty } => vec![
                "--arrival".into(),
                "burst".into(),
                "--rate".into(),
                f(*base_hz),
                "--burst-rate".into(),
                f(*burst_hz),
                "--period".into(),
                f(*period_s),
                "--duty".into(),
                f(*duty),
            ],
            ArrivalProcess::Step { before_hz, after_hz, at_s } => vec![
                "--arrival".into(),
                "step".into(),
                "--rate".into(),
                f(*before_hz),
                "--after-rate".into(),
                f(*after_hz),
                "--at".into(),
                f(*at_s),
            ],
        }
    }

    /// Parse the flags emitted by [`ArrivalProcess::to_cli`].
    pub fn from_args(args: &Args) -> Result<ArrivalProcess, String> {
        let rate = args.f64_or("rate", 100.0);
        match args.get_or("arrival", "uniform") {
            "uniform" => Ok(ArrivalProcess::Uniform { rate_hz: rate }),
            "poisson" => Ok(ArrivalProcess::Poisson { rate_hz: rate }),
            "burst" => Ok(ArrivalProcess::Burst {
                base_hz: rate,
                burst_hz: args.f64_or("burst-rate", 2.0 * rate),
                period_s: args.f64_or("period", 0.1),
                duty: args.f64_or("duty", 0.5),
            }),
            "step" => Ok(ArrivalProcess::Step {
                before_hz: rate,
                after_hz: args.f64_or("after-rate", 2.0 * rate),
                at_s: args.f64_or("at", 0.1),
            }),
            other => Err(format!("unknown arrival process {other:?}")),
        }
    }
}

/// Everything that determines a schedule. Two equal specs generate
/// byte-identical schedules — the determinism the CI-gated suites lean on.
#[derive(Debug, Clone, PartialEq)]
pub struct ScheduleSpec {
    pub process: ArrivalProcess,
    /// Total requests in the schedule.
    pub requests: usize,
    /// Seed for the stochastic processes (ignored by the rng-free ones).
    pub seed: u64,
}

impl ScheduleSpec {
    /// Generate the full arrival schedule ahead of time. Pure function of
    /// the spec: no wall clock, no global state.
    pub fn generate(&self) -> Schedule {
        let mut rng = Rng::new(self.seed);
        let mut t = 0.0f64; // seconds since schedule start
        let mut offsets_ns = Vec::with_capacity(self.requests);
        for _ in 0..self.requests {
            offsets_ns.push((t * 1e9).round() as u64);
            let dt = match &self.process {
                ArrivalProcess::Uniform { rate_hz } => 1.0 / rate_hz,
                ArrivalProcess::Poisson { rate_hz } => {
                    // inverse-CDF exponential; 1 - u avoids ln(0)
                    -(1.0 - rng.f64()).ln() / rate_hz
                }
                ArrivalProcess::Burst { base_hz, burst_hz, period_s, duty } => {
                    let phase = (t / period_s).fract();
                    1.0 / if phase < *duty { *burst_hz } else { *base_hz }
                }
                ArrivalProcess::Step { before_hz, after_hz, at_s } => {
                    1.0 / if t < *at_s { *before_hz } else { *after_hz }
                }
            };
            t += dt;
        }
        Schedule { offsets_ns }
    }
}

/// A precomputed arrival schedule: request `i` leaves at `offsets_ns[i]`
/// nanoseconds after the agent's start instant.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Schedule {
    pub offsets_ns: Vec<u64>,
}

impl Schedule {
    /// Canonical byte serialization (LE u64 count, then LE u64 offsets) —
    /// what the determinism test compares across generator runs.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(8 + 8 * self.offsets_ns.len());
        out.extend_from_slice(&(self.offsets_ns.len() as u64).to_le_bytes());
        for &o in &self.offsets_ns {
            out.extend_from_slice(&o.to_le_bytes());
        }
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Schedule, String> {
        if bytes.len() < 8 {
            return Err("schedule shorter than its header".into());
        }
        let n = u64::from_le_bytes(bytes[0..8].try_into().unwrap()) as usize;
        if bytes.len() != 8 + 8 * n {
            return Err(format!("schedule declares {n} offsets, has {} bytes", bytes.len() - 8));
        }
        let offsets_ns = (0..n)
            .map(|i| u64::from_le_bytes(bytes[8 + 8 * i..16 + 8 * i].try_into().unwrap()))
            .collect();
        Ok(Schedule { offsets_ns })
    }

    /// Mean inter-arrival gap in seconds (0 for degenerate schedules).
    pub fn mean_gap_secs(&self) -> f64 {
        if self.offsets_ns.len() < 2 {
            return 0.0;
        }
        let span = self.offsets_ns.last().unwrap() - self.offsets_ns[0];
        span as f64 / 1e9 / (self.offsets_ns.len() - 1) as f64
    }
}

/// The fixed workload every load suite drives: one small model, a handful
/// of distinct inputs cycled by sequence number. Shared between the agents
/// (which verify replies bit-exactly against the single-node reference) and
/// the harness (which sizes servers for it) so the two can never drift.
pub mod workload {
    use crate::compute::Tensor;
    use crate::model::{zoo, Model};

    /// Weight-derivation seed, matching the serving tests.
    pub const WEIGHT_SEED: u64 = 5;
    /// Input tensor shape `(h, w, c)`.
    pub const INPUT_SHAPE: (i64, i64, i64) = (16, 16, 3);

    pub fn model() -> Model {
        zoo::edgenet(16)
    }

    /// Input for request `seq`: one of `distinct` tensors derived from
    /// `base_seed` — small enough for agents to hold every reference
    /// output, varied enough to catch cross-request mixups.
    pub fn input(seq: u64, base_seed: u64, distinct: u64) -> Tensor {
        let (h, w, c) = INPUT_SHAPE;
        Tensor::random(h, w, c, base_seed + seq % distinct.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_schedule_is_exact() {
        let spec = ScheduleSpec {
            process: ArrivalProcess::Uniform { rate_hz: 1000.0 },
            requests: 4,
            seed: 1,
        };
        let s = spec.generate();
        assert_eq!(s.offsets_ns, vec![0, 1_000_000, 2_000_000, 3_000_000]);
    }

    #[test]
    fn burst_and_step_modulate_the_gap() {
        let burst = ScheduleSpec {
            process: ArrivalProcess::Burst {
                base_hz: 100.0,
                burst_hz: 1000.0,
                period_s: 0.1,
                duty: 0.5,
            },
            requests: 200,
            seed: 0,
        }
        .generate();
        let gaps: Vec<u64> =
            burst.offsets_ns.windows(2).map(|w| w[1] - w[0]).collect();
        assert!(gaps.contains(&1_000_000), "no burst-phase gap");
        assert!(gaps.contains(&10_000_000), "no base-phase gap");

        let step = ScheduleSpec {
            process: ArrivalProcess::Step { before_hz: 100.0, after_hz: 1000.0, at_s: 0.05 },
            requests: 100,
            seed: 0,
        }
        .generate();
        let gaps: Vec<u64> = step.offsets_ns.windows(2).map(|w| w[1] - w[0]).collect();
        assert_eq!(gaps.first(), Some(&10_000_000));
        assert_eq!(gaps.last(), Some(&1_000_000));
    }

    #[test]
    fn schedule_bytes_round_trip() {
        let spec = ScheduleSpec {
            process: ArrivalProcess::Poisson { rate_hz: 500.0 },
            requests: 64,
            seed: 7,
        };
        let s = spec.generate();
        let back = Schedule::from_bytes(&s.to_bytes()).unwrap();
        assert_eq!(back, s);
        assert!(Schedule::from_bytes(&s.to_bytes()[..9]).is_err());
    }

    #[test]
    fn arrival_cli_round_trips() {
        for p in [
            ArrivalProcess::Uniform { rate_hz: 123.5 },
            ArrivalProcess::Poisson { rate_hz: 77.25 },
            ArrivalProcess::Burst { base_hz: 10.0, burst_hz: 90.0, period_s: 0.25, duty: 0.3 },
            ArrivalProcess::Step { before_hz: 40.0, after_hz: 160.0, at_s: 0.5 },
        ] {
            let argv = p.to_cli();
            let args = Args::parse(argv);
            assert_eq!(ArrivalProcess::from_args(&args).unwrap(), p);
        }
    }
}
