//! HDR-style log-bucketed latency histogram with an **exact** merge.
//!
//! Values below 32 map to their own bucket; every power-of-two octave above
//! that is split into 32 sub-buckets, so relative resolution stays ≈3%
//! across the full `u64` range at a fixed 1920 buckets. Recording and
//! merging are pure integer bucket arithmetic: `merge(a, b)` is bucket-wise
//! addition, hence commutative, associative and lossless — N agent
//! processes can each record locally and the orchestrator's merged
//! percentiles are identical to single-process recording, in any merge
//! order. Percentiles are reported at the **bucket ceiling** (clamped to
//! the exact tracked max), which keeps them conservative, monotone in the
//! quantile, and within one bucket width of the true sample percentile.

use crate::util::json::Json;

/// Values `0..LINEAR` get unit-width buckets.
const LINEAR: u64 = 32;
/// Sub-buckets per octave above the linear range.
const SUB: usize = 32;
/// Octaves `k = 5..=63` (values `32..=u64::MAX`).
const OCTAVES: usize = 59;
/// Total bucket count: 32 linear + 59 octaves × 32 sub-buckets.
pub const N_BUCKETS: usize = LINEAR as usize + OCTAVES * SUB;

/// Bucket index for a recorded value.
fn index(v: u64) -> usize {
    if v < LINEAR {
        return v as usize;
    }
    let k = 63 - v.leading_zeros() as usize; // 5..=63
    let sub = ((v - (1u64 << k)) >> (k - 5)) as usize;
    LINEAR as usize + (k - 5) * SUB + sub
}

/// Largest value mapping to bucket `idx` — the ceiling percentiles report.
fn bucket_high(idx: usize) -> u64 {
    if idx < LINEAR as usize {
        return idx as u64;
    }
    let k = (idx - LINEAR as usize) / SUB + 5;
    let sub = ((idx - LINEAR as usize) % SUB) as u64;
    let low = (1u64 << k) + (sub << (k - 5));
    low + ((1u64 << (k - 5)) - 1)
}

/// Width of the bucket holding `v` — the error bound on percentiles.
pub fn bucket_width(v: u64) -> u64 {
    if v < LINEAR {
        1
    } else {
        1u64 << ((63 - v.leading_zeros() as u64) - 5)
    }
}

/// The histogram. Buckets are dense (`N_BUCKETS` u64 counters, ~15 KiB);
/// the JSON form is sparse.
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    count: u64,
    min: u64,
    max: u64,
    sum: u128,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram { counts: vec![0; N_BUCKETS], count: 0, min: u64::MAX, max: 0, sum: 0 }
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    pub fn record(&mut self, v: u64) {
        self.counts[index(v)] += 1;
        self.count += 1;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        self.sum += v as u128;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Fold `other` in: exact bucket-wise addition. Commutative and
    /// order-independent — the property the orchestrator's multi-agent
    /// merge (and its property test) relies on.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.sum += other.sum;
    }

    /// Value at quantile `q` in `[0, 1]`: the ceiling of the bucket holding
    /// the `ceil(q·count)`-th smallest sample, clamped to the tracked max.
    /// 0 for an empty histogram.
    pub fn percentile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_high(idx).min(self.max);
            }
        }
        self.max
    }

    /// Sparse JSON form: counters plus `[[bucket, count], ...]`. Bucket
    /// counts survive f64 transport exactly below 2^53 — far beyond any
    /// realistic run.
    pub fn to_json(&self) -> Json {
        let buckets = self
            .counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| Json::arr([Json::Num(i as f64), Json::Num(c as f64)]))
            .collect::<Vec<_>>();
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("min", Json::Num(self.min() as f64)),
            ("max", Json::Num(self.max as f64)),
            ("sum", Json::Num(self.sum as f64)),
            ("buckets", Json::Arr(buckets)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Histogram, String> {
        let mut h = Histogram::new();
        let count = v.req("count")?.as_f64().ok_or("count not a number")? as u64;
        let min = v.req("min")?.as_f64().ok_or("min not a number")? as u64;
        let max = v.req("max")?.as_f64().ok_or("max not a number")? as u64;
        let sum = v.req("sum")?.as_f64().ok_or("sum not a number")? as u128;
        let mut total = 0u64;
        for b in v.req("buckets")?.as_arr().ok_or("buckets not an array")? {
            let pair = b.as_arr().ok_or("bucket entry not a pair")?;
            if pair.len() != 2 {
                return Err("bucket entry not a pair".into());
            }
            let idx = pair[0].as_f64().ok_or("bucket index not a number")? as usize;
            let c = pair[1].as_f64().ok_or("bucket count not a number")? as u64;
            if idx >= N_BUCKETS {
                return Err(format!("bucket index {idx} out of range"));
            }
            h.counts[idx] += c;
            total += c;
        }
        if total != count {
            return Err(format!("bucket counts sum to {total}, header says {count}"));
        }
        h.count = count;
        h.min = if count == 0 { u64::MAX } else { min };
        h.max = max;
        h.sum = sum;
        Ok(h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn indexing_is_monotone_and_in_range() {
        let mut prev = 0usize;
        for v in (0..4096u64).chain([1 << 20, 1 << 40, u64::MAX / 2, u64::MAX]) {
            let i = index(v);
            assert!(i < N_BUCKETS, "index {i} out of range for {v}");
            assert!(i >= prev, "index not monotone at {v}");
            prev = i;
            assert!(bucket_high(i) >= v, "ceiling below value at {v}");
            assert!(bucket_high(i) - v < bucket_width(v), "ceiling too far at {v}");
        }
    }

    #[test]
    fn percentile_matches_exact_samples_in_linear_range() {
        // below LINEAR every bucket is exact, so percentiles are exact
        let mut h = Histogram::new();
        for v in 1..=20u64 {
            h.record(v);
        }
        assert_eq!(h.percentile(0.5), 10);
        assert_eq!(h.percentile(1.0), 20);
        assert_eq!(h.min(), 1);
        assert_eq!(h.count(), 20);
    }

    #[test]
    fn percentiles_are_monotone_and_clamped() {
        let mut h = Histogram::new();
        let mut rng = Rng::new(9);
        for _ in 0..1000 {
            h.record(rng.below(2_000_000) as u64);
        }
        let qs = [0.5, 0.9, 0.99, 0.999, 1.0];
        let ps: Vec<u64> = qs.iter().map(|&q| h.percentile(q)).collect();
        for w in ps.windows(2) {
            assert!(w[0] <= w[1], "percentiles not monotone: {ps:?}");
        }
        assert!(*ps.last().unwrap() <= h.max());
    }

    #[test]
    fn empty_histogram_is_all_zero() {
        let h = Histogram::new();
        assert_eq!(h.percentile(0.99), 0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn json_round_trip_preserves_everything() {
        let mut h = Histogram::new();
        let mut rng = Rng::new(3);
        for _ in 0..500 {
            h.record(rng.below(10_000_000) as u64);
        }
        let text = h.to_json().to_string();
        let back = Histogram::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.counts, h.counts);
        assert_eq!(back.count, h.count);
        assert_eq!(back.min, h.min);
        assert_eq!(back.max, h.max);
        assert_eq!(back.sum, h.sum);
    }

    #[test]
    fn from_json_rejects_inconsistent_counts() {
        let mut h = Histogram::new();
        h.record(5);
        let mut v = h.to_json();
        if let Json::Obj(m) = &mut v {
            m.insert("count".into(), Json::Num(2.0));
        }
        assert!(Histogram::from_json(&v).is_err());
    }
}
