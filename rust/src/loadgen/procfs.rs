//! Per-process resource sampling from `/proc/<pid>/{statm,stat,io}`.
//!
//! The harness brackets every run with these samples — each load agent
//! self-reports its own usage in its result line, the orchestrator samples
//! the node daemons (which can't self-report) and itself. Everything is
//! best-effort `Option`: off Linux, or for a pid that just exited, the
//! answer is `None`, never a guess. All reads are plain `std::fs` — no
//! dependencies.

use crate::util::json::Json;

/// Page size `/proc/<pid>/statm` counts in. Fixed at 4 KiB: every platform
/// this harness targets (x86-64/aarch64 Linux defaults) uses it, and being
/// a few pages off on an exotic config only scales a *reported* gauge.
const PAGE_BYTES: u64 = 4096;
/// Kernel USER_HZ for `utime`/`stime` ticks (100 on all mainstream builds).
const TICK_MS: u64 = 10;

/// One resource snapshot of one process.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ProcUsage {
    /// Resident set size in bytes (a gauge, not a counter).
    pub rss_bytes: u64,
    /// User + system CPU time consumed so far, in milliseconds.
    pub cpu_ms: u64,
    /// Bytes actually fetched from the storage layer (`/proc/<pid>/io`
    /// `read_bytes`); 0 when the file is unreadable (permissions).
    pub read_bytes: u64,
    /// Bytes sent to the storage layer (`write_bytes`); 0 when unreadable.
    pub write_bytes: u64,
}

impl ProcUsage {
    /// Usage *since* `earlier`: CPU and I/O are counter deltas, RSS stays
    /// the later gauge.
    pub fn since(&self, earlier: &ProcUsage) -> ProcUsage {
        ProcUsage {
            rss_bytes: self.rss_bytes,
            cpu_ms: self.cpu_ms.saturating_sub(earlier.cpu_ms),
            read_bytes: self.read_bytes.saturating_sub(earlier.read_bytes),
            write_bytes: self.write_bytes.saturating_sub(earlier.write_bytes),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("rss_bytes", Json::Num(self.rss_bytes as f64)),
            ("cpu_ms", Json::Num(self.cpu_ms as f64)),
            ("read_bytes", Json::Num(self.read_bytes as f64)),
            ("write_bytes", Json::Num(self.write_bytes as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<ProcUsage, String> {
        let f = |k: &str| -> Result<u64, String> {
            Ok(v.req(k)?.as_f64().ok_or_else(|| format!("{k} not a number"))? as u64)
        };
        Ok(ProcUsage {
            rss_bytes: f("rss_bytes")?,
            cpu_ms: f("cpu_ms")?,
            read_bytes: f("read_bytes")?,
            write_bytes: f("write_bytes")?,
        })
    }
}

/// Snapshot `pid`'s usage. `None` when `/proc` is absent (non-Linux) or the
/// process is gone.
pub fn usage_of(pid: u32) -> Option<ProcUsage> {
    let statm = std::fs::read_to_string(format!("/proc/{pid}/statm")).ok()?;
    let rss_pages: u64 = statm.split_whitespace().nth(1)?.parse().ok()?;
    let stat = std::fs::read_to_string(format!("/proc/{pid}/stat")).ok()?;
    // fields 14/15 (utime/stime) counted *after* the parenthesized comm,
    // which may itself contain spaces and parentheses — split at the last ')'
    let after = &stat[stat.rfind(')')? + 1..];
    let fields: Vec<&str> = after.split_whitespace().collect();
    let utime: u64 = fields.get(11)?.parse().ok()?;
    let stime: u64 = fields.get(12)?.parse().ok()?;
    // io is privileged on some kernels — degrade to zeros, not None
    let (mut read_bytes, mut write_bytes) = (0u64, 0u64);
    if let Ok(io) = std::fs::read_to_string(format!("/proc/{pid}/io")) {
        for line in io.lines() {
            if let Some(v) = line.strip_prefix("read_bytes: ") {
                read_bytes = v.trim().parse().unwrap_or(0);
            } else if let Some(v) = line.strip_prefix("write_bytes: ") {
                write_bytes = v.trim().parse().unwrap_or(0);
            }
        }
    }
    Some(ProcUsage {
        rss_bytes: rss_pages * PAGE_BYTES,
        cpu_ms: (utime + stime) * TICK_MS,
        read_bytes,
        write_bytes,
    })
}

/// Snapshot the calling process.
pub fn self_usage() -> Option<ProcUsage> {
    usage_of(std::process::id())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn self_usage_is_sane_on_linux() {
        let Some(u) = self_usage() else {
            return; // not a /proc platform — nothing to assert
        };
        assert!(u.rss_bytes > PAGE_BYTES, "a live test process resides in memory");
    }

    #[test]
    fn since_subtracts_counters_keeps_gauge() {
        let a = ProcUsage { rss_bytes: 100, cpu_ms: 50, read_bytes: 10, write_bytes: 5 };
        let b = ProcUsage { rss_bytes: 80, cpu_ms: 120, read_bytes: 30, write_bytes: 9 };
        let d = b.since(&a);
        assert_eq!(d, ProcUsage { rss_bytes: 80, cpu_ms: 70, read_bytes: 20, write_bytes: 4 });
    }

    #[test]
    fn json_round_trip() {
        let u = ProcUsage { rss_bytes: 12345, cpu_ms: 678, read_bytes: 9, write_bytes: 0 };
        let text = u.to_json().to_string();
        let back = ProcUsage::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, u);
    }

    #[test]
    fn dead_pid_yields_none() {
        // pid 4_000_000 exceeds default pid_max; on non-Linux /proc is absent
        assert_eq!(usage_of(4_000_000), None);
    }
}
