//! The open-loop load agent: paces a precomputed [`Schedule`] into a
//! serving front door and audits every reply.
//!
//! Open loop means arrivals never wait for service: a writer (the calling
//! thread) sends [`WireMsg::Submit`] frames at the schedule's offsets while
//! a reader thread matches [`WireMsg::Reply`] / [`WireMsg::Denied`] frames
//! by sequence number and records latency into a mergeable
//! [`Histogram`]. A slow server therefore shows up as a growing tail —
//! never as a silently stretched schedule, which is the classic
//! closed-loop measurement bug (coordinated omission).
//!
//! The agent also audits correctness, not just speed: it precomputes the
//! single-node reference output for each distinct input and compares every
//! reply bit-exactly, so a harness assertion about "bit-identical outputs"
//! is checked at the edge, in the process that received the bytes.
//!
//! One process per agent: [`run`] is called by `flexpie-load agent`, and
//! the report travels back to the orchestrator as a single
//! `AGENT {json}` line on stdout ([`AgentReport::to_line`]).

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::compute::{Tensor, WeightStore};
use crate::loadgen::hist::Histogram;
use crate::loadgen::procfs::{self, ProcUsage};
use crate::loadgen::{workload, ScheduleSpec};
use crate::transport::codec::{Frame, WireMsg};
use crate::transport::tcp;
use crate::util::json::Json;

/// Stdout marker the orchestrator greps for.
pub const LINE_PREFIX: &str = "AGENT ";

/// Agent configuration — everything arrives via `flexpie-load agent` CLI
/// flags, so every field must be expressible as a flag.
#[derive(Debug, Clone)]
pub struct AgentOpts {
    /// Agent id (also the wire sender id).
    pub id: u32,
    /// Front-door address to dial.
    pub addr: String,
    /// The arrival schedule to pace.
    pub spec: ScheduleSpec,
    /// Distinct inputs cycled by sequence number.
    pub distinct: u64,
    /// Seed base for input derivation.
    pub input_seed: u64,
    /// Per-suite latency SLO replies are judged against.
    pub slo: Duration,
    /// How long to keep dialing the front door.
    pub connect_deadline: Duration,
    /// Per-read reply timeout — a server that goes quiet this long is a
    /// failed run, not a hang.
    pub reply_timeout: Duration,
    /// Warm-up fraction (0.0..1.0): the leading `warmup × requests`
    /// arrivals are *excluded* from the latency histogram and SLO tally —
    /// they measure cold caches and arena warm-up, not steady state. They
    /// still count toward `sent`/`ok`/`shed`/`failed`, so accounting
    /// conservation always covers the full schedule.
    pub warmup: f64,
}

impl Default for AgentOpts {
    fn default() -> Self {
        AgentOpts {
            id: 0,
            addr: String::new(),
            spec: ScheduleSpec {
                process: crate::loadgen::ArrivalProcess::Uniform { rate_hz: 100.0 },
                requests: 32,
                seed: 1,
            },
            distinct: 4,
            input_seed: 900,
            slo: Duration::from_millis(250),
            connect_deadline: Duration::from_secs(10),
            reply_timeout: Duration::from_secs(30),
            warmup: 0.0,
        }
    }
}

/// What one agent measured. Serializes to/from the `AGENT {json}` line.
#[derive(Debug, Clone)]
pub struct AgentReport {
    pub id: u32,
    pub sent: u64,
    /// Replies received (served requests).
    pub ok: u64,
    /// Denied at admission: queue full or server stopped.
    pub shed: u64,
    /// Failed after admission (denial reason 2).
    pub failed: u64,
    /// Replies whose output was not bit-identical to the reference.
    pub mismatches: u64,
    /// Replies within the SLO (warm-up replies excluded).
    pub slo_ok: u64,
    /// Warm-up replies trimmed from the histogram and SLO tally (they
    /// still count in `ok`).
    pub trimmed: u64,
    /// First send → last terminal frame.
    pub span: Duration,
    /// Reply latency histogram (nanoseconds).
    pub hist: Histogram,
    /// This process's resource delta around the run (None off Linux).
    pub usage: Option<ProcUsage>,
}

impl AgentReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("sent", Json::Num(self.sent as f64)),
            ("ok", Json::Num(self.ok as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("failed", Json::Num(self.failed as f64)),
            ("mismatches", Json::Num(self.mismatches as f64)),
            ("slo_ok", Json::Num(self.slo_ok as f64)),
            ("trimmed", Json::Num(self.trimmed as f64)),
            ("span_ns", Json::Num(self.span.as_nanos() as f64)),
            ("hist", self.hist.to_json()),
            ("proc", self.usage.as_ref().map_or(Json::Null, ProcUsage::to_json)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<AgentReport, String> {
        let f = |k: &str| -> Result<u64, String> {
            Ok(v.req(k)?.as_f64().ok_or_else(|| format!("{k} not a number"))? as u64)
        };
        Ok(AgentReport {
            id: f("id")? as u32,
            sent: f("sent")?,
            ok: f("ok")?,
            shed: f("shed")?,
            failed: f("failed")?,
            mismatches: f("mismatches")?,
            slo_ok: f("slo_ok")?,
            trimmed: f("trimmed")?,
            span: Duration::from_nanos(f("span_ns")?),
            hist: Histogram::from_json(v.req("hist")?)?,
            usage: match v.req("proc")? {
                Json::Null => None,
                other => Some(ProcUsage::from_json(other)?),
            },
        })
    }

    /// The single stdout line the orchestrator parses.
    pub fn to_line(&self) -> String {
        format!("{LINE_PREFIX}{}", self.to_json().to_string())
    }

    /// Parse a stdout line if it is an agent report.
    pub fn parse_line(line: &str) -> Option<Result<AgentReport, String>> {
        let body = line.strip_prefix(LINE_PREFIX)?;
        Some(crate::util::json::parse(body).and_then(|v| AgentReport::from_json(&v)))
    }
}

/// Drive one agent run to completion. Blocks until every submission has
/// its terminal frame (or the reply timeout declares the server dead).
pub fn run(opts: &AgentOpts) -> Result<AgentReport, String> {
    let schedule = opts.spec.generate();
    let total = schedule.offsets_ns.len();

    // Precompute inputs and their single-node reference outputs: replies
    // are audited bit-exactly at the edge.
    let model = workload::model();
    let ws = WeightStore::for_model(&model, workload::WEIGHT_SEED);
    let distinct = opts.distinct.max(1);
    let inputs: Vec<Tensor> =
        (0..distinct).map(|i| workload::input(i, opts.input_seed, distinct)).collect();
    let expected: Vec<Tensor> =
        inputs.iter().map(|t| crate::compute::run_reference(&model, &ws, t)).collect();

    let usage0 = procfs::self_usage();
    let stream = tcp::connect_retry(&opts.addr, opts.connect_deadline)
        .map_err(|e| format!("agent {}: connect {}: {e}", opts.id, opts.addr))?;
    let mut rstream = stream
        .try_clone()
        .map_err(|e| format!("agent {}: clone stream: {e}", opts.id))?;
    rstream
        .set_read_timeout(Some(opts.reply_timeout))
        .map_err(|e| format!("agent {}: set timeout: {e}", opts.id))?;

    // send instants, indexed by sequence number, shared writer → reader
    let send_times: Arc<Mutex<Vec<Option<Instant>>>> = Arc::new(Mutex::new(vec![None; total]));

    struct Tally {
        ok: u64,
        shed: u64,
        failed: u64,
        mismatches: u64,
        slo_ok: u64,
        trimmed: u64,
        hist: Histogram,
        last: Option<Instant>,
    }

    // warm-up cutoff: sequence numbers below this are audited but not
    // measured (cold-start latency would pollute the steady-state tail)
    let warm_cutoff = (total as f64 * opts.warmup.clamp(0.0, 1.0)).floor() as u64;

    let reader_times = send_times.clone();
    let reader_expected = expected.clone();
    let slo = opts.slo;
    let agent_id = opts.id;
    let reader = std::thread::spawn(move || -> Result<Tally, String> {
        let mut t = Tally {
            ok: 0,
            shed: 0,
            failed: 0,
            mismatches: 0,
            slo_ok: 0,
            trimmed: 0,
            hist: Histogram::new(),
            last: None,
        };
        let mut terminal = 0usize;
        while terminal < total {
            let frame = tcp::read_frame(&mut rstream)
                .map_err(|e| format!("agent {agent_id}: read reply: {e}"))?;
            match frame.msg {
                WireMsg::Reply { seq, output } => {
                    let now = Instant::now();
                    let sent_at = reader_times.lock().unwrap()[seq as usize]
                        .ok_or_else(|| format!("agent {agent_id}: reply for unsent seq {seq}"))?;
                    let lat = now.duration_since(sent_at);
                    if seq >= warm_cutoff {
                        t.hist.record(lat.as_nanos() as u64);
                        if lat <= slo {
                            t.slo_ok += 1;
                        }
                    } else {
                        t.trimmed += 1;
                    }
                    let want = &reader_expected[(seq % distinct) as usize];
                    if want.max_abs_diff(&output) != 0.0 {
                        t.mismatches += 1;
                    }
                    t.ok += 1;
                    t.last = Some(now);
                    terminal += 1;
                }
                WireMsg::Denied { reason, .. } => {
                    if reason == 0 || reason == 1 {
                        t.shed += 1;
                    } else {
                        t.failed += 1;
                    }
                    t.last = Some(Instant::now());
                    terminal += 1;
                }
                other => {
                    return Err(format!(
                        "agent {agent_id}: unexpected frame kind {}",
                        other.kind()
                    ))
                }
            }
        }
        Ok(t)
    });

    // Writer: pace the schedule on this thread. `stream` is the write half.
    let mut wstream = stream;
    let start = Instant::now();
    for (i, &off) in schedule.offsets_ns.iter().enumerate() {
        let target = start + Duration::from_nanos(off);
        let now = Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        let input = inputs[(i as u64 % distinct) as usize].clone();
        send_times.lock().unwrap()[i] = Some(Instant::now());
        let frame =
            Frame { node: opts.id, term: 0, msg: WireMsg::Submit { seq: i as u64, input } };
        tcp::send_frame(&mut wstream, &frame)
            .map_err(|e| format!("agent {}: send seq {i}: {e}", opts.id))?;
    }

    let tally = reader.join().map_err(|_| format!("agent {}: reader panicked", opts.id))??;
    drop(wstream); // close our half only after both sides are done
    let span = tally.last.map_or(Duration::ZERO, |l| l.duration_since(start));
    let usage = match (usage0, procfs::self_usage()) {
        (Some(a), Some(b)) => Some(b.since(&a)),
        _ => None,
    };
    Ok(AgentReport {
        id: opts.id,
        sent: total as u64,
        ok: tally.ok,
        shed: tally.shed,
        failed: tally.failed,
        mismatches: tally.mismatches,
        slo_ok: tally.slo_ok,
        trimmed: tally.trimmed,
        span,
        hist: tally.hist,
        usage,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_line_round_trips() {
        let mut hist = Histogram::new();
        for v in [1_000u64, 2_000, 3_000_000] {
            hist.record(v);
        }
        let r = AgentReport {
            id: 3,
            sent: 3,
            ok: 2,
            shed: 1,
            failed: 0,
            mismatches: 0,
            slo_ok: 2,
            trimmed: 1,
            span: Duration::from_millis(12),
            hist,
            usage: Some(ProcUsage { rss_bytes: 4096, cpu_ms: 10, read_bytes: 0, write_bytes: 1 }),
        };
        let line = r.to_line();
        assert!(line.starts_with(LINE_PREFIX));
        assert_eq!(line.lines().count(), 1, "report must stay a single line");
        let back = AgentReport::parse_line(&line).unwrap().unwrap();
        assert_eq!(back.id, r.id);
        assert_eq!(back.sent, r.sent);
        assert_eq!(back.ok, r.ok);
        assert_eq!(back.shed, r.shed);
        assert_eq!(back.slo_ok, r.slo_ok);
        assert_eq!(back.trimmed, r.trimmed);
        assert_eq!(back.span, r.span);
        assert_eq!(back.hist.count(), r.hist.count());
        assert_eq!(back.hist.max(), r.hist.max());
        assert_eq!(back.usage, r.usage);
        assert!(AgentReport::parse_line("RESULT {}").is_none());
    }
}
