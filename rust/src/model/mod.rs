//! Graph IR for inference models.
//!
//! FlexPie consumes a *computation graph* as its intermediate input (paper
//! §3.1). The planner only needs per-layer **metadata** — shapes, kernel
//! geometry, convolution type — so the IR is a linearized chain of
//! [`LayerMeta`] (the paper treats models as layer sequences `L0..Ln`;
//! residual edges are folded into their tail convolution by the
//! pre-optimization passes in [`passes`], mirroring how Xenos fuses
//! element-wise ops into their producers).
//!
//! Spatial coordinates are `(h, w, c)`; dense/matmul layers are embedded in
//! the same coordinate algebra with `h = rows (tokens)`, `w = 1`,
//! `c = features`, which lets the partition geometry in [`crate::partition`]
//! treat every layer uniformly.

pub mod import;
pub mod passes;
pub mod zoo;

/// Convolution (op) type — the `ConvT` categorical feature of the paper's
/// cost-estimator feature vector (Fig 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ConvType {
    /// Standard dense convolution (`K×K×InC` per output channel).
    Standard,
    /// Depthwise convolution (MobileNet): one `K×K` filter per channel.
    Depthwise,
    /// Pointwise (`1×1`) convolution.
    Pointwise,
    /// Fully-connected / generic matmul (`rows × in_c → rows × out_c`).
    Dense,
    /// Attention-style matmul whose output rows depend on **all** input rows
    /// (e.g. `QKᵀ`, `softmax(QKᵀ)V`). Forces a full gather when row-split.
    Attention,
    /// Spatial pooling (max/avg).
    Pool,
}

impl ConvType {
    /// Categorical code fed to the cost estimators.
    pub fn code(self) -> f64 {
        match self {
            ConvType::Standard => 0.0,
            ConvType::Depthwise => 1.0,
            ConvType::Pointwise => 2.0,
            ConvType::Dense => 3.0,
            ConvType::Attention => 4.0,
            ConvType::Pool => 5.0,
        }
    }

    pub const ALL: [ConvType; 6] = [
        ConvType::Standard,
        ConvType::Depthwise,
        ConvType::Pointwise,
        ConvType::Dense,
        ConvType::Attention,
        ConvType::Pool,
    ];
}

/// Coarse op family; decides which compute kernel executes the layer and how
/// channel ranges propagate through [`crate::partition`] region arithmetic.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    Conv,
    Pool,
    MatMul,
}

/// Metadata for one model layer — exactly the information the paper's cost
/// estimator consumes (Fig 4), plus bookkeeping for execution.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerMeta {
    pub name: String,
    pub op: OpKind,
    pub conv_t: ConvType,
    /// Input feature-map shape.
    pub in_h: i64,
    pub in_w: i64,
    pub in_c: i64,
    /// Output feature-map shape.
    pub out_h: i64,
    pub out_w: i64,
    pub out_c: i64,
    /// Kernel size (square), stride, padding. `k=1, s=1, p=0` for matmuls.
    pub k: i64,
    pub s: i64,
    pub p: i64,
    /// Whether a residual edge terminates at this layer's output (the add is
    /// fused into the layer by [`passes::fold_residuals`]).
    pub fused_residual: bool,
    /// Whether a ReLU/GELU is fused into this layer.
    pub fused_activation: bool,
}

impl LayerMeta {
    /// Standard convolution layer constructor; output shape derived from the
    /// usual conv arithmetic `out = (in + 2p - k)/s + 1`.
    pub fn conv(
        name: impl Into<String>,
        conv_t: ConvType,
        in_h: i64,
        in_w: i64,
        in_c: i64,
        out_c: i64,
        k: i64,
        s: i64,
        p: i64,
    ) -> Self {
        let out_h = (in_h + 2 * p - k) / s + 1;
        let out_w = (in_w + 2 * p - k) / s + 1;
        let op = match conv_t {
            ConvType::Pool => OpKind::Pool,
            ConvType::Dense | ConvType::Attention => OpKind::MatMul,
            _ => OpKind::Conv,
        };
        debug_assert!(
            conv_t != ConvType::Depthwise || in_c == out_c,
            "depthwise conv must preserve channel count ({name:?}: {in_c} -> {out_c})",
            name = name.into()
        );
        LayerMeta {
            name: name.into(),
            op,
            conv_t,
            in_h,
            in_w,
            in_c,
            out_h,
            out_w,
            out_c,
            k,
            s,
            p,
            fused_residual: false,
            fused_activation: false,
        }
    }

    /// Pooling layer.
    pub fn pool(name: impl Into<String>, in_h: i64, in_w: i64, c: i64, k: i64, s: i64) -> Self {
        Self::conv(name, ConvType::Pool, in_h, in_w, c, c, k, s, 0)
    }

    /// Dense / fully-connected layer over `rows` tokens:
    /// `(rows × in_f) @ (in_f × out_f)`.
    pub fn dense(name: impl Into<String>, rows: i64, in_f: i64, out_f: i64) -> Self {
        LayerMeta {
            name: name.into(),
            op: OpKind::MatMul,
            conv_t: ConvType::Dense,
            in_h: rows,
            in_w: 1,
            in_c: in_f,
            out_h: rows,
            out_w: 1,
            out_c: out_f,
            k: 1,
            s: 1,
            p: 0,
            fused_residual: false,
            fused_activation: false,
        }
    }

    /// Attention-style matmul: output rows depend on all input rows.
    pub fn attention(name: impl Into<String>, rows: i64, in_f: i64, out_f: i64) -> Self {
        let mut l = Self::dense(name, rows, in_f, out_f);
        l.conv_t = ConvType::Attention;
        l
    }

    /// FLOPs to produce **one output element** of this layer (multiply+add
    /// counted as 2). Used by both the analytic cost model and the partition
    /// cost accounting (inflated NT tiles multiply this by tile volume).
    pub fn flops_per_out_elem(&self) -> f64 {
        let k2 = (self.k * self.k) as f64;
        match self.conv_t {
            ConvType::Standard => 2.0 * k2 * self.in_c as f64,
            ConvType::Depthwise => 2.0 * k2,
            ConvType::Pointwise => 2.0 * self.in_c as f64,
            ConvType::Dense | ConvType::Attention => 2.0 * self.in_c as f64,
            ConvType::Pool => k2,
        }
    }

    /// Total FLOPs for the full (unpartitioned) layer.
    pub fn flops(&self) -> f64 {
        self.flops_per_out_elem() * self.out_volume() as f64
    }

    pub fn in_volume(&self) -> i64 {
        self.in_h * self.in_w * self.in_c
    }

    pub fn out_volume(&self) -> i64 {
        self.out_h * self.out_w * self.out_c
    }

    /// Parameter count (weights) of this layer.
    pub fn params(&self) -> i64 {
        match self.conv_t {
            ConvType::Standard => self.k * self.k * self.in_c * self.out_c,
            ConvType::Depthwise => self.k * self.k * self.out_c,
            ConvType::Pointwise => self.in_c * self.out_c,
            ConvType::Dense | ConvType::Attention => self.in_c * self.out_c,
            ConvType::Pool => 0,
        }
    }

    /// True when the layer's output element `(h, w)` depends only on a local
    /// input window (convolution-like); false when it depends on all rows
    /// (attention). Local layers admit spatial (InH/InW/2D-grid) partitioning
    /// without full gathers.
    pub fn is_spatially_local(&self) -> bool {
        self.conv_t != ConvType::Attention
    }
}

/// A model: a named chain of layers with validated shape compatibility.
#[derive(Debug, Clone)]
pub struct Model {
    pub name: String,
    pub layers: Vec<LayerMeta>,
}

impl Model {
    /// Build a model, validating that consecutive layer shapes match.
    pub fn new(name: impl Into<String>, layers: Vec<LayerMeta>) -> Self {
        let m = Model { name: name.into(), layers };
        m.validate().expect("invalid model");
        m
    }

    /// Check inter-layer shape compatibility.
    pub fn validate(&self) -> Result<(), String> {
        for (i, pair) in self.layers.windows(2).enumerate() {
            let (a, b) = (&pair[0], &pair[1]);
            if (a.out_h, a.out_w, a.out_c) != (b.in_h, b.in_w, b.in_c) {
                return Err(format!(
                    "{}: layer {} ({}) out {}x{}x{} != layer {} ({}) in {}x{}x{}",
                    self.name, i, a.name, a.out_h, a.out_w, a.out_c, i + 1, b.name, b.in_h,
                    b.in_w, b.in_c
                ));
            }
        }
        for (i, l) in self.layers.iter().enumerate() {
            if l.in_h <= 0
                || l.in_w <= 0
                || l.in_c <= 0
                || l.out_h <= 0
                || l.out_w <= 0
                || l.out_c <= 0
            {
                return Err(format!(
                    "{}: layer {} ({}) has non-positive dims",
                    self.name, i, l.name
                ));
            }
            if l.k <= 0 || l.s <= 0 || l.p < 0 {
                return Err(format!("{}: layer {} ({}) has invalid k/s/p", self.name, i, l.name));
            }
        }
        Ok(())
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Total FLOPs for one inference.
    pub fn total_flops(&self) -> f64 {
        self.layers.iter().map(|l| l.flops()).sum()
    }

    /// Total parameter count.
    pub fn total_params(&self) -> i64 {
        self.layers.iter().map(|l| l.params()).sum()
    }

    /// Truncate to the first `n` layers (used by the Thm-1 brute-force tests
    /// and micro-benches).
    pub fn truncated(&self, n: usize) -> Model {
        Model {
            name: format!("{}[..{}]", self.name, n),
            layers: self.layers[..n.min(self.layers.len())].to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_shape_arithmetic() {
        let l = LayerMeta::conv("c", ConvType::Standard, 224, 224, 3, 32, 3, 2, 1);
        assert_eq!((l.out_h, l.out_w, l.out_c), (112, 112, 32));
    }

    #[test]
    fn conv_same_padding_preserves_shape() {
        let l = LayerMeta::conv("c", ConvType::Standard, 56, 56, 64, 64, 3, 1, 1);
        assert_eq!((l.out_h, l.out_w), (56, 56));
    }

    #[test]
    fn flops_standard_conv() {
        let l = LayerMeta::conv("c", ConvType::Standard, 8, 8, 4, 16, 3, 1, 1);
        // 2 * 3*3*4 per out elem, 8*8*16 out elems
        assert_eq!(l.flops(), 2.0 * 36.0 * (8 * 8 * 16) as f64);
    }

    #[test]
    fn flops_depthwise_much_cheaper_than_standard() {
        let dw = LayerMeta::conv("dw", ConvType::Depthwise, 56, 56, 128, 128, 3, 1, 1);
        let st = LayerMeta::conv("st", ConvType::Standard, 56, 56, 128, 128, 3, 1, 1);
        assert!(dw.flops() * 64.0 < st.flops());
    }

    #[test]
    fn dense_embedding_in_spatial_coords() {
        let l = LayerMeta::dense("fc", 128, 768, 3072);
        assert_eq!((l.in_h, l.in_w, l.in_c), (128, 1, 768));
        assert_eq!((l.out_h, l.out_w, l.out_c), (128, 1, 3072));
        assert_eq!(l.flops(), 2.0 * 768.0 * (128 * 3072) as f64);
    }

    #[test]
    fn model_validation_rejects_shape_mismatch() {
        let a = LayerMeta::conv("a", ConvType::Standard, 32, 32, 3, 16, 3, 1, 1);
        let b = LayerMeta::conv("b", ConvType::Standard, 32, 32, 8, 16, 3, 1, 1);
        let m = Model { name: "bad".into(), layers: vec![a, b] };
        assert!(m.validate().is_err());
    }

    #[test]
    fn attention_is_not_spatially_local() {
        assert!(!LayerMeta::attention("qk", 128, 768, 128).is_spatially_local());
        assert!(LayerMeta::dense("fc", 128, 768, 768).is_spatially_local());
    }

    #[test]
    fn truncated_keeps_prefix() {
        let m = zoo::mobilenet_v1(224, 1000);
        let t = m.truncated(5);
        assert_eq!(t.n_layers(), 5);
        assert_eq!(t.layers[..], m.layers[..5]);
    }
}
