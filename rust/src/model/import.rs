//! Model import — the "computation graph as general intermediate input"
//! interface of paper §3.1.
//!
//! FlexPie supports models exported from any training framework via a small
//! JSON description (`.flexpie.json`): a chain of layer records that map
//! 1:1 onto [`LayerMeta`]. The raw (pre-optimization) form may still contain
//! `batch_norm` / `activation` / `residual_add` nodes, which
//! [`super::passes::preoptimize`] folds exactly like Xenos does.
//!
//! ```json
//! {
//!   "name": "my_model",
//!   "nodes": [
//!     {"kind": "conv",     "name": "c0", "in_h": 32, "in_w": 32, "in_c": 3,
//!      "out_c": 16, "k": 3, "s": 1, "p": 1, "conv_t": "standard"},
//!     {"kind": "batch_norm"},
//!     {"kind": "activation"},
//!     {"kind": "pool",     "name": "gap", "k": 32, "s": 32},
//!     {"kind": "dense",    "name": "fc", "out_c": 10}
//!   ]
//! }
//! ```
//!
//! Shapes chain automatically: `in_h/in_w/in_c` may be omitted after the
//! first layer (they default to the previous layer's output), so exporters
//! only state what changes.

use super::passes::{preoptimize, PassStats, RawGraph, RawNode};
use super::{ConvType, LayerMeta, Model};
use crate::util::json::Json;

/// Parse a ConvT name.
fn conv_type(s: &str) -> Result<ConvType, String> {
    match s {
        "standard" | "conv" => Ok(ConvType::Standard),
        "depthwise" | "dw" => Ok(ConvType::Depthwise),
        "pointwise" | "pw" => Ok(ConvType::Pointwise),
        "dense" | "fc" => Ok(ConvType::Dense),
        "attention" => Ok(ConvType::Attention),
        "pool" => Ok(ConvType::Pool),
        other => Err(format!("unknown conv_t {other:?}")),
    }
}

/// Import a model description, returning the planner-ready chain plus the
/// pre-optimization statistics.
pub fn import_json(v: &Json) -> Result<(Model, PassStats), String> {
    let name = v.req("name")?.as_str().ok_or("name must be a string")?.to_string();
    let nodes_json = v.req("nodes")?.as_arr().ok_or("nodes must be an array")?;

    // running output shape for shape chaining
    let mut cur: Option<(i64, i64, i64)> = None;
    let mut nodes: Vec<RawNode> = Vec::new();

    for (i, nj) in nodes_json.iter().enumerate() {
        let kind = nj.req("kind").map_err(|e| format!("node {i}: {e}"))?;
        let kind = kind.as_str().ok_or_else(|| format!("node {i}: kind must be a string"))?;
        let get_i64 = |key: &str| nj.get(key).and_then(Json::as_i64);
        let dim = |key: &str, inherited: Option<i64>| -> Result<i64, String> {
            get_i64(key)
                .or(inherited)
                .ok_or_else(|| format!("node {i} ({kind}): missing {key} and nothing to inherit"))
        };

        match kind {
            "conv" | "pool" | "dense" => {
                let lname = nj
                    .get("name")
                    .and_then(Json::as_str)
                    .map(String::from)
                    .unwrap_or_else(|| format!("n{i}"));
                let (ph, pw, pc) = match cur {
                    Some((h, w, c)) => (Some(h), Some(w), Some(c)),
                    None => (None, None, None),
                };
                let layer = match kind {
                    "dense" => {
                        let rows = dim("rows", ph)?;
                        let in_f = dim("in_c", pc)?;
                        let out_f = dim("out_c", None)?;
                        let ct = nj
                            .get("conv_t")
                            .and_then(Json::as_str)
                            .map(conv_type)
                            .transpose()?
                            .unwrap_or(ConvType::Dense);
                        let mut l = LayerMeta::dense(lname, rows, in_f, out_f);
                        l.conv_t = ct;
                        l
                    }
                    "pool" => {
                        let in_h = dim("in_h", ph)?;
                        let in_w = dim("in_w", pw)?;
                        let in_c = dim("in_c", pc)?;
                        let k = dim("k", None)?;
                        let s = get_i64("s").unwrap_or(k);
                        LayerMeta::pool(lname, in_h, in_w, in_c, k, s)
                    }
                    _ => {
                        let in_h = dim("in_h", ph)?;
                        let in_w = dim("in_w", pw)?;
                        let in_c = dim("in_c", pc)?;
                        let ct = nj
                            .get("conv_t")
                            .and_then(Json::as_str)
                            .map(conv_type)
                            .transpose()?
                            .unwrap_or(ConvType::Standard);
                        let out_c = match ct {
                            ConvType::Depthwise => dim("out_c", Some(in_c))?,
                            _ => dim("out_c", None)?,
                        };
                        let k = dim("k", None)?;
                        let s = get_i64("s").unwrap_or(1);
                        let p = get_i64("p").unwrap_or(0);
                        LayerMeta::conv(lname, ct, in_h, in_w, in_c, out_c, k, s, p)
                    }
                };
                cur = Some((layer.out_h, layer.out_w, layer.out_c));
                nodes.push(RawNode::Layer(layer));
            }
            "batch_norm" | "activation" | "residual_add" => {
                let (h, w, c) =
                    cur.ok_or_else(|| format!("node {i}: {kind} before any layer"))?;
                nodes.push(match kind {
                    "batch_norm" => RawNode::BatchNorm { h, w, c },
                    "activation" => RawNode::Activation { h, w, c },
                    _ => RawNode::ResidualAdd { h, w, c },
                });
            }
            "dead" => nodes.push(RawNode::Dead),
            other => return Err(format!("node {i}: unknown kind {other:?}")),
        }
    }

    let raw = RawGraph { name, nodes };
    let (model, stats) = preoptimize(&raw);
    model.validate()?;
    Ok((model, stats))
}

/// Load a `.flexpie.json` model file.
pub fn load(path: &std::path::Path) -> Result<(Model, PassStats), String> {
    let v = Json::load(path).map_err(|e| e.to_string())?;
    import_json(&v)
}

/// Export a model back to the JSON description (round-trip support, useful
/// for generating descriptions from the zoo).
pub fn export_json(model: &Model) -> Json {
    let nodes: Vec<Json> = model
        .layers
        .iter()
        .map(|l| {
            let kind = match l.conv_t {
                ConvType::Pool => "pool",
                ConvType::Dense | ConvType::Attention => "dense",
                _ => "conv",
            };
            let conv_t = match l.conv_t {
                ConvType::Standard => "standard",
                ConvType::Depthwise => "depthwise",
                ConvType::Pointwise => "pointwise",
                ConvType::Dense => "dense",
                ConvType::Attention => "attention",
                ConvType::Pool => "pool",
            };
            let mut fields = vec![
                ("kind", Json::Str(kind.into())),
                ("name", Json::Str(l.name.clone())),
                ("in_h", Json::Num(l.in_h as f64)),
                ("in_w", Json::Num(l.in_w as f64)),
                ("in_c", Json::Num(l.in_c as f64)),
                ("out_c", Json::Num(l.out_c as f64)),
                ("k", Json::Num(l.k as f64)),
                ("s", Json::Num(l.s as f64)),
                ("p", Json::Num(l.p as f64)),
                ("conv_t", Json::Str(conv_t.into())),
            ];
            if kind == "dense" {
                fields.push(("rows", Json::Num(l.in_h as f64)));
            }
            Json::obj(fields)
        })
        .collect();
    Json::obj(vec![
        ("name", Json::Str(model.name.clone())),
        ("nodes", Json::Arr(nodes)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    const SAMPLE: &str = r#"{
        "name": "imported_cnn",
        "nodes": [
            {"kind": "conv", "name": "c0", "in_h": 32, "in_w": 32, "in_c": 3,
             "out_c": 16, "k": 3, "s": 1, "p": 1},
            {"kind": "batch_norm"},
            {"kind": "activation"},
            {"kind": "conv", "name": "dw1", "conv_t": "depthwise", "k": 3, "s": 2, "p": 1},
            {"kind": "conv", "name": "pw1", "conv_t": "pointwise", "out_c": 32, "k": 1},
            {"kind": "residual_add"},
            {"kind": "pool", "name": "gap", "k": 16},
            {"kind": "dense", "name": "fc", "out_c": 10}
        ]
    }"#;

    #[test]
    fn imports_chain_with_shape_inheritance() {
        let v = parse(SAMPLE).unwrap();
        let (model, stats) = import_json(&v).unwrap();
        assert_eq!(model.name, "imported_cnn");
        assert_eq!(model.n_layers(), 5); // BN/act/residual folded
        assert_eq!(stats.bn_folded, 1);
        assert_eq!(stats.activations_fused, 1);
        assert_eq!(stats.residuals_folded, 1);
        // dw inherits 32×32×16; pw output 16×16×32; gap → 1×1×32; fc → 10
        assert_eq!((model.layers[1].in_h, model.layers[1].in_c), (32, 16));
        assert_eq!(model.layers[2].out_c, 32);
        let last = model.layers.last().unwrap();
        assert_eq!((last.out_h, last.out_w, last.out_c), (1, 1, 10));
    }

    #[test]
    fn imported_model_is_plannable_and_executes() {
        let v = parse(SAMPLE).unwrap();
        let (model, _) = import_json(&v).unwrap();
        let tb = crate::net::Testbed::new(
            4,
            crate::net::Topology::Ring,
            crate::net::Bandwidth::gbps(1.0),
        );
        let cost = crate::cost::CostSource::analytic(&tb);
        let plan = crate::planner::Dpp::new(&model, &cost).plan();
        assert_eq!(crate::engine::verify_plan(&model, &plan, &tb, 1), 0.0);
    }

    #[test]
    fn export_import_roundtrip_zoo() {
        for m in [super::super::zoo::edgenet(16), super::super::zoo::mobilenet_v1(224, 1000)] {
            let j = export_json(&m);
            let (back, _) = import_json(&j).unwrap();
            assert_eq!(back.n_layers(), m.n_layers());
            for (a, b) in back.layers.iter().zip(&m.layers) {
                assert_eq!((a.in_h, a.in_w, a.in_c), (b.in_h, b.in_w, b.in_c));
                assert_eq!((a.out_h, a.out_w, a.out_c), (b.out_h, b.out_w, b.out_c));
                assert_eq!(a.conv_t, b.conv_t);
            }
        }
    }

    #[test]
    fn import_errors_are_descriptive() {
        let missing = parse(r#"{"name": "x", "nodes": [{"kind": "conv", "k": 3}]}"#).unwrap();
        let err = import_json(&missing).unwrap_err();
        assert!(err.contains("missing in_h"), "{err}");
        let badkind = parse(r#"{"name": "x", "nodes": [{"kind": "wat"}]}"#).unwrap();
        assert!(import_json(&badkind).unwrap_err().contains("unknown kind"));
        let orphan_bn = parse(r#"{"name": "x", "nodes": [{"kind": "batch_norm"}]}"#).unwrap();
        assert!(import_json(&orphan_bn).unwrap_err().contains("before any layer"));
    }

    #[test]
    fn file_roundtrip() {
        let dir = crate::util::tmp::TempDir::new("import");
        let p = dir.path().join("m.flexpie.json");
        export_json(&super::super::zoo::edgenet(16)).save(&p).unwrap();
        let (model, _) = load(&p).unwrap();
        assert_eq!(model.n_layers(), 9);
    }
}
