//! Pre-optimization passes (paper §3.1: "FlexPie also integrates
//! pre-optimization strategies from Xenos to optimize [the] computation graph
//! before it is fed into the automatic optimizer").
//!
//! Xenos' dataflow-centric rewrites that matter for partition planning are
//! the ones that change the *layer chain* the planner sees:
//!
//! * **BN folding** — batch-norm scales/shifts fold into the preceding conv's
//!   weights, removing the BN node entirely.
//! * **Activation fusion** — element-wise activations fuse into their
//!   producer (marked `fused_activation`).
//! * **Residual folding** — the residual add fuses into the tail conv of its
//!   block (marked `fused_residual`).
//! * **Dead-layer elimination** — layers whose output feeds nothing.
//!
//! The zoo emits post-pass chains directly, but the passes are exercised (and
//! tested) against a "raw" graph form that still contains BN / activation /
//! add nodes, to mirror the paper's import path.

use super::{ConvType, LayerMeta, Model, OpKind};

/// A raw imported node — the pre-pass graph form (a strict superset of the
/// planner IR: it still contains element-wise nodes).
#[derive(Debug, Clone, PartialEq)]
pub enum RawNode {
    Layer(LayerMeta),
    /// Batch normalization over `c` channels of an `h×w×c` map.
    BatchNorm { h: i64, w: i64, c: i64 },
    /// Element-wise activation (ReLU/GELU/...).
    Activation { h: i64, w: i64, c: i64 },
    /// Residual add joining the current value with a skip edge started
    /// `from_offset` nodes earlier.
    ResidualAdd { h: i64, w: i64, c: i64 },
    /// A node with no consumers (e.g. an auxiliary head dropped at export).
    Dead,
}

/// Raw graph: a chain of nodes as imported from a training framework.
#[derive(Debug, Clone)]
pub struct RawGraph {
    pub name: String,
    pub nodes: Vec<RawNode>,
}

/// Statistics reported by [`preoptimize`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PassStats {
    pub bn_folded: usize,
    pub activations_fused: usize,
    pub residuals_folded: usize,
    pub dead_eliminated: usize,
}

/// Run the full pre-optimization pipeline, producing the planner-ready
/// [`Model`] chain plus rewrite statistics.
pub fn preoptimize(graph: &RawGraph) -> (Model, PassStats) {
    let mut stats = PassStats::default();
    let mut layers: Vec<LayerMeta> = Vec::new();

    for node in &graph.nodes {
        match node {
            RawNode::Layer(l) => layers.push(l.clone()),
            RawNode::BatchNorm { h, w, c } => {
                // Fold into the producing layer; shape must match its output.
                let prev = layers
                    .last_mut()
                    .unwrap_or_else(|| panic!("{}: BN with no producer", graph.name));
                assert_eq!(
                    (prev.out_h, prev.out_w, prev.out_c),
                    (*h, *w, *c),
                    "{}: BN shape mismatch after {}",
                    graph.name,
                    prev.name
                );
                stats.bn_folded += 1;
            }
            RawNode::Activation { h, w, c } => {
                let prev = layers
                    .last_mut()
                    .unwrap_or_else(|| panic!("{}: activation with no producer", graph.name));
                assert_eq!((prev.out_h, prev.out_w, prev.out_c), (*h, *w, *c));
                prev.fused_activation = true;
                stats.activations_fused += 1;
            }
            RawNode::ResidualAdd { h, w, c } => {
                let prev = layers
                    .last_mut()
                    .unwrap_or_else(|| panic!("{}: residual add with no producer", graph.name));
                assert_eq!((prev.out_h, prev.out_w, prev.out_c), (*h, *w, *c));
                prev.fused_residual = true;
                stats.residuals_folded += 1;
            }
            RawNode::Dead => {
                stats.dead_eliminated += 1;
            }
        }
    }

    (Model::new(graph.name.clone(), layers), stats)
}

/// Build the raw (pre-pass) form of a simple conv→BN→ReLU stack — used by
/// tests and by the `flexpie zoo --raw` demo path.
pub fn raw_conv_bn_relu_chain(name: &str, n: usize, h: i64, c: i64) -> RawGraph {
    let mut nodes = Vec::new();
    let mut in_c = 3;
    for i in 0..n {
        let l = LayerMeta::conv(format!("c{i}"), ConvType::Standard, h, h, in_c, c, 3, 1, 1);
        let (oh, ow, oc) = (l.out_h, l.out_w, l.out_c);
        nodes.push(RawNode::Layer(l));
        nodes.push(RawNode::BatchNorm { h: oh, w: ow, c: oc });
        nodes.push(RawNode::Activation { h: oh, w: ow, c: oc });
        in_c = c;
    }
    RawGraph { name: name.into(), nodes }
}

/// Sanity pass run on every model before planning: shape chain validity plus
/// planner-relevant invariants (final layer present, no zero-volume layers).
pub fn verify_planner_ready(model: &Model) -> Result<(), String> {
    model.validate()?;
    if model.layers.is_empty() {
        return Err(format!("{}: empty model", model.name));
    }
    for (i, l) in model.layers.iter().enumerate() {
        if l.op == OpKind::Conv && l.k > l.in_h + 2 * l.p {
            return Err(format!("{}: layer {i} kernel exceeds padded input", model.name));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn folds_bn_and_fuses_activation() {
        let g = raw_conv_bn_relu_chain("t", 3, 16, 8);
        let (m, stats) = preoptimize(&g);
        assert_eq!(m.n_layers(), 3);
        assert_eq!(stats.bn_folded, 3);
        assert_eq!(stats.activations_fused, 3);
        assert!(m.layers.iter().all(|l| l.fused_activation));
    }

    #[test]
    fn folds_residual_add() {
        let l1 = LayerMeta::conv("a", ConvType::Standard, 8, 8, 4, 4, 3, 1, 1);
        let l2 = LayerMeta::conv("b", ConvType::Standard, 8, 8, 4, 4, 3, 1, 1);
        let g = RawGraph {
            name: "res".into(),
            nodes: vec![
                RawNode::Layer(l1),
                RawNode::Layer(l2),
                RawNode::ResidualAdd { h: 8, w: 8, c: 4 },
            ],
        };
        let (m, stats) = preoptimize(&g);
        assert_eq!(stats.residuals_folded, 1);
        assert!(m.layers[1].fused_residual);
        assert!(!m.layers[0].fused_residual);
    }

    #[test]
    fn eliminates_dead_nodes() {
        let l1 = LayerMeta::conv("a", ConvType::Standard, 8, 8, 4, 4, 3, 1, 1);
        let g = RawGraph { name: "d".into(), nodes: vec![RawNode::Layer(l1), RawNode::Dead] };
        let (m, stats) = preoptimize(&g);
        assert_eq!(stats.dead_eliminated, 1);
        assert_eq!(m.n_layers(), 1);
    }

    #[test]
    #[should_panic(expected = "BN shape mismatch")]
    fn bn_shape_mismatch_panics() {
        let l1 = LayerMeta::conv("a", ConvType::Standard, 8, 8, 4, 4, 3, 1, 1);
        let g = RawGraph {
            name: "bad".into(),
            nodes: vec![RawNode::Layer(l1), RawNode::BatchNorm { h: 9, w: 8, c: 4 }],
        };
        preoptimize(&g);
    }

    #[test]
    fn planner_ready_accepts_zoo() {
        for m in super::super::zoo::paper_benchmarks() {
            verify_planner_ready(&m).unwrap();
        }
    }
}
