//! Model zoo — the paper's four evaluation benchmarks (MobileNet, ResNet-18,
//! ResNet-101, BERT) plus small models used by the quickstart example, the
//! AOT artifact menu, and the brute-force optimality tests.
//!
//! The paper imports pre-trained graphs from PyTorch/MindSpore/TF; the
//! planner only consumes layer metadata, so we express the exact same
//! architectures directly in the IR. Residual adds and BN/activations are
//! already folded (the zoo emits the post-[`super::passes`] form; the passes
//! are still exercised by constructing models with explicit residual markers).

use super::{ConvType, LayerMeta, Model};

/// MobileNetV1 (Howard et al. 2017), width multiplier 1.0.
///
/// 28 compute layers: initial 3×3/2 conv, 13 depthwise-separable pairs
/// (depthwise 3×3 + pointwise 1×1), global average pool, and the classifier
/// FC. `input` is the square input resolution (224 in the paper).
pub fn mobilenet_v1(input: i64, classes: i64) -> Model {
    let mut layers = Vec::new();
    let mut h = input;
    let mut c = 32;
    layers.push(LayerMeta::conv("conv0", ConvType::Standard, input, input, 3, 32, 3, 2, 1));
    h /= 2;

    // (out_c, stride) per depthwise-separable block.
    let blocks: [(i64, i64); 13] = [
        (64, 1),
        (128, 2),
        (128, 1),
        (256, 2),
        (256, 1),
        (512, 2),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (1024, 2),
        (1024, 1),
    ];
    for (i, &(out_c, s)) in blocks.iter().enumerate() {
        layers.push(LayerMeta::conv(
            format!("dw{}", i + 1),
            ConvType::Depthwise,
            h,
            h,
            c,
            c,
            3,
            s,
            1,
        ));
        let h2 = (h + 2 - 3) / s + 1;
        layers.push(LayerMeta::conv(
            format!("pw{}", i + 1),
            ConvType::Pointwise,
            h2,
            h2,
            c,
            out_c,
            1,
            1,
            0,
        ));
        h = h2;
        c = out_c;
    }
    layers.push(LayerMeta::pool("avgpool", h, h, c, h, h));
    layers.push(LayerMeta::dense("fc", 1, c, classes));
    Model::new("mobilenet_v1", layers)
}

/// ResNet-18 (He et al. 2016): conv1 + 8 basic blocks (2 convs each) + fc.
/// Downsample 1×1 convs on stage transitions are folded into the block's
/// first conv cost-wise (they run concurrently on the same tile; their FLOPs
/// are ≤6% of the block). Residual adds are marked `fused_residual`.
pub fn resnet18(input: i64, classes: i64) -> Model {
    let mut layers = Vec::new();
    layers.push(LayerMeta::conv("conv1", ConvType::Standard, input, input, 3, 64, 7, 2, 3));
    let mut h = (input + 6 - 7) / 2 + 1;
    layers.push(LayerMeta::pool("maxpool", h, h, 64, 3, 2));
    h = (h - 3) / 2 + 1;

    let stages: [(i64, i64, i64); 4] = [(64, 2, 1), (128, 2, 2), (256, 2, 2), (512, 2, 2)];
    let mut c = 64;
    for (si, &(out_c, n_blocks, first_stride)) in stages.iter().enumerate() {
        for b in 0..n_blocks {
            let s = if b == 0 { first_stride } else { 1 };
            let mut l1 = LayerMeta::conv(
                format!("s{}b{}c1", si + 1, b),
                ConvType::Standard,
                h,
                h,
                c,
                out_c,
                3,
                s,
                1,
            );
            l1.fused_activation = true;
            let h2 = (h + 2 - 3) / s + 1;
            let mut l2 = LayerMeta::conv(
                format!("s{}b{}c2", si + 1, b),
                ConvType::Standard,
                h2,
                h2,
                out_c,
                out_c,
                3,
                1,
                1,
            );
            l2.fused_residual = true;
            l2.fused_activation = true;
            layers.push(l1);
            layers.push(l2);
            h = h2;
            c = out_c;
        }
    }
    layers.push(LayerMeta::pool("avgpool", h, h, c, h, h));
    layers.push(LayerMeta::dense("fc", 1, c, classes));
    Model::new("resnet18", layers)
}

/// ResNet-101: conv1 + bottleneck stages [3, 4, 23, 3] (3 convs each) + fc.
pub fn resnet101(input: i64, classes: i64) -> Model {
    let mut layers = Vec::new();
    layers.push(LayerMeta::conv("conv1", ConvType::Standard, input, input, 3, 64, 7, 2, 3));
    let mut h = (input + 6 - 7) / 2 + 1;
    layers.push(LayerMeta::pool("maxpool", h, h, 64, 3, 2));
    h = (h - 3) / 2 + 1;

    // (mid_c, out_c, n_blocks, first_stride)
    let stages: [(i64, i64, i64, i64); 4] =
        [(64, 256, 3, 1), (128, 512, 4, 2), (256, 1024, 23, 2), (512, 2048, 3, 2)];
    let mut c = 64;
    for (si, &(mid, out_c, n_blocks, first_stride)) in stages.iter().enumerate() {
        for b in 0..n_blocks {
            let s = if b == 0 { first_stride } else { 1 };
            let mut l1 = LayerMeta::conv(
                format!("s{}b{}r", si + 1, b),
                ConvType::Pointwise,
                h,
                h,
                c,
                mid,
                1,
                1,
                0,
            );
            l1.fused_activation = true;
            let mut l2 = LayerMeta::conv(
                format!("s{}b{}c", si + 1, b),
                ConvType::Standard,
                h,
                h,
                mid,
                mid,
                3,
                s,
                1,
            );
            l2.fused_activation = true;
            let h2 = (h + 2 - 3) / s + 1;
            let mut l3 = LayerMeta::conv(
                format!("s{}b{}e", si + 1, b),
                ConvType::Pointwise,
                h2,
                h2,
                mid,
                out_c,
                1,
                1,
                0,
            );
            l3.fused_residual = true;
            l3.fused_activation = true;
            layers.push(l1);
            layers.push(l2);
            layers.push(l3);
            h = h2;
            c = out_c;
        }
    }
    layers.push(LayerMeta::pool("avgpool", h, h, c, h, h));
    layers.push(LayerMeta::dense("fc", 1, c, classes));
    Model::new("resnet101", layers)
}

/// BERT-base encoder stack (12 layers, hidden 768, 12 heads, FFN 3072) over a
/// `seq`-token input. Per encoder layer, the matmul chain is:
/// QKV projection (fused as one 768→2304 dense), attention scores `QKᵀ`
/// (Attention), context `AV` (Attention), output projection, FFN up, FFN
/// down. Attention-typed layers force full-row gathers when row-partitioned,
/// which is why BERT shows little headroom for FlexPie (paper §4.1
/// "Limitation").
pub fn bert_base(seq: i64) -> Model {
    let hidden = 768;
    let ffn = 3072;
    let mut layers = Vec::new();
    for e in 0..12 {
        let mut qkv = LayerMeta::dense(format!("e{e}.qkv"), seq, hidden, 3 * hidden);
        qkv.fused_activation = false;
        layers.push(qkv);
        // Scores: per head (rows=seq, in=3*hidden holding QKV, out=seq per... )
        // We model QKᵀ as an Attention matmul seq×hidden → seq×seq and AV as
        // seq×seq → seq×hidden; head parallelism is inside the kernel.
        layers.push(LayerMeta::attention(format!("e{e}.scores"), seq, 3 * hidden, seq));
        layers.push(LayerMeta::attention(format!("e{e}.context"), seq, seq, hidden));
        let mut proj = LayerMeta::dense(format!("e{e}.proj"), seq, hidden, hidden);
        proj.fused_residual = true;
        layers.push(proj);
        let mut up = LayerMeta::dense(format!("e{e}.ffn_up"), seq, hidden, ffn);
        up.fused_activation = true;
        layers.push(up);
        let mut down = LayerMeta::dense(format!("e{e}.ffn_down"), seq, ffn, hidden);
        down.fused_residual = true;
        layers.push(down);
    }
    Model::new("bert_base", layers)
}

/// EdgeNet — the small quickstart model. Chosen so that (a) one inference is
/// sub-millisecond on the host, (b) its layer shapes are exactly the AOT
/// artifact menu generated by `python/compile/aot.py` (full layers plus the
/// 4-node InH tile shapes), and (c) it still exhibits the paper's trade-offs
/// (early wide spatial layers vs late channel-heavy layers).
pub fn edgenet(input: i64) -> Model {
    assert!(input % 8 == 0, "edgenet input must be divisible by 8");
    let mut layers = Vec::new();
    layers.push(LayerMeta::conv("c0", ConvType::Standard, input, input, 3, 8, 3, 1, 1));
    layers.push(LayerMeta::conv("dw1", ConvType::Depthwise, input, input, 8, 8, 3, 2, 1));
    let h1 = input / 2;
    layers.push(LayerMeta::conv("pw1", ConvType::Pointwise, h1, h1, 8, 16, 1, 1, 0));
    layers.push(LayerMeta::conv("c2", ConvType::Standard, h1, h1, 16, 16, 3, 1, 1));
    layers.push(LayerMeta::conv("dw2", ConvType::Depthwise, h1, h1, 16, 16, 3, 2, 1));
    let h2 = h1 / 2;
    layers.push(LayerMeta::conv("pw2", ConvType::Pointwise, h2, h2, 16, 32, 1, 1, 0));
    layers.push(LayerMeta::conv("c3", ConvType::Standard, h2, h2, 32, 32, 3, 1, 1));
    layers.push(LayerMeta::pool("avgpool", h2, h2, 32, h2, h2));
    layers.push(LayerMeta::dense("fc", 1, 32, 10));
    Model::new("edgenet", layers)
}

/// Tiny N-layer conv chains for brute-force (Thm 1) tests: `same`-padded 3×3
/// convs so every scheme/mode combination is legal and the search space is
/// rich but enumerable.
pub fn tiny_chain(n_layers: usize, h: i64, c: i64) -> Model {
    let mut layers = Vec::new();
    let mut in_c = 3;
    for i in 0..n_layers {
        layers.push(LayerMeta::conv(format!("t{i}"), ConvType::Standard, h, h, in_c, c, 3, 1, 1));
        in_c = c;
    }
    Model::new(format!("tiny{n_layers}"), layers)
}

/// Look a model up by name (CLI entry point).
pub fn by_name(name: &str) -> Option<Model> {
    match name {
        "mobilenet" | "mobilenet_v1" => Some(mobilenet_v1(224, 1000)),
        "resnet18" => Some(resnet18(224, 1000)),
        "resnet101" => Some(resnet101(224, 1000)),
        "bert" | "bert_base" => Some(bert_base(128)),
        "edgenet" => Some(edgenet(16)),
        _ => None,
    }
}

/// The paper's four evaluation benchmarks, in presentation order.
pub fn paper_benchmarks() -> Vec<Model> {
    vec![mobilenet_v1(224, 1000), resnet18(224, 1000), resnet101(224, 1000), bert_base(128)]
}

/// Indices of the micro-bench layers of Fig 2 (MobileNet "L2", "L5", "L13" in
/// the paper's conv-layer numbering: L2 = first depthwise (112×112×32),
/// L5 = dw3 (56×56×128), L13 = dw7 region (14×14×512)).
pub fn mobilenet_microbench_layers() -> [(usize, &'static str); 3] {
    // zoo index: 0=conv0, 1=dw1, 2=pw1, 3=dw2, 4=pw2, 5=dw3, ...
    [(1, "L2"), (5, "L5"), (15, "L13")]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mobilenet_layer_count_and_shapes() {
        let m = mobilenet_v1(224, 1000);
        // 1 + 13*2 + pool + fc = 29
        assert_eq!(m.n_layers(), 29);
        assert_eq!(m.layers[0].out_h, 112);
        let last_conv = &m.layers[26];
        assert_eq!((last_conv.out_h, last_conv.out_w, last_conv.out_c), (7, 7, 1024));
    }

    #[test]
    fn mobilenet_flops_near_paper() {
        // MobileNetV1 @224 is ~1.1 GFLOPs (569 MMACs × 2).
        let m = mobilenet_v1(224, 1000);
        let gf = m.total_flops() / 1e9;
        assert!((0.9..1.4).contains(&gf), "got {gf} GFLOPs");
    }

    #[test]
    fn mobilenet_params_near_paper() {
        let m = mobilenet_v1(224, 1000);
        let mp = m.total_params() as f64 / 1e6;
        assert!((3.0..4.5).contains(&mp), "got {mp} M params");
    }

    #[test]
    fn resnet18_flops_near_paper() {
        // ResNet-18 @224 is ~3.6 GFLOPs.
        let m = resnet18(224, 1000);
        let gf = m.total_flops() / 1e9;
        assert!((3.0..4.2).contains(&gf), "got {gf} GFLOPs");
    }

    #[test]
    fn resnet101_flops_near_paper() {
        // ResNet-101 @224 is ~15.2 GFLOPs (bottleneck downsample convs folded,
        // so we come in slightly under).
        let m = resnet101(224, 1000);
        let gf = m.total_flops() / 1e9;
        assert!((13.0..17.0).contains(&gf), "got {gf} GFLOPs");
    }

    #[test]
    fn resnet101_depth() {
        let m = resnet101(224, 1000);
        // conv1 + pool + 3*(3+4+23+3) + pool + fc = 103
        assert_eq!(m.n_layers(), 103);
    }

    #[test]
    fn bert_base_flops_near_paper() {
        // BERT-base @seq128 forward is ~22.5 GFLOPs; our chain (fused QKV,
        // head-folded attention) should be the same order.
        let m = bert_base(128);
        let gf = m.total_flops() / 1e9;
        assert!((15.0..30.0).contains(&gf), "got {gf} GFLOPs");
    }

    #[test]
    fn all_zoo_models_validate() {
        for m in paper_benchmarks() {
            m.validate().unwrap();
        }
        edgenet(16).validate().unwrap();
        edgenet(32).validate().unwrap();
        tiny_chain(6, 12, 8).validate().unwrap();
    }

    #[test]
    fn microbench_layers_match_paper_shapes() {
        let m = mobilenet_v1(224, 1000);
        let [(l2, _), (l5, _), (l13, _)] = mobilenet_microbench_layers();
        assert_eq!((m.layers[l2].in_h, m.layers[l2].in_c), (112, 32));
        assert_eq!((m.layers[l5].in_h, m.layers[l5].in_c), (56, 128));
        assert_eq!((m.layers[l13].in_h, m.layers[l13].in_c), (14, 512));
    }

    #[test]
    fn by_name_roundtrip() {
        for n in ["mobilenet", "resnet18", "resnet101", "bert", "edgenet"] {
            assert!(by_name(n).is_some(), "{n}");
        }
        assert!(by_name("nonexistent").is_none());
    }
}
