//! Minimal JSON — offline replacement for `serde_json`.
//!
//! Used for: GBDT model persistence, trace corpora, bench result files, and
//! reading the AOT `artifacts/manifest.json` produced by
//! `python/compile/aot.py`. Numbers are emitted with Rust's shortest
//! round-trip float formatting, so save→load is bit-exact for every f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects preserve no insertion order (BTreeMap) — stable
/// output ordering is a feature for diffable artifacts.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors ----------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num_arr<'a, I: IntoIterator<Item = &'a f64>>(items: I) -> Json {
        Json::Arr(items.into_iter().map(|&v| Json::Num(v)).collect())
    }

    // ---- accessors -------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Like `get` but returns an error mentioning the key — for required
    /// fields.
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|v| v as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|v| v as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Extract a Vec<f64> from a numeric array.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr().map(|a| a.iter().filter_map(Json::as_f64).collect())
    }

    // ---- serialization ---------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => write_num(*v, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    it.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_string())
    }

    pub fn load(path: &std::path::Path) -> std::io::Result<Json> {
        let text = std::fs::read_to_string(path)?;
        parse(&text).map_err(|e| std::io::Error::other(format!("{}: {e}", path.display())))
    }
}

fn write_num(v: f64, out: &mut String) {
    if v.is_finite() {
        if v == v.trunc() && v.abs() < 1e15 {
            let _ = write!(out, "{}", v as i64);
        } else {
            // Rust's shortest round-trip formatting; valid JSON.
            let _ = write!(out, "{v:?}");
        }
    } else {
        // JSON has no Inf/NaN; encode as null (loaders treat as 0).
        out.push_str("null");
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, String> {
    let bytes = text.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", b as char, self.pos))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            None => Err("unexpected end".into()),
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(_) => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 char
                    let start = self.pos;
                    let text = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| "invalid utf-8")?;
                    let ch = text.chars().next().unwrap();
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if matches!(b, b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number {text:?} at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-17", "3.5"] {
            let v = parse(text).unwrap();
            assert_eq!(v.to_string(), text);
        }
    }

    #[test]
    fn float_roundtrip_exact() {
        for &x in &[0.1, 1e-12, -2.5e300, std::f64::consts::PI, 1.0 / 3.0] {
            let v = Json::Num(x);
            let back = parse(&v.to_string()).unwrap();
            assert_eq!(back.as_f64().unwrap(), x);
        }
    }

    #[test]
    fn nested_structure() {
        let text = r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": true}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("d").unwrap().as_bool(), Some(true));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64(), Some(2.0));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x\ny"));
        // serialize → parse again
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn string_escapes() {
        let v = Json::Str("quote\" slash\\ tab\t nl\n unicode\u{1}".into());
        let back = parse(&v.to_string()).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn unicode_escape_parse() {
        let v = parse(r#""é中""#).unwrap();
        assert_eq!(v.as_str(), Some("é中"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn save_load_file() {
        let dir = crate::util::tmp::TempDir::new("json_test");
        let p = dir.path().join("x.json");
        let v = Json::obj(vec![("xs", Json::num_arr(&[1.5, 2.5])), ("n", Json::Num(7.0))]);
        v.save(&p).unwrap();
        let back = Json::load(&p).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn non_finite_encodes_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }
}
