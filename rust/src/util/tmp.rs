//! Unique temp directories for tests (offline replacement for `tempfile`).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A directory under the system temp dir, removed on drop.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn new(tag: &str) -> TempDir {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let pid = std::process::id();
        let t = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos();
        let path = std::env::temp_dir().join(format!("flexpie_{tag}_{pid}_{n}_{t}"));
        std::fs::create_dir_all(&path).expect("create temp dir");
        TempDir { path }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_cleans_up() {
        let p;
        {
            let d = TempDir::new("t");
            p = d.path().to_path_buf();
            assert!(p.is_dir());
            std::fs::write(p.join("f.txt"), "x").unwrap();
        }
        assert!(!p.exists());
    }

    #[test]
    fn unique_paths() {
        let a = TempDir::new("u");
        let b = TempDir::new("u");
        assert_ne!(a.path(), b.path());
    }
}
