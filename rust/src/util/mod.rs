//! Self-contained utility substrates.
//!
//! This build environment is fully offline: only the `xla` crate's vendored
//! dependency closure is available. The usual ecosystem crates (serde, rand,
//! clap, criterion, proptest, tokio, rayon) are therefore replaced by the
//! small, tested implementations in this module:
//!
//! * [`rng`] — deterministic PCG-style PRNG + Box-Muller normal sampling
//! * [`json`] — minimal JSON value/parser/writer (model persistence, the
//!   AOT `manifest.json`, bench result files)
//! * [`cli`] — flag-style argument parsing for the `flexpie` binary
//! * [`bench`] — a mini-criterion: warmup + timed iterations + stats
//! * [`prop`] — property-testing driver (random cases, seed reporting,
//!   shrink-free but reproducible)
//! * [`tmp`] — unique temp directories for tests

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod tmp;
