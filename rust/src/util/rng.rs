//! Deterministic pseudo-random numbers (offline replacement for `rand` /
//! `rand_distr`): PCG-XSH-RR-64/32 core, uniform helpers, Fisher-Yates
//! shuffle, and Box-Muller normal sampling.

/// PCG-XSH-RR 64/32 generator — small, fast, statistically solid, and
/// deterministic across platforms (all we need for traces and tests).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
    /// Cached second Box-Muller variate.
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        let mut r = Rng { state: 0, inc: (seed << 1) | 1, spare_normal: None };
        r.next_u32();
        r.state = r.state.wrapping_add(splitmix64(seed));
        r.next_u32();
        r
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6364136223846793005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free is overkill;
    /// modulo bias is negligible for our n ≪ 2³²).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    #[inline]
    pub fn range_incl(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo, hi + 1)
    }

    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element.
    pub fn pick<'a, T>(&mut self, s: &'a [T]) -> &'a T {
        &s[self.below(s.len())]
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self, mean: f64, sigma: f64) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return mean + sigma * z;
        }
        let (u1, u2) = (self.f64().max(1e-300), self.f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        mean + sigma * r * theta.cos()
    }
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean = {mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(2.0, 3.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean = {mean}");
        assert!((var - 9.0).abs() < 0.3, "var = {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
