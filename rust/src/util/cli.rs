//! Tiny CLI argument parser (offline replacement for `clap`).
//!
//! Supports `command --flag value --switch positional` style:
//! `flexpie plan --model mobilenet --nodes 4 --topology ring --bw 5gbps`.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token (the subcommand).
    pub command: Option<String>,
    /// Remaining positional tokens.
    pub positional: Vec<String>,
    /// `--key value` pairs; a flag followed by another flag (or end of args)
    /// is stored with an empty value (boolean switch).
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let toks: Vec<String> = argv.into_iter().collect();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(name) = t.strip_prefix("--") {
                // support --key=value
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    out.flags.insert(name.to_string(), toks[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.insert(name.to_string(), String::new());
                }
            } else if out.command.is_none() {
                out.command = Some(t.clone());
            } else {
                out.positional.push(t.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Parse a bandwidth flag: `5gbps`, `500mbps`, or a bare number (Gb/s).
    pub fn bandwidth_or(&self, key: &str, default_gbps: f64) -> crate::net::Bandwidth {
        match self.get(key) {
            None => crate::net::Bandwidth::gbps(default_gbps),
            Some(v) => parse_bandwidth(v).unwrap_or(crate::net::Bandwidth::gbps(default_gbps)),
        }
    }
}

/// Parse `"5gbps"` / `"500mbps"` / `"2.5"` (Gb/s).
pub fn parse_bandwidth(s: &str) -> Option<crate::net::Bandwidth> {
    let lower = s.to_ascii_lowercase();
    if let Some(v) = lower.strip_suffix("gbps") {
        return v.trim().parse::<f64>().ok().map(crate::net::Bandwidth::gbps);
    }
    if let Some(v) = lower.strip_suffix("mbps") {
        return v.trim().parse::<f64>().ok().map(crate::net::Bandwidth::mbps);
    }
    lower.parse::<f64>().ok().map(crate::net::Bandwidth::gbps)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("plan --model mobilenet --nodes 4 --verbose");
        assert_eq!(a.command.as_deref(), Some("plan"));
        assert_eq!(a.get("model"), Some("mobilenet"));
        assert_eq!(a.usize_or("nodes", 1), 4);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn equals_style() {
        let a = parse("bench --fig=7 --bw=500mbps");
        assert_eq!(a.get("fig"), Some("7"));
        assert!((a.bandwidth_or("bw", 5.0).as_gbps() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn positional_args() {
        let a = parse("run one two");
        assert_eq!(a.command.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["one", "two"]);
    }

    #[test]
    fn bandwidth_parsing() {
        assert!((parse_bandwidth("5gbps").unwrap().as_gbps() - 5.0).abs() < 1e-12);
        assert!((parse_bandwidth("500mbps").unwrap().as_gbps() - 0.5).abs() < 1e-12);
        assert!((parse_bandwidth("2.5").unwrap().as_gbps() - 2.5).abs() < 1e-12);
        assert!(parse_bandwidth("fast").is_none());
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.f64_or("missing", 1.5), 1.5);
        assert_eq!(a.get_or("missing", "d"), "d");
    }
}
