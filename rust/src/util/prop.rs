//! Property-testing driver (offline replacement for `proptest`).
//!
//! Runs a property over many seeded random cases; on failure it reports the
//! exact case seed so the failure replays deterministically:
//!
//! ```no_run
//! use flexpie::util::prop::check;
//! check("tiles_partition_space", 200, |rng| {
//!     let n = rng.range_incl(2, 6);
//!     // ... build a random case, return Err(msg) on violation ...
//!     Ok(())
//! });
//! ```

use super::rng::Rng;

/// Base seed; override with `FLEXPIE_PROP_SEED` to replay a failure.
fn base_seed() -> u64 {
    std::env::var("FLEXPIE_PROP_SEED").ok().and_then(|v| v.parse().ok()).unwrap_or(0x9e37)
}

/// Number of cases multiplier; `FLEXPIE_PROP_CASES` scales all checks.
fn case_multiplier() -> f64 {
    std::env::var("FLEXPIE_PROP_CASES").ok().and_then(|v| v.parse().ok()).unwrap_or(1.0)
}

/// Run `property` over `cases` random cases. Panics with the failing seed on
/// the first violation.
pub fn check<F>(name: &str, cases: usize, property: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let base = base_seed();
    let n = ((cases as f64) * case_multiplier()).max(1.0) as u64;
    for case in 0..n {
        let seed = base ^ (case.wrapping_mul(0x2545F4914F6CDD1D));
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property {name:?} failed on case {case} (replay with \
                 FLEXPIE_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Assertion helpers that return `Result` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

/// `prop_assert_eq!(a, b)` — equality with value reporting.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let counter = std::cell::Cell::new(0usize);
        check("count", 50, |_rng| {
            counter.set(counter.get() + 1);
            Ok(())
        });
        assert!(counter.get() >= 50);
    }

    #[test]
    #[should_panic(expected = "property \"fails\" failed")]
    fn failing_property_panics_with_seed() {
        check("fails", 10, |rng| {
            let v = rng.below(100);
            prop_assert!(v < 101); // always true
            prop_assert!(v < 1000, "fine");
            Err("boom".to_string())
        });
    }

    #[test]
    fn macros_compile_in_property_context() {
        check("macros", 5, |rng| {
            let x = rng.below(10);
            prop_assert!(x < 10);
            prop_assert_eq!(x, x);
            Ok(())
        });
    }
}
