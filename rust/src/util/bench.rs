//! Mini benchmark harness (offline replacement for `criterion`).
//!
//! `cargo bench` runs each `[[bench]]` target's `main()`; targets use
//! [`BenchRunner`] for timed micro-sections and plain table printing for the
//! paper-figure reproductions.

use std::time::{Duration, Instant};

/// Timing statistics over the measured iterations.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
    pub iters: usize,
}

impl Stats {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean {:>10} median {:>10} min {:>10} ({} iters)",
            fmt_dur(self.mean),
            fmt_dur(self.median),
            fmt_dur(self.min),
            self.iters
        )
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Benchmark driver: warms up, then measures for a target wall-clock budget.
pub struct BenchRunner {
    pub name: String,
    /// Minimum measured iterations.
    pub min_iters: usize,
    /// Wall-clock budget for the measurement phase.
    pub budget: Duration,
}

impl BenchRunner {
    pub fn new(name: &str) -> BenchRunner {
        // honor FLEXPIE_BENCH_FAST=1 for CI-speed runs
        let fast = std::env::var("FLEXPIE_BENCH_FAST").is_ok();
        BenchRunner {
            name: name.to_string(),
            min_iters: if fast { 3 } else { 10 },
            budget: if fast { Duration::from_millis(200) } else { Duration::from_secs(2) },
        }
    }

    /// Measure `f`, which returns a value that is black-boxed to prevent
    /// dead-code elimination.
    pub fn bench<T, F: FnMut() -> T>(&self, label: &str, mut f: F) -> Stats {
        // warmup
        for _ in 0..2 {
            black_box(f());
        }
        let mut times = Vec::new();
        let start = Instant::now();
        while times.len() < self.min_iters || start.elapsed() < self.budget {
            let t0 = Instant::now();
            black_box(f());
            times.push(t0.elapsed());
            if times.len() >= 10_000 {
                break;
            }
        }
        times.sort();
        let total: Duration = times.iter().sum();
        let stats = Stats {
            mean: total / times.len() as u32,
            median: times[times.len() / 2],
            min: times[0],
            max: *times.last().unwrap(),
            iters: times.len(),
        };
        println!("{}/{label:<40} {stats}", self.name);
        stats
    }
}

/// Identity function the optimizer cannot see through.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Render the single-line `RESULT {...}` JSON trajectory record without
/// printing it — split from [`emit_result`] so the one-line/escaping
/// contract is unit-testable: string fields may contain quotes, backslashes
/// or newlines and the record must still be one grep-able line that parses
/// back to the same values.
pub fn result_line(v: &crate::util::json::Json) -> String {
    format!("RESULT {}", v.to_string())
}

/// Emit the single-line `RESULT {...}` JSON trajectory record.
///
/// Every bench and e2e summary prints exactly this shape, and CI greps it
/// out of the logs (`grep '^RESULT '`) to upload as an artifact — one
/// emitter keeps the prefix and formatting identical everywhere so the
/// extraction can never drift per target.
pub fn emit_result(fields: Vec<(&str, crate::util::json::Json)>) {
    println!("{}", result_line(&crate::util::json::Json::obj(fields)));
}

/// [`emit_result`] for callers that already hold an assembled [`Json`]
/// object (e.g. a harness suite report).
pub fn emit_result_json(v: &crate::util::json::Json) {
    println!("{}", result_line(v));
}

/// Fixed-width table printer for the paper-figure benches.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>, I: IntoIterator<Item = S>>(headers: I) -> Table {
        Table { headers: headers.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, cells: I) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                out.push_str(&format!("{:<width$}", c, width = widths[i]));
            }
            out.push('\n');
        };
        line(&self.headers, &widths, &mut out);
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            line(row, &widths, &mut out);
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = BenchRunner {
            name: "t".into(),
            min_iters: 3,
            budget: Duration::from_millis(10),
        };
        let stats = r.bench("noop", || 1 + 1);
        assert!(stats.iters >= 3);
        assert!(stats.min <= stats.median && stats.median <= stats.max);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(["model", "time"]);
        t.row(["mobilenet", "1.5 ms"]);
        t.row(["r", "2"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("model"));
        assert!(lines[2].starts_with("mobilenet"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(["a", "b"]);
        t.row(["only one"]);
    }

    #[test]
    fn result_line_survives_pathological_fields() {
        use crate::util::json::{parse, Json};
        // quotes, backslashes, newlines and tabs in a string field must
        // neither break the single-line contract nor the parse-back
        let name = "suite \"q\"\\path\nwith\tnewline";
        let line = result_line(&Json::obj(vec![
            ("suite", Json::Str(name.into())),
            ("ok", Json::Num(3.0)),
        ]));
        assert!(line.starts_with("RESULT {"), "{line}");
        assert_eq!(line.lines().count(), 1, "RESULT must stay one grep-able line");
        let v = parse(line.strip_prefix("RESULT ").unwrap()).unwrap();
        assert_eq!(v.req("suite").unwrap().as_str(), Some(name));
        assert_eq!(v.req("ok").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn fmt_dur_units() {
        assert_eq!(fmt_dur(Duration::from_secs(2)), "2.000 s");
        assert_eq!(fmt_dur(Duration::from_millis(5)), "5.000 ms");
        assert_eq!(fmt_dur(Duration::from_micros(7)), "7.000 µs");
        assert_eq!(fmt_dur(Duration::from_nanos(3)), "3.0 ns");
    }
}
