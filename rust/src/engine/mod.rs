//! The inference engine: evaluates plans on the virtual clock and executes
//! them with real numerics.
//!
//! * [`evaluate`] — the paper's reported metric: end-to-end inference time
//!   of a plan on a testbed, from the analytic ground-truth model (the
//!   simulator's physics). Deterministic and noise-free.
//! * [`execute`] — runs the plan on the simulated cluster
//!   ([`crate::cluster`]) with real tensors, returning the output plus the
//!   virtual-clock timing; [`verify_plan`] compares the distributed output
//!   against the single-node reference bit-for-bit.

use crate::compute::{run_reference, Tensor, WeightStore};
use crate::cost::CostSource;
use crate::model::Model;
use crate::net::Testbed;
use crate::partition::Plan;
use crate::planner::exhaustive::plan_cost;

pub use crate::planner::exhaustive::PlanCost as TimingReport;

impl TimingReport {
    pub fn total_ms(&self) -> f64 {
        self.total * 1e3
    }
}

/// Evaluate `plan` on `testbed` — the simulator's ground-truth inference
/// time (what every figure reports).
pub fn evaluate(model: &Model, plan: &Plan, testbed: &Testbed) -> TimingReport {
    plan_cost(model, plan, &CostSource::analytic(testbed))
}

/// Result of a real-numerics execution.
#[derive(Debug)]
pub struct ExecutionResult {
    pub output: Tensor,
    pub timing: TimingReport,
    /// Payload bytes actually exchanged by the cluster threads.
    pub bytes_exchanged: u64,
    pub messages: usize,
}

/// Execute `plan` on the simulated cluster with real numerics.
pub fn execute(
    model: &Model,
    plan: &Plan,
    weights: &WeightStore,
    input: &Tensor,
    testbed: &Testbed,
) -> ExecutionResult {
    let run = crate::cluster::run_distributed(model, plan, weights, input, testbed.nodes);
    ExecutionResult {
        output: run.output,
        timing: evaluate(model, plan, testbed),
        bytes_exchanged: run.bytes_exchanged,
        messages: run.messages,
    }
}

/// Execute `plan` and compare against the single-node reference; returns the
/// max abs difference (0.0 expected — each output element has exactly one
/// accumulation order).
pub fn verify_plan(model: &Model, plan: &Plan, testbed: &Testbed, seed: u64) -> f32 {
    let weights = WeightStore::for_model(model, seed);
    let l0 = &model.layers[0];
    let input = Tensor::random(l0.in_h, l0.in_w, l0.in_c, seed ^ 0xdead);
    let reference = run_reference(model, &weights, &input);
    let result = execute(model, plan, &weights, &input, testbed);
    reference.max_abs_diff(&result.output)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::net::{Bandwidth, Topology};
    use crate::partition::Scheme;
    use crate::planner::Dpp;

    fn tb(nodes: usize, gbps: f64) -> Testbed {
        Testbed::new(nodes, Topology::Ring, Bandwidth::gbps(gbps))
    }

    #[test]
    fn evaluate_agrees_with_dpp_estimate() {
        let testbed = tb(4, 1.0);
        let cost = CostSource::analytic(&testbed);
        let model = zoo::edgenet(16);
        let plan = Dpp::new(&model, &cost).plan();
        let report = evaluate(&model, &plan, &testbed);
        assert!((report.total - plan.est_cost).abs() < 1e-9 * plan.est_cost.max(1.0));
        assert!(report.total_ms() > 0.0);
    }

    #[test]
    fn dpp_plan_executes_correctly() {
        // The headline end-to-end property: the optimizer's plan, executed
        // distributed with real numerics, equals the single-node reference.
        let testbed = tb(4, 1.0);
        let cost = CostSource::analytic(&testbed);
        let model = zoo::edgenet(16);
        let plan = Dpp::new(&model, &cost).plan();
        assert_eq!(verify_plan(&model, &plan, &testbed, 7), 0.0);
    }

    #[test]
    fn execute_reports_bytes_consistent_with_estimate() {
        // Cluster-exchanged payload bytes must equal the cost model's
        // bytes_moved (same geometry → same intersections).
        let testbed = tb(4, 5.0);
        let model = zoo::edgenet(16);
        let plan = Plan::uniform(Scheme::InH, model.n_layers());
        let ws = WeightStore::for_model(&model, 3);
        let input = Tensor::random(16, 16, 3, 5);
        let res = execute(&model, &plan, &ws, &input, &testbed);
        assert_eq!(res.bytes_exchanged, res.timing.bytes_moved);
    }

    #[test]
    fn faster_network_reduces_estimated_time() {
        let model = zoo::edgenet(32);
        let plan = Plan::uniform(Scheme::OutC, model.n_layers());
        let fast = evaluate(&model, &plan, &tb(4, 5.0)).total;
        let slow = evaluate(&model, &plan, &tb(4, 0.1)).total;
        assert!(slow > fast);
    }
}
