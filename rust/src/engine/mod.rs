//! The inference engine: evaluates plans on the virtual clock and executes
//! them with real numerics.
//!
//! * [`evaluate`] — the paper's reported metric: end-to-end inference time
//!   of a plan on a testbed, from the analytic ground-truth model (the
//!   simulator's physics). Deterministic and noise-free.
//! * [`execute`] — runs the plan on the simulated cluster
//!   ([`crate::cluster`]) with real tensors, returning the output plus the
//!   virtual-clock timing; [`verify_plan`] compares the distributed output
//!   against the single-node reference bit-for-bit.
//! * [`execute_stream`] — the streaming entry point: runs a whole input
//!   sequence through the block-pipelined executor
//!   ([`crate::cluster::pipeline`]), yielding completions in submission
//!   order, bit-identical to running [`execute`] per input. Its timing
//!   report carries both objectives' virtual costs: per-item latency and
//!   the bottleneck stage time that bounds steady-state throughput.

use crate::cluster::pipeline::{run_pipelined, PipelineStats};
use crate::compute::{run_reference, Tensor, WeightStore};
use crate::cost::CostSource;
use crate::model::Model;
use crate::net::Testbed;
use crate::partition::Plan;
use crate::planner::exhaustive::{plan_cost, stage_costs_from};

pub use crate::planner::exhaustive::PlanCost as TimingReport;

impl TimingReport {
    pub fn total_ms(&self) -> f64 {
        self.total * 1e3
    }
}

/// Evaluate `plan` on `testbed` — the simulator's ground-truth inference
/// time (what every figure reports).
pub fn evaluate(model: &Model, plan: &Plan, testbed: &Testbed) -> TimingReport {
    plan_cost(model, plan, &CostSource::analytic(testbed))
}

/// Result of a real-numerics execution.
#[derive(Debug)]
pub struct ExecutionResult {
    pub output: Tensor,
    pub timing: TimingReport,
    /// Payload bytes actually exchanged by the cluster threads.
    pub bytes_exchanged: u64,
    pub messages: usize,
}

/// Execute `plan` on the simulated cluster with real numerics.
pub fn execute(
    model: &Model,
    plan: &Plan,
    weights: &WeightStore,
    input: &Tensor,
    testbed: &Testbed,
) -> ExecutionResult {
    let run = crate::cluster::run_distributed(model, plan, weights, input, testbed.nodes);
    ExecutionResult {
        output: run.output,
        timing: evaluate(model, plan, testbed),
        bytes_exchanged: run.bytes_exchanged,
        messages: run.messages,
    }
}

/// Result of a streaming (pipelined) execution over an input sequence.
#[derive(Debug)]
pub struct StreamResult {
    /// Outputs in submission order.
    pub outputs: Vec<Tensor>,
    /// Virtual-clock latency of one inference under `plan` (unchanged by
    /// pipelining — each item still traverses every stage).
    pub timing: TimingReport,
    /// Virtual-clock seconds of each pipeline stage (blocks + gather); the
    /// max is the steady-state per-item service time under pipelining.
    pub stage_times: Vec<f64>,
    /// Payload bytes each item moved (identical across items, equal to the
    /// lockstep executor's accounting).
    pub bytes_per_item: u64,
    pub messages_per_item: usize,
    /// Host-side per-stage occupancy/byte counters from the executor.
    pub pipeline: PipelineStats,
}

impl StreamResult {
    /// The virtual-clock bottleneck stage time (what
    /// [`crate::cost::Objective::Throughput`] minimizes).
    pub fn bottleneck(&self) -> f64 {
        self.stage_times.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }
}

/// Execute `plan` over a sequence of `inputs` on the block-pipelined
/// executor, with up to `depth` submissions queued at the entry. Outputs
/// come back in submission order and are bit-identical to executing each
/// input through [`execute`] (asserted by the tests below across the zoo).
pub fn execute_stream(
    model: &Model,
    plan: &Plan,
    weights: &WeightStore,
    inputs: &[Tensor],
    testbed: &Testbed,
    depth: usize,
) -> StreamResult {
    let cost = CostSource::analytic(testbed);
    let (completions, pipeline) =
        run_pipelined(model, plan, weights, inputs, testbed.nodes, depth);
    let (mut bytes, mut msgs) = (0u64, 0usize);
    let outputs = completions
        .into_iter()
        .map(|c| {
            bytes = c.bytes_exchanged;
            msgs = c.messages;
            c.output
        })
        .collect();
    let timing = plan_cost(model, plan, &cost);
    let stage_times = stage_costs_from(plan, &timing);
    StreamResult {
        outputs,
        timing,
        stage_times,
        bytes_per_item: bytes,
        messages_per_item: msgs,
        pipeline,
    }
}

/// Execute `plan` and compare against the single-node reference; returns the
/// max abs difference (0.0 expected — each output element has exactly one
/// accumulation order).
pub fn verify_plan(model: &Model, plan: &Plan, testbed: &Testbed, seed: u64) -> f32 {
    let weights = WeightStore::for_model(model, seed);
    let l0 = &model.layers[0];
    let input = Tensor::random(l0.in_h, l0.in_w, l0.in_c, seed ^ 0xdead);
    let reference = run_reference(model, &weights, &input);
    let result = execute(model, plan, &weights, &input, testbed);
    reference.max_abs_diff(&result.output)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::net::{Bandwidth, Topology};
    use crate::partition::Scheme;
    use crate::planner::Dpp;

    fn tb(nodes: usize, gbps: f64) -> Testbed {
        Testbed::new(nodes, Topology::Ring, Bandwidth::gbps(gbps))
    }

    #[test]
    fn evaluate_agrees_with_dpp_estimate() {
        let testbed = tb(4, 1.0);
        let cost = CostSource::analytic(&testbed);
        let model = zoo::edgenet(16);
        let plan = Dpp::new(&model, &cost).plan();
        let report = evaluate(&model, &plan, &testbed);
        assert!((report.total - plan.est_cost).abs() < 1e-9 * plan.est_cost.max(1.0));
        assert!(report.total_ms() > 0.0);
    }

    #[test]
    fn dpp_plan_executes_correctly() {
        // The headline end-to-end property: the optimizer's plan, executed
        // distributed with real numerics, equals the single-node reference.
        let testbed = tb(4, 1.0);
        let cost = CostSource::analytic(&testbed);
        let model = zoo::edgenet(16);
        let plan = Dpp::new(&model, &cost).plan();
        assert_eq!(verify_plan(&model, &plan, &testbed, 7), 0.0);
    }

    #[test]
    fn execute_reports_bytes_consistent_with_estimate() {
        // Cluster-exchanged payload bytes must equal the cost model's
        // bytes_moved (same geometry → same intersections).
        let testbed = tb(4, 5.0);
        let model = zoo::edgenet(16);
        let plan = Plan::uniform(Scheme::InH, model.n_layers());
        let ws = WeightStore::for_model(&model, 3);
        let input = Tensor::random(16, 16, 3, 5);
        let res = execute(&model, &plan, &ws, &input, &testbed);
        assert_eq!(res.bytes_exchanged, res.timing.bytes_moved);
    }

    #[test]
    fn streaming_execution_is_bit_identical_to_lockstep_across_zoo() {
        // the tentpole invariant: the pipelined executor's outputs equal
        // per-input lockstep execution, for planner-produced plans, across
        // the (small-numerics) model zoo
        let testbed = tb(4, 1.0);
        let models = [
            zoo::edgenet(16),
            zoo::tiny_chain(5, 16, 8),
            zoo::mobilenet_v1(32, 10).truncated(5),
        ];
        for model in &models {
            let cost = CostSource::analytic(&testbed);
            let plan = Dpp::new(model, &cost).plan();
            let ws = WeightStore::for_model(model, 9);
            let l0 = &model.layers[0];
            let inputs: Vec<Tensor> = (0..4u64)
                .map(|i| Tensor::random(l0.in_h, l0.in_w, l0.in_c, 70 + i))
                .collect();
            let stream = execute_stream(model, &plan, &ws, &inputs, &testbed, 3);
            assert_eq!(stream.outputs.len(), inputs.len(), "{}", model.name);
            for (i, (input, out)) in inputs.iter().zip(&stream.outputs).enumerate() {
                let lockstep = execute(model, &plan, &ws, input, &testbed);
                assert_eq!(
                    lockstep.output.max_abs_diff(out),
                    0.0,
                    "{} item {i} diverged from lockstep",
                    model.name
                );
                assert_eq!(stream.bytes_per_item, lockstep.bytes_exchanged);
                assert_eq!(stream.messages_per_item, lockstep.messages);
            }
        }
    }

    #[test]
    fn stream_stage_times_decompose_the_latency() {
        let testbed = tb(4, 1.0);
        let model = zoo::edgenet(16);
        let plan = Plan::uniform(Scheme::InH, model.n_layers());
        let ws = WeightStore::for_model(&model, 2);
        let inputs = vec![Tensor::random(16, 16, 3, 8)];
        let stream = execute_stream(&model, &plan, &ws, &inputs, &testbed, 1);
        let sum: f64 = stream.stage_times.iter().sum();
        assert!((sum - stream.timing.total).abs() < 1e-9 * stream.timing.total);
        assert!(stream.bottleneck() < stream.timing.total);
        assert_eq!(stream.bytes_per_item, stream.timing.bytes_moved);
        // one stage per block plus the gather
        assert_eq!(stream.stage_times.len(), plan.blocks().len() + 1);
        assert_eq!(stream.pipeline.stages.len(), plan.blocks().len());
    }

    #[test]
    fn faster_network_reduces_estimated_time() {
        let model = zoo::edgenet(32);
        let plan = Plan::uniform(Scheme::OutC, model.n_layers());
        let fast = evaluate(&model, &plan, &tb(4, 5.0)).total;
        let slow = evaluate(&model, &plan, &tb(4, 0.1)).total;
        assert!(slow > fast);
    }
}
