//! The Dynamic Partition Planner (DPP) — the paper's Algorithm 1.
//!
//! DPP searches the combinatorial space of per-layer `(scheme, mode)` pairs
//! (`Pᵢ = (pᵢ, tᵢ)`) for the sequence `S = [P₀ … Pₙ]` with the lowest
//! estimated end-to-end inference time. The paper's three key designs map
//! onto this implementation as follows:
//!
//! * **Key design 1 — reverse search.** The DP runs from `Lₙ` down to `L₀`
//!   ([`dpp`] iterates block ends `j = n..0`), because NT inflation
//!   propagates *backwards*: a block's interior tiles are determined by its
//!   end layer, so states anchored at block ends have well-defined costs.
//! * **Key design 2 — skip NT states.** DP states exist only at T
//!   boundaries: `best[i][p]` is the optimal cost of layers `i..n` given the
//!   block starting at `i` was entered through a transmission from a
//!   producer partitioned under `p`. Substructures that would *start* inside
//!   an NT run are never evaluated (their cost is indeterminate — exactly
//!   the paper's "Why skip NT states?").
//! * **Key design 3 — backtrack and generate combined sequences.** For every
//!   anchor `j`, the planner extends the fused block backwards `i = j..0`,
//!   incrementally growing the combined sequence `CS[i..j]` and pricing it
//!   with the i-Estimator (inflated tiles) and the s-Estimator (the entry
//!   boundary), pruned by branch-and-bound thresholds.
//!
//! [`exhaustive`] provides the brute-force reference used to validate
//! Theorem 1 (optimality under an exact cost oracle).

pub mod dpp;
pub mod exhaustive;

pub use dpp::{Dpp, DppConfig, SearchStats};

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::cost::{CostSource, MemoStore};
use crate::model::Model;
use crate::net::Testbed;
use crate::partition::Plan;

/// How the replanning entry points run the search: worker threads for the
/// wavefront-parallel DPP and an optional shared query memo. Every setting
/// is cost-transparent — plans are bit-identical across worker counts and
/// memoization, so callers can tune for speed freely.
#[derive(Debug, Clone, Default)]
pub struct PlannerOpts {
    /// DPP worker threads: `0` = one per available core (capped at the
    /// scheme count), `1` = serial.
    pub workers: usize,
    /// Shared estimator-query memo; `None` plans uncached.
    pub memo: Option<Arc<MemoStore>>,
}

impl PlannerOpts {
    pub fn serial() -> PlannerOpts {
        PlannerOpts { workers: 1, memo: None }
    }

    fn cost_for(&self, testbed: &Testbed) -> CostSource {
        let cost = CostSource::analytic(testbed);
        match &self.memo {
            Some(store) => cost.memoized(store),
            None => cost,
        }
    }
}

/// Plan for a concrete cluster snapshot: one-shot DPP over the analytic cost
/// model of `testbed`. This is the replanning entry point the runtime
/// adaptation layer ([`crate::elastic`]) calls off the request path whenever
/// effective conditions drift out of the active plan's regime. Runs the
/// parallel search with default [`PlannerOpts`]; the result is bit-identical
/// to the serial, unmemoized search.
pub fn plan_for_testbed(model: &Model, testbed: &Testbed) -> Plan {
    plan_for_testbed_opts(model, testbed, &PlannerOpts::default()).0
}

/// [`plan_for_testbed`] with explicit search options, returning the search
/// statistics (estimator-call counts, memo hit/miss/rescale counters).
pub fn plan_for_testbed_opts(
    model: &Model,
    testbed: &Testbed,
    opts: &PlannerOpts,
) -> (Plan, SearchStats) {
    let cost = opts.cost_for(testbed);
    let cfg = DppConfig { workers: opts.workers, ..DppConfig::default() };
    Dpp::with_config(model, &cost, cfg).plan_with_stats()
}

/// Plan one model for many condition cells concurrently — the batch shape of
/// the background replanner's speculative n−1 failover pre-computation. Each
/// search runs serially on one pool thread (no nested fan-out) against the
/// shared memo, and results come back in input order.
pub fn plan_batch(model: &Model, testbeds: &[Testbed], opts: &PlannerOpts) -> Vec<Plan> {
    if testbeds.is_empty() {
        return Vec::new();
    }
    let requested = if opts.workers == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        opts.workers
    };
    let pool = requested.min(testbeds.len());
    let inner = PlannerOpts { workers: 1, memo: opts.memo.clone() };
    if pool <= 1 {
        return testbeds
            .iter()
            .map(|tb| plan_for_testbed_opts(model, tb, &inner).0)
            .collect();
    }
    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<Plan>>> = testbeds.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..pool {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= testbeds.len() {
                    break;
                }
                let plan = plan_for_testbed_opts(model, &testbeds[i], &inner).0;
                *results[i].lock().unwrap() = Some(plan);
            });
        }
    });
    results
        .into_iter()
        .map(|slot| slot.into_inner().unwrap().expect("pool worker filled every slot"))
        .collect()
}

/// Seed `store` with the *complete* query universe of `(model, testbed)` by
/// running one unpruned (but parallel) search and discarding the plan.
/// Pruned searches evaluate a condition-dependent subset of that universe,
/// so after a prewarm every future replan of the same cluster — at any
/// bandwidth — answers all sync queries from cached geometry (hits or
/// analytic rescales, never inner estimator calls).
pub fn prewarm_memo(model: &Model, testbed: &Testbed, store: &Arc<MemoStore>) -> SearchStats {
    let cost = CostSource::analytic(testbed).memoized(store);
    let cfg = DppConfig { prune: false, workers: 0, ..DppConfig::default() };
    Dpp::with_config(model, &cost, cfg).plan_with_stats().1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::net::{Bandwidth, Topology};

    fn tb(gbps: f64) -> Testbed {
        Testbed::new(4, Topology::Ring, Bandwidth::gbps(gbps))
    }

    #[test]
    fn opts_do_not_change_plans() {
        let model = zoo::edgenet(16);
        let testbed = tb(1.0);
        let reference = plan_for_testbed_opts(&model, &testbed, &PlannerOpts::serial()).0;
        let store = MemoStore::shared();
        for opts in [
            PlannerOpts::default(),
            PlannerOpts { workers: 4, memo: None },
            PlannerOpts { workers: 4, memo: Some(store.clone()) },
            PlannerOpts { workers: 1, memo: Some(store) },
        ] {
            let (plan, _) = plan_for_testbed_opts(&model, &testbed, &opts);
            assert_eq!(plan.est_cost.to_bits(), reference.est_cost.to_bits());
            assert_eq!(plan.steps, reference.steps);
        }
    }

    #[test]
    fn plan_batch_matches_individual_planning() {
        let model = zoo::edgenet(16);
        let cells: Vec<Testbed> = [1.0, 0.5, 0.25, 0.125]
            .iter()
            .map(|&f| tb(1.0).with_bandwidth_factor(f))
            .collect();
        let opts = PlannerOpts { workers: 4, memo: Some(MemoStore::shared()) };
        let batch = plan_batch(&model, &cells, &opts);
        assert_eq!(batch.len(), cells.len());
        for (plan, cell) in batch.iter().zip(&cells) {
            let solo = plan_for_testbed(&model, cell);
            assert_eq!(plan.est_cost.to_bits(), solo.est_cost.to_bits());
            assert_eq!(plan.steps, solo.steps);
        }
    }

    #[test]
    fn prewarmed_store_makes_bandwidth_drift_replans_query_free() {
        // the acceptance property: after a prewarm, a pure-bandwidth-drift
        // replan performs ZERO inner sync (and compute) queries
        let model = zoo::edgenet(16);
        let base = tb(1.0);
        let store = MemoStore::shared();
        prewarm_memo(&model, &base, &store);
        let opts = PlannerOpts { workers: 0, memo: Some(store.clone()) };
        for factor in [0.5, 0.4, 0.125, 1.0] {
            let drifted = base.with_bandwidth_factor(factor);
            let (plan, stats) = plan_for_testbed_opts(&model, &drifted, &opts);
            assert_eq!(
                stats.memo.sync_misses, 0,
                "bandwidth drift ({factor}×) re-queried the estimator: {}",
                stats.memo
            );
            assert_eq!(stats.memo.compute_misses, 0, "{}", stats.memo);
            if factor != 1.0 {
                assert!(stats.memo.sync_rescales > 0, "drift must re-price: {}", stats.memo);
            }
            // and the query-free plan is still exactly the fresh plan
            let fresh = Dpp::new(&model, &CostSource::analytic(&drifted)).plan();
            assert_eq!(plan.est_cost.to_bits(), fresh.est_cost.to_bits());
            assert_eq!(plan.steps, fresh.steps);
        }
    }
}

