//! The Dynamic Partition Planner (DPP) — the paper's Algorithm 1.
//!
//! DPP searches the combinatorial space of per-layer `(scheme, mode)` pairs
//! (`Pᵢ = (pᵢ, tᵢ)`) for the sequence `S = [P₀ … Pₙ]` with the lowest
//! estimated end-to-end inference time. The paper's three key designs map
//! onto this implementation as follows:
//!
//! * **Key design 1 — reverse search.** The DP runs from `Lₙ` down to `L₀`
//!   ([`dpp`] iterates block ends `j = n..0`), because NT inflation
//!   propagates *backwards*: a block's interior tiles are determined by its
//!   end layer, so states anchored at block ends have well-defined costs.
//! * **Key design 2 — skip NT states.** DP states exist only at T
//!   boundaries: `best[i][p]` is the optimal cost of layers `i..n` given the
//!   block starting at `i` was entered through a transmission from a
//!   producer partitioned under `p`. Substructures that would *start* inside
//!   an NT run are never evaluated (their cost is indeterminate — exactly
//!   the paper's "Why skip NT states?").
//! * **Key design 3 — backtrack and generate combined sequences.** For every
//!   anchor `j`, the planner extends the fused block backwards `i = j..0`,
//!   incrementally growing the combined sequence `CS[i..j]` and pricing it
//!   with the i-Estimator (inflated tiles) and the s-Estimator (the entry
//!   boundary), pruned by branch-and-bound thresholds.
//!
//! [`exhaustive`] provides the brute-force reference used to validate
//! Theorem 1 (optimality under an exact cost oracle).

pub mod dpp;
pub mod exhaustive;

pub use dpp::{Dpp, DppConfig, SearchStats};

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::cost::{CostSource, MemoStore, Objective};
use crate::model::Model;
use crate::net::Testbed;
use crate::partition::Plan;

/// How the replanning entry points run the search: worker threads for the
/// wavefront-parallel DPP and an optional shared query memo. Every setting
/// is cost-transparent — plans are bit-identical across worker counts and
/// memoization, so callers can tune for speed freely.
#[derive(Debug, Clone, Default)]
pub struct PlannerOpts {
    /// DPP worker threads: `0` = one per available core (capped at the
    /// scheme count), `1` = serial.
    pub workers: usize,
    /// Shared estimator-query memo; `None` plans uncached.
    pub memo: Option<Arc<MemoStore>>,
}

impl PlannerOpts {
    pub fn serial() -> PlannerOpts {
        PlannerOpts { workers: 1, memo: None }
    }

    fn cost_for(&self, testbed: &Testbed) -> CostSource {
        let cost = CostSource::analytic(testbed);
        match &self.memo {
            Some(store) => cost.memoized(store),
            None => cost,
        }
    }
}

/// Plan for a concrete cluster snapshot: one-shot DPP over the analytic cost
/// model of `testbed`. This is the replanning entry point the runtime
/// adaptation layer ([`crate::elastic`]) calls off the request path whenever
/// effective conditions drift out of the active plan's regime. Runs the
/// parallel search with default [`PlannerOpts`]; the result is bit-identical
/// to the serial, unmemoized search.
pub fn plan_for_testbed(model: &Model, testbed: &Testbed) -> Plan {
    plan_for_testbed_opts(model, testbed, &PlannerOpts::default()).0
}

/// [`plan_for_testbed`] with explicit search options, returning the search
/// statistics (estimator-call counts, memo hit/miss/rescale counters).
pub fn plan_for_testbed_opts(
    model: &Model,
    testbed: &Testbed,
    opts: &PlannerOpts,
) -> (Plan, SearchStats) {
    plan_with_objective(model, testbed, Objective::Latency, opts)
}

/// Plan under an explicit [`Objective`]: `Latency` reproduces
/// [`plan_for_testbed_opts`]; `Throughput` minimizes the bottleneck
/// pipeline-stage time for the block-pipelined executor
/// ([`crate::cluster::pipeline`]). `est_cost` on the returned plan is the
/// objective's own metric (summed stages vs bottleneck stage seconds).
pub fn plan_with_objective(
    model: &Model,
    testbed: &Testbed,
    objective: Objective,
    opts: &PlannerOpts,
) -> (Plan, SearchStats) {
    let cost = opts.cost_for(testbed);
    let cfg = DppConfig { workers: opts.workers, objective, ..DppConfig::default() };
    Dpp::with_config(model, &cost, cfg).plan_with_stats()
}

/// Plan one model for many condition cells concurrently — the batch shape of
/// the background replanner's speculative n−1 failover pre-computation. Each
/// search runs serially on one pool thread (no nested fan-out) against the
/// shared memo, and results come back in input order.
pub fn plan_batch(model: &Model, testbeds: &[Testbed], opts: &PlannerOpts) -> Vec<Plan> {
    if testbeds.is_empty() {
        return Vec::new();
    }
    let requested = if opts.workers == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        opts.workers
    };
    let pool = requested.min(testbeds.len());
    let inner = PlannerOpts { workers: 1, memo: opts.memo.clone() };
    if pool <= 1 {
        return testbeds
            .iter()
            .map(|tb| plan_for_testbed_opts(model, tb, &inner).0)
            .collect();
    }
    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<Plan>>> = testbeds.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..pool {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= testbeds.len() {
                    break;
                }
                let plan = plan_for_testbed_opts(model, &testbeds[i], &inner).0;
                *results[i].lock().unwrap() = Some(plan);
            });
        }
    });
    results
        .into_iter()
        .map(|slot| slot.into_inner().unwrap().expect("pool worker filled every slot"))
        .collect()
}

/// Seed `store` with the *complete* query universe of `(model, testbed)` by
/// running one unpruned (but parallel) search and discarding the plan.
/// Pruned searches evaluate a condition-dependent subset of that universe,
/// so after a prewarm every future replan of the same cluster — at any
/// bandwidth — answers all sync queries from cached geometry (hits or
/// analytic rescales, never inner estimator calls).
pub fn prewarm_memo(model: &Model, testbed: &Testbed, store: &Arc<MemoStore>) -> SearchStats {
    let cost = CostSource::analytic(testbed).memoized(store);
    let cfg = DppConfig { prune: false, workers: 0, ..DppConfig::default() };
    Dpp::with_config(model, &cost, cfg).plan_with_stats().1
}

/// [`prewarm_memo`] with cross-process persistence (the ROADMAP's
/// cross-model memo persistence item): entries saved by a previous process
/// are absorbed into `store` first, then the prewarm sweep runs over the
/// warm store — when the file already covers this `(model, testbed)` the
/// sweep performs **zero cold estimator queries** (every answer is a cache
/// hit or an analytic rescale), and the file is rewritten only when the
/// sweep actually added entries. Returns `true` when the disk store fully
/// covered the model (nothing cold, nothing re-saved).
///
/// The file composes: prewarming several models (or testbeds) against the
/// same path merges their query universes — entries are namespaced by
/// testbed signature and keyed by exact query geometry, so each first-time
/// model extends the file and every later process starts warm for all of
/// them.
///
/// The file is rewritten when the sweep performed any cold query or the
/// file was absent; entries that reached `store` by other means (e.g. a
/// plain [`prewarm_memo`] of another model before this call) are persisted
/// only on those rewrites — use one persistent path per store for exact
/// mirroring.
pub fn prewarm_memo_persistent(
    model: &Model,
    testbed: &Testbed,
    store: &Arc<MemoStore>,
    path: &std::path::Path,
) -> std::io::Result<bool> {
    let existed = path.exists();
    if existed {
        store.load_into(path)?;
    }
    let stats = prewarm_memo(model, testbed, store);
    let covered = stats.memo.compute_misses == 0 && stats.memo.sync_misses == 0;
    if !existed || !covered {
        store.save(path)?;
    }
    Ok(existed && covered)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::net::{Bandwidth, Topology};

    fn tb(gbps: f64) -> Testbed {
        Testbed::new(4, Topology::Ring, Bandwidth::gbps(gbps))
    }

    #[test]
    fn opts_do_not_change_plans() {
        let model = zoo::edgenet(16);
        let testbed = tb(1.0);
        let reference = plan_for_testbed_opts(&model, &testbed, &PlannerOpts::serial()).0;
        let store = MemoStore::shared();
        for opts in [
            PlannerOpts::default(),
            PlannerOpts { workers: 4, memo: None },
            PlannerOpts { workers: 4, memo: Some(store.clone()) },
            PlannerOpts { workers: 1, memo: Some(store) },
        ] {
            let (plan, _) = plan_for_testbed_opts(&model, &testbed, &opts);
            assert_eq!(plan.est_cost.to_bits(), reference.est_cost.to_bits());
            assert_eq!(plan.steps, reference.steps);
        }
    }

    #[test]
    fn objective_threads_through_to_the_search() {
        let model = zoo::edgenet(16);
        let testbed = tb(0.5);
        let (thr, _) = plan_with_objective(
            &model,
            &testbed,
            Objective::Throughput,
            &PlannerOpts::default(),
        );
        let direct = Dpp::with_config(
            &model,
            &CostSource::analytic(&testbed),
            DppConfig { objective: Objective::Throughput, ..Default::default() },
        )
        .plan();
        assert_eq!(thr.est_cost.to_bits(), direct.est_cost.to_bits());
        assert_eq!(thr.steps, direct.steps);
        // latency is the default objective
        let (lat, _) = plan_for_testbed_opts(&model, &testbed, &PlannerOpts::default());
        assert_eq!(lat.steps, plan_for_testbed(&model, &testbed).steps);
    }

    #[test]
    fn plan_batch_matches_individual_planning() {
        let model = zoo::edgenet(16);
        let cells: Vec<Testbed> = [1.0, 0.5, 0.25, 0.125]
            .iter()
            .map(|&f| tb(1.0).with_bandwidth_factor(f))
            .collect();
        let opts = PlannerOpts { workers: 4, memo: Some(MemoStore::shared()) };
        let batch = plan_batch(&model, &cells, &opts);
        assert_eq!(batch.len(), cells.len());
        for (plan, cell) in batch.iter().zip(&cells) {
            let solo = plan_for_testbed(&model, cell);
            assert_eq!(plan.est_cost.to_bits(), solo.est_cost.to_bits());
            assert_eq!(plan.steps, solo.steps);
        }
    }

    #[test]
    fn persisted_memo_store_replans_with_zero_cold_queries() {
        // the ROADMAP acceptance: a reloaded store replans with zero cold
        // estimator queries, across a bandwidth sweep, with plans
        // bit-identical to fresh searches
        let model = zoo::edgenet(16);
        let base = tb(1.0);
        let dir = crate::util::tmp::TempDir::new("memo_persist");
        let p = dir.path().join("edgenet.memo.json");
        let store = MemoStore::shared();
        assert!(
            !prewarm_memo_persistent(&model, &base, &store, &p).unwrap(),
            "first prewarm is a fresh search"
        );
        assert!(p.exists(), "prewarm must persist the store");

        // a fresh process: a new store warmed purely from disk
        let reloaded = MemoStore::shared();
        assert!(
            prewarm_memo_persistent(&model, &base, &reloaded, &p).unwrap(),
            "second prewarm must come from disk"
        );
        assert_eq!(reloaded.len(), store.len());
        let opts = PlannerOpts { workers: 0, memo: Some(reloaded) };
        for factor in [1.0, 0.5, 0.25] {
            let drifted = base.with_bandwidth_factor(factor);
            let (plan, stats) = plan_for_testbed_opts(&model, &drifted, &opts);
            assert_eq!(
                stats.memo.compute_misses, 0,
                "cold compute query after reload ({factor}×): {}",
                stats.memo
            );
            assert_eq!(
                stats.memo.sync_misses, 0,
                "cold sync query after reload ({factor}×): {}",
                stats.memo
            );
            let fresh = Dpp::new(&model, &CostSource::analytic(&drifted)).plan();
            assert_eq!(plan.est_cost.to_bits(), fresh.est_cost.to_bits());
            assert_eq!(plan.steps, fresh.steps);
        }
    }

    #[test]
    fn persisted_memo_store_composes_across_models() {
        // the cross-model claim: one file accumulates several models'
        // query universes; later processes start warm for all of them
        let base = tb(1.0);
        let dir = crate::util::tmp::TempDir::new("memo_multi");
        let p = dir.path().join("shared.memo.json");
        let a = zoo::tiny_chain(3, 12, 8);
        let b = zoo::tiny_chain(5, 16, 8);
        assert!(
            !prewarm_memo_persistent(&a, &base, &MemoStore::shared(), &p).unwrap(),
            "first model is cold"
        );
        assert!(
            !prewarm_memo_persistent(&b, &base, &MemoStore::shared(), &p).unwrap(),
            "a new model must extend the file, not be reported warm"
        );
        // a third process starts warm for BOTH models from one load
        let store = MemoStore::shared();
        assert!(prewarm_memo_persistent(&a, &base, &store, &p).unwrap());
        assert!(prewarm_memo_persistent(&b, &base, &store, &p).unwrap());
    }

    #[test]
    fn prewarmed_store_makes_bandwidth_drift_replans_query_free() {
        // the acceptance property: after a prewarm, a pure-bandwidth-drift
        // replan performs ZERO inner sync (and compute) queries
        let model = zoo::edgenet(16);
        let base = tb(1.0);
        let store = MemoStore::shared();
        prewarm_memo(&model, &base, &store);
        let opts = PlannerOpts { workers: 0, memo: Some(store.clone()) };
        for factor in [0.5, 0.4, 0.125, 1.0] {
            let drifted = base.with_bandwidth_factor(factor);
            let (plan, stats) = plan_for_testbed_opts(&model, &drifted, &opts);
            assert_eq!(
                stats.memo.sync_misses, 0,
                "bandwidth drift ({factor}×) re-queried the estimator: {}",
                stats.memo
            );
            assert_eq!(stats.memo.compute_misses, 0, "{}", stats.memo);
            if factor != 1.0 {
                assert!(stats.memo.sync_rescales > 0, "drift must re-price: {}", stats.memo);
            }
            // and the query-free plan is still exactly the fresh plan
            let fresh = Dpp::new(&model, &CostSource::analytic(&drifted)).plan();
            assert_eq!(plan.est_cost.to_bits(), fresh.est_cost.to_bits());
            assert_eq!(plan.steps, fresh.steps);
        }
    }
}

