//! The Dynamic Partition Planner (DPP) — the paper's Algorithm 1.
//!
//! DPP searches the combinatorial space of per-layer `(scheme, mode)` pairs
//! (`Pᵢ = (pᵢ, tᵢ)`) for the sequence `S = [P₀ … Pₙ]` with the lowest
//! estimated end-to-end inference time. The paper's three key designs map
//! onto this implementation as follows:
//!
//! * **Key design 1 — reverse search.** The DP runs from `Lₙ` down to `L₀`
//!   ([`dpp`] iterates block ends `j = n..0`), because NT inflation
//!   propagates *backwards*: a block's interior tiles are determined by its
//!   end layer, so states anchored at block ends have well-defined costs.
//! * **Key design 2 — skip NT states.** DP states exist only at T
//!   boundaries: `best[i][p]` is the optimal cost of layers `i..n` given the
//!   block starting at `i` was entered through a transmission from a
//!   producer partitioned under `p`. Substructures that would *start* inside
//!   an NT run are never evaluated (their cost is indeterminate — exactly
//!   the paper's "Why skip NT states?").
//! * **Key design 3 — backtrack and generate combined sequences.** For every
//!   anchor `j`, the planner extends the fused block backwards `i = j..0`,
//!   incrementally growing the combined sequence `CS[i..j]` and pricing it
//!   with the i-Estimator (inflated tiles) and the s-Estimator (the entry
//!   boundary), pruned by branch-and-bound thresholds.
//!
//! [`exhaustive`] provides the brute-force reference used to validate
//! Theorem 1 (optimality under an exact cost oracle).

pub mod dpp;
pub mod exhaustive;

pub use dpp::{Dpp, DppConfig, SearchStats};

use crate::cost::CostSource;
use crate::model::Model;
use crate::net::Testbed;
use crate::partition::Plan;

/// Plan for a concrete cluster snapshot: one-shot DPP over the analytic cost
/// model of `testbed`. This is the replanning entry point the runtime
/// adaptation layer ([`crate::elastic`]) calls off the request path whenever
/// effective conditions drift out of the active plan's regime.
pub fn plan_for_testbed(model: &Model, testbed: &Testbed) -> Plan {
    let cost = CostSource::analytic(testbed);
    Dpp::new(model, &cost).plan()
}
