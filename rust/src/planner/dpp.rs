//! The dynamic-programming core of DPP (Algorithm 1 of the paper).
//!
//! ## DP formulation
//!
//! Let the model be `L₀ … L_{n-1}`. A plan is a partition of the chain into
//! *fused blocks* `[i..=j]` — NT at layers `i..j`, T at layer `j` — each
//! under a single scheme (cross-scheme realignment requires transmission).
//! Define
//!
//! ```text
//! after[i][q] = minimal cost of the boundary entering layer i (producer =
//!               layer i-1 partitioned under q) plus all of layers i..n-1
//! after[n][q] = cost of gathering layer n-1's tiles (scheme q) to the leader
//! ```
//!
//! with the recurrence (block `[i..=j]` under scheme `r`):
//!
//! ```text
//! after[i][q] = min over j ≥ i, r:
//!     s-Est(boundary: q → entry_need(block i..=j under r))
//!   + Σ_{l=i..j} i-Est(layer l, inflated tile under r)
//!   + after[j+1][r]
//! answer = min over j, r: s-Est(scatter) + Σ i-Est + after[j+1][r]
//! ```
//!
//! The search runs block ends `j` from `n-1` down to `0` (reverse search) and
//! extends each block backwards `i = j..0`, growing the NT-inflated tiles
//! incrementally — one receptive-field step per layer, so the whole search
//! does `O(n²k)` compute queries and `O(n²k²)` sync queries before pruning.
//!
//! ## Pruning (paper §3.3 "Piecing together")
//!
//! 1. NT-prefixed substructures are never enumerated (structural).
//! 2. `after[j+1]` memoization bounds every extension (`tail` below).
//! 3. Dynamic thresholds: a block extension whose compute-plus-tail already
//!    meets or exceeds every incumbent at its entry layer is skipped before
//!    any s-Estimator call; both rules are *sound* (they never discard an
//!    improving candidate), so pruned and unpruned searches return plans of
//!    equal cost — asserted by the Thm-1 tests.

use std::time::{Duration, Instant};

use crate::cost::query::{boundary_query, compute_query_tiles, gather_query, scatter_query};
use crate::cost::CostSource;
use crate::model::Model;
use crate::partition::geometry::{in_regions, out_tiles};
use crate::partition::{Mode, Plan, PlanStep, Scheme, Tile};

/// Planner configuration. The defaults reproduce the paper's FlexPie; the
/// restrictions implement baselines and ablations:
/// `enable_fusion = false` → layerwise optimization (DINA/PartialDI);
/// `schemes = [s]` with fusion → fused-layer optimization (AOFL/EdgeCI).
#[derive(Debug, Clone)]
pub struct DppConfig {
    /// Candidate schemes (the paper's `k` dimensions).
    pub schemes: Vec<Scheme>,
    /// Allow NT fusion (multi-layer blocks).
    pub enable_fusion: bool,
    /// Enable the dynamic-threshold pruning (ablation switch; pruning is
    /// sound, so plans are identical either way — only search time differs).
    pub prune: bool,
    /// Maximum fused-block span (`0` = unlimited).
    pub max_block_span: usize,
}

impl Default for DppConfig {
    fn default() -> Self {
        DppConfig {
            schemes: Scheme::ALL.to_vec(),
            enable_fusion: true,
            prune: true,
            max_block_span: 0,
        }
    }
}

/// Search-effort statistics (the paper reports DPP search time; the ablation
/// bench also reports estimator-call counts with pruning on/off).
#[derive(Debug, Clone, Copy, Default)]
pub struct SearchStats {
    pub compute_queries: usize,
    pub sync_queries: usize,
    pub candidates_pruned: usize,
    pub elapsed: Duration,
}

/// The Dynamic Partition Planner.
pub struct Dpp<'a> {
    pub model: &'a Model,
    pub cost: &'a CostSource,
    pub cfg: DppConfig,
}

impl<'a> Dpp<'a> {
    pub fn new(model: &'a Model, cost: &'a CostSource) -> Dpp<'a> {
        Dpp { model, cost, cfg: DppConfig::default() }
    }

    pub fn with_config(model: &'a Model, cost: &'a CostSource, cfg: DppConfig) -> Dpp<'a> {
        assert!(!cfg.schemes.is_empty(), "need at least one scheme");
        Dpp { model, cost, cfg }
    }

    pub fn plan(&self) -> Plan {
        self.plan_with_stats().0
    }

    pub fn plan_with_stats(&self) -> (Plan, SearchStats) {
        let t0 = Instant::now();
        let mut stats = SearchStats::default();
        let tb = self.cost.testbed();
        let nodes = tb.nodes;
        let layers = &self.model.layers;
        let n = layers.len();
        assert!(n > 0, "empty model");
        let schemes = &self.cfg.schemes;
        let k = schemes.len();

        // after[i][qi]: boundary-into-i (producer scheme q) + layers i..n-1.
        let mut after = vec![vec![f64::INFINITY; k]; n + 1];
        // choice[i][qi] = (block end j, block scheme index ri)
        let mut choice = vec![vec![(usize::MAX, usize::MAX); k]; n + 1];
        let mut root = f64::INFINITY;
        let mut root_choice = (usize::MAX, usize::MAX);

        // Base case: gather of the last layer.
        for (qi, &q) in schemes.iter().enumerate() {
            let gq = gather_query(&layers[n - 1], q, tb);
            stats.sync_queries += 1;
            after[n][qi] = self.cost.sync_time(&gq);
        }

        let max_span = if !self.cfg.enable_fusion {
            1
        } else if self.cfg.max_block_span == 0 {
            n
        } else {
            self.cfg.max_block_span
        };

        // Reverse search over block ends (Key design 1).
        for j in (0..n).rev() {
            for (ri, &r) in schemes.iter().enumerate() {
                let tail = after[j + 1][ri];
                // Tiles at the current top layer of the block (out space of
                // layer i), extended incrementally as i decreases.
                let mut cur_tiles: Vec<Tile> = out_tiles(&layers[j], r, nodes);
                let mut block_cost = 0.0f64;

                for i in (0..=j).rev() {
                    if j - i + 1 > max_span {
                        break;
                    }
                    if i < j {
                        // One backward receptive-field step (NT inflation).
                        cur_tiles = cur_tiles
                            .iter()
                            .map(|t| in_regions(&layers[i + 1], t))
                            .collect();
                    }
                    let cq = compute_query_tiles(&layers[i], &cur_tiles, r, tb);
                    stats.compute_queries += 1;
                    block_cost += self.cost.compute_time(&cq);
                    let base = block_cost + tail;

                    // Dynamic-threshold pruning: if compute+tail alone can no
                    // longer beat any incumbent at this entry layer, skip the
                    // (k) s-Estimator evaluations. Sound because sync ≥ 0.
                    if self.cfg.prune {
                        let worst_incumbent = if i == 0 {
                            root
                        } else {
                            after[i].iter().cloned().fold(f64::NEG_INFINITY, f64::max)
                        };
                        if base >= worst_incumbent {
                            stats.candidates_pruned += 1;
                            continue;
                        }
                    }

                    let entry_need: Vec<Tile> =
                        cur_tiles.iter().map(|t| in_regions(&layers[i], t)).collect();

                    if i == 0 {
                        let sq = scatter_query(&layers[0], r, &entry_need, tb);
                        stats.sync_queries += 1;
                        let total = self.cost.sync_time(&sq) + base;
                        if total < root {
                            root = total;
                            root_choice = (j, ri);
                        }
                    } else {
                        for (qi, &q) in schemes.iter().enumerate() {
                            let bq = boundary_query(
                                &layers[i - 1],
                                q,
                                &layers[i],
                                r,
                                &entry_need,
                                tb,
                            );
                            stats.sync_queries += 1;
                            let total = self.cost.sync_time(&bq) + base;
                            if total < after[i][qi] {
                                after[i][qi] = total;
                                choice[i][qi] = (j, ri);
                            }
                        }
                    }
                }
            }
        }

        assert!(root.is_finite(), "DPP found no feasible plan");

        // Reconstruct the step sequence from the backpointers.
        let mut steps = Vec::with_capacity(n);
        let (mut j, mut ri) = root_choice;
        let mut i = 0usize;
        loop {
            let r = schemes[ri];
            for _ in i..j {
                steps.push(PlanStep { scheme: r, mode: Mode::NT });
            }
            steps.push(PlanStep { scheme: r, mode: Mode::T });
            if j + 1 >= n {
                break;
            }
            let (nj, nri) = choice[j + 1][ri];
            debug_assert_ne!(nj, usize::MAX, "broken backpointer at layer {}", j + 1);
            i = j + 1;
            j = nj;
            ri = nri;
        }
        debug_assert_eq!(steps.len(), n);

        stats.elapsed = t0.elapsed();
        let plan = Plan { steps, est_cost: root };
        debug_assert!(plan.validate().is_ok(), "DPP produced invalid plan: {:?}", plan.validate());
        (plan, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::net::{Bandwidth, Testbed, Topology};
    use crate::planner::exhaustive::plan_cost;

    fn analytic(nodes: usize, gbps: f64) -> CostSource {
        CostSource::analytic(&Testbed::new(nodes, Topology::Ring, Bandwidth::gbps(gbps)))
    }

    #[test]
    fn plans_are_structurally_valid() {
        let cost = analytic(4, 5.0);
        for model in [zoo::edgenet(16), zoo::mobilenet_v1(224, 1000).truncated(9)] {
            let plan = Dpp::new(&model, &cost).plan();
            plan.validate().unwrap();
            assert_eq!(plan.steps.len(), model.n_layers());
            assert!(plan.est_cost.is_finite() && plan.est_cost > 0.0);
        }
    }

    #[test]
    fn est_cost_matches_independent_plan_costing() {
        // The DP's accumulated cost must equal re-costing the reconstructed
        // plan from scratch with the same cost source.
        let cost = analytic(4, 1.0);
        let model = zoo::edgenet(16);
        let plan = Dpp::new(&model, &cost).plan();
        let recost = plan_cost(&model, &plan, &cost).total;
        assert!(
            (plan.est_cost - recost).abs() < 1e-9 * plan.est_cost.max(1.0),
            "dp={} recost={}",
            plan.est_cost,
            recost
        );
    }

    #[test]
    fn pruning_preserves_optimality() {
        let cost = analytic(3, 0.5);
        let model = zoo::mobilenet_v1(224, 1000).truncated(11);
        let pruned = Dpp::with_config(
            &model,
            &cost,
            DppConfig { prune: true, ..Default::default() },
        )
        .plan();
        let unpruned = Dpp::with_config(
            &model,
            &cost,
            DppConfig { prune: false, ..Default::default() },
        )
        .plan();
        assert!((pruned.est_cost - unpruned.est_cost).abs() < 1e-12 * pruned.est_cost);
    }

    #[test]
    fn pruning_reduces_work() {
        let cost = analytic(4, 5.0);
        let model = zoo::mobilenet_v1(224, 1000);
        let (_, with) = Dpp::with_config(
            &model,
            &cost,
            DppConfig { prune: true, ..Default::default() },
        )
        .plan_with_stats();
        let (_, without) = Dpp::with_config(
            &model,
            &cost,
            DppConfig { prune: false, ..Default::default() },
        )
        .plan_with_stats();
        assert!(with.sync_queries < without.sync_queries);
        assert!(with.candidates_pruned > 0);
    }

    #[test]
    fn fusion_beats_no_fusion_at_low_bandwidth() {
        // With a slow interconnect, NT fusion should pay off on the early
        // (sync-heavy) layers, so the fused planner strictly improves on the
        // layerwise-restricted one.
        let cost = analytic(4, 0.1);
        let model = zoo::mobilenet_v1(224, 1000).truncated(9);
        let fused = Dpp::new(&model, &cost).plan();
        let layerwise = Dpp::with_config(
            &model,
            &cost,
            DppConfig { enable_fusion: false, ..Default::default() },
        )
        .plan();
        assert!(fused.est_cost <= layerwise.est_cost + 1e-12);
        assert!(fused.n_fused_layers() > 0, "expected NT layers: {}", fused.render());
    }

    #[test]
    fn fused_cost_never_worse_than_any_uniform_plan() {
        let cost = analytic(4, 1.0);
        let model = zoo::edgenet(16);
        let dpp = Dpp::new(&model, &cost).plan();
        for s in Scheme::ALL {
            let uniform = Plan::uniform(s, model.n_layers());
            let u = plan_cost(&model, &uniform, &cost).total;
            assert!(
                dpp.est_cost <= u + 1e-9,
                "DPP {} worse than uniform {s} {u}",
                dpp.est_cost
            );
        }
    }

    #[test]
    fn single_layer_model() {
        let cost = analytic(4, 5.0);
        let model = zoo::tiny_chain(1, 12, 8);
        let plan = Dpp::new(&model, &cost).plan();
        assert_eq!(plan.steps.len(), 1);
        assert_eq!(plan.steps[0].mode, Mode::T);
    }

    #[test]
    fn restricted_scheme_set_is_respected() {
        let cost = analytic(4, 1.0);
        let model = zoo::edgenet(16);
        let plan = Dpp::with_config(
            &model,
            &cost,
            DppConfig { schemes: vec![Scheme::OutC], ..Default::default() },
        )
        .plan();
        assert!(plan.steps.iter().all(|s| s.scheme == Scheme::OutC));
    }

    #[test]
    fn max_block_span_is_respected() {
        let cost = analytic(4, 0.1);
        let model = zoo::tiny_chain(8, 32, 16);
        let plan = Dpp::with_config(
            &model,
            &cost,
            DppConfig { max_block_span: 2, ..Default::default() },
        )
        .plan();
        for (s, e, _) in plan.blocks() {
            assert!(e - s + 1 <= 2);
        }
    }
}
