//! The dynamic-programming core of DPP (Algorithm 1 of the paper).
//!
//! ## DP formulation
//!
//! Let the model be `L₀ … L_{n-1}`. A plan is a partition of the chain into
//! *fused blocks* `[i..=j]` — NT at layers `i..j`, T at layer `j` — each
//! under a single scheme (cross-scheme realignment requires transmission).
//! Define
//!
//! ```text
//! after[i][q] = minimal cost of the boundary entering layer i (producer =
//!               layer i-1 partitioned under q) plus all of layers i..n-1
//! after[n][q] = cost of gathering layer n-1's tiles (scheme q) to the leader
//! ```
//!
//! with the recurrence (block `[i..=j]` under scheme `r`):
//!
//! ```text
//! after[i][q] = min over j ≥ i, r:
//!     s-Est(boundary: q → entry_need(block i..=j under r))
//!   + Σ_{l=i..j} i-Est(layer l, inflated tile under r)
//!   + after[j+1][r]
//! answer = min over j, r: s-Est(scatter) + Σ i-Est + after[j+1][r]
//! ```
//!
//! The search runs block ends `j` from `n-1` down to `0` (reverse search) and
//! extends each block backwards `i = j..0`, growing the NT-inflated tiles
//! incrementally — one receptive-field step per layer, so the whole search
//! does `O(n²k)` compute queries and `O(n²k²)` sync queries before pruning.
//!
//! ## Pruning (paper §3.3 "Piecing together")
//!
//! 1. NT-prefixed substructures are never enumerated (structural).
//! 2. `after[j+1]` memoization bounds every extension (`tail` below).
//! 3. Dynamic thresholds: a block extension whose compute-plus-tail already
//!    meets or exceeds every incumbent at its entry layer is skipped before
//!    any s-Estimator call; both rules are *sound* (they never discard an
//!    improving candidate), so pruned and unpruned searches return plans of
//!    equal cost — asserted by the Thm-1 tests.
//!
//! ## Parallel search ([`DppConfig::workers`])
//!
//! The reverse search is a wavefront DP: once every block ending at layers
//! `> j` has been priced, `after[j+1..]` is final, so the `k` per-scheme
//! block extensions of wavefront `j` are mutually independent. With
//! `workers > 1` they fan out over `std::thread::scope` workers that read a
//! shared lower-bound table (the merged `after[]`/root incumbents as atomic
//! f64 bit patterns) for pruning, and emit their candidate updates into
//! per-scheme buffers that the main thread merges **in the serial search's
//! exact order** after a wavefront barrier. Two invariants make the
//! parallel search return *the same plan, bit for bit*:
//!
//! * pruning thresholds are read only from wavefront-start state, so every
//!   pruning decision is a pure function of the (deterministic) DP state —
//!   no cross-thread timing can change which candidates are evaluated; and
//! * a candidate that would improve an entry at its merge position can
//!   never be pruned (its sync-free bound would have to both exceed the
//!   incumbent and stay below it — the same soundness argument as serial
//!   pruning), so the merged adoption sequence is identical to the serial
//!   one.
//!
//! ## Objectives ([`DppConfig::objective`])
//!
//! The same DP serves two objectives. [`Objective::Latency`] folds a
//! stage's cost into the tail with `+` (the paper's summed critical path);
//! [`Objective::Throughput`] folds with `max`, minimizing the bottleneck
//! pipeline-stage time (entry sync + block compute per block, gather as its
//! own stage) that sets the block-pipelined executor's steady-state service
//! rate. Both folds are monotone nondecreasing in the tail, so the optimal
//! substructure argument — and therefore Theorem 1, the pruning soundness
//! (each objective prunes on its own sync-free lower bound), and the
//! parallel bit-identity argument — carries over unchanged.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Barrier, Mutex};
use std::time::{Duration, Instant};

use crate::cost::memo::MemoStats;
use crate::cost::query::{boundary_query, compute_query_tiles, gather_query, scatter_query};
use crate::cost::{CostSource, Objective};
use crate::model::Model;
use crate::partition::geometry::{in_regions, out_tiles};
use crate::partition::{Mode, Plan, PlanStep, Scheme, Tile};

/// Planner configuration. The defaults reproduce the paper's FlexPie; the
/// restrictions implement baselines and ablations:
/// `enable_fusion = false` → layerwise optimization (DINA/PartialDI);
/// `schemes = [s]` with fusion → fused-layer optimization (AOFL/EdgeCI).
#[derive(Debug, Clone)]
pub struct DppConfig {
    /// Candidate schemes (the paper's `k` dimensions).
    pub schemes: Vec<Scheme>,
    /// Allow NT fusion (multi-layer blocks).
    pub enable_fusion: bool,
    /// Enable the dynamic-threshold pruning (ablation switch; pruning is
    /// sound, so plans are identical either way — only search time differs).
    pub prune: bool,
    /// Maximum fused-block span (`0` = unlimited).
    pub max_block_span: usize,
    /// Worker threads for the wavefront-parallel search: `1` = serial
    /// (default), `0` = one per available core, capped at the scheme count.
    /// Serial and parallel searches return bit-identical plans.
    pub workers: usize,
    /// What the search minimizes: summed stages (latency, the paper's
    /// objective) or the bottleneck stage (throughput of the pipelined
    /// executor). The same DP, queries, memo and workers serve both — only
    /// the fold of stage cost into tail cost changes (`+` vs `max`), which
    /// preserves optimal substructure because both folds are monotone in the
    /// tail.
    pub objective: Objective,
}

impl Default for DppConfig {
    fn default() -> Self {
        DppConfig {
            schemes: Scheme::ALL.to_vec(),
            enable_fusion: true,
            prune: true,
            max_block_span: 0,
            workers: 1,
            objective: Objective::Latency,
        }
    }
}

/// Search-effort statistics (the paper reports DPP search time; the ablation
/// bench also reports estimator-call counts with pruning on/off).
#[derive(Debug, Clone, Copy, Default)]
pub struct SearchStats {
    pub compute_queries: usize,
    pub sync_queries: usize,
    pub candidates_pruned: usize,
    pub elapsed: Duration,
    /// Worker threads the search actually ran on (1 = serial).
    pub workers: usize,
    /// Memo-cache counters for this search (all zero when the cost source
    /// is not memoized).
    pub memo: MemoStats,
}

/// A worker's output for one `(j, r)` block extension: candidate updates in
/// the serial search's emission order, plus its share of the effort stats.
#[derive(Default)]
struct TaskOut {
    compute_queries: usize,
    sync_queries: usize,
    pruned: usize,
    candidates: Vec<Cand>,
}

enum Cand {
    /// A full-chain candidate (block reaches layer 0; cost includes scatter).
    Root { total: f64 },
    /// A boundary candidate for `after[i][qi]`.
    Boundary { i: usize, qi: usize, total: f64 },
}

/// The objective's sync-free lower bound on any candidate of the current
/// block extension (sync ≥ 0 under both folds) — the dynamic-threshold
/// pruning test. Shared by the serial and parallel searches so their
/// arithmetic (and the bit-identity invariant) cannot drift.
fn fold_bound(objective: Objective, block_cost: f64, tail: f64) -> f64 {
    match objective {
        Objective::Latency => block_cost + tail,
        Objective::Throughput => block_cost.max(tail),
    }
}

/// Fold a candidate's sync cost into its DP total under the objective. The
/// latency arm keeps the `sync + (block + tail)` association order the
/// original search used, for bit-stability of `est_cost` across PRs.
fn fold_total(objective: Objective, sync: f64, block_cost: f64, tail: f64) -> f64 {
    match objective {
        Objective::Latency => sync + (block_cost + tail),
        Objective::Throughput => (sync + block_cost).max(tail),
    }
}

/// The Dynamic Partition Planner.
pub struct Dpp<'a> {
    pub model: &'a Model,
    pub cost: &'a CostSource,
    pub cfg: DppConfig,
}

impl<'a> Dpp<'a> {
    pub fn new(model: &'a Model, cost: &'a CostSource) -> Dpp<'a> {
        Dpp { model, cost, cfg: DppConfig::default() }
    }

    pub fn with_config(model: &'a Model, cost: &'a CostSource, cfg: DppConfig) -> Dpp<'a> {
        assert!(!cfg.schemes.is_empty(), "need at least one scheme");
        Dpp { model, cost, cfg }
    }

    pub fn plan(&self) -> Plan {
        self.plan_with_stats().0
    }

    pub fn plan_with_stats(&self) -> (Plan, SearchStats) {
        let t0 = Instant::now();
        let memo_before = self.cost.memo_stats();
        let workers = self.effective_workers();
        let (plan, mut stats) = if workers <= 1 {
            self.search_serial()
        } else {
            self.search_parallel(workers)
        };
        stats.workers = workers.max(1);
        stats.memo = self.cost.memo_stats().delta_since(memo_before);
        stats.elapsed = t0.elapsed();
        debug_assert!(plan.validate().is_ok(), "DPP produced invalid plan: {:?}", plan.validate());
        (plan, stats)
    }

    fn effective_workers(&self) -> usize {
        let w = if self.cfg.workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.cfg.workers
        };
        // one task per scheme exists per wavefront — more workers would idle
        w.min(self.cfg.schemes.len())
    }

    fn max_span(&self, n: usize) -> usize {
        if !self.cfg.enable_fusion {
            1
        } else if self.cfg.max_block_span == 0 {
            n
        } else {
            self.cfg.max_block_span
        }
    }

    fn search_serial(&self) -> (Plan, SearchStats) {
        let mut stats = SearchStats::default();
        let tb = self.cost.testbed();
        let nodes = tb.nodes;
        let layers = &self.model.layers;
        let n = layers.len();
        assert!(n > 0, "empty model");
        let schemes = &self.cfg.schemes;
        let k = schemes.len();

        // after[i][qi]: boundary-into-i (producer scheme q) + layers i..n-1.
        let mut after = vec![vec![f64::INFINITY; k]; n + 1];
        // worst[i] = max over q of after[i][q] — the pruning incumbent,
        // maintained incrementally on adoption instead of re-folded per
        // candidate (the inner loop runs O(n²k²) times, adoptions are rare).
        let mut worst = vec![f64::INFINITY; n + 1];
        // choice[i][qi] = (block end j, block scheme index ri)
        let mut choice = vec![vec![(usize::MAX, usize::MAX); k]; n + 1];
        let mut root = f64::INFINITY;
        let mut root_choice = (usize::MAX, usize::MAX);

        // Base case: gather of the last layer.
        for (qi, &q) in schemes.iter().enumerate() {
            let gq = gather_query(&layers[n - 1], q, tb);
            stats.sync_queries += 1;
            after[n][qi] = self.cost.sync_time(&gq);
        }

        let max_span = self.max_span(n);

        // Reverse search over block ends (Key design 1).
        for j in (0..n).rev() {
            for (ri, &r) in schemes.iter().enumerate() {
                let tail = after[j + 1][ri];
                // Tiles at the current top layer of the block (out space of
                // layer i), extended incrementally as i decreases.
                let mut cur_tiles: Vec<Tile> = out_tiles(&layers[j], r, nodes);
                let mut block_cost = 0.0f64;

                for i in (0..=j).rev() {
                    if j - i + 1 > max_span {
                        break;
                    }
                    if i < j {
                        // One backward receptive-field step (NT inflation).
                        cur_tiles = cur_tiles
                            .iter()
                            .map(|t| in_regions(&layers[i + 1], t))
                            .collect();
                    }
                    let cq = compute_query_tiles(&layers[i], &cur_tiles, r, tb);
                    stats.compute_queries += 1;
                    block_cost += self.cost.compute_time(&cq);
                    let objective = self.cfg.objective;

                    // Dynamic-threshold pruning: if the sync-free bound can
                    // no longer beat any incumbent at this entry layer, skip
                    // the (k) s-Estimator evaluations. Sound because sync ≥ 0
                    // under both folds.
                    if self.cfg.prune {
                        let worst_incumbent = if i == 0 { root } else { worst[i] };
                        if fold_bound(objective, block_cost, tail) >= worst_incumbent {
                            stats.candidates_pruned += 1;
                            continue;
                        }
                    }

                    let entry_need: Vec<Tile> =
                        cur_tiles.iter().map(|t| in_regions(&layers[i], t)).collect();

                    if i == 0 {
                        let sq = scatter_query(&layers[0], r, &entry_need, tb);
                        stats.sync_queries += 1;
                        let total =
                            fold_total(objective, self.cost.sync_time(&sq), block_cost, tail);
                        if total < root {
                            root = total;
                            root_choice = (j, ri);
                        }
                    } else {
                        for (qi, &q) in schemes.iter().enumerate() {
                            let bq = boundary_query(
                                &layers[i - 1],
                                q,
                                &layers[i],
                                r,
                                &entry_need,
                                tb,
                            );
                            stats.sync_queries += 1;
                            let total =
                                fold_total(objective, self.cost.sync_time(&bq), block_cost, tail);
                            if total < after[i][qi] {
                                after[i][qi] = total;
                                choice[i][qi] = (j, ri);
                                worst[i] = after[i]
                                    .iter()
                                    .cloned()
                                    .fold(f64::NEG_INFINITY, f64::max);
                            }
                        }
                    }
                }
            }
        }

        (self.reconstruct(&choice, root, root_choice, n), stats)
    }

    /// The wavefront-parallel search: per wavefront `j`, the `k` per-scheme
    /// block extensions run on a persistent worker pool; the main thread
    /// merges their candidates deterministically and republishes the shared
    /// incumbent table. See the module docs for the bit-identity argument.
    fn search_parallel(&self, workers: usize) -> (Plan, SearchStats) {
        let mut stats = SearchStats::default();
        let layers = &self.model.layers;
        let n = layers.len();
        assert!(n > 0, "empty model");
        let schemes = &self.cfg.schemes;
        let k = schemes.len();
        let tb = self.cost.testbed();
        let max_span = self.max_span(n);

        let inf = f64::INFINITY.to_bits();
        // Shared lower-bound table: merged after[]/root values as f64 bit
        // patterns. Written only between wavefronts (all costs ≥ 0, so the
        // bit patterns order like the floats).
        let after_bits: Vec<AtomicU64> = (0..(n + 1) * k).map(|_| AtomicU64::new(inf)).collect();
        let worst_bits: Vec<AtomicU64> = (0..n + 1).map(|_| AtomicU64::new(inf)).collect();
        let root_bits = AtomicU64::new(inf);
        let cur_j = AtomicUsize::new(usize::MAX);
        let next_task = AtomicUsize::new(0);
        let barrier = Barrier::new(workers + 1);
        let slots: Vec<Mutex<TaskOut>> = (0..k).map(|_| Mutex::new(TaskOut::default())).collect();
        // A panicking worker must still reach the wavefront barrier (or the
        // whole search deadlocks); the payload is parked here and re-raised
        // by the main thread after the workers have been released.
        let worker_panic: Mutex<Option<Box<dyn std::any::Any + Send>>> = Mutex::new(None);

        // Main-thread DP state (merge + reconstruction).
        let mut after = vec![vec![f64::INFINITY; k]; n + 1];
        let mut choice = vec![vec![(usize::MAX, usize::MAX); k]; n + 1];
        let mut root = f64::INFINITY;
        let mut root_choice = (usize::MAX, usize::MAX);

        // Base case: gather of the last layer.
        for (qi, &q) in schemes.iter().enumerate() {
            let gq = gather_query(&layers[n - 1], q, tb);
            stats.sync_queries += 1;
            let v = self.cost.sync_time(&gq);
            after[n][qi] = v;
            after_bits[n * k + qi].store(v.to_bits(), Ordering::Relaxed);
        }

        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    barrier.wait();
                    let j = cur_j.load(Ordering::Relaxed);
                    if j == usize::MAX {
                        break;
                    }
                    loop {
                        let ri = next_task.fetch_add(1, Ordering::Relaxed);
                        if ri >= k {
                            break;
                        }
                        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                            || {
                                self.extend_block(
                                    j,
                                    ri,
                                    &after_bits,
                                    &worst_bits,
                                    &root_bits,
                                    max_span,
                                )
                            },
                        ));
                        match result {
                            Ok(out) => *slots[ri].lock().unwrap() = out,
                            Err(payload) => {
                                worker_panic.lock().unwrap().get_or_insert(payload);
                            }
                        }
                    }
                    barrier.wait();
                });
            }

            let mut dirty: Vec<usize> = Vec::with_capacity(n);
            let mut is_dirty = vec![false; n + 1];
            for j in (0..n).rev() {
                next_task.store(0, Ordering::Relaxed);
                cur_j.store(j, Ordering::Relaxed);
                barrier.wait(); // release the wavefront
                barrier.wait(); // wait for every (j, r) task

                // Re-raise a worker panic (after letting the pool exit, so
                // scope's implicit join can't deadlock on the barrier).
                if let Some(payload) = worker_panic.lock().unwrap().take() {
                    cur_j.store(usize::MAX, Ordering::Relaxed);
                    barrier.wait();
                    std::panic::resume_unwind(payload);
                }

                // Deterministic merge, in the serial search's order: scheme
                // index ascending, and within a task in emission order.
                for ri in 0..k {
                    let out = std::mem::take(&mut *slots[ri].lock().unwrap());
                    stats.compute_queries += out.compute_queries;
                    stats.sync_queries += out.sync_queries;
                    stats.candidates_pruned += out.pruned;
                    for cand in out.candidates {
                        match cand {
                            Cand::Root { total } => {
                                if total < root {
                                    root = total;
                                    root_choice = (j, ri);
                                }
                            }
                            Cand::Boundary { i, qi, total } => {
                                if total < after[i][qi] {
                                    after[i][qi] = total;
                                    choice[i][qi] = (j, ri);
                                    if !is_dirty[i] {
                                        is_dirty[i] = true;
                                        dirty.push(i);
                                    }
                                }
                            }
                        }
                    }
                }
                // Republish the incumbent table for the next wavefront.
                for &i in &dirty {
                    for qi in 0..k {
                        after_bits[i * k + qi].store(after[i][qi].to_bits(), Ordering::Relaxed);
                    }
                    let w = after[i].iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                    worst_bits[i].store(w.to_bits(), Ordering::Relaxed);
                    is_dirty[i] = false;
                }
                dirty.clear();
                root_bits.store(root.to_bits(), Ordering::Relaxed);
            }
            cur_j.store(usize::MAX, Ordering::Relaxed);
            barrier.wait(); // release workers to exit
        });

        (self.reconstruct(&choice, root, root_choice, n), stats)
    }

    /// One `(j, r)` block extension against a frozen incumbent table:
    /// emits, in the serial search's order, every candidate that improves on
    /// the wavefront-start incumbents.
    fn extend_block(
        &self,
        j: usize,
        ri: usize,
        after_bits: &[AtomicU64],
        worst_bits: &[AtomicU64],
        root_bits: &AtomicU64,
        max_span: usize,
    ) -> TaskOut {
        let tb = self.cost.testbed();
        let nodes = tb.nodes;
        let layers = &self.model.layers;
        let schemes = &self.cfg.schemes;
        let k = schemes.len();
        let r = schemes[ri];
        let mut out = TaskOut::default();
        let tail = f64::from_bits(after_bits[(j + 1) * k + ri].load(Ordering::Relaxed));
        let root_start = f64::from_bits(root_bits.load(Ordering::Relaxed));
        let mut cur_tiles: Vec<Tile> = out_tiles(&layers[j], r, nodes);
        let mut block_cost = 0.0f64;

        for i in (0..=j).rev() {
            if j - i + 1 > max_span {
                break;
            }
            if i < j {
                cur_tiles = cur_tiles.iter().map(|t| in_regions(&layers[i + 1], t)).collect();
            }
            let cq = compute_query_tiles(&layers[i], &cur_tiles, r, tb);
            out.compute_queries += 1;
            block_cost += self.cost.compute_time(&cq);
            let objective = self.cfg.objective;

            if self.cfg.prune {
                let worst = if i == 0 {
                    root_start
                } else {
                    f64::from_bits(worst_bits[i].load(Ordering::Relaxed))
                };
                if fold_bound(objective, block_cost, tail) >= worst {
                    out.pruned += 1;
                    continue;
                }
            }

            let entry_need: Vec<Tile> =
                cur_tiles.iter().map(|t| in_regions(&layers[i], t)).collect();

            if i == 0 {
                let sq = scatter_query(&layers[0], r, &entry_need, tb);
                out.sync_queries += 1;
                let total = fold_total(objective, self.cost.sync_time(&sq), block_cost, tail);
                if total < root_start {
                    out.candidates.push(Cand::Root { total });
                }
            } else {
                for (qi, &q) in schemes.iter().enumerate() {
                    let bq = boundary_query(&layers[i - 1], q, &layers[i], r, &entry_need, tb);
                    out.sync_queries += 1;
                    let total = fold_total(objective, self.cost.sync_time(&bq), block_cost, tail);
                    let start = f64::from_bits(after_bits[i * k + qi].load(Ordering::Relaxed));
                    if total < start {
                        out.candidates.push(Cand::Boundary { i, qi, total });
                    }
                }
            }
        }
        out
    }

    /// Reconstruct the step sequence from the backpointers.
    fn reconstruct(
        &self,
        choice: &[Vec<(usize, usize)>],
        root: f64,
        root_choice: (usize, usize),
        n: usize,
    ) -> Plan {
        assert!(root.is_finite(), "DPP found no feasible plan");
        let schemes = &self.cfg.schemes;
        let mut steps = Vec::with_capacity(n);
        let (mut j, mut ri) = root_choice;
        let mut i = 0usize;
        loop {
            let r = schemes[ri];
            for _ in i..j {
                steps.push(PlanStep { scheme: r, mode: Mode::NT });
            }
            steps.push(PlanStep { scheme: r, mode: Mode::T });
            if j + 1 >= n {
                break;
            }
            let (nj, nri) = choice[j + 1][ri];
            debug_assert_ne!(nj, usize::MAX, "broken backpointer at layer {}", j + 1);
            i = j + 1;
            j = nj;
            ri = nri;
        }
        debug_assert_eq!(steps.len(), n);
        Plan { steps, est_cost: root }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::MemoStore;
    use crate::model::zoo;
    use crate::net::{Bandwidth, Testbed, Topology};
    use crate::planner::exhaustive::plan_cost;

    fn analytic(nodes: usize, gbps: f64) -> CostSource {
        CostSource::analytic(&Testbed::new(nodes, Topology::Ring, Bandwidth::gbps(gbps)))
    }

    #[test]
    fn plans_are_structurally_valid() {
        let cost = analytic(4, 5.0);
        for model in [zoo::edgenet(16), zoo::mobilenet_v1(224, 1000).truncated(9)] {
            let plan = Dpp::new(&model, &cost).plan();
            plan.validate().unwrap();
            assert_eq!(plan.steps.len(), model.n_layers());
            assert!(plan.est_cost.is_finite() && plan.est_cost > 0.0);
        }
    }

    #[test]
    fn est_cost_matches_independent_plan_costing() {
        // The DP's accumulated cost must equal re-costing the reconstructed
        // plan from scratch with the same cost source.
        let cost = analytic(4, 1.0);
        let model = zoo::edgenet(16);
        let plan = Dpp::new(&model, &cost).plan();
        let recost = plan_cost(&model, &plan, &cost).total;
        assert!(
            (plan.est_cost - recost).abs() < 1e-9 * plan.est_cost.max(1.0),
            "dp={} recost={}",
            plan.est_cost,
            recost
        );
    }

    #[test]
    fn pruning_preserves_optimality() {
        let cost = analytic(3, 0.5);
        let model = zoo::mobilenet_v1(224, 1000).truncated(11);
        let pruned = Dpp::with_config(
            &model,
            &cost,
            DppConfig { prune: true, ..Default::default() },
        )
        .plan();
        let unpruned = Dpp::with_config(
            &model,
            &cost,
            DppConfig { prune: false, ..Default::default() },
        )
        .plan();
        assert!((pruned.est_cost - unpruned.est_cost).abs() < 1e-12 * pruned.est_cost);
    }

    #[test]
    fn pruning_reduces_work() {
        let cost = analytic(4, 5.0);
        let model = zoo::mobilenet_v1(224, 1000);
        let (_, with) = Dpp::with_config(
            &model,
            &cost,
            DppConfig { prune: true, ..Default::default() },
        )
        .plan_with_stats();
        let (_, without) = Dpp::with_config(
            &model,
            &cost,
            DppConfig { prune: false, ..Default::default() },
        )
        .plan_with_stats();
        assert!(with.sync_queries < without.sync_queries);
        assert!(with.candidates_pruned > 0);
    }

    #[test]
    fn parallel_search_matches_serial_bit_for_bit() {
        // the tentpole invariant: wavefront-parallel search returns the
        // serial search's plan, bit for bit, for any worker count
        for (nodes, gbps) in [(4usize, 0.5f64), (3, 5.0)] {
            let cost = analytic(nodes, gbps);
            for model in [zoo::edgenet(16), zoo::mobilenet_v1(224, 1000).truncated(10)] {
                let serial = Dpp::with_config(
                    &model,
                    &cost,
                    DppConfig { workers: 1, ..Default::default() },
                )
                .plan();
                for workers in [2usize, 4, 0] {
                    let par = Dpp::with_config(
                        &model,
                        &cost,
                        DppConfig { workers, ..Default::default() },
                    )
                    .plan();
                    assert_eq!(
                        par.est_cost.to_bits(),
                        serial.est_cost.to_bits(),
                        "{} w={workers}: {} vs {}",
                        model.name,
                        par.est_cost,
                        serial.est_cost
                    );
                    assert_eq!(par.steps, serial.steps, "{} w={workers}", model.name);
                }
            }
        }
    }

    #[test]
    fn parallel_unpruned_also_matches_serial() {
        let cost = analytic(4, 1.0);
        let model = zoo::edgenet(16);
        let serial = Dpp::with_config(
            &model,
            &cost,
            DppConfig { prune: false, workers: 1, ..Default::default() },
        )
        .plan();
        let par = Dpp::with_config(
            &model,
            &cost,
            DppConfig { prune: false, workers: 4, ..Default::default() },
        )
        .plan();
        assert_eq!(par.est_cost.to_bits(), serial.est_cost.to_bits());
        assert_eq!(par.steps, serial.steps);
    }

    #[test]
    fn memoized_search_is_bit_identical_and_warm_on_repeat() {
        let tb = Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0));
        let plain = CostSource::analytic(&tb);
        let store = MemoStore::shared();
        let memo = plain.clone().memoized(&store);
        let model = zoo::edgenet(16);
        let (p0, s0) = Dpp::new(&model, &plain).plan_with_stats();
        assert_eq!(s0.memo, Default::default(), "unmemoized source reports no memo stats");
        let (p1, s1) = Dpp::new(&model, &memo).plan_with_stats();
        assert_eq!(p1.est_cost.to_bits(), p0.est_cost.to_bits());
        assert_eq!(p1.steps, p0.steps);
        assert!(s1.memo.sync_misses > 0, "first search fills the cache: {}", s1.memo);
        // an identical search replays the exact query sequence: fully warm
        let (p2, s2) = Dpp::new(&model, &memo).plan_with_stats();
        assert_eq!(p2, p1);
        assert_eq!(s2.memo.sync_misses, 0, "repeat search must be warm: {}", s2.memo);
        assert_eq!(s2.memo.compute_misses, 0, "repeat search must be warm: {}", s2.memo);
        assert!(s2.memo.sync_hits > 0 && s2.memo.compute_hits > 0);
    }

    #[test]
    fn parallel_memoized_matches_serial_unmemoized() {
        let tb = Testbed::new(4, Topology::Ps, Bandwidth::gbps(0.5));
        let plain = CostSource::analytic(&tb);
        let store = MemoStore::shared();
        let memo = plain.clone().memoized(&store);
        let model = zoo::mobilenet_v1(224, 1000).truncated(8);
        let serial = Dpp::new(&model, &plain).plan();
        let par = Dpp::with_config(
            &model,
            &memo,
            DppConfig { workers: 4, ..Default::default() },
        )
        .plan();
        assert_eq!(par.est_cost.to_bits(), serial.est_cost.to_bits());
        assert_eq!(par.steps, serial.steps);
    }

    #[test]
    fn throughput_objective_matches_exhaustive_bottleneck() {
        // Theorem 1 under the bottleneck fold: the DP's throughput plan must
        // tie the brute-force minimum over every legal plan.
        use crate::cost::Objective;
        use crate::planner::exhaustive::{bottleneck_cost, exhaustive_plan_with};
        for (nodes, gbps) in [(4usize, 5.0f64), (3, 0.5)] {
            let cost = analytic(nodes, gbps);
            for model in [zoo::tiny_chain(4, 12, 8), zoo::edgenet(16).truncated(5)] {
                let dpp = Dpp::with_config(
                    &model,
                    &cost,
                    DppConfig { objective: Objective::Throughput, ..Default::default() },
                )
                .plan();
                let brute = exhaustive_plan_with(
                    &model,
                    &cost,
                    &Scheme::ALL,
                    Objective::Throughput,
                );
                let dpp_bn = bottleneck_cost(&model, &dpp, &cost);
                let tol = 1e-9 * brute.est_cost.max(1e-12);
                assert!(
                    (dpp_bn - brute.est_cost).abs() <= tol,
                    "{} n={nodes} bw={gbps}: DPP {} ({}) vs exhaustive {} ({})",
                    model.name,
                    dpp_bn,
                    dpp.render(),
                    brute.est_cost,
                    brute.render()
                );
                // the DP's own estimate equals the independent re-costing
                assert!((dpp.est_cost - dpp_bn).abs() <= tol);
            }
        }
    }

    #[test]
    fn throughput_objective_is_parallel_and_prune_transparent() {
        use crate::cost::Objective;
        let cost = analytic(4, 0.5);
        let model = zoo::edgenet(16);
        let serial = Dpp::with_config(
            &model,
            &cost,
            DppConfig { objective: Objective::Throughput, workers: 1, ..Default::default() },
        )
        .plan();
        for (workers, prune) in [(4usize, true), (4, false), (1, false)] {
            let other = Dpp::with_config(
                &model,
                &cost,
                DppConfig {
                    objective: Objective::Throughput,
                    workers,
                    prune,
                    ..Default::default()
                },
            )
            .plan();
            assert_eq!(
                other.est_cost.to_bits(),
                serial.est_cost.to_bits(),
                "w={workers} prune={prune}"
            );
            assert_eq!(other.steps, serial.steps, "w={workers} prune={prune}");
        }
    }

    #[test]
    fn throughput_plan_bottleneck_never_worse_than_latency_plan() {
        use crate::cost::Objective;
        use crate::planner::exhaustive::bottleneck_cost;
        let cost = analytic(4, 1.0);
        let model = zoo::edgenet(16);
        let lat = Dpp::new(&model, &cost).plan();
        let thr = Dpp::with_config(
            &model,
            &cost,
            DppConfig { objective: Objective::Throughput, ..Default::default() },
        )
        .plan();
        let lat_bn = bottleneck_cost(&model, &lat, &cost);
        assert!(
            thr.est_cost <= lat_bn + 1e-12 * lat_bn,
            "throughput plan bottleneck {} worse than latency plan's {}",
            thr.est_cost,
            lat_bn
        );
        // and the latency plan stays (weakly) ahead on end-to-end latency
        assert!(lat.est_cost <= plan_cost(&model, &thr, &cost).total + 1e-9 * lat.est_cost);
    }

    #[test]
    fn fusion_beats_no_fusion_at_low_bandwidth() {
        // With a slow interconnect, NT fusion should pay off on the early
        // (sync-heavy) layers, so the fused planner strictly improves on the
        // layerwise-restricted one.
        let cost = analytic(4, 0.1);
        let model = zoo::mobilenet_v1(224, 1000).truncated(9);
        let fused = Dpp::new(&model, &cost).plan();
        let layerwise = Dpp::with_config(
            &model,
            &cost,
            DppConfig { enable_fusion: false, ..Default::default() },
        )
        .plan();
        assert!(fused.est_cost <= layerwise.est_cost + 1e-12);
        assert!(fused.n_fused_layers() > 0, "expected NT layers: {}", fused.render());
    }

    #[test]
    fn fused_cost_never_worse_than_any_uniform_plan() {
        let cost = analytic(4, 1.0);
        let model = zoo::edgenet(16);
        let dpp = Dpp::new(&model, &cost).plan();
        for s in Scheme::ALL {
            let uniform = Plan::uniform(s, model.n_layers());
            let u = plan_cost(&model, &uniform, &cost).total;
            assert!(dpp.est_cost <= u + 1e-9, "DPP {} worse than uniform {s} {u}", dpp.est_cost);
        }
    }

    #[test]
    fn single_layer_model() {
        let cost = analytic(4, 5.0);
        let model = zoo::tiny_chain(1, 12, 8);
        let plan = Dpp::new(&model, &cost).plan();
        assert_eq!(plan.steps.len(), 1);
        assert_eq!(plan.steps[0].mode, Mode::T);
        // the degenerate chain is also parallel-safe
        let par = Dpp::with_config(
            &model,
            &cost,
            DppConfig { workers: 4, ..Default::default() },
        )
        .plan();
        assert_eq!(par, plan);
    }

    #[test]
    fn restricted_scheme_set_is_respected() {
        let cost = analytic(4, 1.0);
        let model = zoo::edgenet(16);
        let plan = Dpp::with_config(
            &model,
            &cost,
            DppConfig { schemes: vec![Scheme::OutC], ..Default::default() },
        )
        .plan();
        assert!(plan.steps.iter().all(|s| s.scheme == Scheme::OutC));
    }

    #[test]
    fn max_block_span_is_respected() {
        let cost = analytic(4, 0.1);
        let model = zoo::tiny_chain(8, 32, 16);
        let plan = Dpp::with_config(
            &model,
            &cost,
            DppConfig { max_block_span: 2, ..Default::default() },
        )
        .plan();
        for (s, e, _) in plan.blocks() {
            assert!(e - s + 1 <= 2);
        }
    }
}
