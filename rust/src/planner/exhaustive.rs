//! Exhaustive search + independent plan costing — the Theorem 1 reference.
//!
//! [`exhaustive_plan`] enumerates *every* legal plan (all block compositions
//! of the layer chain × all scheme assignments per block) and costs each via
//! [`plan_cost`]; Theorem 1 says DPP must return a plan of equal cost when
//! both consult the same cost oracle. The enumeration is
//! `Σ_compositions k^#blocks = k(k+1)^{n-1}` plans, so tests keep `n ≤ 8`.
//!
//! [`plan_cost`] is also the canonical "re-cost a finished plan" routine
//! used by the evaluation engine and the baselines: scatter + per-block
//! inflated compute + inter-block boundaries + final gather, all through the
//! exact same query builders the DP uses.

use crate::cost::query::{boundary_query, compute_query, gather_query, scatter_query};
use crate::cost::{CostSource, Objective};
use crate::model::Model;
use crate::partition::inflate::BlockGeometry;
use crate::partition::{Mode, Plan, PlanStep, Scheme};

/// Cost breakdown of one plan under one cost source.
#[derive(Debug, Clone, Default)]
pub struct PlanCost {
    pub total: f64,
    pub compute: f64,
    pub sync: f64,
    /// Per-layer compute seconds (plan order).
    pub per_layer_compute: Vec<f64>,
    /// Per-boundary sync seconds: scatter, inter-block boundaries, gather.
    pub per_boundary_sync: Vec<f64>,
    /// Total bytes moved across all boundaries.
    pub bytes_moved: u64,
}

/// Cost a complete plan: the sum the DP minimizes, recomputed independently.
pub fn plan_cost(model: &Model, plan: &Plan, cost: &CostSource) -> PlanCost {
    plan.validate().expect("invalid plan");
    assert_eq!(plan.steps.len(), model.n_layers());
    let tb = cost.testbed();
    let layers = &model.layers;
    let n = layers.len();
    let blocks = plan.blocks();
    let mut out = PlanCost { per_layer_compute: vec![0.0; n], ..Default::default() };

    // Geometry per block (needed before boundaries: the *consumer's*
    // entry requirement prices each boundary).
    let geos: Vec<BlockGeometry> = blocks
        .iter()
        .map(|&(s, e, scheme)| BlockGeometry::new(&layers[s..=e], scheme, tb.nodes))
        .collect();

    // Scatter into the first block.
    {
        let (s, _, scheme) = blocks[0];
        let q = scatter_query(&layers[s], scheme, &geos[0].entry_need, tb);
        let t = cost.sync_time(&q);
        out.bytes_moved += q.total_bytes();
        out.per_boundary_sync.push(t);
        out.sync += t;
    }

    for (bi, &(s, e, scheme)) in blocks.iter().enumerate() {
        // Block compute (inflated tiles).
        for l in s..=e {
            let cq = compute_query(&layers[s..=e], &geos[bi], l - s, tb);
            let t = cost.compute_time(&cq);
            out.per_layer_compute[l] = t;
            out.compute += t;
        }
        // Boundary out of this block.
        let t = if e == n - 1 {
            let gq = gather_query(&layers[n - 1], scheme, tb);
            out.bytes_moved += gq.total_bytes();
            cost.sync_time(&gq)
        } else {
            let (ns, _, nscheme) = blocks[bi + 1];
            let bq = boundary_query(
                &layers[e],
                scheme,
                &layers[ns],
                nscheme,
                &geos[bi + 1].entry_need,
                tb,
            );
            out.bytes_moved += bq.total_bytes();
            cost.sync_time(&bq)
        };
        out.per_boundary_sync.push(t);
        out.sync += t;
    }

    out.total = out.compute + out.sync;
    out
}

/// Per-pipeline-stage seconds of `plan`: one entry per fused block (the
/// block's entry synchronization — scatter for block 0, a realignment
/// boundary otherwise — plus its layer compute), then the final gather as
/// its own stage. The sum is [`plan_cost`]'s `total` up to float
/// associativity; the max is the bottleneck the pipelined executor's
/// steady-state throughput is set by.
///
/// Boundary transfers are attributed to the *consuming* stage: a producer
/// hands its patches to the interconnect and proceeds to its next item
/// (asynchronous sends), so a stage's virtual time is "wait for the entry
/// boundary, then compute". This is also the attribution the DP's state
/// space supports — an entry boundary depends only on the previous block's
/// scheme (the `after[i][q]` state), whereas an exit boundary would depend
/// on the *next* block choice. The host executor's wall-clock occupancy
/// ([`crate::cluster::pipeline::PipelineStats`]) attributes patch
/// *assembly* to the producing stage thread instead, so the measured
/// bottleneck stage can sit one stage ahead of the virtual prediction when
/// exchange assembly rivals compute.
pub fn stage_costs(model: &Model, plan: &Plan, cost: &CostSource) -> Vec<f64> {
    stage_costs_from(plan, &plan_cost(model, plan, cost))
}

/// [`stage_costs`] from an already-computed [`PlanCost`] of the same plan —
/// callers that need both the total and the stage decomposition cost the
/// plan once.
pub fn stage_costs_from(plan: &Plan, pc: &PlanCost) -> Vec<f64> {
    let blocks = plan.blocks();
    let mut out = Vec::with_capacity(blocks.len() + 1);
    for (bi, &(s, e, _)) in blocks.iter().enumerate() {
        let mut t = pc.per_boundary_sync[bi];
        for l in s..=e {
            t += pc.per_layer_compute[l];
        }
        out.push(t);
    }
    out.push(*pc.per_boundary_sync.last().expect("plan has a gather boundary"));
    out
}

/// The bottleneck (max) pipeline-stage time of `plan` — what
/// [`Objective::Throughput`] minimizes.
pub fn bottleneck_cost(model: &Model, plan: &Plan, cost: &CostSource) -> f64 {
    stage_costs(model, plan, cost).into_iter().fold(f64::NEG_INFINITY, f64::max)
}

/// Cost a plan under either objective: summed stages for latency (exactly
/// [`plan_cost`]'s `total`), bottleneck stage for throughput.
pub fn objective_cost(
    model: &Model,
    plan: &Plan,
    cost: &CostSource,
    objective: Objective,
) -> f64 {
    match objective {
        Objective::Latency => plan_cost(model, plan, cost).total,
        Objective::Throughput => bottleneck_cost(model, plan, cost),
    }
}

/// Enumerate every legal plan and return the cheapest. `schemes` restricts
/// the per-block scheme choices (defaults to all four).
pub fn exhaustive_plan(model: &Model, cost: &CostSource, schemes: &[Scheme]) -> Plan {
    exhaustive_plan_with(model, cost, schemes, Objective::Latency)
}

/// [`exhaustive_plan`] under an explicit [`Objective`] — the brute-force
/// reference for the throughput (bottleneck) optimality tests.
pub fn exhaustive_plan_with(
    model: &Model,
    cost: &CostSource,
    schemes: &[Scheme],
    objective: Objective,
) -> Plan {
    let n = model.n_layers();
    assert!(n >= 1);
    assert!(
        n <= 12,
        "exhaustive search is k(k+1)^(n-1) plans; refusing n = {n} (cap 12)"
    );
    let mut best: Option<Plan> = None;
    let mut steps: Vec<PlanStep> = Vec::with_capacity(n);
    enumerate(model, cost, schemes, objective, 0, &mut steps, &mut best);
    best.expect("no plan found")
}

fn enumerate(
    model: &Model,
    cost: &CostSource,
    schemes: &[Scheme],
    objective: Objective,
    start: usize,
    steps: &mut Vec<PlanStep>,
    best: &mut Option<Plan>,
) {
    let n = model.n_layers();
    if start == n {
        let mut plan = Plan { steps: steps.clone(), est_cost: f64::NAN };
        let c = objective_cost(model, &plan, cost, objective);
        plan.est_cost = c;
        if best.as_ref().map(|b| c < b.est_cost).unwrap_or(true) {
            *best = Some(plan);
        }
        return;
    }
    for end in start..n {
        for &scheme in schemes {
            for _ in start..end {
                steps.push(PlanStep { scheme, mode: Mode::NT });
            }
            steps.push(PlanStep { scheme, mode: Mode::T });
            enumerate(model, cost, schemes, objective, end + 1, steps, best);
            steps.truncate(start);
        }
    }
}

/// Count the number of plans the exhaustive search visits (diagnostics for
/// the search-space figures): `k·(k+1)^{n-1}`.
pub fn search_space_size(n_layers: usize, k: usize) -> f64 {
    k as f64 * ((k + 1) as f64).powi(n_layers as i32 - 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::net::{Bandwidth, Testbed, Topology};

    fn analytic(nodes: usize, gbps: f64) -> CostSource {
        CostSource::analytic(&Testbed::new(nodes, Topology::Ring, Bandwidth::gbps(gbps)))
    }

    #[test]
    fn plan_cost_uniform_edge_cases() {
        let cost = analytic(4, 5.0);
        let model = zoo::tiny_chain(3, 12, 8);
        let plan = Plan::uniform(Scheme::InH, 3);
        let pc = plan_cost(&model, &plan, &cost);
        assert!(pc.total > 0.0);
        assert_eq!(pc.per_layer_compute.len(), 3);
        // scatter + 2 inter-layer boundaries + gather
        assert_eq!(pc.per_boundary_sync.len(), 4);
        assert!((pc.total - pc.compute - pc.sync).abs() < 1e-15);
    }

    #[test]
    fn exhaustive_small_model_beats_uniform() {
        let cost = analytic(3, 1.0);
        let model = zoo::tiny_chain(4, 12, 8);
        let ex = exhaustive_plan(&model, &cost, &Scheme::ALL);
        for s in Scheme::ALL {
            let u = plan_cost(&model, &Plan::uniform(s, 4), &cost).total;
            assert!(ex.est_cost <= u + 1e-12);
        }
    }

    #[test]
    fn stage_costs_sum_to_total_and_bound_bottleneck() {
        let cost = analytic(4, 1.0);
        let model = zoo::tiny_chain(4, 12, 8);
        let plan = Plan::uniform(Scheme::InH, 4);
        let pc = plan_cost(&model, &plan, &cost);
        let stages = stage_costs(&model, &plan, &cost);
        // 4 all-T blocks + the gather stage
        assert_eq!(stages.len(), 5);
        let sum: f64 = stages.iter().sum();
        assert!((sum - pc.total).abs() < 1e-12 * pc.total);
        let bn = bottleneck_cost(&model, &plan, &cost);
        assert!(stages.iter().all(|&s| s <= bn));
        assert!(bn < pc.total, "a multi-stage plan's bottleneck is below its sum");
        assert_eq!(objective_cost(&model, &plan, &cost, Objective::Throughput), bn);
        assert_eq!(objective_cost(&model, &plan, &cost, Objective::Latency), pc.total);
    }

    #[test]
    fn exhaustive_throughput_never_worse_on_bottleneck() {
        // the throughput-objective brute force must (weakly) beat the
        // latency-objective winner on the bottleneck metric
        let cost = analytic(3, 0.5);
        let model = zoo::tiny_chain(4, 12, 8);
        let lat = exhaustive_plan(&model, &cost, &Scheme::ALL);
        let thr = exhaustive_plan_with(&model, &cost, &Scheme::ALL, Objective::Throughput);
        let lat_bn = bottleneck_cost(&model, &lat, &cost);
        assert!(thr.est_cost <= lat_bn + 1e-12 * lat_bn);
    }

    #[test]
    fn search_space_size_formula() {
        assert_eq!(search_space_size(1, 4), 4.0);
        assert_eq!(search_space_size(2, 4), 20.0);
        // n layers, k=4: 4·5^(n-1)
        assert_eq!(search_space_size(4, 4), 4.0 * 125.0);
    }

    #[test]
    #[should_panic(expected = "refusing")]
    fn exhaustive_refuses_large_models() {
        let cost = analytic(3, 1.0);
        let model = zoo::mobilenet_v1(224, 1000);
        let _ = exhaustive_plan(&model, &cost, &Scheme::ALL);
    }
}
