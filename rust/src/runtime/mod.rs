//! AOT-artifact runtime — loads and executes the compiled JAX/Pallas menu.
//!
//! `make artifacts` runs `python/compile/aot.py` once at build time, lowering
//! each (op, shape) in the artifact menu to **HLO text** (jax ≥ 0.5 emits
//! serialized protos with 64-bit ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids) and writing `artifacts/manifest.json`. This
//! module loads that manifest and exposes `execute_layer` to the engine.
//!
//! Two backends implement the execution:
//!
//! * **`pjrt` feature** ([`pjrt`]) — the real path: compiles the HLO text on
//!   the PJRT CPU client (vendored `xla` crate) and runs the Pallas-lowered
//!   kernel. Requires the vendored dependency closure, so it is
//!   off-by-default in the offline build.
//! * **default** — a native fallback that answers the same manifest queries
//!   and executes the layer with [`crate::compute`]'s kernels (which the
//!   PJRT path is validated against to float tolerance anyway). This keeps
//!   every downstream consumer — the e2e example, the robustness tests —
//!   compiling and behaving identically in dependency-free builds.
//!
//! Python never runs at inference time — the artifacts directory is the only
//! interface between the layers.

use std::collections::HashMap;
use std::path::Path;

use crate::model::{ConvType, LayerMeta};
use crate::util::json::Json;

#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::Runtime;

/// Runtime error (offline replacement for `anyhow::Error`): a message chain
/// rendered by `Display`, matching what the tests grep for.
#[derive(Debug)]
pub struct RuntimeError(pub String);

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

impl From<std::io::Error> for RuntimeError {
    fn from(e: std::io::Error) -> Self {
        RuntimeError(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, RuntimeError>;

pub(crate) fn err(msg: impl Into<String>) -> RuntimeError {
    RuntimeError(msg.into())
}

/// Shape signature of a layer computation — must match the naming scheme in
/// `python/compile/aot.py` exactly.
pub fn signature(layer: &LayerMeta, in_h: i64, in_w: i64) -> String {
    let op = match layer.conv_t {
        ConvType::Standard => "conv2d",
        ConvType::Depthwise => "dwconv",
        ConvType::Pointwise => "conv2d",
        ConvType::Dense | ConvType::Attention => "dense",
        ConvType::Pool => "avgpool",
    };
    let relu = if layer.fused_activation { "_relu" } else { "" };
    match layer.conv_t {
        ConvType::Dense | ConvType::Attention => {
            format!("{op}_m{}_k{}_n{}{relu}", layer.out_h, layer.in_c, layer.out_c)
        }
        _ => format!(
            "{op}_ih{in_h}_iw{in_w}_ic{}_oc{}_k{}_s{}_p{}{relu}",
            layer.in_c, layer.out_c, layer.k, layer.s, layer.p
        ),
    }
}

/// The artifact manifest: signature → HLO file name.
#[derive(Debug, Default)]
pub struct Manifest {
    pub entries: HashMap<String, String>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let v = Json::load(&path)
            .map_err(|e| err(format!("loading {}: {e}", path.display())))?;
        let obj = v
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| err("manifest missing 'artifacts' object"))?;
        let mut entries = HashMap::new();
        for (k, val) in obj {
            entries.insert(
                k.clone(),
                val.as_str()
                    .ok_or_else(|| err(format!("bad manifest entry {k}")))?
                    .to_string(),
            );
        }
        Ok(Manifest { entries })
    }
}

/// Native-fallback runtime: manifest-driven like the PJRT backend, but layer
/// execution goes through [`crate::compute`]. Signatures absent from the
/// manifest — and manifest entries whose artifact file is missing — error
/// exactly like the real backend, so artifact-coverage and corruption logic
/// upstream behaves the same.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    manifest: Manifest,
    dir: std::path::PathBuf,
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Load the runtime from an artifacts directory (errors if the manifest
    /// is absent — run `make artifacts`).
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        Ok(Runtime { manifest, dir: dir.to_path_buf() })
    }

    pub fn platform(&self) -> String {
        "cpu".to_string()
    }

    pub fn has(&self, sig: &str) -> bool {
        self.manifest.entries.contains_key(sig)
    }

    pub fn n_artifacts(&self) -> usize {
        self.manifest.entries.len()
    }

    /// Execute one layer. `input` must be the full input window in HWC
    /// layout matching the signature's `in_h × in_w`; weights/bias use the
    /// same layout as [`crate::compute::LayerWeights`].
    pub fn execute_layer(
        &self,
        layer: &LayerMeta,
        weights: &crate::compute::LayerWeights,
        input: &crate::compute::Tensor,
    ) -> Result<crate::compute::Tensor> {
        let sig = signature(layer, input.h, input.w);
        let file = self
            .manifest
            .entries
            .get(&sig)
            .ok_or_else(|| err(format!("no artifact for signature {sig}")))?;
        // Mirror the PJRT backend's errors-at-use contract: a manifest entry
        // whose artifact file is gone is corruption, even though the native
        // kernels don't read the HLO text.
        let path = self.dir.join(file);
        if !path.is_file() {
            return Err(err(format!("missing artifact file {}", path.display())));
        }
        if input.h != layer.in_h || input.w != layer.in_w || input.c != layer.in_c {
            return Err(err(format!(
                "native fallback only executes full-layer windows \
                 (got {}x{}x{}, layer wants {}x{}x{})",
                input.h, input.w, input.c, layer.in_h, layer.in_w, layer.in_c
            )));
        }
        use crate::compute::{compute_region, PatchStore, RegionTensor};
        use crate::partition::Region;
        let mut store = PatchStore::new();
        store.add(RegionTensor::new(
            Region::full(layer.in_h, layer.in_w, layer.in_c),
            input.clone(),
        ));
        let out = compute_region(
            layer,
            weights,
            &store,
            &Region::full(layer.out_h, layer.out_w, layer.out_c),
        );
        Ok(out.t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv(h: i64) -> LayerMeta {
        LayerMeta::conv("c", ConvType::Standard, h, h, 3, 8, 3, 1, 1)
    }

    #[test]
    fn signatures_are_stable() {
        let l = conv(16);
        assert_eq!(signature(&l, 16, 16), "conv2d_ih16_iw16_ic3_oc8_k3_s1_p1");
        let d = LayerMeta::dense("fc", 1, 32, 10);
        assert_eq!(signature(&d, 1, 1), "dense_m1_k32_n10");
        let mut r = conv(16);
        r.fused_activation = true;
        assert!(signature(&r, 16, 16).ends_with("_relu"));
    }

    #[test]
    fn manifest_parse() {
        let dir = crate::util::tmp::TempDir::new("manifest");
        std::fs::write(
            dir.path().join("manifest.json"),
            r#"{"artifacts": {"conv2d_ih16_iw16_ic3_oc8_k3_s1_p1": "conv0.hlo.txt"}, "generated_by": "aot.py"}"#,
        )
        .unwrap();
        let m = Manifest::load(dir.path()).unwrap();
        assert_eq!(m.entries.len(), 1);
        assert_eq!(
            m.entries["conv2d_ih16_iw16_ic3_oc8_k3_s1_p1"],
            "conv0.hlo.txt"
        );
    }

    #[test]
    fn missing_manifest_is_error() {
        let dir = crate::util::tmp::TempDir::new("nomanifest");
        assert!(Runtime::load(dir.path()).is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn fallback_executes_covered_layer_natively() {
        use crate::compute::{run_reference, Tensor, WeightStore};
        use crate::model::zoo;
        let model = zoo::edgenet(16);
        let dir = crate::util::tmp::TempDir::new("fallback");
        // manifest covering every layer of the chain
        let mut entries = String::new();
        for l in &model.layers {
            let sig = signature(l, l.in_h, l.in_w);
            if !entries.is_empty() {
                entries.push(',');
            }
            entries.push_str(&format!(r#""{sig}": "{sig}.hlo.txt""#));
            // the fallback checks the artifact file exists (errors-at-use)
            std::fs::write(dir.path().join(format!("{sig}.hlo.txt")), "stub").unwrap();
        }
        std::fs::write(
            dir.path().join("manifest.json"),
            format!(r#"{{"artifacts": {{{entries}}}}}"#),
        )
        .unwrap();
        let rt = Runtime::load(dir.path()).unwrap();
        assert!(rt.n_artifacts() >= model.n_layers() - 1); // dup sigs collapse
        let ws = WeightStore::for_model(&model, 7);
        let input = Tensor::random(16, 16, 3, 3);
        let reference = run_reference(&model, &ws, &input);
        let mut cur = input;
        for (i, layer) in model.layers.iter().enumerate() {
            cur = rt.execute_layer(layer, &ws.layers[i], &cur).unwrap();
        }
        assert_eq!(reference.max_abs_diff(&cur), 0.0);
        // absent signature errors cleanly
        let odd = conv(17);
        let e = rt
            .execute_layer(&odd, &ws.layers[0], &Tensor::zeros(17, 17, 3))
            .unwrap_err();
        assert!(e.to_string().contains("no artifact"), "{e}");
    }
}
