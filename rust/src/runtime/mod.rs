//! PJRT runtime — loads and executes the AOT-compiled JAX/Pallas artifacts.
//!
//! `make artifacts` runs `python/compile/aot.py` once at build time, lowering
//! each (op, shape) in the artifact menu to **HLO text** (jax ≥ 0.5 emits
//! serialized protos with 64-bit ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids) and writing `artifacts/manifest.json`. This
//! module loads that manifest, compiles executables on the PJRT CPU client
//! lazily, and exposes `execute_layer` to the engine: when a layer's exact
//! shape signature is present, the JAX/Pallas version runs; otherwise the
//! engine falls back to [`crate::compute`] (and tests assert both paths
//! agree to float tolerance).
//!
//! Python never runs at inference time — the artifacts directory is the only
//! interface between the layers.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

use crate::compute::Tensor;
use crate::model::{ConvType, LayerMeta};
use crate::util::json::Json;

/// Shape signature of a layer computation — must match the naming scheme in
/// `python/compile/aot.py` exactly.
pub fn signature(layer: &LayerMeta, in_h: i64, in_w: i64) -> String {
    let op = match layer.conv_t {
        ConvType::Standard => "conv2d",
        ConvType::Depthwise => "dwconv",
        ConvType::Pointwise => "conv2d",
        ConvType::Dense | ConvType::Attention => "dense",
        ConvType::Pool => "avgpool",
    };
    let relu = if layer.fused_activation { "_relu" } else { "" };
    match layer.conv_t {
        ConvType::Dense | ConvType::Attention => {
            format!("{op}_m{}_k{}_n{}{relu}", layer.out_h, layer.in_c, layer.out_c)
        }
        _ => format!(
            "{op}_ih{in_h}_iw{in_w}_ic{}_oc{}_k{}_s{}_p{}{relu}",
            layer.in_c, layer.out_c, layer.k, layer.s, layer.p
        ),
    }
}

/// The artifact manifest: signature → HLO file name.
#[derive(Debug, Default)]
pub struct Manifest {
    pub entries: HashMap<String, String>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let v = Json::load(&path).with_context(|| format!("loading {}", path.display()))?;
        let obj = v
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing 'artifacts' object"))?;
        let mut entries = HashMap::new();
        for (k, val) in obj {
            entries.insert(
                k.clone(),
                val.as_str().ok_or_else(|| anyhow!("bad manifest entry {k}"))?.to_string(),
            );
        }
        Ok(Manifest { entries })
    }
}

/// The PJRT runtime: CPU client + lazily compiled executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Load the runtime from an artifacts directory (errors if the manifest
    /// is absent — run `make artifacts`).
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        Ok(Runtime { client, manifest, dir: dir.to_path_buf(), cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn has(&self, sig: &str) -> bool {
        self.manifest.entries.contains_key(sig)
    }

    pub fn n_artifacts(&self) -> usize {
        self.manifest.entries.len()
    }

    fn executable(&self, sig: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(sig) {
            return Ok(e.clone());
        }
        let file = self
            .manifest
            .entries
            .get(sig)
            .ok_or_else(|| anyhow!("no artifact for signature {sig}"))?;
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compile {sig}: {e:?}"))?;
        let exe = std::sync::Arc::new(exe);
        self.cache.lock().unwrap().insert(sig.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute one layer via its AOT artifact. `input` must be the full
    /// (padded-to-valid) input window in HWC layout matching the signature's
    /// `in_h × in_w`; weights/bias use the same layout as
    /// [`crate::compute::LayerWeights`].
    pub fn execute_layer(
        &self,
        layer: &LayerMeta,
        weights: &crate::compute::LayerWeights,
        input: &Tensor,
    ) -> Result<Tensor> {
        let sig = signature(layer, input.h, input.w);
        let exe = self.executable(&sig)?;

        let in_lit = xla::Literal::vec1(&input.data)
            .reshape(&[input.h, input.w, input.c])
            .map_err(|e| anyhow!("reshape input: {e:?}"))?;
        let args: Vec<xla::Literal> = match layer.conv_t {
            ConvType::Pool => vec![in_lit],
            ConvType::Depthwise => {
                let w = xla::Literal::vec1(&weights.w)
                    .reshape(&[layer.k, layer.k, layer.out_c])
                    .map_err(|e| anyhow!("reshape w: {e:?}"))?;
                let b = xla::Literal::vec1(&weights.b);
                vec![in_lit, w, b]
            }
            ConvType::Dense | ConvType::Attention => {
                let w = xla::Literal::vec1(&weights.w)
                    .reshape(&[layer.in_c, layer.out_c])
                    .map_err(|e| anyhow!("reshape w: {e:?}"))?;
                let b = xla::Literal::vec1(&weights.b);
                vec![in_lit, w, b]
            }
            _ => {
                let w = xla::Literal::vec1(&weights.w)
                    .reshape(&[layer.k, layer.k, layer.in_c, layer.out_c])
                    .map_err(|e| anyhow!("reshape w: {e:?}"))?;
                let b = xla::Literal::vec1(&weights.b);
                vec![in_lit, w, b]
            }
        };

        let result = exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow!("execute {sig}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let out = result.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let data = out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;

        let (oh, ow, oc) = (layer.out_h, layer.out_w, layer.out_c);
        if data.len() != (oh * ow * oc) as usize {
            return Err(anyhow!(
                "artifact {sig} returned {} elements, expected {}",
                data.len(),
                oh * ow * oc
            ));
        }
        Ok(Tensor { h: oh, w: ow, c: oc, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv(h: i64) -> LayerMeta {
        LayerMeta::conv("c", ConvType::Standard, h, h, 3, 8, 3, 1, 1)
    }

    #[test]
    fn signatures_are_stable() {
        let l = conv(16);
        assert_eq!(signature(&l, 16, 16), "conv2d_ih16_iw16_ic3_oc8_k3_s1_p1");
        let d = LayerMeta::dense("fc", 1, 32, 10);
        assert_eq!(signature(&d, 1, 1), "dense_m1_k32_n10");
        let mut r = conv(16);
        r.fused_activation = true;
        assert!(signature(&r, 16, 16).ends_with("_relu"));
    }

    #[test]
    fn manifest_parse() {
        let dir = crate::util::tmp::TempDir::new("manifest");
        std::fs::write(
            dir.path().join("manifest.json"),
            r#"{"artifacts": {"conv2d_ih16_iw16_ic3_oc8_k3_s1_p1": "conv0.hlo.txt"}, "generated_by": "aot.py"}"#,
        )
        .unwrap();
        let m = Manifest::load(dir.path()).unwrap();
        assert_eq!(m.entries.len(), 1);
        assert_eq!(
            m.entries["conv2d_ih16_iw16_ic3_oc8_k3_s1_p1"],
            "conv0.hlo.txt"
        );
    }

    #[test]
    fn missing_manifest_is_error() {
        let dir = crate::util::tmp::TempDir::new("nomanifest");
        assert!(Runtime::load(dir.path()).is_err());
    }
}
