//! PJRT backend — the real artifact execution path (`--features pjrt`).
//!
//! Compiles the HLO text emitted by `python/compile/aot.py` on the PJRT CPU
//! client and runs the Pallas-lowered kernels. Requires the vendored `xla`
//! crate (this module does not compile without it — the offline default
//! build uses the native fallback in [`super`] instead).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use super::{err, signature, Manifest, Result};
use crate::compute::Tensor;
use crate::model::{ConvType, LayerMeta};

/// The PJRT runtime: CPU client + lazily compiled executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Load the runtime from an artifacts directory (errors if the manifest
    /// is absent — run `make artifacts`).
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| err(format!("PJRT client: {e:?}")))?;
        Ok(Runtime { client, manifest, dir: dir.to_path_buf(), cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn has(&self, sig: &str) -> bool {
        self.manifest.entries.contains_key(sig)
    }

    pub fn n_artifacts(&self) -> usize {
        self.manifest.entries.len()
    }

    fn executable(&self, sig: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(sig) {
            return Ok(e.clone());
        }
        let file = self
            .manifest
            .entries
            .get(sig)
            .ok_or_else(|| err(format!("no artifact for signature {sig}")))?;
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| err("non-utf8 path"))?,
        )
        .map_err(|e| err(format!("parse {}: {e:?}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| err(format!("compile {sig}: {e:?}")))?;
        let exe = std::sync::Arc::new(exe);
        self.cache.lock().unwrap().insert(sig.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute one layer via its AOT artifact. `input` must be the full
    /// (padded-to-valid) input window in HWC layout matching the signature's
    /// `in_h × in_w`; weights/bias use the same layout as
    /// [`crate::compute::LayerWeights`].
    pub fn execute_layer(
        &self,
        layer: &LayerMeta,
        weights: &crate::compute::LayerWeights,
        input: &Tensor,
    ) -> Result<Tensor> {
        let sig = signature(layer, input.h, input.w);
        let exe = self.executable(&sig)?;

        let in_lit = xla::Literal::vec1(&input.data)
            .reshape(&[input.h, input.w, input.c])
            .map_err(|e| err(format!("reshape input: {e:?}")))?;
        let args: Vec<xla::Literal> = match layer.conv_t {
            ConvType::Pool => vec![in_lit],
            ConvType::Depthwise => {
                let w = xla::Literal::vec1(&weights.w)
                    .reshape(&[layer.k, layer.k, layer.out_c])
                    .map_err(|e| err(format!("reshape w: {e:?}")))?;
                let b = xla::Literal::vec1(&weights.b);
                vec![in_lit, w, b]
            }
            ConvType::Dense | ConvType::Attention => {
                let w = xla::Literal::vec1(&weights.w)
                    .reshape(&[layer.in_c, layer.out_c])
                    .map_err(|e| err(format!("reshape w: {e:?}")))?;
                let b = xla::Literal::vec1(&weights.b);
                vec![in_lit, w, b]
            }
            _ => {
                let w = xla::Literal::vec1(&weights.w)
                    .reshape(&[layer.k, layer.k, layer.in_c, layer.out_c])
                    .map_err(|e| err(format!("reshape w: {e:?}")))?;
                let b = xla::Literal::vec1(&weights.b);
                vec![in_lit, w, b]
            }
        };

        let result = exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| err(format!("execute {sig}: {e:?}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| err(format!("fetch result: {e:?}")))?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let out = result.to_tuple1().map_err(|e| err(format!("untuple: {e:?}")))?;
        let data = out.to_vec::<f32>().map_err(|e| err(format!("to_vec: {e:?}")))?;

        let (oh, ow, oc) = (layer.out_h, layer.out_w, layer.out_c);
        if data.len() != (oh * ow * oc) as usize {
            return Err(err(format!(
                "artifact {sig} returned {} elements, expected {}",
                data.len(),
                oh * ow * oc
            )));
        }
        Ok(Tensor { h: oh, w: ow, c: oc, data })
    }
}
