//! Runtime adaptation — dynamic conditions, plan cache, online replanning.
//!
//! The paper's DPP planner (and everything in [`crate::planner`]) assumes a
//! *frozen* cluster: fixed bandwidth, fixed device speeds, no failures. A
//! production serving system sees none of that — links drift diurnally,
//! devices slow down under thermal pressure, and nodes drop out and rejoin
//! (DistrEdge, arXiv 2202.01699; DEFER, arXiv 2201.06769). This subsystem
//! makes the serving path condition-aware without ever stalling a request:
//!
//! * [`conditions`] — deterministic, seeded condition traces over virtual
//!   time: bandwidth/compute drift plus device outages, with built-in
//!   scenario profiles (`stable`, `diurnal-drift`, `lossy-link`,
//!   `node-churn`) and scripted overrides for tests. The [`ConditionSource`]
//!   trait abstracts *where* snapshots come from: scripted traces and the
//!   probe-measured [`crate::telemetry::TelemetrySource`] drive the same
//!   stack interchangeably.
//! * [`cache`] — the plan cache: DPP results memoized under quantized
//!   condition snapshots with LRU eviction, so revisited regimes are served
//!   warm instead of re-searched.
//! * [`controller`] — the monitor + replanner core: it re-prices the active
//!   plan under effective conditions (through the shared
//!   [`crate::cost::memo`] query cache), detects degradation past a
//!   threshold, a node-set change, or a shift out of the active plan's
//!   condition cell (how recoveries swap back), replans (cache first,
//!   memoized parallel DPP on a miss), and swaps the new plan in *between*
//!   batches — on node failure it degrades gracefully to the best
//!   n−1-device plan. [`ElasticController`] drives the core synchronously
//!   (simple, deterministic, but a cold replan stalls its boundary).
//! * [`chaos`] — the deterministic chaos-test harness: seeded fault
//!   schedules (kills and restores of *any* node — the leader included —
//!   back-to-back failures, bandwidth collapses) compiled into condition
//!   traces, plus a driver that audits a served request stream for the
//!   three invariants: bit-identical outputs, zero silent drops, and
//!   preserved completion order.
//! * [`background`] — the production driver: a dedicated planner thread
//!   runs the same core and publishes into an atomic [`PlanSlot`], so a
//!   batch boundary's plan acquisition is a single atomic epoch load;
//!   while the cluster is healthy the thread speculatively pre-computes
//!   the best n−1 failover plan per likely-lost node into the LRU cache,
//!   making node-churn failover a pure cache hit instead of a search.
//!
//! [`crate::serve::Server::start_elastic`] wires an [`ElasticFrontend`]
//! into the router loop and reports [`crate::metrics::AdaptationMetrics`]
//! plus the batch-boundary stall distribution alongside the router
//! counters.

pub mod background;
pub mod cache;
pub mod chaos;
pub mod conditions;
pub mod controller;

pub use background::{
    BackgroundReplanner, BoundaryDecision, ElasticFrontend, PlanSlot, PlanVersion,
};
pub use cache::{CacheKey, PlanCache};
pub use chaos::{run_chaos, ChaosEvent, ChaosOutcome, ChaosSchedule};
pub use conditions::{
    ClusterSnapshot, ConditionSource, ConditionTrace, Outage, Profile, SnapshotKey,
};
pub use controller::{AdaptEvent, BatchDecision, ElasticConfig, ElasticController, SwapReason};
