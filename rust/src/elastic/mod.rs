//! Runtime adaptation — dynamic conditions, plan cache, online replanning.
//!
//! The paper's DPP planner (and everything in [`crate::planner`]) assumes a
//! *frozen* cluster: fixed bandwidth, fixed device speeds, no failures. A
//! production serving system sees none of that — links drift diurnally,
//! devices slow down under thermal pressure, and nodes drop out and rejoin
//! (DistrEdge, arXiv 2202.01699; DEFER, arXiv 2201.06769). This subsystem
//! makes the serving path condition-aware without ever stalling a request:
//!
//! * [`conditions`] — deterministic, seeded condition traces over virtual
//!   time: bandwidth/compute drift plus device outages, with built-in
//!   scenario profiles (`stable`, `diurnal-drift`, `lossy-link`,
//!   `node-churn`) and scripted overrides for tests.
//! * [`cache`] — the plan cache: DPP results memoized under quantized
//!   condition snapshots with LRU eviction, so revisited regimes are served
//!   warm instead of re-searched.
//! * [`controller`] — the monitor + replanner: per batch boundary it
//!   re-prices the active plan under effective conditions, detects
//!   degradation past a threshold, a node-set change, or a shift out of
//!   the active plan's condition cell (how recoveries swap back), replans
//!   (cache first, DPP on a miss — the search runs on the router thread at
//!   the batch boundary, so admission never blocks on planning but the
//!   batch being formed waits out a cold miss; async replanning is a
//!   ROADMAP item), and swaps the new plan in *between* batches — on node
//!   failure it degrades gracefully to the best n−1-device plan.
//!
//! [`crate::serve::Server::start_elastic`] wires a controller into the
//! router loop and reports [`crate::metrics::AdaptationMetrics`] alongside
//! the router counters.

pub mod cache;
pub mod conditions;
pub mod controller;

pub use cache::{CacheKey, PlanCache};
pub use conditions::{ClusterSnapshot, ConditionTrace, Outage, Profile, SnapshotKey};
pub use controller::{
    AdaptEvent, BatchDecision, ElasticConfig, ElasticController, SwapReason,
};
