//! Dynamic cluster conditions over virtual time.
//!
//! A [`ConditionTrace`] is a deterministic, seeded function from virtual
//! time to a [`ClusterSnapshot`]: which devices are alive, how fast the
//! interconnect currently is relative to the baseline [`Testbed`], and how
//! fast each device currently runs relative to its profile. Built-in
//! [`Profile`]s cover the scenario families DistrEdge/DEFER motivate —
//! steady state, slow diurnal bandwidth drift, bursty lossy links, and node
//! churn — and explicit outages can be scripted on top of any profile for
//! reproducible failure tests.
//!
//! Everything here is a pure function of `(profile, seed, t)`, so a trace
//! can be replayed exactly: the same trace drives the planner's condition
//! snapshots, the serving router's per-batch checks, and the tests that
//! assert on both.

use crate::net::Testbed;
use crate::util::rng::Rng;

/// Where the elastic stack's condition snapshots come from.
///
/// The monitor, plan cache, background replanner and serving router only
/// ever consume [`ClusterSnapshot`]s, so the *provenance* of those
/// snapshots is swappable: a scripted simulation ([`ConditionTrace`] — the
/// deterministic world model every test and chaos schedule is built on) or
/// measured telemetry ([`crate::telemetry::TelemetrySource`] — passive
/// probes on the traffic the cluster already moves, an active low-rate
/// prober for idle links, and per-node compute/liveness measurements,
/// aggregated through a ring-buffer store). The whole adaptation stack runs
/// unchanged on either.
///
/// Sampling takes `&mut self` because measured sources do real work per
/// sample (heartbeat sweep, rate-limited active probes, store reads);
/// scripted traces are pure functions and ignore the mutability.
pub trait ConditionSource: Send {
    /// Number of devices in the cluster this source describes.
    fn node_count(&self) -> usize;

    /// Effective cluster conditions at virtual time `t`.
    fn sample(&mut self, t: f64) -> ClusterSnapshot;

    /// Passive traffic observation: `bytes` of boundary payload moved in
    /// `msgs` messages by an inference finishing at virtual time `t`.
    /// Measured sources turn this into effective-bandwidth samples — the
    /// cluster's own scatter/realignment/gather traffic is the probe;
    /// scripted traces (which already *are* the ground truth) ignore it.
    fn observe_traffic(&mut self, _t: f64, _bytes: u64, _msgs: u64) {}
}

impl ConditionSource for ConditionTrace {
    fn node_count(&self) -> usize {
        self.nodes
    }

    fn sample(&mut self, t: f64) -> ClusterSnapshot {
        ConditionTrace::sample(self, t)
    }
}

/// Built-in condition scenario families.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Profile {
    /// Baseline conditions forever (the paper's static-testbed assumption).
    Stable,
    /// Smooth sinusoidal bandwidth drift between 100% and 40% of baseline
    /// over one `period` (a compressed "day"), with a mild per-node compute
    /// wobble whose phase is seeded per node.
    DiurnalDrift,
    /// Bursty link degradation: in each `period`-long window the link is,
    /// with seeded probability, down to 15% of baseline bandwidth.
    LossyLink,
    /// Devices drop out and rejoin: seeded outages of non-leader nodes.
    NodeChurn,
}

impl Profile {
    pub const ALL: [Profile; 4] =
        [Profile::Stable, Profile::DiurnalDrift, Profile::LossyLink, Profile::NodeChurn];

    pub fn name(self) -> &'static str {
        match self {
            Profile::Stable => "stable",
            Profile::DiurnalDrift => "diurnal-drift",
            Profile::LossyLink => "lossy-link",
            Profile::NodeChurn => "node-churn",
        }
    }
}

impl std::fmt::Display for Profile {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for Profile {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "stable" => Ok(Profile::Stable),
            "diurnal" | "diurnal-drift" => Ok(Profile::DiurnalDrift),
            "lossy" | "lossy-link" => Ok(Profile::LossyLink),
            "churn" | "node-churn" => Ok(Profile::NodeChurn),
            other => Err(format!("unknown condition profile {other:?}")),
        }
    }
}

/// One device outage interval `[from, until)` in virtual seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Outage {
    pub node: usize,
    pub from: f64,
    pub until: f64,
}

/// One scripted link-degradation interval `[from, until)`: the baseline
/// bandwidth is multiplied by `factor` while active (stacks with the
/// profile's own factor).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BandwidthDip {
    pub from: f64,
    pub until: f64,
    pub factor: f64,
}

/// A deterministic condition trace for an `nodes`-device cluster.
#[derive(Debug, Clone)]
pub struct ConditionTrace {
    pub profile: Profile,
    pub seed: u64,
    pub nodes: usize,
    /// Characteristic period of the profile's variation, virtual seconds.
    pub period: f64,
    /// Scripted + profile-generated outages. The built-in profiles only
    /// churn ranks `1..` (they model worker churn), but scripted outages —
    /// and the chaos harness built on them — may take any node down,
    /// including rank 0: leadership re-elects onto the lowest surviving
    /// rank ([`crate::cluster::election::elect_leader`]).
    outages: Vec<Outage>,
    /// Scripted bandwidth-degradation intervals.
    dips: Vec<BandwidthDip>,
    /// Per-node phase offsets for the compute wobble, radians.
    phases: Vec<f64>,
}

impl ConditionTrace {
    fn base(profile: Profile, nodes: usize, seed: u64, period: f64) -> ConditionTrace {
        assert!(nodes >= 1, "empty cluster");
        // SnapshotKey packs liveness into a u64 mask (and Testbed caps at 16
        // nodes anyway).
        assert!(nodes <= 64, "condition traces support at most 64 nodes");
        assert!(period > 0.0, "period must be positive");
        let mut rng = Rng::new(seed ^ 0xe1a5_71c0);
        let phases: Vec<f64> =
            (0..nodes).map(|_| rng.range_f64(0.0, 2.0 * std::f64::consts::PI)).collect();
        ConditionTrace {
            profile,
            seed,
            nodes,
            period,
            outages: Vec::new(),
            dips: Vec::new(),
            phases,
        }
    }

    /// Baseline conditions forever.
    pub fn stable(nodes: usize) -> ConditionTrace {
        Self::base(Profile::Stable, nodes, 0, 1.0)
    }

    /// Diurnal bandwidth drift (period = one compressed "day" of 60 virtual
    /// seconds).
    pub fn diurnal_drift(nodes: usize, seed: u64) -> ConditionTrace {
        Self::base(Profile::DiurnalDrift, nodes, seed, 60.0)
    }

    /// Bursty lossy link (1-second windows, ~30% of them degraded).
    pub fn lossy_link(nodes: usize, seed: u64) -> ConditionTrace {
        Self::base(Profile::LossyLink, nodes, seed, 1.0)
    }

    /// Node churn: each non-leader node independently suffers, with 75%
    /// probability, one seeded outage somewhere in `[period, 3·period)`,
    /// lasting between one and two periods (period = 10 virtual seconds);
    /// the remaining nodes stay healthy for the whole trace.
    pub fn node_churn(nodes: usize, seed: u64) -> ConditionTrace {
        let mut trace = Self::base(Profile::NodeChurn, nodes, seed, 10.0);
        let mut rng = Rng::new(seed ^ 0xc4u64);
        for node in 1..nodes {
            if !rng.bool(0.75) {
                continue; // this node stays healthy
            }
            let from = rng.range_f64(trace.period, 3.0 * trace.period);
            let len = rng.range_f64(trace.period, 2.0 * trace.period);
            trace.outages.push(Outage { node, from, until: from + len });
        }
        trace
    }

    /// Script an explicit outage on top of the profile (for reproducible
    /// failure tests). `until = f64::INFINITY` makes it permanent. Any node
    /// may be scripted down — rank 0 included: no node is immortal, and a
    /// leader outage exercises the election/handoff path. The only backstop
    /// is in [`Self::sample`]: a schedule that takes *every* node down at
    /// once keeps the lowest rank up as the survivor of last resort.
    pub fn with_outage(mut self, node: usize, from: f64, until: f64) -> ConditionTrace {
        assert!(node < self.nodes, "outage node {node} out of range");
        assert!(from < until, "empty outage interval");
        self.outages.push(Outage { node, from, until });
        self
    }

    /// Script a bandwidth collapse on top of the profile (for reproducible
    /// degradation tests). `until = f64::INFINITY` makes it permanent.
    pub fn with_bandwidth_dip(mut self, from: f64, until: f64, factor: f64) -> ConditionTrace {
        assert!(from < until, "empty dip interval");
        assert!(factor > 0.0 && factor.is_finite(), "bad dip factor {factor}");
        self.dips.push(BandwidthDip { from, until, factor });
        self
    }

    pub fn outages(&self) -> &[Outage] {
        &self.outages
    }

    /// The effective cluster conditions at virtual time `t` — deterministic:
    /// the same `(trace, t)` always yields the same snapshot.
    pub fn sample(&self, t: f64) -> ClusterSnapshot {
        let mut alive = vec![true; self.nodes];
        for o in &self.outages {
            if t >= o.from && t < o.until {
                alive[o.node] = false;
            }
        }
        // Survivor of last resort: a cluster with zero devices cannot serve
        // anything, so if a schedule takes every node down at once the
        // lowest rank stays up — the same rank-based rule the leader
        // election uses, so the revived node is also the leader.
        if !alive.contains(&true) {
            alive[0] = true;
        }

        let mut bandwidth_factor = 1.0;
        let mut speed_factors = vec![1.0; self.nodes];
        match self.profile {
            Profile::Stable | Profile::NodeChurn => {}
            Profile::DiurnalDrift => {
                let phase = 2.0 * std::f64::consts::PI * t / self.period;
                // 1.0 at t = 0, down to 0.4 at half period, back to 1.0.
                bandwidth_factor = 0.4 + 0.6 * 0.5 * (1.0 + phase.cos());
                for (i, s) in speed_factors.iter_mut().enumerate() {
                    *s = (1.0 + 0.1 * (phase + self.phases[i]).sin()).max(0.5);
                }
            }
            Profile::LossyLink => {
                let window = (t / self.period).floor().max(0.0) as u64;
                let mut rng =
                    Rng::new(self.seed ^ window.wrapping_mul(0x9E37_79B9_7F4A_7C15));
                if rng.bool(0.3) {
                    bandwidth_factor = 0.15;
                }
            }
        }
        for d in &self.dips {
            if t >= d.from && t < d.until {
                bandwidth_factor *= d.factor;
            }
        }
        ClusterSnapshot { t, alive, bandwidth_factor, speed_factors }
    }
}

/// Effective cluster conditions at one instant of virtual time.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSnapshot {
    pub t: f64,
    /// Per-node liveness (indexed by original node id).
    pub alive: Vec<bool>,
    /// Multiplier on the baseline link bandwidth (0 < factor ≤ 1 typical).
    pub bandwidth_factor: f64,
    /// Per-node multiplier on the baseline speed factors.
    pub speed_factors: Vec<f64>,
}

impl ClusterSnapshot {
    pub fn alive_count(&self) -> usize {
        self.alive.iter().filter(|&&a| a).count()
    }

    /// The effective testbed: `base` with dead nodes removed, bandwidth
    /// scaled, and per-node speeds scaled.
    pub fn apply(&self, base: &Testbed) -> Testbed {
        assert_eq!(self.alive.len(), base.nodes, "snapshot/testbed node mismatch");
        let mut tb = base.subset(&self.alive).with_bandwidth_factor(self.bandwidth_factor);
        let mut k = 0;
        for i in 0..base.nodes {
            if self.alive[i] {
                tb.speed[k] *= self.speed_factors[i];
                k += 1;
            }
        }
        tb
    }

    /// Quantize into a cache key: conditions that round to the same buckets
    /// share a plan. Bandwidth and speed factors bucket in 12.5% steps, so
    /// e.g. a 3% bandwidth wiggle hits the same cached plan while a 25%
    /// collapse lands in a different cell.
    pub fn quantize(&self) -> SnapshotKey {
        let mut alive_mask = 0u64;
        let mut speed_buckets = Vec::with_capacity(self.alive_count());
        for (i, &a) in self.alive.iter().enumerate() {
            if a {
                alive_mask |= 1 << i;
                let b = (self.speed_factors[i] * 8.0).round().clamp(0.0, 255.0) as u8;
                speed_buckets.push(b);
            }
        }
        let bw_bucket = (self.bandwidth_factor * 8.0).round().clamp(0.0, 4.0e9) as u32;
        SnapshotKey { alive_mask, bw_bucket, speed_buckets }
    }
}

/// Quantized snapshot — the condition part of the plan-cache key.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct SnapshotKey {
    pub alive_mask: u64,
    pub bw_bucket: u32,
    pub speed_buckets: Vec<u8>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{Bandwidth, Topology};

    #[test]
    fn traces_are_deterministic() {
        for make in [
            ConditionTrace::stable as fn(usize) -> ConditionTrace,
        ] {
            let a = make(4);
            let b = make(4);
            assert_eq!(a.sample(3.7), b.sample(3.7));
        }
        for (a, b) in [
            (ConditionTrace::diurnal_drift(4, 7), ConditionTrace::diurnal_drift(4, 7)),
            (ConditionTrace::lossy_link(4, 7), ConditionTrace::lossy_link(4, 7)),
            (ConditionTrace::node_churn(4, 7), ConditionTrace::node_churn(4, 7)),
        ] {
            for t in [0.0, 1.3, 11.9, 47.2] {
                assert_eq!(a.sample(t), b.sample(t));
            }
        }
    }

    #[test]
    fn stable_is_identity() {
        let base = Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0));
        let snap = ConditionTrace::stable(4).sample(123.4);
        assert_eq!(snap.alive_count(), 4);
        assert_eq!(snap.apply(&base), base);
    }

    #[test]
    fn diurnal_drift_dips_and_recovers() {
        let trace = ConditionTrace::diurnal_drift(4, 1);
        let full = trace.sample(0.0).bandwidth_factor;
        let dip = trace.sample(trace.period / 2.0).bandwidth_factor;
        let back = trace.sample(trace.period).bandwidth_factor;
        assert!((full - 1.0).abs() < 1e-9);
        assert!((dip - 0.4).abs() < 1e-9);
        assert!((back - 1.0).abs() < 1e-9);
        // speeds stay in a sane band
        for s in trace.sample(17.0).speed_factors {
            assert!((0.5..=1.5).contains(&s));
        }
    }

    #[test]
    fn lossy_link_has_degraded_and_clean_windows() {
        let trace = ConditionTrace::lossy_link(4, 3);
        let factors: Vec<f64> =
            (0..200).map(|w| trace.sample(w as f64 + 0.5).bandwidth_factor).collect();
        assert!(factors.iter().any(|&f| f < 0.5), "no lossy window in 200");
        assert!(factors.iter().any(|&f| f > 0.9), "no clean window in 200");
        // constant within a window
        assert_eq!(trace.sample(5.1).bandwidth_factor, trace.sample(5.9).bandwidth_factor);
    }

    #[test]
    fn node_churn_kills_and_revives_non_leader_nodes() {
        // across seeds: some node goes down during the churn horizon and the
        // leader never does
        let mut saw_outage = false;
        for seed in 0..8u64 {
            let trace = ConditionTrace::node_churn(4, seed);
            for step in 0..400 {
                let snap = trace.sample(step as f64 * 0.1);
                assert!(snap.alive[0], "leader died (seed {seed})");
                if snap.alive_count() < 4 {
                    saw_outage = true;
                }
            }
            if !trace.outages().is_empty() {
                let o = trace.outages()[0];
                assert!(o.until.is_finite(), "churn outages end");
            }
        }
        assert!(saw_outage, "no churn in 8 seeds");
    }

    #[test]
    fn scripted_outage_is_exact() {
        let trace = ConditionTrace::stable(4).with_outage(2, 5.0, f64::INFINITY);
        assert_eq!(trace.sample(4.9).alive_count(), 4);
        let snap = trace.sample(5.0);
        assert_eq!(snap.alive_count(), 3);
        assert!(!snap.alive[2]);
        assert_eq!(trace.sample(1e12).alive_count(), 3);
    }

    #[test]
    fn leader_outage_is_scriptable() {
        // no immortal nodes: rank 0 goes down like any other, and comes back
        let trace = ConditionTrace::stable(4).with_outage(0, 2.0, 5.0);
        assert!(trace.sample(1.9).alive[0]);
        let snap = trace.sample(3.0);
        assert!(!snap.alive[0], "leader outage was silently revived");
        assert_eq!(snap.alive_count(), 3);
        assert!(trace.sample(5.0).alive[0], "leader never rejoined");
    }

    #[test]
    fn all_nodes_down_keeps_a_survivor_of_last_resort() {
        let trace = ConditionTrace::stable(2)
            .with_outage(0, 1.0, 3.0)
            .with_outage(1, 2.0, 4.0);
        // overlap [2, 3): every node scripted down → rank 0 revives
        let snap = trace.sample(2.5);
        assert_eq!(snap.alive, vec![true, false]);
        // outside the overlap the script is honored exactly
        assert_eq!(trace.sample(1.5).alive, vec![false, true]);
        assert_eq!(trace.sample(3.5).alive, vec![true, false]);
    }

    #[test]
    fn overlapping_outages_union_and_end_independently() {
        // two scripted outages overlap on the same node and a third overlaps
        // on a different node: liveness is the union of active intervals,
        // and each interval ends on its own schedule
        let trace = ConditionTrace::stable(4)
            .with_outage(1, 1.0, 4.0)
            .with_outage(1, 3.0, 6.0) // same node, overlapping tail
            .with_outage(2, 3.5, 5.0); // different node, inside the overlap
        assert_eq!(trace.sample(0.5).alive, vec![true; 4]);
        assert_eq!(trace.sample(3.2).alive, vec![true, false, true, true]);
        // both node-1 intervals and the node-2 interval active at once
        assert_eq!(trace.sample(3.7).alive, vec![true, false, false, true]);
        // first node-1 interval over, second still holds it down
        assert_eq!(trace.sample(4.5).alive, vec![true, false, false, true]);
        // node 2 back first, node 1 still down until 6.0
        assert_eq!(trace.sample(5.5).alive, vec![true, false, true, true]);
        assert_eq!(trace.sample(6.0).alive, vec![true; 4]);
    }

    #[test]
    fn dip_spanning_an_outage_window_applies_throughout() {
        // a bandwidth dip starts before and ends after an outage: the dip
        // factor must hold across the outage's start, duration and end, and
        // stacked dips multiply while both are active
        let trace = ConditionTrace::stable(4)
            .with_bandwidth_dip(1.0, 10.0, 0.5)
            .with_outage(2, 3.0, 6.0)
            .with_bandwidth_dip(4.0, 5.0, 0.5); // nested second dip
        let at = |t: f64| trace.sample(t);
        assert_eq!(at(0.5).bandwidth_factor, 1.0);
        // dip active, node still up
        assert_eq!(at(2.0).bandwidth_factor, 0.5);
        assert_eq!(at(2.0).alive_count(), 4);
        // outage starts inside the dip: both effects visible at once
        let mid = at(3.5);
        assert_eq!(mid.bandwidth_factor, 0.5);
        assert!(!mid.alive[2]);
        // nested dip stacks multiplicatively while the outage holds
        assert!((at(4.5).bandwidth_factor - 0.25).abs() < 1e-12);
        // outage ends inside the dip: bandwidth still degraded
        let after_outage = at(7.0);
        assert_eq!(after_outage.bandwidth_factor, 0.5);
        assert_eq!(after_outage.alive_count(), 4);
        assert_eq!(at(10.0).bandwidth_factor, 1.0);
    }

    #[test]
    fn sampling_outside_the_trace_horizon_clamps() {
        // A trace is a total function of t: asking for a time before the
        // trace starts, or far past its last scripted event, must clamp
        // deterministically instead of panicking or going out of range.
        // Negative t: the lossy-link window index clamps to window 0.
        let lossy = ConditionTrace::lossy_link(4, 3);
        let neg = lossy.sample(-7.3);
        assert_eq!(neg.bandwidth_factor, lossy.sample(0.5).bandwidth_factor);
        assert_eq!(neg.alive_count(), 4);
        // Past the churn horizon (all outages end by 5·period): baseline.
        let churn = ConditionTrace::node_churn(4, 1);
        let late = churn.sample(1e9);
        assert_eq!(late.alive, vec![true; 4]);
        assert_eq!(late.bandwidth_factor, 1.0);
        // A scripted trace shorter than the requested slot: sampling past
        // the last dip/outage returns to the profile baseline exactly.
        let short = ConditionTrace::stable(4)
            .with_outage(1, 0.5, 1.0)
            .with_bandwidth_dip(0.0, 2.0, 0.3);
        let past = short.sample(2.0);
        assert_eq!(past.alive, vec![true; 4]);
        assert_eq!(past.bandwidth_factor, 1.0);
        assert_eq!(short.sample(-1.0).alive, vec![true; 4]);
    }

    #[test]
    fn condition_source_trait_matches_inherent_sampling() {
        // the trait object path must be indistinguishable from calling the
        // trace directly — the elastic stack's source-agnosticism contract
        let trace = ConditionTrace::diurnal_drift(4, 9).with_outage(2, 1.0, 2.0);
        let mut boxed: Box<dyn ConditionSource> = Box::new(trace.clone());
        assert_eq!(boxed.node_count(), 4);
        for t in [0.0, 0.7, 1.5, 2.5, 31.0] {
            assert_eq!(boxed.sample(t), trace.sample(t));
        }
        // traffic observations are a no-op for scripted traces
        boxed.observe_traffic(1.0, 1 << 20, 12);
        assert_eq!(boxed.sample(0.7), trace.sample(0.7));
    }

    #[test]
    fn apply_scales_bandwidth_and_speed() {
        let base = Testbed::new(4, Topology::Ring, Bandwidth::gbps(2.0));
        let snap = ClusterSnapshot {
            t: 0.0,
            alive: vec![true, true, false, true],
            bandwidth_factor: 0.5,
            speed_factors: vec![1.0, 0.8, 1.0, 1.0],
        };
        let tb = snap.apply(&base);
        assert_eq!(tb.nodes, 3);
        assert!((tb.bandwidth.as_gbps() - 1.0).abs() < 1e-12);
        assert!((tb.speed[1] - 0.8).abs() < 1e-12);
    }

    #[test]
    fn quantization_buckets_nearby_conditions_together() {
        let trace = ConditionTrace::stable(4);
        let a = trace.sample(1.0);
        let mut b = trace.sample(2.0);
        b.bandwidth_factor = 0.97; // 3% wiggle — same 12.5% bucket as 1.0
        assert_eq!(a.quantize(), b.quantize());
        let mut c = trace.sample(3.0);
        c.bandwidth_factor = 0.5; // a real collapse — different cell
        assert_ne!(a.quantize(), c.quantize());
        let mut d = trace.sample(4.0);
        d.alive[3] = false; // node loss always changes the key
        assert_ne!(a.quantize(), d.quantize());
    }
}
