//! Non-blocking replanning: a dedicated planner thread behind an atomic
//! plan slot.
//!
//! PR 1 ran the whole monitor → replan → swap pipeline inline at every
//! batch boundary, so a cold DPP search stood between a condition shift and
//! the next batch. This module moves all of it off the serving path:
//!
//! * [`PlanSlot`] — the published plan: a seqlock-style epoch counter in
//!   front of the current [`PlanVersion`]. The router's steady-state
//!   acquisition is **one atomic load** (epoch compare against its locally
//!   cached version); only when the planner actually published something new
//!   does the router take the uncontended read lock to fetch the new `Arc`.
//! * [`BackgroundReplanner`] — the planner thread: owns the
//!   [`ReplanCore`](super::controller) (monitor, plan cache, memoized
//!   parallel DPP) and serves asynchronous observation messages from the
//!   router. While the cluster is healthy it speculatively pre-computes the
//!   best n−1 failover plan for every alive node — the leader included —
//!   into the LRU plan cache, and refreshes that set whenever conditions
//!   shift cells — so any node loss, leader or worker, is served by a pure
//!   cache hit.
//! * [`ElasticFrontend`] — the router-side handle: samples the condition
//!   trace (cheap and deterministic), compares the liveness mask and
//!   quantized cell against the cached version, and either proceeds with
//!   the published plan (bandwidth drift: fire-and-forget `Observe`, keep
//!   serving on the stale-but-valid plan) or — only when the node *set*
//!   changed, where executing with stale cost bookkeeping would corrupt the
//!   virtual clock — rendezvouses with the planner, which answers from the
//!   speculative cache.
//!
//! The split keeps every batch boundary wait-free in the common case,
//! bounded by a cache lookup on failover, and never blocked on a DPP
//! search for any condition the speculative pass has covered.
//!
//! Two additions close the loop the purely reactive stack was missing:
//!
//! * **Forecast pre-warming** ([`ElasticConfig::forecast`]): the frontend
//!   fits a [`ForecastEngine`] over the snapshots it already samples —
//!   scripted or probe-measured, provenance doesn't matter — and when the
//!   projection leaves the published plan's quantized cell it sends a
//!   fire-and-forget `Prewarm` ask. The planner fills that cell (and
//!   pre-speculates its n−1/leader-loss cells at the *forecast* bandwidth)
//!   once its queue idles, so the shift — and a failover landing with it —
//!   arrives to a warm cache. Pre-warms never publish: a wrong forecast
//!   costs a cache entry, never a swap.
//! * **Staleness accounting** ([`ElasticConfig::stale_after_checks`]):
//!   drift asks are fire-and-forget, so a wedged planner thread used to be
//!   invisible — the router would serve an outdated plan forever. Each
//!   boundary served while an ask has been outstanding past the bound now
//!   counts into `AdaptationMetrics::stale_plan_boundaries`.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

use super::cache::CacheKey;
use super::conditions::{ClusterSnapshot, ConditionSource, ConditionTrace};
use super::controller::{ElasticConfig, ReplanCore};
use crate::cluster::election::elect_leader;
use crate::metrics::{summarize, AdaptationMetrics, Summary};
use crate::model::Model;
use crate::net::Testbed;
use crate::partition::Plan;
use crate::telemetry::ForecastEngine;

/// One published planning decision: everything a batch boundary needs,
/// immutable once published.
#[derive(Debug, Clone)]
pub struct PlanVersion {
    /// Publication sequence number (strictly increasing).
    pub epoch: u64,
    pub plan: Arc<Plan>,
    /// Condition cell the plan was decided for.
    pub key: CacheKey,
    /// Liveness mask the plan was decided for. The leader is *derived*,
    /// never cached: consumers elect from the freshest mask they hold
    /// ([`crate::cluster::election::elect_leader`]), so a published
    /// version can never serve a stale leader identity.
    pub alive: Vec<bool>,
    /// Effective node count of that mask.
    pub nodes: usize,
    /// Predicted virtual seconds per item at decision time.
    pub cost_per_item: f64,
}

/// The atomic plan slot: single-writer (the planner thread), any-reader.
/// Readers that cache the current `Arc<PlanVersion>` pay one atomic epoch
/// load per check; the lock is touched only across an actual publication.
pub struct PlanSlot {
    epoch: AtomicU64,
    cur: RwLock<Arc<PlanVersion>>,
}

impl PlanSlot {
    pub fn new(initial: Arc<PlanVersion>) -> PlanSlot {
        PlanSlot { epoch: AtomicU64::new(initial.epoch), cur: RwLock::new(initial) }
    }

    /// The epoch of the most recent publication (one atomic load).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// The current version (takes the read lock).
    pub fn load(&self) -> Arc<PlanVersion> {
        self.cur.read().unwrap().clone()
    }

    /// Publish a new version: store it, then advance the epoch so readers
    /// observing the new epoch always find (at least) this version.
    pub fn publish(&self, v: Arc<PlanVersion>) {
        let e = v.epoch;
        *self.cur.write().unwrap() = v;
        self.epoch.store(e, Ordering::Release);
    }

    /// Reader fast path: refresh `cached` only if the slot moved on.
    /// Returns whether `cached` was replaced. Steady state is a single
    /// atomic load and no lock.
    pub fn refresh(&self, cached: &mut Arc<PlanVersion>) -> bool {
        if self.epoch() == cached.epoch {
            return false;
        }
        *cached = self.load();
        true
    }
}

/// Messages from the router to the planner thread.
enum Ask {
    /// Conditions left the published plan's cell (same node set): decide in
    /// the background and publish; the router keeps serving meanwhile.
    Observe(ClusterSnapshot),
    /// The node set changed: decide (speculative cache hit in the covered
    /// cases), publish, then ack so the caller can pick up the new version.
    Failover(ClusterSnapshot, SyncSender<()>),
    /// Forecasted conditions: warm the cache for the projected cell (and
    /// its n−1/leader-loss cells at the forecast bandwidth) once the queue
    /// is idle. Never publishes — the forecast hasn't arrived yet.
    Prewarm(ClusterSnapshot),
    /// Test/bench rendezvous: ack once every ask queued before this one —
    /// deferred pre-warms and idle speculation included — has completed.
    Sync(SyncSender<()>),
}

/// The dedicated planner thread plus its publication slot. Usually driven
/// through [`ElasticFrontend`]; exposed for tests and custom routers.
pub struct BackgroundReplanner {
    slot: Arc<PlanSlot>,
    tx: Option<Sender<Ask>>,
    handle: Option<std::thread::JoinHandle<AdaptationMetrics>>,
}

impl BackgroundReplanner {
    /// Plan for `snap0` on the caller's thread (a server must not accept
    /// traffic before any plan exists), publish epoch 1, then hand the core
    /// to the planner thread, which immediately pre-computes the n−1
    /// failover set before serving its first message.
    pub fn start(
        model: Model,
        base: Testbed,
        snap0: &ClusterSnapshot,
        cfg: ElasticConfig,
    ) -> BackgroundReplanner {
        let core = ReplanCore::new(model, base, snap0, cfg, /* inline = */ false);
        let v0 = Arc::new(PlanVersion {
            epoch: 1,
            plan: core.active_plan(),
            key: core.active_key.clone(),
            alive: snap0.alive.clone(),
            nodes: snap0.alive_count(),
            cost_per_item: core.active_cost,
        });
        let slot = Arc::new(PlanSlot::new(v0));
        let (tx, rx) = channel::<Ask>();
        let thread_slot = slot.clone();
        let init_snap = snap0.clone();
        let handle = std::thread::spawn(move || planner_main(core, init_snap, thread_slot, rx));
        BackgroundReplanner { slot, tx: Some(tx), handle: Some(handle) }
    }

    pub fn slot(&self) -> &Arc<PlanSlot> {
        &self.slot
    }

    fn observe(&self, snap: ClusterSnapshot) {
        if let Some(tx) = &self.tx {
            let _ = tx.send(Ask::Observe(snap));
        }
    }

    /// Fire-and-forget forecast pre-warm: the planner fills the projected
    /// cell (and its failover cells) when its queue next idles.
    fn prewarm(&self, snap: ClusterSnapshot) {
        if let Some(tx) = &self.tx {
            let _ = tx.send(Ask::Prewarm(snap));
        }
    }

    /// Block until every ask sent before this call — queued pre-warms and
    /// the idle speculation pass included — has been fully processed.
    /// Deterministic rendezvous for tests and benches; the serving path
    /// never calls it.
    pub fn quiesce(&self) {
        let (ack_tx, ack_rx) = sync_channel(1);
        if let Some(tx) = &self.tx {
            if tx.send(Ask::Sync(ack_tx)).is_ok() {
                let _ = ack_rx.recv();
            }
        }
    }

    /// Rendezvous: returns once the planner has published a decision for
    /// `snap`'s node set.
    fn failover(&self, snap: ClusterSnapshot) {
        let (ack_tx, ack_rx) = sync_channel(1);
        if let Some(tx) = &self.tx {
            if tx.send(Ask::Failover(snap, ack_tx)).is_ok() {
                ack_rx.recv().expect("background planner died during failover");
            }
        }
    }

    /// Stop the planner (it drains every queued ask first) and collect its
    /// adaptation counters.
    fn finish(&mut self) -> AdaptationMetrics {
        drop(self.tx.take());
        match self.handle.take() {
            Some(h) => h.join().expect("background planner panicked"),
            None => AdaptationMetrics::default(),
        }
    }
}

impl Drop for BackgroundReplanner {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// One deferred single-search work item: the forecast cell itself, or one
/// of its n−1/leader-loss neighbours at the forecast bandwidth. Expanding
/// an [`Ask::Prewarm`] into these units is what keeps the interleave bound
/// honest — the planner re-polls its queue between every search.
enum PrewarmUnit {
    Forecast(ClusterSnapshot),
    Speculative(ClusterSnapshot),
}

fn planner_main(
    mut core: ReplanCore,
    init_snap: ClusterSnapshot,
    slot: Arc<PlanSlot>,
    rx: Receiver<Ask>,
) -> AdaptationMetrics {
    let mut epoch = 1u64;
    // Healthy-cluster speculation runs before the first ask is served, so
    // any failover arriving later in this thread's queue is a cache hit.
    let mut cur_snap = init_snap;
    core.speculate_failovers(&cur_snap);
    while let Ok(first) = rx.recv() {
        // Drain the queue before any pre-warming or re-speculation: a
        // failover rendezvous must only ever wait behind decide() work
        // (cache-first) plus at most the single pre-warm search already in
        // progress — never behind a whole batch of forecast fills or
        // speculative n−1 searches for a superseded regime.
        let mut prewarms: VecDeque<PrewarmUnit> = VecDeque::new();
        let mut syncs: Vec<SyncSender<()>> = Vec::new();
        let mut next = Some(first);
        loop {
            // serve every decide-class ask currently queued — rendezvous
            // and drift decisions always jump ahead of deferred pre-warms
            while let Some(ask) = next.take() {
                match ask {
                    Ask::Observe(snap) => {
                        let d = core.decide(&snap);
                        epoch += 1;
                        publish(&slot, epoch, &core, &d, &snap);
                        cur_snap = snap;
                    }
                    Ask::Failover(snap, ack) => {
                        let d = core.decide(&snap);
                        epoch += 1;
                        publish(&slot, epoch, &core, &d, &snap);
                        let _ = ack.send(());
                        cur_snap = snap;
                    }
                    Ask::Prewarm(snap) => {
                        // expand into single-search units: the projected
                        // cell first, then its n−1 cells at the forecast
                        // bandwidth (none for a lone survivor)
                        if snap.alive_count() > 1 {
                            for node in 0..snap.alive.len() {
                                if snap.alive[node] {
                                    let mut hyp = snap.clone();
                                    hyp.alive[node] = false;
                                    prewarms.push_back(PrewarmUnit::Speculative(hyp));
                                }
                            }
                        }
                        prewarms.push_front(PrewarmUnit::Forecast(snap));
                    }
                    Ask::Sync(ack) => syncs.push(ack),
                }
                next = rx.try_recv().ok();
            }
            // queue idle this instant: run ONE deferred single-search
            // unit, then re-check the queue, so an ask landing mid-batch
            // waits behind at most the search already started
            match prewarms.pop_front() {
                Some(PrewarmUnit::Forecast(snap)) => {
                    core.prewarm_forecast_cell(&snap);
                    next = rx.try_recv().ok();
                }
                Some(PrewarmUnit::Speculative(snap)) => {
                    core.speculate_one(&snap);
                    next = rx.try_recv().ok();
                }
                None => break,
            }
        }
        // Pre-warms done and queue idle: refresh the speculative n−1 set
        // for the regime we actually ended up in (a no-op for cells the
        // cache already holds). Syncs ack last, so a quiesced caller
        // observes all of it completed.
        core.speculate_failovers(&cur_snap);
        for ack in syncs {
            let _ = ack.send(());
        }
    }
    core.metrics()
}

fn publish(
    slot: &PlanSlot,
    epoch: u64,
    core: &ReplanCore,
    d: &super::controller::BatchDecision,
    snap: &ClusterSnapshot,
) {
    slot.publish(Arc::new(PlanVersion {
        epoch,
        plan: d.plan.clone(),
        key: core.active_key.clone(),
        alive: snap.alive.clone(),
        nodes: d.testbed.nodes,
        cost_per_item: d.cost_per_item,
    }));
}

/// What a batch boundary gets back from [`ElasticFrontend::acquire`]: the
/// published plan plus the *fresh* liveness mask execution must respect.
#[derive(Debug, Clone)]
pub struct BoundaryDecision {
    pub plan: Arc<Plan>,
    /// Current per-node liveness (always fresh — a batch must never be
    /// scheduled onto a dead node, even while the optimized plan for the
    /// new membership is still being fetched).
    pub alive: Vec<bool>,
    /// Alive-node count (what [`crate::serve::Response::nodes`] reports).
    pub nodes: usize,
    /// Elected leader of the *fresh* mask (lowest surviving rank): the
    /// original rank owning scatter/ingress and gather for the next batch.
    pub leader: usize,
    /// Predicted virtual seconds per item, from the published version.
    pub cost_per_item: f64,
}

/// The router-side handle: trace sampling + plan acquisition + the
/// fire-and-forget / rendezvous messaging described in the module docs.
/// Boundary-stall samples kept for the shutdown summary (a bounded ring —
/// a server that runs for days must not grow per-boundary state without
/// bound, same invariant as [`super::controller::MAX_EVENTS`]).
const MAX_STALL_SAMPLES: usize = 4096;

/// Pending forecasts awaiting maturity, bounded so a long-horizon
/// misconfiguration cannot grow router-side state.
const MAX_PENDING_FORECASTS: usize = 64;

/// One projection waiting to be scored against reality.
struct PendingForecast {
    matures_at: f64,
    bw_bucket: u32,
}

pub struct ElasticFrontend {
    source: Box<dyn ConditionSource>,
    model_name: String,
    replanner: BackgroundReplanner,
    /// Locally cached version — the epoch fast path compares against this.
    cur: Arc<PlanVersion>,
    /// Cell we last asked the planner about, to avoid re-sending an ask
    /// every boundary while the planner is still working on it.
    last_asked: Option<CacheKey>,
    /// Monotone count of boundary events — full consultations *and*
    /// pipelined-path probes — so the staleness clock below runs on both
    /// serving shapes (the pipelined router only probes while the epoch
    /// hasn't moved, which is exactly the wedged-planner case).
    boundary_events: u64,
    /// Boundary-event count when `last_asked` was sent — the staleness
    /// clock.
    asked_at_event: u64,
    /// Boundary events an unanswered ask may span before the stale counter
    /// runs ([`ElasticConfig::stale_after_checks`]).
    stale_after: u64,
    stale_boundaries: u64,
    /// Forecast-driven pre-warming (None = reactive only).
    forecast: Option<ForecastEngine>,
    /// Last projected cell we asked the planner to pre-warm.
    last_forecast_key: Option<CacheKey>,
    /// Timestamp of the last snapshot the forecaster scored/observed — a
    /// probe and the acquire that follows it share a `vt`, and the engine
    /// must see each boundary exactly once.
    last_forecast_t: f64,
    /// Projections waiting to mature for horizon-error accounting.
    pending_forecasts: VecDeque<PendingForecast>,
    forecast_evals: u64,
    forecast_bucket_err: u64,
    checks: u64,
    /// Ring of the most recent boundary-stall samples.
    stalls: Vec<Duration>,
    stall_cursor: usize,
}

impl ElasticFrontend {
    /// Plan for the trace's `t = 0` conditions and start the background
    /// planner — the scripted-simulation entry point.
    pub fn start(
        model: Model,
        base: Testbed,
        trace: ConditionTrace,
        cfg: ElasticConfig,
    ) -> ElasticFrontend {
        Self::start_with_source(model, base, Box::new(trace), cfg)
    }

    /// Start against any [`ConditionSource`] — scripted traces and the
    /// probe-measured [`crate::telemetry::TelemetrySource`] drive the
    /// identical adaptation stack through this one entry point.
    pub fn start_with_source(
        model: Model,
        base: Testbed,
        mut source: Box<dyn ConditionSource>,
        cfg: ElasticConfig,
    ) -> ElasticFrontend {
        assert_eq!(source.node_count(), base.nodes, "source/testbed node mismatch");
        let snap0 = source.sample(0.0);
        let model_name = model.name.clone();
        let stale_after = cfg.stale_after_checks;
        let forecast = cfg.forecast.clone().map(|fcfg| ForecastEngine::new(base.nodes, fcfg));
        let replanner = BackgroundReplanner::start(model, base, &snap0, cfg);
        let cur = replanner.slot().load();
        ElasticFrontend {
            source,
            model_name,
            replanner,
            cur,
            last_asked: None,
            boundary_events: 0,
            asked_at_event: 0,
            stale_after,
            stale_boundaries: 0,
            forecast,
            last_forecast_key: None,
            last_forecast_t: f64::NEG_INFINITY,
            pending_forecasts: VecDeque::new(),
            forecast_evals: 0,
            forecast_bucket_err: 0,
            checks: 0,
            stalls: Vec::new(),
            stall_cursor: 0,
        }
    }

    /// Consult the frontend at a batch boundary (virtual time `vt`).
    ///
    /// Steady state: sample the trace, one atomic epoch load, done — no
    /// locks, no planning. On a cell shift with an unchanged node set, the
    /// ask is fire-and-forget and the published (stale-cell but valid) plan
    /// keeps serving. Only a node-set change rendezvouses with the planner,
    /// and the speculative n−1 cache makes that a lookup, not a search.
    pub fn acquire(&mut self, vt: f64) -> BoundaryDecision {
        let t0 = Instant::now();
        self.checks += 1;
        self.boundary_events += 1;
        let snap = self.source.sample(vt);
        self.replanner.slot().refresh(&mut self.cur);
        if snap.alive != self.cur.alive {
            self.replanner.failover(snap.clone());
            self.replanner.slot().refresh(&mut self.cur);
            self.last_asked = None;
        } else {
            let key = CacheKey::new(&self.model_name, snap.quantize());
            self.track_drift_ask(&snap, key);
        }
        self.run_forecast(&snap);
        let nodes = snap.alive_count();
        let leader = elect_leader(&snap.alive).expect("no surviving node");
        let decision = BoundaryDecision {
            plan: self.cur.plan.clone(),
            alive: snap.alive,
            nodes,
            leader,
            cost_per_item: self.cur.cost_per_item,
        };
        let stall = t0.elapsed();
        if self.stalls.len() < MAX_STALL_SAMPLES {
            self.stalls.push(stall);
        } else {
            self.stalls[self.stall_cursor] = stall;
            self.stall_cursor = (self.stall_cursor + 1) % MAX_STALL_SAMPLES;
        }
        decision
    }

    /// Cheap per-batch probe for the *pipelined* serving path: does the
    /// running generation have to drain? True when the liveness mask at `vt`
    /// differs from the current generation's, or when the background planner
    /// has published a new plan epoch. A condition-cell shift with an
    /// unchanged node set fires the same fire-and-forget `Observe` ask as
    /// [`Self::acquire`] — the replanner's eventual publication is what
    /// flips this probe to true — but the probe itself never rendezvouses,
    /// never counts as a consultation, and never changes the served plan:
    /// the full `acquire` runs once per drained generation instead of once
    /// per batch.
    pub fn needs_flush(&mut self, vt: f64) -> bool {
        self.boundary_events += 1;
        let snap = self.source.sample(vt);
        if snap.alive != self.cur.alive {
            return true;
        }
        let key = CacheKey::new(&self.model_name, snap.quantize());
        self.track_drift_ask(&snap, key);
        self.run_forecast(&snap);
        self.replanner.slot().epoch() != self.cur.epoch
    }

    /// Shared drift-ask bookkeeping for consultations and probes: send the
    /// fire-and-forget ask once per cell, stop the clock once the published
    /// plan covers the cell, and count every boundary event served past the
    /// staleness bound — on *both* serving shapes, so a wedged planner
    /// thread surfaces as [`crate::metrics::AdaptationMetrics`]'s
    /// `stale_plan_boundaries` no matter how the router drives us.
    fn track_drift_ask(&mut self, snap: &ClusterSnapshot, key: CacheKey) {
        if key == self.cur.key {
            // published plan covers this cell: any outstanding ask is
            // satisfied (or superseded) — stop the staleness clock
            self.last_asked = None;
            return;
        }
        // The clock anchors at the OLDEST unanswered ask and only resets
        // once a publication covers the conditions being served: under
        // continuing drift each new cell re-asks, but a wedged planner
        // must still trip the bound — resetting per ask would hide it for
        // as long as the conditions keep moving.
        if self.last_asked.is_none() {
            self.asked_at_event = self.boundary_events;
        } else if self.boundary_events.saturating_sub(self.asked_at_event) > self.stale_after {
            // an ask has been outstanding past the staleness bound and
            // this boundary is being served on the outdated plan: a wedged
            // planner thread surfaces here instead of staying silent
            self.stale_boundaries += 1;
        }
        if self.last_asked.as_ref() != Some(&key) {
            self.replanner.observe(snap.clone());
            self.last_asked = Some(key);
        }
    }

    /// Whether original-rank `leader` is down at virtual time `vt` — the
    /// pipelined router's second probe, distinguishing a *leader* loss
    /// (the gather owner holding every in-flight output is gone → the
    /// generation must abort and its requests fail explicitly) from any
    /// other flush (drain normally; outputs stay reachable). Pure source
    /// sampling: no planner interaction, no counters.
    pub fn leader_lost(&mut self, vt: f64, leader: usize) -> bool {
        !self.source.sample(vt).alive[leader]
    }

    /// Forward a passive traffic observation (boundary payload `bytes` in
    /// `msgs` messages, finished at `vt`) to the condition source. The
    /// router calls this after each executed batch: for a measured source
    /// the cluster's own traffic becomes the bandwidth probe; scripted
    /// traces ignore it.
    pub fn observe_traffic(&mut self, vt: f64, bytes: u64, msgs: u64) {
        self.source.observe_traffic(vt, bytes, msgs);
    }

    /// Deterministic rendezvous with the planner thread (see
    /// [`BackgroundReplanner::quiesce`]); tests and benches only.
    pub fn quiesce(&self) {
        self.replanner.quiesce();
    }

    /// Feed the forecaster and, when the projection leaves the published
    /// plan's cell, ask the planner to pre-warm it. Also scores matured
    /// projections against the conditions that actually arrived.
    fn run_forecast(&mut self, snap: &ClusterSnapshot) {
        if self.forecast.is_none() || snap.t <= self.last_forecast_t {
            // reactive-only, or this boundary was already observed (a
            // pipelined probe and the acquire that follows share a vt —
            // scoring it twice would inflate the horizon-error counters)
            return;
        }
        self.last_forecast_t = snap.t;
        let Some(engine) = &mut self.forecast else {
            return;
        };
        // score matured projections against reality first
        let actual_bucket = snap.quantize().bw_bucket;
        while let Some(front) = self.pending_forecasts.front() {
            if front.matures_at > snap.t {
                break;
            }
            let predicted = self.pending_forecasts.pop_front().unwrap().bw_bucket;
            self.forecast_evals += 1;
            self.forecast_bucket_err += u64::from(predicted.abs_diff(actual_bucket));
        }
        engine.observe(snap);
        let Some(projected) = engine.projected() else {
            return;
        };
        if self.pending_forecasts.len() == MAX_PENDING_FORECASTS {
            self.pending_forecasts.pop_front();
        }
        self.pending_forecasts.push_back(PendingForecast {
            matures_at: projected.t,
            bw_bucket: projected.quantize().bw_bucket,
        });
        let key = CacheKey::new(&self.model_name, projected.quantize());
        if key != self.cur.key && self.last_forecast_key.as_ref() != Some(&key) {
            self.last_forecast_key = Some(key);
            self.replanner.prewarm(projected);
        }
    }

    /// Stop the planner (draining queued asks) and return the adaptation
    /// counters plus the distribution of batch-boundary acquisition stalls.
    pub fn finish(mut self) -> (AdaptationMetrics, Summary) {
        let mut metrics = self.replanner.finish();
        // checks and the router-side forecast/staleness accounting are a
        // frontend notion: fold them in here
        metrics.checks = self.checks;
        metrics.stale_plan_boundaries = self.stale_boundaries;
        metrics.forecast_evals = self.forecast_evals;
        metrics.forecast_bucket_err = self.forecast_bucket_err;
        (metrics, summarize(&self.stalls))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::net::{Bandwidth, Topology};
    use crate::partition::Scheme;

    fn base() -> Testbed {
        Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0))
    }

    fn version(epoch: u64) -> Arc<PlanVersion> {
        Arc::new(PlanVersion {
            epoch,
            plan: Arc::new(Plan::uniform(Scheme::InH, 4)),
            key: CacheKey::new("m", ConditionTrace::stable(4).sample(0.0).quantize()),
            alive: vec![true; 4],
            nodes: 4,
            cost_per_item: 1.0,
        })
    }

    #[test]
    fn plan_slot_fast_path_only_reloads_on_publish() {
        let slot = PlanSlot::new(version(1));
        let mut cached = slot.load();
        assert!(!slot.refresh(&mut cached), "no publish → no reload");
        assert_eq!(cached.epoch, 1);
        slot.publish(version(2));
        assert_eq!(slot.epoch(), 2);
        assert!(slot.refresh(&mut cached));
        assert_eq!(cached.epoch, 2);
        assert!(!slot.refresh(&mut cached));
    }

    #[test]
    fn stable_trace_never_asks_the_planner() {
        let model = zoo::edgenet(16);
        let trace = ConditionTrace::stable(4);
        let mut fe = ElasticFrontend::start(model.clone(), base(), trace, ElasticConfig::default());
        let p0 = fe.cur.plan.clone();
        for i in 0..10 {
            let d = fe.acquire(i as f64 * 0.01);
            assert_eq!(d.nodes, 4);
            assert_eq!(*d.plan, *p0, "stable conditions must keep the initial plan");
        }
        let (m, stalls) = fe.finish();
        assert_eq!(m.checks, 10);
        assert_eq!(m.plan_swaps, 0);
        assert_eq!(m.failovers, 0);
        assert_eq!(m.inline_replans, 0);
        // healthy-cluster speculation ran in the background regardless —
        // one n−1 cell per alive node, the leader's included
        assert_eq!(m.speculative_plans, 4);
        assert_eq!(m.replans, 5); // initial + 4 speculative
        assert_eq!(stalls.count, 10);
    }

    #[test]
    fn needs_flush_tracks_node_set_and_epoch_but_never_swaps() {
        let model = zoo::edgenet(16);
        // node 2 dies at t = 1; a dip starts at t = 10
        let trace = ConditionTrace::stable(4)
            .with_outage(2, 1.0, 5.0)
            .with_bandwidth_dip(10.0, f64::INFINITY, 0.1);
        let mut fe = ElasticFrontend::start(model, base(), trace, ElasticConfig::default());
        let epoch0 = fe.cur.epoch;
        assert!(!fe.needs_flush(0.5), "healthy steady state must not flush");
        assert_eq!(fe.cur.epoch, epoch0, "probe must not adopt a plan");
        assert!(fe.needs_flush(1.5), "node loss must force a drain");
        // the probe did not rendezvous: the cached version is unchanged
        assert_eq!(fe.cur.alive, vec![true; 4]);
        // acquire (the per-generation consultation) performs the failover
        let d = fe.acquire(1.5);
        assert_eq!(d.nodes, 3);
        // recovery: mask differs from the 3-node generation → drain again
        assert!(fe.needs_flush(6.0));
        let d = fe.acquire(6.0);
        assert_eq!(d.nodes, 4);
        // bandwidth collapse: the probe fires the observe ask and reports a
        // flush only once the background planner publishes
        let deadline = Instant::now() + Duration::from_secs(30);
        while !fe.needs_flush(10.5) {
            assert!(
                Instant::now() < deadline,
                "drift publication never flipped the flush probe"
            );
            std::thread::yield_now();
        }
        let d = fe.acquire(10.5);
        assert_eq!(d.nodes, 4);
        let (m, _) = fe.finish();
        assert_eq!(m.checks, 3, "probes must not count as consultations: {m}");
        assert_eq!(m.inline_replans, 0, "{m}");
    }

    #[test]
    fn leader_loss_probe_and_failover_hand_off() {
        // node 0 dies over [1, 5): the probe sees it, the flush fires, the
        // failover elects rank 1, and the rejoin hands leadership back
        let model = zoo::edgenet(16);
        let trace = ConditionTrace::stable(4).with_outage(0, 1.0, 5.0);
        let mut fe = ElasticFrontend::start(model, base(), trace, ElasticConfig::default());
        assert!(!fe.leader_lost(0.5, 0));
        assert!(fe.leader_lost(1.5, 0), "leader outage missed by the probe");
        assert!(fe.needs_flush(1.5), "leader loss must force a flush");
        let d = fe.acquire(1.5);
        assert_eq!(d.nodes, 3);
        assert_eq!(d.alive, vec![false, true, true, true]);
        assert_eq!(d.leader, 1, "lowest surviving rank must lead");
        assert!(!fe.leader_lost(1.5, d.leader));
        // rejoin: original rank 0 reclaims leadership deterministically
        let d = fe.acquire(5.5);
        assert_eq!(d.nodes, 4);
        assert_eq!(d.leader, 0);
        let (m, _) = fe.finish();
        assert_eq!(m.checks, 2, "probes must not count as consultations: {m}");
        assert_eq!(m.failovers, 2);
        assert_eq!(m.leader_handoffs, 2, "down + reclaim handoffs: {m}");
        assert!(
            m.speculative_hits >= 1,
            "leader failover was not served from the speculative cache: {m}"
        );
        assert_eq!(m.inline_replans, 0, "{m}");
    }

    /// A frontend whose planner is *wedged*: the ask channel exists and
    /// accepts sends, but nothing ever drains it or publishes. Exactly the
    /// failure mode the staleness bound is for, constructed deterministically.
    fn wedged_frontend(
        trace: ConditionTrace,
        stale_after: u64,
    ) -> (ElasticFrontend, Receiver<Ask>) {
        let v0 = version(1);
        let slot = Arc::new(PlanSlot::new(v0));
        let (tx, rx) = channel::<Ask>();
        let replanner = BackgroundReplanner { slot: slot.clone(), tx: Some(tx), handle: None };
        let cur = slot.load();
        let fe = ElasticFrontend {
            source: Box::new(trace),
            model_name: "m".into(),
            replanner,
            cur,
            last_asked: None,
            boundary_events: 0,
            asked_at_event: 0,
            stale_after,
            stale_boundaries: 0,
            forecast: None,
            last_forecast_key: None,
            last_forecast_t: f64::NEG_INFINITY,
            pending_forecasts: VecDeque::new(),
            forecast_evals: 0,
            forecast_bucket_err: 0,
            checks: 0,
            stalls: Vec::new(),
            stall_cursor: 0,
        };
        (fe, rx)
    }

    #[test]
    fn wedged_planner_surfaces_as_stale_plan_boundaries() {
        // permanent collapse at t = 0: every boundary sits outside the
        // published plan's cell, the drift ask goes out once, and nothing
        // ever answers it — after `stale_after` more boundaries, each
        // further boundary on the old plan must count as stale
        let trace = ConditionTrace::stable(4).with_bandwidth_dip(0.0, f64::INFINITY, 0.1);
        let (mut fe, rx) = wedged_frontend(trace, 2);
        for k in 0..6 {
            let d = fe.acquire(k as f64 + 0.5);
            assert_eq!(d.nodes, 4, "wedged planner must not affect serving");
        }
        // exactly one ask went out (no re-send storm against a dead thread)
        assert_eq!(rx.try_iter().count(), 1, "ask was re-sent every boundary");
        let (m, stalls) = fe.finish();
        assert_eq!(m.checks, 6);
        // asked at check 1; checks 4, 5, 6 exceed the bound of 2
        assert_eq!(m.stale_plan_boundaries, 3, "{m}");
        assert_eq!(stalls.count, 6);
    }

    #[test]
    fn wedged_planner_stays_visible_under_continuing_drift() {
        // conditions keep crossing cells while the planner is wedged: each
        // new cell re-asks, but the staleness clock must anchor at the
        // oldest unanswered ask — drift must not keep resetting it, or the
        // wedge would stay invisible exactly when it hurts most
        let trace = ConditionTrace::stable(4)
            .with_bandwidth_dip(0.0, 2.0, 0.8)
            .with_bandwidth_dip(2.0, 4.0, 0.6)
            .with_bandwidth_dip(4.0, f64::INFINITY, 0.4);
        let (mut fe, rx) = wedged_frontend(trace, 2);
        for k in 0..6 {
            fe.acquire(k as f64 + 0.5); // cells: 0.8, 0.8, 0.6, 0.6, 0.4, 0.4
        }
        assert_eq!(rx.try_iter().count(), 3, "one ask per newly entered cell");
        let (m, _) = fe.finish();
        // oldest unanswered ask at event 1; events 4, 5, 6 exceed bound 2
        assert_eq!(m.stale_plan_boundaries, 3, "drift reset the staleness clock: {m}");
    }

    #[test]
    fn wedged_planner_surfaces_through_pipelined_probes_too() {
        // the pipelined router only probes (needs_flush) while the epoch
        // hasn't moved — exactly the wedged case — so the canary must fire
        // from probes alone, without a single full consultation
        let trace = ConditionTrace::stable(4).with_bandwidth_dip(0.0, f64::INFINITY, 0.1);
        let (mut fe, rx) = wedged_frontend(trace, 2);
        for k in 0..6 {
            assert!(!fe.needs_flush(k as f64 + 0.5), "a wedged planner cannot publish");
        }
        assert_eq!(rx.try_iter().count(), 1, "ask was re-sent every probe");
        let (m, _) = fe.finish();
        assert_eq!(m.checks, 0, "probes must not count as consultations");
        // asked at probe event 1; events 4, 5, 6 exceed the bound of 2
        assert_eq!(m.stale_plan_boundaries, 3, "{m}");
    }

    #[test]
    fn healthy_planner_never_reports_staleness() {
        // the same collapse against a live planner: the ask is answered,
        // the new cell is adopted, and the stale counter stays at zero
        let model = zoo::edgenet(16);
        let trace = ConditionTrace::stable(4).with_bandwidth_dip(1.0, f64::INFINITY, 0.1);
        let cfg = ElasticConfig { stale_after_checks: 1, ..ElasticConfig::default() };
        let mut fe = ElasticFrontend::start(model, base(), trace, cfg);
        for k in 0..8 {
            fe.acquire(k as f64 + 0.5);
            // rendezvous so the drift publication always lands within the
            // (deliberately tight) one-boundary staleness bound
            fe.quiesce();
        }
        let (m, _) = fe.finish();
        assert_eq!(m.stale_plan_boundaries, 0, "{m}");
        assert!(m.replans >= 2, "collapse never replanned: {m}");
    }

    #[test]
    fn forecast_prewarms_the_coming_cell_and_serves_it_warm() {
        // A scripted staircase descent (no RNG, no trig): the forecaster
        // must project the next quantized cell from the trend, the planner
        // must pre-warm it, and the shift itself must be a forecast-
        // attributed cache hit that runs no new search at the boundary.
        let model = zoo::edgenet(16);
        let trace = ConditionTrace::stable(4)
            .with_bandwidth_dip(1.0, 2.0, 0.95)
            .with_bandwidth_dip(2.0, 3.0, 0.90)
            .with_bandwidth_dip(3.0, 4.0, 0.85)
            .with_bandwidth_dip(4.0, 5.0, 0.80)
            .with_bandwidth_dip(5.0, f64::INFINITY, 0.75);
        let cfg = ElasticConfig {
            forecast: Some(crate::telemetry::ForecastConfig::default()),
            ..ElasticConfig::default()
        };
        let mut fe = ElasticFrontend::start(model, base(), trace, cfg);
        for k in 0..20 {
            let d = fe.acquire(k as f64 * 0.5);
            assert_eq!(d.nodes, 4);
            // rendezvous: pre-warms complete before the next boundary, so
            // the assertion below is deterministic
            fe.quiesce();
        }
        let (m, _) = fe.finish();
        assert!(m.forecasts >= 1, "no pre-warm was ever requested: {m}");
        assert!(m.forecast_plans >= 1, "no forecast cell was ever planned: {m}");
        assert!(
            m.forecast_hits >= 1,
            "a predicted shift was not served from the forecast-warmed cache: {m}"
        );
        assert!(m.forecast_evals >= 1, "no projection ever matured: {m}");
        assert_eq!(m.inline_replans, 0, "{m}");
        assert_eq!(m.failovers, 0, "{m}");
        assert_eq!(m.stale_plan_boundaries, 0, "{m}");
    }

    #[test]
    fn bandwidth_shift_is_fire_and_forget_and_lands_between_batches() {
        // collapse the link permanently; the boundary that sees it must not
        // wait for the replan, and the new plan must eventually be adopted
        let model = zoo::edgenet(16);
        let trace = ConditionTrace::stable(4).with_bandwidth_dip(1.0, f64::INFINITY, 0.1);
        let mut fe = ElasticFrontend::start(model.clone(), base(), trace, ElasticConfig::default());
        let d0 = fe.acquire(0.5);
        assert_eq!(d0.nodes, 4);
        let epoch_before = fe.cur.epoch;
        let d1 = fe.acquire(1.5); // sees the dip, keeps serving immediately
        assert_eq!(d1.nodes, 4);
        // the ask is async: give the planner a bounded moment to publish
        let deadline = Instant::now() + Duration::from_secs(30);
        while fe.replanner.slot().epoch() == epoch_before {
            assert!(Instant::now() < deadline, "planner never published the drift replan");
            std::thread::yield_now();
        }
        let d2 = fe.acquire(2.5);
        assert!(fe.cur.epoch > epoch_before, "published plan was not picked up");
        assert_eq!(d2.nodes, 4);
        let (m, _) = fe.finish();
        assert_eq!(m.checks, 3);
        assert!(m.degraded_checks >= 1, "collapse never reached the monitor: {m}");
        assert_eq!(m.inline_replans, 0, "drift replans must run in the background: {m}");
    }
}
