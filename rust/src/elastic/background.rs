//! Non-blocking replanning: a dedicated planner thread behind an atomic
//! plan slot.
//!
//! PR 1 ran the whole monitor → replan → swap pipeline inline at every
//! batch boundary, so a cold DPP search stood between a condition shift and
//! the next batch. This module moves all of it off the serving path:
//!
//! * [`PlanSlot`] — the published plan: a seqlock-style epoch counter in
//!   front of the current [`PlanVersion`]. The router's steady-state
//!   acquisition is **one atomic load** (epoch compare against its locally
//!   cached version); only when the planner actually published something new
//!   does the router take the uncontended read lock to fetch the new `Arc`.
//! * [`BackgroundReplanner`] — the planner thread: owns the
//!   [`ReplanCore`](super::controller) (monitor, plan cache, memoized
//!   parallel DPP) and serves asynchronous observation messages from the
//!   router. While the cluster is healthy it speculatively pre-computes the
//!   best n−1 failover plan for every alive node — the leader included —
//!   into the LRU plan cache, and refreshes that set whenever conditions
//!   shift cells — so any node loss, leader or worker, is served by a pure
//!   cache hit.
//! * [`ElasticFrontend`] — the router-side handle: samples the condition
//!   trace (cheap and deterministic), compares the liveness mask and
//!   quantized cell against the cached version, and either proceeds with
//!   the published plan (bandwidth drift: fire-and-forget `Observe`, keep
//!   serving on the stale-but-valid plan) or — only when the node *set*
//!   changed, where executing with stale cost bookkeeping would corrupt the
//!   virtual clock — rendezvouses with the planner, which answers from the
//!   speculative cache.
//!
//! The split keeps every batch boundary wait-free in the common case,
//! bounded by a cache lookup on failover, and never blocked on a DPP
//! search for any condition the speculative pass has covered.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

use super::cache::CacheKey;
use super::conditions::{ClusterSnapshot, ConditionTrace};
use super::controller::{ElasticConfig, ReplanCore};
use crate::cluster::election::elect_leader;
use crate::metrics::{summarize, AdaptationMetrics, Summary};
use crate::model::Model;
use crate::net::Testbed;
use crate::partition::Plan;

/// One published planning decision: everything a batch boundary needs,
/// immutable once published.
#[derive(Debug, Clone)]
pub struct PlanVersion {
    /// Publication sequence number (strictly increasing).
    pub epoch: u64,
    pub plan: Arc<Plan>,
    /// Condition cell the plan was decided for.
    pub key: CacheKey,
    /// Liveness mask the plan was decided for. The leader is *derived*,
    /// never cached: consumers elect from the freshest mask they hold
    /// ([`crate::cluster::election::elect_leader`]), so a published
    /// version can never serve a stale leader identity.
    pub alive: Vec<bool>,
    /// Effective node count of that mask.
    pub nodes: usize,
    /// Predicted virtual seconds per item at decision time.
    pub cost_per_item: f64,
}

/// The atomic plan slot: single-writer (the planner thread), any-reader.
/// Readers that cache the current `Arc<PlanVersion>` pay one atomic epoch
/// load per check; the lock is touched only across an actual publication.
pub struct PlanSlot {
    epoch: AtomicU64,
    cur: RwLock<Arc<PlanVersion>>,
}

impl PlanSlot {
    pub fn new(initial: Arc<PlanVersion>) -> PlanSlot {
        PlanSlot { epoch: AtomicU64::new(initial.epoch), cur: RwLock::new(initial) }
    }

    /// The epoch of the most recent publication (one atomic load).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// The current version (takes the read lock).
    pub fn load(&self) -> Arc<PlanVersion> {
        self.cur.read().unwrap().clone()
    }

    /// Publish a new version: store it, then advance the epoch so readers
    /// observing the new epoch always find (at least) this version.
    pub fn publish(&self, v: Arc<PlanVersion>) {
        let e = v.epoch;
        *self.cur.write().unwrap() = v;
        self.epoch.store(e, Ordering::Release);
    }

    /// Reader fast path: refresh `cached` only if the slot moved on.
    /// Returns whether `cached` was replaced. Steady state is a single
    /// atomic load and no lock.
    pub fn refresh(&self, cached: &mut Arc<PlanVersion>) -> bool {
        if self.epoch() == cached.epoch {
            return false;
        }
        *cached = self.load();
        true
    }
}

/// Messages from the router to the planner thread.
enum Ask {
    /// Conditions left the published plan's cell (same node set): decide in
    /// the background and publish; the router keeps serving meanwhile.
    Observe(ClusterSnapshot),
    /// The node set changed: decide (speculative cache hit in the covered
    /// cases), publish, then ack so the caller can pick up the new version.
    Failover(ClusterSnapshot, SyncSender<()>),
}

/// The dedicated planner thread plus its publication slot. Usually driven
/// through [`ElasticFrontend`]; exposed for tests and custom routers.
pub struct BackgroundReplanner {
    slot: Arc<PlanSlot>,
    tx: Option<Sender<Ask>>,
    handle: Option<std::thread::JoinHandle<AdaptationMetrics>>,
}

impl BackgroundReplanner {
    /// Plan for `snap0` on the caller's thread (a server must not accept
    /// traffic before any plan exists), publish epoch 1, then hand the core
    /// to the planner thread, which immediately pre-computes the n−1
    /// failover set before serving its first message.
    pub fn start(
        model: Model,
        base: Testbed,
        snap0: &ClusterSnapshot,
        cfg: ElasticConfig,
    ) -> BackgroundReplanner {
        let core = ReplanCore::new(model, base, snap0, cfg, /* inline = */ false);
        let v0 = Arc::new(PlanVersion {
            epoch: 1,
            plan: core.active_plan(),
            key: core.active_key.clone(),
            alive: snap0.alive.clone(),
            nodes: snap0.alive_count(),
            cost_per_item: core.active_cost,
        });
        let slot = Arc::new(PlanSlot::new(v0));
        let (tx, rx) = channel::<Ask>();
        let thread_slot = slot.clone();
        let init_snap = snap0.clone();
        let handle = std::thread::spawn(move || planner_main(core, init_snap, thread_slot, rx));
        BackgroundReplanner { slot, tx: Some(tx), handle: Some(handle) }
    }

    pub fn slot(&self) -> &Arc<PlanSlot> {
        &self.slot
    }

    fn observe(&self, snap: ClusterSnapshot) {
        if let Some(tx) = &self.tx {
            let _ = tx.send(Ask::Observe(snap));
        }
    }

    /// Rendezvous: returns once the planner has published a decision for
    /// `snap`'s node set.
    fn failover(&self, snap: ClusterSnapshot) {
        let (ack_tx, ack_rx) = sync_channel(1);
        if let Some(tx) = &self.tx {
            if tx.send(Ask::Failover(snap, ack_tx)).is_ok() {
                ack_rx.recv().expect("background planner died during failover");
            }
        }
    }

    /// Stop the planner (it drains every queued ask first) and collect its
    /// adaptation counters.
    fn finish(&mut self) -> AdaptationMetrics {
        drop(self.tx.take());
        match self.handle.take() {
            Some(h) => h.join().expect("background planner panicked"),
            None => AdaptationMetrics::default(),
        }
    }
}

impl Drop for BackgroundReplanner {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn planner_main(
    mut core: ReplanCore,
    init_snap: ClusterSnapshot,
    slot: Arc<PlanSlot>,
    rx: Receiver<Ask>,
) -> AdaptationMetrics {
    let mut epoch = 1u64;
    // Healthy-cluster speculation runs before the first ask is served, so
    // any failover arriving later in this thread's queue is a cache hit.
    core.speculate_failovers(&init_snap);
    while let Ok(first) = rx.recv() {
        // Drain the queue before re-speculating: a failover rendezvous must
        // only ever wait behind decide() work (cache-first), never behind a
        // batch of speculative n−1 searches for a superseded regime.
        let mut ask = first;
        let last_snap = loop {
            let snap = match ask {
                Ask::Observe(snap) => {
                    let d = core.decide(&snap);
                    epoch += 1;
                    publish(&slot, epoch, &core, &d, &snap);
                    snap
                }
                Ask::Failover(snap, ack) => {
                    let d = core.decide(&snap);
                    epoch += 1;
                    publish(&slot, epoch, &core, &d, &snap);
                    let _ = ack.send(());
                    snap
                }
            };
            match rx.try_recv() {
                Ok(next) => ask = next,
                Err(_) => break snap,
            }
        };
        // queue is idle: refresh the speculative n−1 set for the regime we
        // actually ended up in (a no-op for cells the cache already holds)
        core.speculate_failovers(&last_snap);
    }
    core.metrics()
}

fn publish(
    slot: &PlanSlot,
    epoch: u64,
    core: &ReplanCore,
    d: &super::controller::BatchDecision,
    snap: &ClusterSnapshot,
) {
    slot.publish(Arc::new(PlanVersion {
        epoch,
        plan: d.plan.clone(),
        key: core.active_key.clone(),
        alive: snap.alive.clone(),
        nodes: d.testbed.nodes,
        cost_per_item: d.cost_per_item,
    }));
}

/// What a batch boundary gets back from [`ElasticFrontend::acquire`]: the
/// published plan plus the *fresh* liveness mask execution must respect.
#[derive(Debug, Clone)]
pub struct BoundaryDecision {
    pub plan: Arc<Plan>,
    /// Current per-node liveness (always fresh — a batch must never be
    /// scheduled onto a dead node, even while the optimized plan for the
    /// new membership is still being fetched).
    pub alive: Vec<bool>,
    /// Alive-node count (what [`crate::serve::Response::nodes`] reports).
    pub nodes: usize,
    /// Elected leader of the *fresh* mask (lowest surviving rank): the
    /// original rank owning scatter/ingress and gather for the next batch.
    pub leader: usize,
    /// Predicted virtual seconds per item, from the published version.
    pub cost_per_item: f64,
}

/// The router-side handle: trace sampling + plan acquisition + the
/// fire-and-forget / rendezvous messaging described in the module docs.
/// Boundary-stall samples kept for the shutdown summary (a bounded ring —
/// a server that runs for days must not grow per-boundary state without
/// bound, same invariant as [`super::controller::MAX_EVENTS`]).
const MAX_STALL_SAMPLES: usize = 4096;

pub struct ElasticFrontend {
    trace: ConditionTrace,
    model_name: String,
    replanner: BackgroundReplanner,
    /// Locally cached version — the epoch fast path compares against this.
    cur: Arc<PlanVersion>,
    /// Cell we last asked the planner about, to avoid re-sending an ask
    /// every boundary while the planner is still working on it.
    last_asked: Option<CacheKey>,
    checks: u64,
    /// Ring of the most recent boundary-stall samples.
    stalls: Vec<Duration>,
    stall_cursor: usize,
}

impl ElasticFrontend {
    /// Plan for the trace's `t = 0` conditions and start the background
    /// planner.
    pub fn start(
        model: Model,
        base: Testbed,
        trace: ConditionTrace,
        cfg: ElasticConfig,
    ) -> ElasticFrontend {
        assert_eq!(trace.nodes, base.nodes, "trace/testbed node mismatch");
        let snap0 = trace.sample(0.0);
        let model_name = model.name.clone();
        let replanner = BackgroundReplanner::start(model, base, &snap0, cfg);
        let cur = replanner.slot().load();
        ElasticFrontend {
            trace,
            model_name,
            replanner,
            cur,
            last_asked: None,
            checks: 0,
            stalls: Vec::new(),
            stall_cursor: 0,
        }
    }

    /// Consult the frontend at a batch boundary (virtual time `vt`).
    ///
    /// Steady state: sample the trace, one atomic epoch load, done — no
    /// locks, no planning. On a cell shift with an unchanged node set, the
    /// ask is fire-and-forget and the published (stale-cell but valid) plan
    /// keeps serving. Only a node-set change rendezvouses with the planner,
    /// and the speculative n−1 cache makes that a lookup, not a search.
    pub fn acquire(&mut self, vt: f64) -> BoundaryDecision {
        let t0 = Instant::now();
        self.checks += 1;
        let snap = self.trace.sample(vt);
        self.replanner.slot().refresh(&mut self.cur);
        if snap.alive != self.cur.alive {
            self.replanner.failover(snap.clone());
            self.replanner.slot().refresh(&mut self.cur);
            self.last_asked = None;
        } else {
            let key = CacheKey::new(&self.model_name, snap.quantize());
            if key != self.cur.key && self.last_asked.as_ref() != Some(&key) {
                self.replanner.observe(snap.clone());
                self.last_asked = Some(key);
            }
        }
        let nodes = snap.alive_count();
        let leader = elect_leader(&snap.alive).expect("no surviving node");
        let decision = BoundaryDecision {
            plan: self.cur.plan.clone(),
            alive: snap.alive,
            nodes,
            leader,
            cost_per_item: self.cur.cost_per_item,
        };
        let stall = t0.elapsed();
        if self.stalls.len() < MAX_STALL_SAMPLES {
            self.stalls.push(stall);
        } else {
            self.stalls[self.stall_cursor] = stall;
            self.stall_cursor = (self.stall_cursor + 1) % MAX_STALL_SAMPLES;
        }
        decision
    }

    /// Cheap per-batch probe for the *pipelined* serving path: does the
    /// running generation have to drain? True when the liveness mask at `vt`
    /// differs from the current generation's, or when the background planner
    /// has published a new plan epoch. A condition-cell shift with an
    /// unchanged node set fires the same fire-and-forget `Observe` ask as
    /// [`Self::acquire`] — the replanner's eventual publication is what
    /// flips this probe to true — but the probe itself never rendezvouses,
    /// never counts as a consultation, and never changes the served plan:
    /// the full `acquire` runs once per drained generation instead of once
    /// per batch.
    pub fn needs_flush(&mut self, vt: f64) -> bool {
        let snap = self.trace.sample(vt);
        if snap.alive != self.cur.alive {
            return true;
        }
        let key = CacheKey::new(&self.model_name, snap.quantize());
        if key != self.cur.key && self.last_asked.as_ref() != Some(&key) {
            self.replanner.observe(snap);
            self.last_asked = Some(key);
        }
        self.replanner.slot().epoch() != self.cur.epoch
    }

    /// Whether original-rank `leader` is down at virtual time `vt` — the
    /// pipelined router's second probe, distinguishing a *leader* loss
    /// (the gather owner holding every in-flight output is gone → the
    /// generation must abort and its requests fail explicitly) from any
    /// other flush (drain normally; outputs stay reachable). Pure trace
    /// sampling: no planner interaction, no counters.
    pub fn leader_lost(&self, vt: f64, leader: usize) -> bool {
        !self.trace.sample(vt).alive[leader]
    }

    /// Stop the planner (draining queued asks) and return the adaptation
    /// counters plus the distribution of batch-boundary acquisition stalls.
    pub fn finish(mut self) -> (AdaptationMetrics, Summary) {
        let mut metrics = self.replanner.finish();
        // checks are a router-side notion: one per consulted boundary
        metrics.checks = self.checks;
        (metrics, summarize(&self.stalls))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::net::{Bandwidth, Topology};
    use crate::partition::Scheme;

    fn base() -> Testbed {
        Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0))
    }

    fn version(epoch: u64) -> Arc<PlanVersion> {
        Arc::new(PlanVersion {
            epoch,
            plan: Arc::new(Plan::uniform(Scheme::InH, 4)),
            key: CacheKey::new("m", ConditionTrace::stable(4).sample(0.0).quantize()),
            alive: vec![true; 4],
            nodes: 4,
            cost_per_item: 1.0,
        })
    }

    #[test]
    fn plan_slot_fast_path_only_reloads_on_publish() {
        let slot = PlanSlot::new(version(1));
        let mut cached = slot.load();
        assert!(!slot.refresh(&mut cached), "no publish → no reload");
        assert_eq!(cached.epoch, 1);
        slot.publish(version(2));
        assert_eq!(slot.epoch(), 2);
        assert!(slot.refresh(&mut cached));
        assert_eq!(cached.epoch, 2);
        assert!(!slot.refresh(&mut cached));
    }

    #[test]
    fn stable_trace_never_asks_the_planner() {
        let model = zoo::edgenet(16);
        let trace = ConditionTrace::stable(4);
        let mut fe = ElasticFrontend::start(model.clone(), base(), trace, ElasticConfig::default());
        let p0 = fe.cur.plan.clone();
        for i in 0..10 {
            let d = fe.acquire(i as f64 * 0.01);
            assert_eq!(d.nodes, 4);
            assert_eq!(*d.plan, *p0, "stable conditions must keep the initial plan");
        }
        let (m, stalls) = fe.finish();
        assert_eq!(m.checks, 10);
        assert_eq!(m.plan_swaps, 0);
        assert_eq!(m.failovers, 0);
        assert_eq!(m.inline_replans, 0);
        // healthy-cluster speculation ran in the background regardless —
        // one n−1 cell per alive node, the leader's included
        assert_eq!(m.speculative_plans, 4);
        assert_eq!(m.replans, 5); // initial + 4 speculative
        assert_eq!(stalls.count, 10);
    }

    #[test]
    fn needs_flush_tracks_node_set_and_epoch_but_never_swaps() {
        let model = zoo::edgenet(16);
        // node 2 dies at t = 1; a dip starts at t = 10
        let trace = ConditionTrace::stable(4)
            .with_outage(2, 1.0, 5.0)
            .with_bandwidth_dip(10.0, f64::INFINITY, 0.1);
        let mut fe = ElasticFrontend::start(model, base(), trace, ElasticConfig::default());
        let epoch0 = fe.cur.epoch;
        assert!(!fe.needs_flush(0.5), "healthy steady state must not flush");
        assert_eq!(fe.cur.epoch, epoch0, "probe must not adopt a plan");
        assert!(fe.needs_flush(1.5), "node loss must force a drain");
        // the probe did not rendezvous: the cached version is unchanged
        assert_eq!(fe.cur.alive, vec![true; 4]);
        // acquire (the per-generation consultation) performs the failover
        let d = fe.acquire(1.5);
        assert_eq!(d.nodes, 3);
        // recovery: mask differs from the 3-node generation → drain again
        assert!(fe.needs_flush(6.0));
        let d = fe.acquire(6.0);
        assert_eq!(d.nodes, 4);
        // bandwidth collapse: the probe fires the observe ask and reports a
        // flush only once the background planner publishes
        let deadline = Instant::now() + Duration::from_secs(30);
        while !fe.needs_flush(10.5) {
            assert!(
                Instant::now() < deadline,
                "drift publication never flipped the flush probe"
            );
            std::thread::yield_now();
        }
        let d = fe.acquire(10.5);
        assert_eq!(d.nodes, 4);
        let (m, _) = fe.finish();
        assert_eq!(m.checks, 3, "probes must not count as consultations: {m}");
        assert_eq!(m.inline_replans, 0, "{m}");
    }

    #[test]
    fn leader_loss_probe_and_failover_hand_off() {
        // node 0 dies over [1, 5): the probe sees it, the flush fires, the
        // failover elects rank 1, and the rejoin hands leadership back
        let model = zoo::edgenet(16);
        let trace = ConditionTrace::stable(4).with_outage(0, 1.0, 5.0);
        let mut fe = ElasticFrontend::start(model, base(), trace, ElasticConfig::default());
        assert!(!fe.leader_lost(0.5, 0));
        assert!(fe.leader_lost(1.5, 0), "leader outage missed by the probe");
        assert!(fe.needs_flush(1.5), "leader loss must force a flush");
        let d = fe.acquire(1.5);
        assert_eq!(d.nodes, 3);
        assert_eq!(d.alive, vec![false, true, true, true]);
        assert_eq!(d.leader, 1, "lowest surviving rank must lead");
        assert!(!fe.leader_lost(1.5, d.leader));
        // rejoin: original rank 0 reclaims leadership deterministically
        let d = fe.acquire(5.5);
        assert_eq!(d.nodes, 4);
        assert_eq!(d.leader, 0);
        let (m, _) = fe.finish();
        assert_eq!(m.checks, 2, "probes must not count as consultations: {m}");
        assert_eq!(m.failovers, 2);
        assert_eq!(m.leader_handoffs, 2, "down + reclaim handoffs: {m}");
        assert!(
            m.speculative_hits >= 1,
            "leader failover was not served from the speculative cache: {m}"
        );
        assert_eq!(m.inline_replans, 0, "{m}");
    }

    #[test]
    fn bandwidth_shift_is_fire_and_forget_and_lands_between_batches() {
        // collapse the link permanently; the boundary that sees it must not
        // wait for the replan, and the new plan must eventually be adopted
        let model = zoo::edgenet(16);
        let trace = ConditionTrace::stable(4).with_bandwidth_dip(1.0, f64::INFINITY, 0.1);
        let mut fe = ElasticFrontend::start(model.clone(), base(), trace, ElasticConfig::default());
        let d0 = fe.acquire(0.5);
        assert_eq!(d0.nodes, 4);
        let epoch_before = fe.cur.epoch;
        let d1 = fe.acquire(1.5); // sees the dip, keeps serving immediately
        assert_eq!(d1.nodes, 4);
        // the ask is async: give the planner a bounded moment to publish
        let deadline = Instant::now() + Duration::from_secs(30);
        while fe.replanner.slot().epoch() == epoch_before {
            assert!(Instant::now() < deadline, "planner never published the drift replan");
            std::thread::yield_now();
        }
        let d2 = fe.acquire(2.5);
        assert!(fe.cur.epoch > epoch_before, "published plan was not picked up");
        assert_eq!(d2.nodes, 4);
        let (m, _) = fe.finish();
        assert_eq!(m.checks, 3);
        assert!(m.degraded_checks >= 1, "collapse never reached the monitor: {m}");
        assert_eq!(m.inline_replans, 0, "drift replans must run in the background: {m}");
    }
}
