//! Deterministic chaos-test harness — seeded fault schedules and the
//! invariants that must survive them.
//!
//! The elastic subsystem claims that *any* node — the leader included —
//! can die mid-stream without the cluster losing work or corrupting an
//! output. This module makes that claim executable:
//!
//! * [`ChaosSchedule`] — a seeded, fully deterministic fault schedule:
//!   kills and restores of arbitrary nodes (every schedule is guaranteed
//!   to strike the *current leader* at least once — no immortal nodes),
//!   back-to-back double failures, and bandwidth collapses. The schedule
//!   compiles into a [`ConditionTrace`], so faults are injected exactly
//!   where the serving stack samples conditions: at batch boundaries.
//! * [`run_chaos`] — the driver: serves a request stream through
//!   [`crate::serve::Server::start_elastic`] under the schedule's trace
//!   and audits every single request.
//! * [`ChaosOutcome`] — the audit: after every event, surviving outputs
//!   must stay **bit-identical** to the fresh single-node reference
//!   ([`run_reference`]), no accepted request may be *silently* dropped
//!   (every one either completes or is explicitly failed and counted by
//!   the router), and completion order must be preserved (the router's
//!   delivery sequence numbers stay increasing in submission order).
//!   [`ChaosOutcome::verify`] enforces all three.
//!
//! A schedule is a pure function of `(nodes, seed, slots, slot_len)`:
//! re-running the same chaos test reproduces the same kills at the same
//! virtual times against the same deterministic inputs, so a failure in CI
//! replays locally bit for bit.
//!
//! ## Schedule generation
//!
//! Virtual time is divided into `slots` windows of `slot_len` seconds.
//! Each slot rolls one of: a single-node kill (any alive node, lasting
//! 1–2.5 slots), a back-to-back double kill (two nodes, 5% of a slot
//! apart), a bandwidth collapse (to 10–40% for 0.5–1.5 slots), or a quiet
//! slot. Kills are only scheduled while at least two nodes are up at the
//! kill instant, which structurally guarantees a survivor at *every*
//! instant: the latest-starting kill always left some node untouched, and
//! that node cannot have gone down since. The first eligible slot after
//! the opening one always targets the current leader, so every schedule
//! exercises election, abort, and re-admission.

use crate::cluster::election::elect_leader;
use crate::compute::{run_reference, Tensor, WeightStore};
use crate::model::Model;
use crate::net::Testbed;
use crate::serve::{AdmitError, ServeConfig, Server};
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::conditions::ConditionTrace;
use super::controller::ElasticConfig;

/// One scheduled fault.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ChaosEvent {
    /// `node` is down over `[from, until)` virtual seconds; the restore is
    /// the interval end.
    Kill { node: usize, from: f64, until: f64 },
    /// Link bandwidth is multiplied by `factor` over `[from, until)`.
    Collapse { factor: f64, from: f64, until: f64 },
}

/// A deterministic fault schedule for an `nodes`-device cluster.
#[derive(Debug, Clone)]
pub struct ChaosSchedule {
    pub nodes: usize,
    pub seed: u64,
    /// Slot length, virtual seconds.
    pub slot: f64,
    pub events: Vec<ChaosEvent>,
}

impl ChaosSchedule {
    /// Generate a schedule over `slots × slot_len` virtual seconds. Pure in
    /// `(nodes, seed, slots, slot_len)`. Every schedule kills the
    /// then-current leader at least once (asserted in tests via
    /// [`Self::kills_leader`]).
    pub fn generate(nodes: usize, seed: u64, slots: usize, slot_len: f64) -> ChaosSchedule {
        assert!(nodes >= 2, "chaos needs at least two nodes to kill one");
        assert!(slots >= 6, "too few slots to guarantee a leader strike");
        assert!(slot_len > 0.0 && slot_len.is_finite(), "bad slot length");
        let mut rng = Rng::new(seed ^ 0x00c4_a05c_4ed0_1e5a);
        let mut down_until = vec![f64::NEG_INFINITY; nodes];
        let mut events: Vec<ChaosEvent> = Vec::new();
        let mut leader_struck = false;
        for k in 0..slots {
            let t = (k as f64 + 0.5) * slot_len;
            let alive: Vec<usize> = (0..nodes).filter(|&i| down_until[i] <= t).collect();
            // No immortal nodes: the first eligible slot at k >= 1 strikes
            // the current leader (lowest alive rank), so every schedule
            // exercises election and the abort path. (Slot 0 rolls the
            // ordinary dice and may still hit the leader by chance — the
            // k >= 1 guard only keeps the *scripted* strike from landing
            // before the server's first healthy boundary.)
            if !leader_struck && k >= 1 && alive.len() >= 2 {
                let leader = alive[0];
                let until = t + slot_len * rng.range_f64(1.0, 2.0);
                down_until[leader] = down_until[leader].max(until);
                events.push(ChaosEvent::Kill { node: leader, from: t, until });
                leader_struck = true;
                continue;
            }
            let roll = rng.f64();
            if roll < 0.40 {
                if alive.len() >= 2 {
                    let node = *rng.pick(&alive);
                    let until = t + slot_len * rng.range_f64(1.0, 2.5);
                    down_until[node] = down_until[node].max(until);
                    events.push(ChaosEvent::Kill { node, from: t, until });
                }
            } else if roll < 0.60 {
                if alive.len() >= 3 {
                    // back-to-back double failure, 5% of a slot apart
                    let i = rng.below(alive.len());
                    let j = (i + 1 + rng.below(alive.len() - 1)) % alive.len();
                    let (a, b) = (alive[i], alive[j]);
                    let until_a = t + slot_len * rng.range_f64(1.0, 2.0);
                    let t2 = t + 0.05 * slot_len;
                    let until_b = t2 + slot_len * rng.range_f64(1.0, 2.0);
                    down_until[a] = down_until[a].max(until_a);
                    down_until[b] = down_until[b].max(until_b);
                    events.push(ChaosEvent::Kill { node: a, from: t, until: until_a });
                    events.push(ChaosEvent::Kill { node: b, from: t2, until: until_b });
                }
            } else if roll < 0.80 {
                let factor = rng.range_f64(0.1, 0.4);
                let until = t + slot_len * rng.range_f64(0.5, 1.5);
                events.push(ChaosEvent::Collapse { factor, from: t, until });
            }
            // else: a quiet slot
        }
        ChaosSchedule { nodes, seed, slot: slot_len, events }
    }

    /// Number of scheduled fault events.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Total virtual-time horizon the events span.
    pub fn horizon(&self) -> f64 {
        self.events
            .iter()
            .map(|e| match *e {
                ChaosEvent::Kill { until, .. } | ChaosEvent::Collapse { until, .. } => until,
            })
            .fold(0.0, f64::max)
    }

    /// Liveness mask at virtual time `t` (kills starting exactly at `t`
    /// included), with the same survivor-of-last-resort backstop as
    /// [`ConditionTrace::sample`].
    pub fn alive_at(&self, t: f64) -> Vec<bool> {
        let mut alive = self.alive_raw(t, /* include_start = */ true);
        if !alive.contains(&true) {
            alive[0] = true;
        }
        alive
    }

    fn alive_raw(&self, t: f64, include_start: bool) -> Vec<bool> {
        let mut alive = vec![true; self.nodes];
        for e in &self.events {
            if let ChaosEvent::Kill { node, from, until } = *e {
                let started = if include_start { t >= from } else { t > from };
                if started && t < until {
                    alive[node] = false;
                }
            }
        }
        alive
    }

    /// Whether some kill strikes the node that was leader the instant
    /// before the kill — i.e. the schedule exercises leader failover.
    pub fn kills_leader(&self) -> bool {
        self.events.iter().any(|e| match e {
            ChaosEvent::Kill { node, from, .. } => {
                elect_leader(&self.alive_raw(*from, false)) == Some(*node)
            }
            ChaosEvent::Collapse { .. } => false,
        })
    }

    /// Compile the schedule into the deterministic [`ConditionTrace`] the
    /// elastic serving path samples at batch boundaries.
    pub fn trace(&self) -> ConditionTrace {
        let mut tr = ConditionTrace::stable(self.nodes);
        for e in &self.events {
            match *e {
                ChaosEvent::Kill { node, from, until } => {
                    tr = tr.with_outage(node, from, until);
                }
                ChaosEvent::Collapse { factor, from, until } => {
                    tr = tr.with_bandwidth_dip(from, until, factor);
                }
            }
        }
        tr
    }

    pub fn to_json(&self) -> Json {
        let events: Vec<Json> = self
            .events
            .iter()
            .map(|e| match *e {
                ChaosEvent::Kill { node, from, until } => Json::obj(vec![
                    ("kind", Json::Str("kill".into())),
                    ("node", Json::Num(node as f64)),
                    ("from", Json::Num(from)),
                    ("until", Json::Num(until)),
                ]),
                ChaosEvent::Collapse { factor, from, until } => Json::obj(vec![
                    ("kind", Json::Str("collapse".into())),
                    ("factor", Json::Num(factor)),
                    ("from", Json::Num(from)),
                    ("until", Json::Num(until)),
                ]),
            })
            .collect();
        Json::obj(vec![
            ("nodes", Json::Num(self.nodes as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("slot", Json::Num(self.slot)),
            ("events", Json::Arr(events)),
        ])
    }
}

/// The audit of one chaos run — what [`run_chaos`] measured and what
/// [`ChaosOutcome::verify`] enforces.
#[derive(Debug, Clone)]
pub struct ChaosOutcome {
    pub seed: u64,
    /// Fault events the schedule injected.
    pub events: usize,
    /// Requests accepted by the server (all of them — admission retries on
    /// backpressure until accepted).
    pub requests: u64,
    /// Requests that completed with a response.
    pub ok: u64,
    /// Requests explicitly failed *and accounted for* by the router
    /// (leader-loss aborts + shutdown drains).
    pub failed_reported: u64,
    /// Client-observed disconnects the router never accounted for — silent
    /// drops. The headline invariant: must be 0.
    pub lost: u64,
    /// Completed responses whose output differed from the single-node
    /// reference. Must be 0.
    pub mismatches: u64,
    /// Responses whose delivery sequence went backwards relative to
    /// submission order. Must be 0.
    pub reordered: u64,
    /// Node-set failovers the elastic controller performed.
    pub failovers: u64,
    /// Failovers that moved leadership.
    pub leader_handoffs: u64,
    /// Failovers served from the speculative n−1 plan cache.
    pub speculative_hits: u64,
    /// Smallest / largest cluster any completed response rode on.
    pub min_nodes: usize,
    pub max_nodes: usize,
    /// Pipeline generations served (0 on the lockstep path).
    pub generations: u64,
    /// Requests that lost their generation mid-flight and were re-executed
    /// to completion instead of being failed back to the client.
    pub replays: u64,
    /// Total re-executions, counting each replay of each request.
    pub replay_attempts: u64,
}

impl ChaosOutcome {
    /// Enforce the harness invariants; `Err` lists every violation.
    pub fn verify(&self) -> Result<(), String> {
        let mut errs = Vec::new();
        if self.ok + self.failed_reported != self.requests {
            errs.push(format!(
                "accounting hole: {} ok + {} failed != {} accepted",
                self.ok, self.failed_reported, self.requests
            ));
        }
        if self.lost != 0 {
            errs.push(format!("{} requests silently dropped", self.lost));
        }
        if self.mismatches != 0 {
            errs.push(format!("{} outputs diverged from the reference", self.mismatches));
        }
        if self.reordered != 0 {
            errs.push(format!("{} responses delivered out of order", self.reordered));
        }
        if errs.is_empty() {
            Ok(())
        } else {
            Err(errs.join("; "))
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seed", Json::Num(self.seed as f64)),
            ("events", Json::Num(self.events as f64)),
            ("requests", Json::Num(self.requests as f64)),
            ("ok", Json::Num(self.ok as f64)),
            ("failed_reported", Json::Num(self.failed_reported as f64)),
            ("lost", Json::Num(self.lost as f64)),
            ("mismatches", Json::Num(self.mismatches as f64)),
            ("reordered", Json::Num(self.reordered as f64)),
            ("failovers", Json::Num(self.failovers as f64)),
            ("leader_handoffs", Json::Num(self.leader_handoffs as f64)),
            ("speculative_hits", Json::Num(self.speculative_hits as f64)),
            ("min_nodes", Json::Num(self.min_nodes as f64)),
            ("max_nodes", Json::Num(self.max_nodes as f64)),
            ("generations", Json::Num(self.generations as f64)),
            ("replays", Json::Num(self.replays as f64)),
            ("replay_attempts", Json::Num(self.replay_attempts as f64)),
        ])
    }
}

impl std::fmt::Display for ChaosOutcome {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "seed={} events={} requests={} ok={} failed={} lost={} mismatches={} \
             reordered={} failovers={} handoffs={} spec_hits={} replays={} attempts={} \
             nodes={}..{}",
            self.seed,
            self.events,
            self.requests,
            self.ok,
            self.failed_reported,
            self.lost,
            self.mismatches,
            self.reordered,
            self.failovers,
            self.leader_handoffs,
            self.speculative_hits,
            self.replays,
            self.replay_attempts,
            self.min_nodes,
            self.max_nodes
        )
    }
}

/// Serve `requests` deterministic inputs through an elastic [`Server`]
/// under `schedule`'s fault trace and audit every request. Submissions are
/// made up front (retrying on backpressure — admission never abandons a
/// request) so that in pipelined mode batches genuinely overlap the
/// injected faults; responses are collected in submission order.
pub fn run_chaos(
    model: &Model,
    base: &Testbed,
    schedule: &ChaosSchedule,
    cfg: ServeConfig,
    ecfg: ElasticConfig,
    requests: u64,
    input_seed: u64,
) -> ChaosOutcome {
    assert_eq!(base.nodes, schedule.nodes, "schedule/testbed node mismatch");
    let weights = WeightStore::for_model(model, 5);
    let server = Server::start_elastic(
        model.clone(),
        weights.clone(),
        base.clone(),
        schedule.trace(),
        cfg,
        ecfg,
    );

    let l0 = &model.layers[0];
    let inputs: Vec<Tensor> = (0..requests)
        .map(|i| Tensor::random(l0.in_h, l0.in_w, l0.in_c, input_seed + i))
        .collect();
    let mut rxs = Vec::with_capacity(inputs.len());
    for t in &inputs {
        loop {
            match server.submit(t.clone()) {
                Ok(rx) => {
                    rxs.push(rx);
                    break;
                }
                Err(AdmitError::QueueFull) => std::thread::yield_now(),
                Err(AdmitError::Stopped) => panic!("server stopped during chaos run"),
            }
        }
    }

    let mut ok = 0u64;
    let mut client_failed = 0u64;
    let mut mismatches = 0u64;
    let mut reordered = 0u64;
    let mut last_seq: Option<u64> = None;
    let mut min_nodes = usize::MAX;
    let mut max_nodes = 0usize;
    for (input, rx) in inputs.iter().zip(rxs) {
        match rx.recv() {
            Ok(resp) => {
                ok += 1;
                let reference = run_reference(model, &weights, input);
                if reference.max_abs_diff(&resp.output) != 0.0 {
                    mismatches += 1;
                }
                if last_seq.is_some_and(|prev| resp.seq <= prev) {
                    reordered += 1;
                }
                last_seq = Some(resp.seq);
                min_nodes = min_nodes.min(resp.nodes);
                max_nodes = max_nodes.max(resp.nodes);
            }
            Err(_) => client_failed += 1,
        }
    }

    let stats = server.shutdown();
    let m = stats.adaptation.expect("elastic path reports adaptation");
    let failed_reported = stats.failed_on_leader_loss + stats.failed_on_shutdown;
    ChaosOutcome {
        seed: schedule.seed,
        events: schedule.len(),
        requests,
        ok,
        failed_reported,
        // a disconnect the router never accounted for is a silent drop
        lost: client_failed.saturating_sub(failed_reported),
        mismatches,
        reordered,
        failovers: m.failovers,
        leader_handoffs: m.leader_handoffs,
        speculative_hits: m.speculative_hits,
        min_nodes: if ok == 0 { 0 } else { min_nodes },
        max_nodes,
        generations: stats.pipeline.map_or(0, |p| p.generations),
        replays: stats.replayed_on_leader_loss + stats.replayed_on_dead_cluster,
        replay_attempts: stats.replay_attempts,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::net::{Bandwidth, Topology};
    use crate::planner::plan_for_testbed;
    use std::time::Duration;

    #[test]
    fn schedules_are_deterministic_and_seed_sensitive() {
        let a = ChaosSchedule::generate(4, 7, 10, 1.0);
        let b = ChaosSchedule::generate(4, 7, 10, 1.0);
        assert_eq!(a.events, b.events);
        let c = ChaosSchedule::generate(4, 8, 10, 1.0);
        assert_ne!(a.events, c.events, "different seeds must differ");
        assert!(!a.is_empty());
    }

    #[test]
    fn every_schedule_kills_the_leader_and_keeps_a_survivor() {
        for seed in 0..12u64 {
            for nodes in [2usize, 3, 4] {
                let s = ChaosSchedule::generate(nodes, seed, 10, 1.0);
                assert!(s.kills_leader(), "seed {seed} nodes {nodes}: leader immortal");
                // structural survivor invariant, checked *without* the
                // backstop on a fine grid across the whole horizon
                let horizon = s.horizon();
                let mut t = 0.0;
                while t < horizon + 1.0 {
                    let alive = s.alive_raw(t, true);
                    assert!(
                        alive.contains(&true),
                        "seed {seed} nodes {nodes}: no survivor at t={t}"
                    );
                    t += 0.05;
                }
            }
        }
    }

    #[test]
    fn trace_matches_schedule_liveness() {
        let s = ChaosSchedule::generate(4, 3, 10, 1.0);
        let trace = s.trace();
        let mut t = 0.0;
        while t < s.horizon() + 1.0 {
            assert_eq!(trace.sample(t).alive, s.alive_at(t), "t={t}");
            t += 0.21;
        }
    }

    #[test]
    fn schedule_json_round_trips_fields() {
        let s = ChaosSchedule::generate(4, 5, 8, 2.0);
        let j = s.to_json();
        assert_eq!(j.get("nodes").and_then(Json::as_usize), Some(4));
        assert_eq!(j.get("seed").and_then(Json::as_usize), Some(5));
        let events = j.get("events").and_then(Json::as_arr).unwrap();
        assert_eq!(events.len(), s.len());
    }

    #[test]
    fn chaos_run_smoke_loses_nothing() {
        // a short generated schedule through the lockstep elastic server:
        // every invariant must hold and at least one failover must land
        let model = zoo::edgenet(16);
        let base = Testbed::new(3, Topology::Ring, Bandwidth::gbps(1.0));
        let c0 = {
            let p = plan_for_testbed(&model, &base);
            crate::engine::evaluate(&model, &p, &base).total
        };
        let schedule = ChaosSchedule::generate(3, 1, 6, 2.0 * c0);
        let cfg = ServeConfig {
            max_batch: 1,
            batch_window: Duration::ZERO,
            queue_depth: 32,
            pipeline_depth: 1,
            ..ServeConfig::default()
        };
        let out = run_chaos(
            &model,
            &base,
            &schedule,
            cfg,
            ElasticConfig::default(),
            16,
            900,
        );
        out.verify().expect("chaos invariants violated");
        assert_eq!(out.requests, 16);
        assert_eq!(out.ok, 16, "lockstep mode never leaves work in flight: {out}");
        assert!(out.failovers >= 1, "schedule injected no observed failover: {out}");
    }
}
