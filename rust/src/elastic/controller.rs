//! The elastic controller: per-batch condition monitoring, degradation
//! detection, cached/incremental replanning, and plan swapping.
//!
//! The decision logic lives in [`ReplanCore`], shared by two drivers:
//!
//! * [`ElasticController`] — the synchronous path: the caller (router or
//!   experiment loop) samples the [`ConditionTrace`] and runs the monitor +
//!   replanner inline at every batch boundary. Simple and deterministic,
//!   but a cold replan stalls the boundary that triggers it.
//! * [`crate::elastic::background::BackgroundReplanner`] — the production
//!   path: the same core runs on a dedicated planner thread, publishing
//!   into an atomic plan slot, with speculative n−1 failover planning while
//!   the cluster is healthy.
//!
//! At every consulted boundary the core re-prices the active plan on the
//! effective [`Testbed`] (the *monitor*). Three triggers force adaptation:
//!
//! * **node-set change** — a device died or rejoined. The active plan still
//!   *executes* on the new cluster (plans are node-count-agnostic), but it
//!   was optimized for the wrong cluster, so a replan is mandatory; the
//!   swap lands at the next batch boundary, never mid-batch.
//! * **cost degradation** — the active plan's predicted cost exceeded
//!   `degrade_threshold ×` its adoption-time cost (bandwidth collapse,
//!   device slowdown).
//! * **condition-cell shift** — conditions left the quantized cell the
//!   active plan was planned for, in either direction. This is what swaps
//!   *back* after a recovery: the clean regime's plan is warm in the cache,
//!   and without this trigger a collapse-optimized plan would serve the
//!   recovered cluster forever.
//!
//! Replans consult the [`PlanCache`] first: conditions quantize into cells
//! ([`ClusterSnapshot::quantize`]), so revisited regimes get their plan back
//! without running DPP. On a genuine miss the core plans fresh — parallel
//! DPP over a shared, prewarmed query memo, so a pure-bandwidth-drift replan
//! performs zero estimator sync queries (see [`crate::cost::memo`]). After
//! any adaptation the cost baseline re-anchors to the new conditions, so a
//! regime nothing can plan around (e.g. a uniform bandwidth collapse) is
//! accepted as the new normal instead of triggering a replan storm.

use std::collections::HashSet;
use std::sync::Arc;

use super::cache::{CacheKey, PlanCache};
use super::conditions::{ClusterSnapshot, ConditionTrace};
use crate::cluster::election::{elect_leader, Leadership};
use crate::cost::{CostSource, MemoStore};
use crate::metrics::AdaptationMetrics;
use crate::model::Model;
use crate::net::Testbed;
use crate::partition::Plan;
use crate::planner::exhaustive::plan_cost;
use crate::planner::{plan_batch, plan_for_testbed_opts, prewarm_memo, PlannerOpts};

/// Controller tuning knobs.
#[derive(Debug, Clone)]
pub struct ElasticConfig {
    /// Replan when the active plan's predicted cost exceeds this multiple of
    /// its adoption-time cost.
    pub degrade_threshold: f64,
    /// Plan-cache capacity (distinct condition cells held warm).
    pub cache_capacity: usize,
    /// DPP worker threads per replan (`0` = one per available core, capped
    /// at the scheme count; `1` = serial). Cost-transparent.
    pub planner_workers: usize,
    /// Seed the query memo with the full-cluster query universe at startup
    /// (one unpruned search), so later bandwidth-drift replans are
    /// estimator-query-free.
    pub prewarm_memo: bool,
    /// Staleness bound on fire-and-forget drift asks: once an ask has gone
    /// unanswered for more than this many consulted boundaries, every
    /// further boundary served on the outdated plan counts into
    /// [`crate::metrics::AdaptationMetrics::stale_plan_boundaries`] — a
    /// wedged planner thread surfaces as a counter instead of silently
    /// serving an old plan forever.
    pub stale_after_checks: u64,
    /// Enable forecast-driven cache pre-warming: the frontend fits a
    /// [`crate::telemetry::ForecastEngine`] over the snapshots it already
    /// samples and asks the background planner to pre-plan the projected
    /// condition cell (and pre-speculate its n−1/leader-loss cells at the
    /// *forecast* bandwidth) before the shift lands. `None` = reactive
    /// monitoring only, the PR 1–4 behavior.
    pub forecast: Option<crate::telemetry::ForecastConfig>,
}

impl Default for ElasticConfig {
    fn default() -> Self {
        ElasticConfig {
            degrade_threshold: 1.25,
            cache_capacity: 32,
            planner_workers: 0,
            prewarm_memo: true,
            stale_after_checks: 32,
            forecast: None,
        }
    }
}

/// Why the active plan was swapped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SwapReason {
    /// A device left or rejoined the cluster.
    NodeSetChanged,
    /// Predicted cost degraded past the threshold.
    Degraded,
    /// Conditions moved to a different quantized cell without degrading —
    /// typically a *recovery* (bandwidth back up, device sped up), where the
    /// clean regime's plan is warm in the cache and strictly better.
    ConditionsShifted,
}

/// One adaptation event, for logs and examples.
#[derive(Debug, Clone)]
pub struct AdaptEvent {
    pub t: f64,
    pub reason: SwapReason,
    /// Effective node count after the swap.
    pub nodes: usize,
    /// Predicted per-item cost of the old plan under the new conditions.
    pub cost_before: f64,
    /// Predicted per-item cost of the adopted plan under the new conditions.
    pub cost_after: f64,
}

/// What the router should do for the next batch.
#[derive(Debug, Clone)]
pub struct BatchDecision {
    pub plan: Arc<Plan>,
    /// Effective testbed the batch executes on.
    pub testbed: Testbed,
    /// Per-node liveness (baseline node ids) — the mask
    /// [`crate::cluster::run_degraded`] executes against.
    pub alive: Vec<bool>,
    /// Original rank of the elected leader (lowest surviving rank) — the
    /// node that owns scatter/ingress and gather for this batch.
    pub leader: usize,
    /// Predicted virtual seconds per item under current conditions.
    pub cost_per_item: f64,
    /// True when this boundary adapted (plan and/or node set changed).
    pub swapped: bool,
    pub reason: Option<SwapReason>,
}

/// Most recent [`AdaptEvent`]s retained by a controller — old events are
/// dropped so a server that adapts for days doesn't grow without bound.
pub const MAX_EVENTS: usize = 256;

/// Speculative-key attribution set cap (cleared when exceeded; only costs
/// some `speculative_hits` attribution, never correctness).
const MAX_SPECULATIVE_KEYS: usize = 1024;

/// The adaptation state machine shared by the synchronous controller and
/// the background replanner: monitor → cache-first replan → swap.
pub(crate) struct ReplanCore {
    pub(crate) model: Model,
    pub(crate) base: Testbed,
    cfg: ElasticConfig,
    pub(crate) cache: PlanCache,
    opts: PlannerOpts,
    active: Arc<Plan>,
    /// Condition cell the active plan was planned for. Leaving the cell in
    /// *any* direction re-consults the cache — degradation is caught by the
    /// threshold below, but improvement (recovery) must also swap back,
    /// otherwise a collapse-optimized plan would serve the clean regime
    /// forever.
    pub(crate) active_key: CacheKey,
    /// Liveness mask the active plan was optimized for. Compared by
    /// membership, not count: a simultaneous die+rejoin between two batch
    /// boundaries still changes the set and must force a replan.
    active_alive: Vec<bool>,
    /// Rank-based leadership observer — the single source of truth for
    /// handoff detection (fed the fresh mask on every node-set change;
    /// its term bumps exactly when the lowest surviving rank moves).
    leadership: Leadership,
    /// Cost baseline the degradation monitor compares against (tracks the
    /// best cost seen for the active plan since adoption).
    pub(crate) active_cost: f64,
    pub(crate) metrics: AdaptationMetrics,
    events: Vec<AdaptEvent>,
    /// Cells filled by [`Self::speculate_failovers`], for hit attribution.
    speculative_keys: HashSet<CacheKey>,
    /// Cells filled by [`Self::prewarm_forecast_cell`] (forecast-driven),
    /// for hit attribution on the serving path.
    forecast_keys: HashSet<CacheKey>,
    /// Whether searches triggered by [`Self::decide`] run on the serving
    /// router's thread (the synchronous controller) — counted as
    /// `inline_replans`.
    inline: bool,
}

impl ReplanCore {
    /// Plan for the conditions in `snap0` and start monitoring.
    pub(crate) fn new(
        model: Model,
        base: Testbed,
        snap0: &ClusterSnapshot,
        cfg: ElasticConfig,
        inline: bool,
    ) -> ReplanCore {
        assert_eq!(snap0.alive.len(), base.nodes, "snapshot/testbed node mismatch");
        let memo = MemoStore::shared();
        if cfg.prewarm_memo {
            prewarm_memo(&model, &base, &memo);
        }
        let opts = PlannerOpts { workers: cfg.planner_workers, memo: Some(memo) };
        let mut cache = PlanCache::new(cfg.cache_capacity);
        let effective = snap0.apply(&base);
        let key = CacheKey::new(&model.name, snap0.quantize());
        let plan = Arc::new(plan_for_testbed_opts(&model, &effective, &opts).0);
        cache.misses += 1; // the initial plan is an unavoidable cold miss
        cache.put(key.clone(), plan.clone());
        let active_cost = plan.est_cost;
        let metrics = AdaptationMetrics { replans: 1, ..AdaptationMetrics::default() };
        ReplanCore {
            model,
            base,
            cfg,
            cache,
            opts,
            active: plan,
            active_key: key,
            active_alive: snap0.alive.clone(),
            leadership: Leadership::new(&snap0.alive),
            active_cost,
            metrics,
            events: Vec::new(),
            speculative_keys: HashSet::new(),
            forecast_keys: HashSet::new(),
            inline,
        }
    }

    pub(crate) fn active_plan(&self) -> Arc<Plan> {
        self.active.clone()
    }

    pub(crate) fn events(&self) -> &[AdaptEvent] {
        &self.events
    }

    /// Adaptation counters, with the cache's view folded in.
    pub(crate) fn metrics(&self) -> AdaptationMetrics {
        let mut m = self.metrics;
        m.cache_hits = self.cache.hits;
        m.cache_misses = self.cache.misses;
        m
    }

    /// The memoized analytic oracle for `effective` — shares the core's
    /// query store, so monitor re-pricing rides the same warm cache as the
    /// planner.
    fn cost_source(&self, effective: &Testbed) -> CostSource {
        match &self.opts.memo {
            Some(store) => CostSource::analytic(effective).memoized(store),
            None => CostSource::analytic(effective),
        }
    }

    fn replan(&mut self, effective: &Testbed) -> Arc<Plan> {
        let plan = Arc::new(plan_for_testbed_opts(&self.model, effective, &self.opts).0);
        self.metrics.replans += 1;
        if self.inline {
            self.metrics.inline_replans += 1;
        }
        plan
    }

    fn lookup_or_replan(
        &mut self,
        key: &CacheKey,
        effective: &Testbed,
        node_change: bool,
    ) -> Arc<Plan> {
        if let Some(plan) = self.cache.get(key) {
            if self.speculative_keys.contains(key) {
                self.metrics.speculative_hits += 1;
            }
            if self.forecast_keys.contains(key) {
                self.metrics.forecast_hits += 1;
            }
            return plan;
        }
        // A miss means any speculative/forecast fill of this cell is gone
        // (LRU eviction): drop the attribution so future hits on the
        // ordinary replan below don't count as pre-warmed.
        self.speculative_keys.remove(key);
        self.forecast_keys.remove(key);
        if self.metrics.forecasts > 0 && !node_change {
            // Forecasting was active and a same-node-set shift — the kind
            // of event the forecaster exists to predict — still missed the
            // warm set. Node-set misses are excluded: liveness is carried,
            // never extrapolated, so e.g. a double node death is not a
            // forecastable event and must not deflate the hit rate.
            self.metrics.forecast_misses += 1;
        }
        let plan = self.replan(effective);
        self.cache.put(key.clone(), plan.clone());
        plan
    }

    /// Run the monitor + replanner for the conditions in `snap` and return
    /// the plan for the batch about to form. Swaps happen here and only
    /// here — always between batches, whichever thread drives the core.
    pub(crate) fn decide(&mut self, snap: &ClusterSnapshot) -> BatchDecision {
        let effective = snap.apply(&self.base);
        let cost = self.cost_source(&effective);
        let leader = elect_leader(&snap.alive).expect("no surviving node");

        // Monitor: re-price the active plan under current conditions
        // (through the shared memo, so drift checks are mostly rescales).
        let current_cost = plan_cost(&self.model, &self.active, &cost).total;
        let node_change = snap.alive != self.active_alive;
        let degraded = current_cost > self.active_cost * self.cfg.degrade_threshold;
        if degraded {
            self.metrics.degraded_checks += 1;
        }
        let key = CacheKey::new(&self.model.name, snap.quantize());
        let cell_change = key != self.active_key;

        if !(node_change || degraded || cell_change) {
            // Fast path: conditions within the active plan's regime. Track
            // recoveries so the baseline never lags below current reality.
            self.active_cost = self.active_cost.min(current_cost);
            return BatchDecision {
                plan: self.active.clone(),
                testbed: effective,
                alive: snap.alive.clone(),
                leader,
                cost_per_item: current_cost,
                swapped: false,
                reason: None,
            };
        }

        let plan = self.lookup_or_replan(&key, &effective, node_change);
        let new_cost = plan_cost(&self.model, &plan, &cost).total;
        // Steps-only comparison: a replan that lands on the same step
        // sequence (with a different est_cost under the new conditions) is
        // not a swap the router can observe.
        let structurally_new = plan.steps != self.active.steps;
        let swapped = node_change || structurally_new;
        let reason = if node_change {
            SwapReason::NodeSetChanged
        } else if degraded {
            SwapReason::Degraded
        } else {
            SwapReason::ConditionsShifted
        };
        if swapped {
            if structurally_new {
                self.metrics.plan_swaps += 1;
            }
            if node_change {
                self.metrics.failovers += 1;
                // the observer bumps its term (and we count a handoff)
                // exactly when the lowest surviving rank moved
                if self.leadership.observe(&snap.alive).is_some() {
                    self.metrics.leader_handoffs += 1;
                }
            }
            if self.events.len() == MAX_EVENTS {
                self.events.remove(0);
            }
            self.events.push(AdaptEvent {
                t: snap.t,
                reason,
                nodes: effective.nodes,
                cost_before: current_cost,
                cost_after: new_cost,
            });
        }
        self.active = plan;
        self.active_key = key;
        self.active_alive = snap.alive.clone();
        // Re-anchor the baseline: if even the fresh plan is expensive under
        // these conditions, that is the new normal, not degradation.
        self.active_cost = new_cost;
        BatchDecision {
            plan: self.active.clone(),
            testbed: effective,
            alive: snap.alive.clone(),
            leader,
            cost_per_item: new_cost,
            swapped,
            reason: swapped.then_some(reason),
        }
    }

    /// Pre-compute the best n−1 failover plan for every alive node — the
    /// leader included: no node is immortal, and a leader loss re-elects
    /// the next-lowest rank as gather owner, so its n−1 cell must be just
    /// as warm — under the conditions in `snap`, filling only cells the
    /// cache doesn't hold yet. The background planner calls this while the
    /// cluster is healthy, so any node-loss failover becomes a pure cache
    /// hit; the searches run as a [`plan_batch`] over the shared memo.
    pub(crate) fn speculate_failovers(&mut self, snap: &ClusterSnapshot) {
        if snap.alive_count() <= 1 {
            return; // killing the only survivor leaves nothing to plan for
        }
        let mut work: Vec<(CacheKey, Testbed)> = Vec::new();
        for node in 0..snap.alive.len() {
            if !snap.alive[node] {
                continue;
            }
            let mut hyp = snap.clone();
            hyp.alive[node] = false;
            let key = CacheKey::new(&self.model.name, hyp.quantize());
            if self.cache.peek(&key) || work.iter().any(|(k, _)| *k == key) {
                continue;
            }
            work.push((key, hyp.apply(&self.base)));
        }
        if work.is_empty() {
            return;
        }
        let testbeds: Vec<Testbed> = work.iter().map(|(_, tb)| tb.clone()).collect();
        let plans = plan_batch(&self.model, &testbeds, &self.opts);
        if self.speculative_keys.len() > MAX_SPECULATIVE_KEYS {
            self.speculative_keys.clear();
        }
        for ((key, _), plan) in work.into_iter().zip(plans) {
            self.metrics.replans += 1;
            self.metrics.speculative_plans += 1;
            self.speculative_keys.insert(key.clone());
            self.cache.put(key, Arc::new(plan));
        }
    }

    /// Warm the cache for a *forecast* condition cell without touching the
    /// active plan: plan the projected cell if it isn't cached yet. Never
    /// publishes, never swaps: if the forecast is wrong, the only cost is
    /// a cache entry. One cache-fill search at most — the background
    /// planner interleaves these single-search units with its ask queue,
    /// and covers the projected cell's n−1/leader-loss neighbours through
    /// equally fine-grained [`Self::speculate_one`] units (so a regime
    /// shift and a node loss arriving *together* are both cache hits — the
    /// cold-failover rendezvous gap PR 2 left open).
    pub(crate) fn prewarm_forecast_cell(&mut self, snap: &ClusterSnapshot) {
        self.metrics.forecasts += 1;
        let key = CacheKey::new(&self.model.name, snap.quantize());
        if self.cache.peek(&key) {
            return;
        }
        let effective = snap.apply(&self.base);
        let plan = self.replan(&effective);
        self.metrics.forecast_plans += 1;
        if self.forecast_keys.len() > MAX_SPECULATIVE_KEYS {
            self.forecast_keys.clear();
        }
        self.forecast_keys.insert(key.clone());
        self.cache.put(key, plan);
    }

    /// Pre-compute one condition cell speculatively (attributed exactly
    /// like [`Self::speculate_failovers`]'s fills) if the cache lacks it —
    /// the single-search work unit the background planner interleaves with
    /// its queue so a failover rendezvous never waits behind more than the
    /// search already in progress.
    pub(crate) fn speculate_one(&mut self, snap: &ClusterSnapshot) {
        let key = CacheKey::new(&self.model.name, snap.quantize());
        if self.cache.peek(&key) {
            return;
        }
        let plan = self.replan(&snap.apply(&self.base));
        self.metrics.speculative_plans += 1;
        if self.speculative_keys.len() > MAX_SPECULATIVE_KEYS {
            self.speculative_keys.clear();
        }
        self.speculative_keys.insert(key.clone());
        self.cache.put(key, plan);
    }
}

/// The synchronous per-server adaptation state machine: samples the trace
/// and runs [`ReplanCore::decide`] inline at every consulted boundary.
pub struct ElasticController {
    core: ReplanCore,
    trace: ConditionTrace,
}

impl ElasticController {
    /// Plan for the conditions at `t = 0` and start monitoring.
    pub fn new(
        model: Model,
        base: Testbed,
        trace: ConditionTrace,
        cfg: ElasticConfig,
    ) -> ElasticController {
        assert_eq!(trace.nodes, base.nodes, "trace/testbed node mismatch");
        let snap0 = trace.sample(0.0);
        let core = ReplanCore::new(model, base, &snap0, cfg, /* inline = */ true);
        ElasticController { core, trace }
    }

    pub fn active_plan(&self) -> Arc<Plan> {
        self.core.active_plan()
    }

    /// The most recent adaptation events (bounded by [`MAX_EVENTS`]; the
    /// cumulative counts live in [`Self::metrics`]).
    pub fn events(&self) -> &[AdaptEvent] {
        self.core.events()
    }

    /// Adaptation counters, with the cache's view folded in.
    pub fn metrics(&self) -> AdaptationMetrics {
        self.core.metrics()
    }

    pub fn cache(&self) -> &PlanCache {
        &self.core.cache
    }

    /// Consult the controller at a batch boundary. Samples conditions at
    /// virtual time `t`, runs the degradation monitor, and returns the plan
    /// plus effective testbed for the batch about to form. Swaps happen
    /// here and only here — i.e. always between batches.
    pub fn on_batch(&mut self, t: f64) -> BatchDecision {
        let snap = self.trace.sample(t);
        self.core.metrics.checks += 1;
        self.core.decide(&snap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::net::{Bandwidth, Topology};

    fn base(nodes: usize) -> Testbed {
        Testbed::new(nodes, Topology::Ring, Bandwidth::gbps(1.0))
    }

    fn controller(trace: ConditionTrace) -> ElasticController {
        ElasticController::new(
            zoo::edgenet(16),
            base(trace.nodes),
            trace,
            ElasticConfig::default(),
        )
    }

    #[test]
    fn stable_trace_never_swaps() {
        let mut ctl = controller(ConditionTrace::stable(4));
        let initial = ctl.active_plan();
        for i in 0..20 {
            let d = ctl.on_batch(i as f64 * 0.01);
            assert!(!d.swapped);
            assert_eq!(d.testbed.nodes, 4);
            assert_eq!(*d.plan, *initial);
        }
        let m = ctl.metrics();
        assert_eq!(m.checks, 20);
        assert_eq!(m.plan_swaps, 0);
        assert_eq!(m.failovers, 0);
        assert_eq!(m.replans, 1); // the initial plan only
        assert_eq!(m.speculative_plans, 0, "the sync controller never speculates");
    }

    #[test]
    fn node_failure_forces_failover_at_batch_boundary() {
        let trace = ConditionTrace::stable(4).with_outage(2, 1.0, f64::INFINITY);
        let mut ctl = controller(trace);
        let before = ctl.on_batch(0.5);
        assert_eq!(before.testbed.nodes, 4);
        assert!(!before.swapped);
        let after = ctl.on_batch(1.5);
        assert_eq!(after.testbed.nodes, 3, "failover missed");
        assert!(after.swapped);
        assert_eq!(after.reason, Some(SwapReason::NodeSetChanged));
        let m = ctl.metrics();
        assert_eq!(m.failovers, 1);
        assert!(m.replans >= 2);
        assert!(m.inline_replans >= 1, "sync-path searches run inline: {m}");
    }

    #[test]
    fn recovery_is_served_from_cache() {
        let trace = ConditionTrace::stable(4).with_outage(1, 1.0, 2.0);
        let mut ctl = controller(trace);
        let p0 = ctl.active_plan();
        ctl.on_batch(0.5); // healthy
        ctl.on_batch(1.5); // degraded to 3 nodes
        let back = ctl.on_batch(2.5); // recovered — same cell as t=0
        assert_eq!(back.testbed.nodes, 4);
        assert_eq!(*back.plan, *p0, "recovery should restore the original plan");
        let m = ctl.metrics();
        assert_eq!(m.failovers, 2); // down and back up
        assert!(m.cache_hits >= 1, "recovery did not hit the cache: {m}");
        // only two distinct cells were ever planned: 4-node and 3-node
        assert_eq!(m.replans, 2);
    }

    #[test]
    fn membership_change_with_same_count_still_fails_over() {
        // node 1 dies at t=1; at t=2 node 1 rejoins just as node 2 dies —
        // the alive COUNT never changes across that boundary, but the set
        // does, and the plan was optimized for the wrong membership
        let trace = ConditionTrace::stable(4)
            .with_outage(1, 1.0, 2.0)
            .with_outage(2, 2.0, f64::INFINITY);
        let mut ctl = controller(trace);
        ctl.on_batch(0.5);
        let a = ctl.on_batch(1.5);
        assert_eq!(a.testbed.nodes, 3);
        assert!(!a.alive[1]);
        let b = ctl.on_batch(2.5);
        assert_eq!(b.testbed.nodes, 3);
        assert!(b.alive[1] && !b.alive[2]);
        assert_eq!(
            ctl.metrics().failovers,
            2,
            "equal-count membership change must still fail over"
        );
    }

    #[test]
    fn bandwidth_collapse_triggers_degradation_replan() {
        // drop bandwidth to 10% permanently from t = 1: sync costs inflate
        // 10×, blowing the active plan past the 1.25× threshold (sync is far
        // more than the required 2.9% of baseline cost at 1 Gb/s)
        let trace = ConditionTrace::stable(4).with_bandwidth_dip(1.0, f64::INFINITY, 0.1);
        let mut ctl = controller(trace);
        let before = ctl.on_batch(0.5);
        assert!(!before.swapped);
        let after = ctl.on_batch(1.5);
        let m = ctl.metrics();
        assert_eq!(m.degraded_checks, 1, "collapse did not trip the monitor: {m}");
        assert!(m.replans >= 2, "degradation did not replan: {m}");
        assert!(after.cost_per_item > before.cost_per_item);
        // once re-anchored to the collapsed regime, no replan storm
        let again = ctl.on_batch(2.5);
        assert!(!again.swapped);
        assert_eq!(ctl.metrics().degraded_checks, 1);
    }

    #[test]
    fn recovery_after_dip_restores_clean_regime_plan() {
        // bandwidth collapses over [1, 2) and recovers: the clean regime
        // must get its original plan back (from cache) instead of being
        // served the collapse-optimized plan forever
        let trace = ConditionTrace::stable(4).with_bandwidth_dip(1.0, 2.0, 0.1);
        let mut ctl = controller(trace);
        let p0 = ctl.active_plan();
        ctl.on_batch(0.5); // clean
        ctl.on_batch(1.5); // collapsed → degradation replan
        let back = ctl.on_batch(2.5); // recovered → cell shift → warm swap
        assert_eq!(*back.plan, *p0, "clean regime did not get its plan back");
        assert!(
            (back.cost_per_item - p0.est_cost).abs() <= 1e-9 * p0.est_cost,
            "recovered cost {} != planned cost {}",
            back.cost_per_item,
            p0.est_cost
        );
        assert!(ctl.metrics().cache_hits >= 1);
    }

    #[test]
    fn diurnal_drift_monitoring_is_stable() {
        // a full compressed day: the controller may adapt at the dip, must
        // never lose a node, and every lookup is accounted for
        let mut ctl = controller(ConditionTrace::diurnal_drift(4, 3));
        for step in 0..120 {
            let d = ctl.on_batch(step as f64 * 0.5);
            assert_eq!(d.testbed.nodes, 4);
            assert!(d.cost_per_item > 0.0);
        }
        let m = ctl.metrics();
        assert_eq!(m.checks, 120);
        assert_eq!(m.failovers, 0);
        assert_eq!(m.replans + m.cache_hits, m.cache_misses + m.cache_hits);
    }

    #[test]
    fn events_record_swaps() {
        let trace = ConditionTrace::stable(4).with_outage(3, 1.0, f64::INFINITY);
        let mut ctl = controller(trace);
        ctl.on_batch(0.2);
        ctl.on_batch(1.2);
        let evs = ctl.events();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].reason, SwapReason::NodeSetChanged);
        assert_eq!(evs[0].nodes, 3);
        assert!(evs[0].cost_before > 0.0 && evs[0].cost_after > 0.0);
    }

    #[test]
    fn speculation_fills_only_missing_cells_and_attributes_hits() {
        // drive the core directly the way the background planner does
        let trace = ConditionTrace::stable(4).with_outage(2, 1.0, f64::INFINITY);
        let snap0 = trace.sample(0.0);
        let mut core = ReplanCore::new(
            zoo::edgenet(16),
            base(4),
            &snap0,
            ElasticConfig::default(),
            false,
        );
        core.speculate_failovers(&snap0);
        let m = core.metrics();
        assert_eq!(m.speculative_plans, 4, "one n−1 plan per alive node, leader included: {m}");
        assert_eq!(m.inline_replans, 0, "background core never replans inline: {m}");
        // speculating again is a no-op: every cell is already cached
        core.speculate_failovers(&snap0);
        assert_eq!(core.metrics().speculative_plans, 4);

        // the node-2 failover is now a pure (attributed) cache hit, and the
        // served plan equals planning directly for the degraded testbed
        let snap_down = trace.sample(1.5);
        let d = core.decide(&snap_down);
        assert_eq!(d.testbed.nodes, 3);
        assert_eq!(d.leader, 0, "a worker loss must not move leadership");
        let m = core.metrics();
        assert_eq!(m.speculative_hits, 1, "failover was not served speculatively: {m}");
        assert_eq!(m.replans, 5, "failover must not search: {m}");
        assert_eq!(m.leader_handoffs, 0, "{m}");
        let tb3 = base(4).subset(&[true, true, false, true]);
        assert_eq!(*d.plan, crate::planner::plan_for_testbed(&core.model, &tb3));
    }

    #[test]
    fn leader_loss_is_speculated_elected_and_served_from_cache() {
        // kill node 0: the speculative pass must already hold the
        // leader-loss cell, the election must hand off to rank 1, and the
        // served plan must equal planning directly for the survivors
        let trace = ConditionTrace::stable(4).with_outage(0, 1.0, 2.0);
        let snap0 = trace.sample(0.0);
        let mut core = ReplanCore::new(
            zoo::edgenet(16),
            base(4),
            &snap0,
            ElasticConfig::default(),
            false,
        );
        core.speculate_failovers(&snap0);
        assert_eq!(core.metrics().speculative_plans, 4);

        let snap_down = trace.sample(1.5);
        assert!(!snap_down.alive[0]);
        let d = core.decide(&snap_down);
        assert_eq!(d.testbed.nodes, 3);
        assert_eq!(d.leader, 1, "leadership must hand off to the lowest survivor");
        let m = core.metrics();
        assert_eq!(m.failovers, 1);
        assert_eq!(m.leader_handoffs, 1, "leader loss must count a handoff: {m}");
        assert_eq!(m.speculative_hits, 1, "leader failover must be a cache hit: {m}");
        assert_eq!(m.replans, 5, "leader failover must not search: {m}");
        let tb3 = base(4).subset(&[false, true, true, true]);
        assert_eq!(*d.plan, crate::planner::plan_for_testbed(&core.model, &tb3));

        // rejoin: original rank 0 reclaims leadership — a second handoff
        let back = core.decide(&trace.sample(2.5));
        assert_eq!(back.leader, 0);
        assert_eq!(core.metrics().leader_handoffs, 2);
    }

    #[test]
    fn prewarmed_forecast_cell_serves_the_shift_without_a_search() {
        // pre-warm the dip cell the way the background planner does from a
        // forecast; when the dip actually lands, the replan must be a
        // forecast-attributed cache hit that runs zero searches
        let trace = ConditionTrace::stable(4).with_bandwidth_dip(5.0, f64::INFINITY, 0.4);
        let snap0 = trace.sample(0.0);
        let mut core = ReplanCore::new(
            zoo::edgenet(16),
            base(4),
            &snap0,
            ElasticConfig::default(),
            false,
        );
        // "forecast": the projected snapshot equals the dip conditions —
        // warmed one single-search unit at a time, exactly the way the
        // background planner expands an `Ask::Prewarm`
        let projected = trace.sample(6.0);
        core.prewarm_forecast_cell(&projected);
        for node in 0..4 {
            let mut hyp = projected.clone();
            hyp.alive[node] = false;
            core.speculate_one(&hyp);
        }
        let m = core.metrics();
        assert_eq!(m.forecasts, 1);
        assert_eq!(m.forecast_plans, 1, "dip cell was not pre-planned: {m}");
        // its n−1 cells were speculated at the *forecast* bandwidth
        assert_eq!(m.speculative_plans, 4, "{m}");
        let replans_before = m.replans;

        // the dip lands: cache hit, no new search, plan equals planning
        // directly for the degraded testbed
        let d = core.decide(&trace.sample(6.0));
        let m = core.metrics();
        assert_eq!(m.forecast_hits, 1, "shift not served from the forecast cell: {m}");
        assert_eq!(m.forecast_misses, 0, "{m}");
        assert_eq!(m.replans, replans_before, "the pre-warmed shift ran a search: {m}");
        let dipped = base(4).with_bandwidth_factor(0.4);
        assert_eq!(*d.plan, crate::planner::plan_for_testbed(&core.model, &dipped));

        // a node dying right at the dip: the n−1-at-forecast-bandwidth cell
        // is already warm — the gap this subsystem exists to close
        let mut down = trace.sample(6.5);
        down.alive[2] = false;
        let d2 = core.decide(&down);
        let m = core.metrics();
        assert_eq!(d2.testbed.nodes, 3);
        assert_eq!(m.speculative_hits, 1, "dip-time failover was not pre-speculated: {m}");
        assert_eq!(m.replans, replans_before, "dip-time failover ran a search: {m}");
    }

    #[test]
    fn prewarming_a_cached_cell_is_attribution_free() {
        // pre-warming the cell the active plan already covers must not
        // re-plan it or claim forecast credit for later ordinary hits
        let trace = ConditionTrace::stable(4);
        let snap0 = trace.sample(0.0);
        let mut core = ReplanCore::new(
            zoo::edgenet(16),
            base(4),
            &snap0,
            ElasticConfig::default(),
            false,
        );
        core.prewarm_forecast_cell(&snap0);
        let m = core.metrics();
        assert_eq!(m.forecasts, 1);
        assert_eq!(m.forecast_plans, 0, "active cell re-planned: {m}");
        // a speculative unit for an already-cached cell is also a no-op
        core.speculate_failovers(&snap0);
        let plans_before = core.metrics().speculative_plans;
        let mut hyp = snap0.clone();
        hyp.alive[3] = false;
        core.speculate_one(&hyp);
        assert_eq!(core.metrics().speculative_plans, plans_before, "cached cell re-planned");
        let d = core.decide(&trace.sample(1.0));
        assert!(!d.swapped);
        assert_eq!(core.metrics().forecast_hits, 0);
    }

    #[test]
    fn speculation_skips_a_single_survivor() {
        // a 1-node "cluster" has no n−1 cell to warm
        let trace = ConditionTrace::stable(1);
        let snap0 = trace.sample(0.0);
        let mut core = ReplanCore::new(
            zoo::edgenet(16),
            base(1),
            &snap0,
            ElasticConfig::default(),
            false,
        );
        core.speculate_failovers(&snap0);
        assert_eq!(core.metrics().speculative_plans, 0);
    }
}
