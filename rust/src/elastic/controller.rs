//! The elastic controller: per-batch condition monitoring, degradation
//! detection, cached/incremental replanning, and plan swapping.
//!
//! The controller sits between the serving router and the planner. At every
//! batch boundary the router calls [`ElasticController::on_batch`] with the
//! current virtual time; the controller samples the [`ConditionTrace`],
//! derives the effective [`Testbed`], and re-prices the active plan on it
//! (the *monitor*). Three triggers force adaptation:
//!
//! * **node-set change** — a device died or rejoined. The active plan still
//!   *executes* on the new cluster (plans are node-count-agnostic), but it
//!   was optimized for the wrong cluster, so a replan is mandatory; the
//!   swap lands at the next batch boundary, never mid-batch.
//! * **cost degradation** — the active plan's predicted cost exceeded
//!   `degrade_threshold ×` its adoption-time cost (bandwidth collapse,
//!   device slowdown).
//! * **condition-cell shift** — conditions left the quantized cell the
//!   active plan was planned for, in either direction. This is what swaps
//!   *back* after a recovery: the clean regime's plan is warm in the cache,
//!   and without this trigger a collapse-optimized plan would serve the
//!   recovered cluster forever.
//!
//! Replans consult the [`PlanCache`] first: conditions quantize into cells
//! ([`ClusterSnapshot::quantize`]), so revisited regimes get their plan back
//! without running DPP. On a genuine miss the controller plans fresh via
//! [`crate::planner::plan_for_testbed`] and caches the result. After any
//! adaptation the cost baseline re-anchors to the new conditions, so a
//! regime nothing can plan around (e.g. a uniform bandwidth collapse) is
//! accepted as the new normal instead of triggering a replan storm.

use std::sync::Arc;

use super::cache::{CacheKey, PlanCache};
use super::conditions::ConditionTrace;
use crate::engine;
use crate::metrics::AdaptationMetrics;
use crate::model::Model;
use crate::net::Testbed;
use crate::partition::Plan;
use crate::planner::plan_for_testbed;

/// Controller tuning knobs.
#[derive(Debug, Clone)]
pub struct ElasticConfig {
    /// Replan when the active plan's predicted cost exceeds this multiple of
    /// its adoption-time cost.
    pub degrade_threshold: f64,
    /// Plan-cache capacity (distinct condition cells held warm).
    pub cache_capacity: usize,
}

impl Default for ElasticConfig {
    fn default() -> Self {
        ElasticConfig { degrade_threshold: 1.25, cache_capacity: 32 }
    }
}

/// Why the active plan was swapped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SwapReason {
    /// A device left or rejoined the cluster.
    NodeSetChanged,
    /// Predicted cost degraded past the threshold.
    Degraded,
    /// Conditions moved to a different quantized cell without degrading —
    /// typically a *recovery* (bandwidth back up, device sped up), where the
    /// clean regime's plan is warm in the cache and strictly better.
    ConditionsShifted,
}

/// One adaptation event, for logs and examples.
#[derive(Debug, Clone)]
pub struct AdaptEvent {
    pub t: f64,
    pub reason: SwapReason,
    /// Effective node count after the swap.
    pub nodes: usize,
    /// Predicted per-item cost of the old plan under the new conditions.
    pub cost_before: f64,
    /// Predicted per-item cost of the adopted plan under the new conditions.
    pub cost_after: f64,
}

/// What the router should do for the next batch.
#[derive(Debug, Clone)]
pub struct BatchDecision {
    pub plan: Arc<Plan>,
    /// Effective testbed the batch executes on.
    pub testbed: Testbed,
    /// Per-node liveness (baseline node ids) — the mask
    /// [`crate::cluster::run_degraded`] executes against.
    pub alive: Vec<bool>,
    /// Predicted virtual seconds per item under current conditions.
    pub cost_per_item: f64,
    /// True when this boundary adapted (plan and/or node set changed).
    pub swapped: bool,
    pub reason: Option<SwapReason>,
}

/// Most recent [`AdaptEvent`]s retained by a controller — old events are
/// dropped so a server that adapts for days doesn't grow without bound.
pub const MAX_EVENTS: usize = 256;

/// The per-server adaptation state machine.
pub struct ElasticController {
    model: Model,
    base: Testbed,
    trace: ConditionTrace,
    cfg: ElasticConfig,
    cache: PlanCache,
    active: Arc<Plan>,
    /// Condition cell the active plan was planned for. Leaving the cell in
    /// *any* direction re-consults the cache — degradation is caught by the
    /// threshold below, but improvement (recovery) must also swap back,
    /// otherwise a collapse-optimized plan would serve the clean regime
    /// forever.
    active_key: CacheKey,
    /// Liveness mask the active plan was optimized for. Compared by
    /// membership, not count: a simultaneous die+rejoin between two batch
    /// boundaries still changes the set and must force a replan.
    active_alive: Vec<bool>,
    /// Cost baseline the degradation monitor compares against (tracks the
    /// best cost seen for the active plan since adoption).
    active_cost: f64,
    metrics: AdaptationMetrics,
    events: Vec<AdaptEvent>,
}

impl ElasticController {
    /// Plan for the conditions at `t = 0` and start monitoring.
    pub fn new(
        model: Model,
        base: Testbed,
        trace: ConditionTrace,
        cfg: ElasticConfig,
    ) -> ElasticController {
        assert_eq!(trace.nodes, base.nodes, "trace/testbed node mismatch");
        let mut cache = PlanCache::new(cfg.cache_capacity);
        let snap = trace.sample(0.0);
        let effective = snap.apply(&base);
        let key = CacheKey::new(&model.name, snap.quantize());
        let plan = Arc::new(plan_for_testbed(&model, &effective));
        cache.misses += 1; // the initial plan is an unavoidable cold miss
        cache.put(key.clone(), plan.clone());
        let active_cost = plan.est_cost;
        let metrics = AdaptationMetrics { replans: 1, ..AdaptationMetrics::default() };
        ElasticController {
            model,
            base,
            trace,
            cfg,
            cache,
            active: plan,
            active_key: key,
            active_alive: snap.alive,
            active_cost,
            metrics,
            events: Vec::new(),
        }
    }

    pub fn active_plan(&self) -> Arc<Plan> {
        self.active.clone()
    }

    /// The most recent adaptation events (bounded by [`MAX_EVENTS`]; the
    /// cumulative counts live in [`Self::metrics`]).
    pub fn events(&self) -> &[AdaptEvent] {
        &self.events
    }

    /// Adaptation counters, with the cache's view folded in.
    pub fn metrics(&self) -> AdaptationMetrics {
        let mut m = self.metrics;
        m.cache_hits = self.cache.hits;
        m.cache_misses = self.cache.misses;
        m
    }

    pub fn cache(&self) -> &PlanCache {
        &self.cache
    }

    fn lookup_or_replan(&mut self, key: &CacheKey, effective: &Testbed) -> Arc<Plan> {
        if let Some(plan) = self.cache.get(key) {
            return plan;
        }
        let plan = Arc::new(plan_for_testbed(&self.model, effective));
        self.metrics.replans += 1;
        self.cache.put(key.clone(), plan.clone());
        plan
    }

    /// Consult the controller at a batch boundary. Samples conditions at
    /// virtual time `t`, runs the degradation monitor, and returns the plan
    /// plus effective testbed for the batch about to form. Swaps happen
    /// here and only here — i.e. always between batches.
    pub fn on_batch(&mut self, t: f64) -> BatchDecision {
        let snap = self.trace.sample(t);
        let effective = snap.apply(&self.base);
        self.metrics.checks += 1;

        // Monitor: re-price the active plan under current conditions.
        let current_cost = engine::evaluate(&self.model, &self.active, &effective).total;
        let node_change = snap.alive != self.active_alive;
        let degraded = current_cost > self.active_cost * self.cfg.degrade_threshold;
        if degraded {
            self.metrics.degraded_checks += 1;
        }
        let key = CacheKey::new(&self.model.name, snap.quantize());
        let cell_change = key != self.active_key;

        if !(node_change || degraded || cell_change) {
            // Fast path: conditions within the active plan's regime. Track
            // recoveries so the baseline never lags below current reality.
            self.active_cost = self.active_cost.min(current_cost);
            return BatchDecision {
                plan: self.active.clone(),
                testbed: effective,
                alive: snap.alive,
                cost_per_item: current_cost,
                swapped: false,
                reason: None,
            };
        }

        let plan = self.lookup_or_replan(&key, &effective);
        let new_cost = engine::evaluate(&self.model, &plan, &effective).total;
        // Steps-only comparison: a replan that lands on the same step
        // sequence (with a different est_cost under the new conditions) is
        // not a swap the router can observe.
        let structurally_new = plan.steps != self.active.steps;
        let swapped = node_change || structurally_new;
        let reason = if node_change {
            SwapReason::NodeSetChanged
        } else if degraded {
            SwapReason::Degraded
        } else {
            SwapReason::ConditionsShifted
        };
        if swapped {
            if structurally_new {
                self.metrics.plan_swaps += 1;
            }
            if node_change {
                self.metrics.failovers += 1;
            }
            if self.events.len() == MAX_EVENTS {
                self.events.remove(0);
            }
            self.events.push(AdaptEvent {
                t,
                reason,
                nodes: effective.nodes,
                cost_before: current_cost,
                cost_after: new_cost,
            });
        }
        self.active = plan;
        self.active_key = key;
        self.active_alive = snap.alive.clone();
        // Re-anchor the baseline: if even the fresh plan is expensive under
        // these conditions, that is the new normal, not degradation.
        self.active_cost = new_cost;
        BatchDecision {
            plan: self.active.clone(),
            testbed: effective,
            alive: snap.alive,
            cost_per_item: new_cost,
            swapped,
            reason: swapped.then_some(reason),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::net::{Bandwidth, Topology};

    fn base(nodes: usize) -> Testbed {
        Testbed::new(nodes, Topology::Ring, Bandwidth::gbps(1.0))
    }

    fn controller(trace: ConditionTrace) -> ElasticController {
        ElasticController::new(
            zoo::edgenet(16),
            base(trace.nodes),
            trace,
            ElasticConfig::default(),
        )
    }

    #[test]
    fn stable_trace_never_swaps() {
        let mut ctl = controller(ConditionTrace::stable(4));
        let initial = ctl.active_plan();
        for i in 0..20 {
            let d = ctl.on_batch(i as f64 * 0.01);
            assert!(!d.swapped);
            assert_eq!(d.testbed.nodes, 4);
            assert_eq!(*d.plan, *initial);
        }
        let m = ctl.metrics();
        assert_eq!(m.checks, 20);
        assert_eq!(m.plan_swaps, 0);
        assert_eq!(m.failovers, 0);
        assert_eq!(m.replans, 1); // the initial plan only
    }

    #[test]
    fn node_failure_forces_failover_at_batch_boundary() {
        let trace = ConditionTrace::stable(4).with_outage(2, 1.0, f64::INFINITY);
        let mut ctl = controller(trace);
        let before = ctl.on_batch(0.5);
        assert_eq!(before.testbed.nodes, 4);
        assert!(!before.swapped);
        let after = ctl.on_batch(1.5);
        assert_eq!(after.testbed.nodes, 3, "failover missed");
        assert!(after.swapped);
        assert_eq!(after.reason, Some(SwapReason::NodeSetChanged));
        let m = ctl.metrics();
        assert_eq!(m.failovers, 1);
        assert!(m.replans >= 2);
    }

    #[test]
    fn recovery_is_served_from_cache() {
        let trace = ConditionTrace::stable(4).with_outage(1, 1.0, 2.0);
        let mut ctl = controller(trace);
        let p0 = ctl.active_plan();
        ctl.on_batch(0.5); // healthy
        ctl.on_batch(1.5); // degraded to 3 nodes
        let back = ctl.on_batch(2.5); // recovered — same cell as t=0
        assert_eq!(back.testbed.nodes, 4);
        assert_eq!(*back.plan, *p0, "recovery should restore the original plan");
        let m = ctl.metrics();
        assert_eq!(m.failovers, 2); // down and back up
        assert!(m.cache_hits >= 1, "recovery did not hit the cache: {m}");
        // only two distinct cells were ever planned: 4-node and 3-node
        assert_eq!(m.replans, 2);
    }

    #[test]
    fn membership_change_with_same_count_still_fails_over() {
        // node 1 dies at t=1; at t=2 node 1 rejoins just as node 2 dies —
        // the alive COUNT never changes across that boundary, but the set
        // does, and the plan was optimized for the wrong membership
        let trace = ConditionTrace::stable(4)
            .with_outage(1, 1.0, 2.0)
            .with_outage(2, 2.0, f64::INFINITY);
        let mut ctl = controller(trace);
        ctl.on_batch(0.5);
        let a = ctl.on_batch(1.5);
        assert_eq!(a.testbed.nodes, 3);
        assert!(!a.alive[1]);
        let b = ctl.on_batch(2.5);
        assert_eq!(b.testbed.nodes, 3);
        assert!(b.alive[1] && !b.alive[2]);
        assert_eq!(
            ctl.metrics().failovers,
            2,
            "equal-count membership change must still fail over"
        );
    }

    #[test]
    fn bandwidth_collapse_triggers_degradation_replan() {
        // drop bandwidth to 10% permanently from t = 1: sync costs inflate
        // 10×, blowing the active plan past the 1.25× threshold (sync is far
        // more than the required 2.9% of baseline cost at 1 Gb/s)
        let trace = ConditionTrace::stable(4).with_bandwidth_dip(1.0, f64::INFINITY, 0.1);
        let mut ctl = controller(trace);
        let before = ctl.on_batch(0.5);
        assert!(!before.swapped);
        let after = ctl.on_batch(1.5);
        let m = ctl.metrics();
        assert_eq!(m.degraded_checks, 1, "collapse did not trip the monitor: {m}");
        assert!(m.replans >= 2, "degradation did not replan: {m}");
        assert!(after.cost_per_item > before.cost_per_item);
        // once re-anchored to the collapsed regime, no replan storm
        let again = ctl.on_batch(2.5);
        assert!(!again.swapped);
        assert_eq!(ctl.metrics().degraded_checks, 1);
    }

    #[test]
    fn recovery_after_dip_restores_clean_regime_plan() {
        // bandwidth collapses over [1, 2) and recovers: the clean regime
        // must get its original plan back (from cache) instead of being
        // served the collapse-optimized plan forever
        let trace = ConditionTrace::stable(4).with_bandwidth_dip(1.0, 2.0, 0.1);
        let mut ctl = controller(trace);
        let p0 = ctl.active_plan();
        ctl.on_batch(0.5); // clean
        ctl.on_batch(1.5); // collapsed → degradation replan
        let back = ctl.on_batch(2.5); // recovered → cell shift → warm swap
        assert_eq!(*back.plan, *p0, "clean regime did not get its plan back");
        assert!(
            (back.cost_per_item - p0.est_cost).abs() <= 1e-9 * p0.est_cost,
            "recovered cost {} != planned cost {}",
            back.cost_per_item,
            p0.est_cost
        );
        assert!(ctl.metrics().cache_hits >= 1);
    }

    #[test]
    fn diurnal_drift_monitoring_is_stable() {
        // a full compressed day: the controller may adapt at the dip, must
        // never lose a node, and every lookup is accounted for
        let mut ctl = controller(ConditionTrace::diurnal_drift(4, 3));
        for step in 0..120 {
            let d = ctl.on_batch(step as f64 * 0.5);
            assert_eq!(d.testbed.nodes, 4);
            assert!(d.cost_per_item > 0.0);
        }
        let m = ctl.metrics();
        assert_eq!(m.checks, 120);
        assert_eq!(m.failovers, 0);
        assert_eq!(m.replans + m.cache_hits, m.cache_misses + m.cache_hits);
    }

    #[test]
    fn events_record_swaps() {
        let trace = ConditionTrace::stable(4).with_outage(3, 1.0, f64::INFINITY);
        let mut ctl = controller(trace);
        ctl.on_batch(0.2);
        ctl.on_batch(1.2);
        let evs = ctl.events();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].reason, SwapReason::NodeSetChanged);
        assert_eq!(evs[0].nodes, 3);
        assert!(evs[0].cost_before > 0.0 && evs[0].cost_after > 0.0);
    }
}
