//! Plan cache — memoized DPP results keyed by (model, quantized conditions).
//!
//! Replanning is the expensive step of online adaptation (a full DPP search
//! is `O(n²k)` estimator queries), and edge conditions revisit the same
//! regimes — a link that degrades at noon recovers at night, a device that
//! drops rejoins. The cache makes those revisits free: plans are stored
//! under a [`CacheKey`] whose condition half is the 12.5%-bucketed
//! [`SnapshotKey`], so near-identical conditions share one plan, and an LRU
//! policy bounds memory on long-running servers.

use std::collections::HashMap;
use std::sync::Arc;

use super::conditions::SnapshotKey;
use crate::partition::Plan;

/// Cache key: which model, under which quantized cluster conditions.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct CacheKey {
    pub model: String,
    pub snapshot: SnapshotKey,
}

impl CacheKey {
    pub fn new(model: &str, snapshot: SnapshotKey) -> CacheKey {
        CacheKey { model: model.to_string(), snapshot }
    }
}

struct Entry {
    plan: Arc<Plan>,
    last_used: u64,
}

/// LRU-evicting memo of planned solutions.
pub struct PlanCache {
    capacity: usize,
    tick: u64,
    map: HashMap<CacheKey, Entry>,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl PlanCache {
    pub fn new(capacity: usize) -> PlanCache {
        assert!(capacity >= 1, "cache capacity must be >= 1");
        PlanCache { capacity, tick: 0, map: HashMap::new(), hits: 0, misses: 0, evictions: 0 }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Fraction of lookups served warm (0.0 before any lookup).
    pub fn hit_rate(&self) -> f64 {
        crate::metrics::hit_ratio(self.hits, self.misses)
    }

    /// Whether a plan is cached for `key`, without touching recency or the
    /// hit/miss counters — the background planner's speculative pass uses
    /// this so probing for work never skews the serving-path hit rate.
    pub fn peek(&self, key: &CacheKey) -> bool {
        self.map.contains_key(key)
    }

    /// The currently cached condition cells (arbitrary order), without
    /// touching recency or counters — warm-set introspection for logs and
    /// examples (`examples/elastic_serving.rs` prints the cells a day of
    /// drift leaves warm). Cheap: capacities are tens of entries.
    pub fn keys(&self) -> Vec<CacheKey> {
        self.map.keys().cloned().collect()
    }

    /// Look up a warm plan, updating recency and hit/miss counters.
    pub fn get(&mut self, key: &CacheKey) -> Option<Arc<Plan>> {
        self.tick += 1;
        match self.map.get_mut(key) {
            Some(e) => {
                e.last_used = self.tick;
                self.hits += 1;
                Some(e.plan.clone())
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert (or refresh) a plan, evicting the least recently used entry
    /// when over capacity.
    pub fn put(&mut self, key: CacheKey, plan: Arc<Plan>) {
        self.tick += 1;
        self.map.insert(key, Entry { plan, last_used: self.tick });
        if self.map.len() > self.capacity {
            // O(n) LRU scan — capacities are tens of entries, not millions.
            let victim = self
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
                .expect("non-empty map over capacity");
            self.map.remove(&victim);
            self.evictions += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elastic::conditions::ConditionTrace;
    use crate::model::zoo;
    use crate::net::{Bandwidth, Testbed, Topology};
    use crate::partition::{Plan, Scheme};
    use crate::planner::plan_for_testbed;

    fn key(model: &str, t: f64) -> CacheKey {
        CacheKey::new(model, ConditionTrace::stable(4).sample(t).quantize())
    }

    fn dummy_plan(n: usize) -> Arc<Plan> {
        Arc::new(Plan::uniform(Scheme::InH, n))
    }

    #[test]
    fn near_identical_conditions_hit() {
        let mut cache = PlanCache::new(4);
        // two snapshots a few percent apart → same quantized cell
        let a = crate::elastic::conditions::ClusterSnapshot {
            t: 0.0,
            alive: vec![true; 4],
            bandwidth_factor: 1.0,
            speed_factors: vec![1.0; 4],
        };
        let mut b = a.clone();
        b.t = 0.3;
        b.bandwidth_factor = 0.97;
        b.speed_factors[2] = 1.02;
        let k1 = CacheKey::new("m", a.quantize());
        let k2 = CacheKey::new("m", b.quantize());
        assert_eq!(k1, k2, "a 3% wiggle crossed a bucket");
        assert!(cache.get(&k1).is_none());
        cache.put(k1, dummy_plan(4));
        assert!(cache.get(&k2).is_some());
        assert_eq!((cache.hits, cache.misses), (1, 1));
        assert!((cache.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn distinct_models_do_not_collide() {
        let mut cache = PlanCache::new(4);
        cache.put(key("a", 0.0), dummy_plan(4));
        assert!(cache.get(&key("b", 0.0)).is_none());
        assert!(cache.get(&key("a", 0.0)).is_some());
    }

    #[test]
    fn eviction_respects_capacity_and_recency() {
        let mut cache = PlanCache::new(2);
        let trace = ConditionTrace::stable(4);
        let mut keys = Vec::new();
        for (i, bw) in [1.0, 0.75, 0.5].iter().enumerate() {
            let mut snap = trace.sample(i as f64);
            snap.bandwidth_factor = *bw;
            keys.push(CacheKey::new("m", snap.quantize()));
        }
        cache.put(keys[0].clone(), dummy_plan(4));
        cache.put(keys[1].clone(), dummy_plan(4));
        assert!(cache.get(&keys[0]).is_some()); // freshen keys[0]
        cache.put(keys[2].clone(), dummy_plan(4)); // evicts keys[1] (LRU)
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.evictions, 1);
        assert!(cache.get(&keys[0]).is_some());
        assert!(cache.get(&keys[1]).is_none(), "LRU victim survived");
        assert!(cache.get(&keys[2]).is_some());
    }

    #[test]
    fn eviction_follows_exact_lru_order_under_sustained_pressure() {
        // fill far past capacity and check the *sequence* of victims: with
        // no intervening gets, puts evict in insertion order; a get reorders
        let mut cache = PlanCache::new(3);
        let trace = ConditionTrace::stable(4);
        let keys: Vec<CacheKey> = (0..6)
            .map(|i| {
                let mut snap = trace.sample(i as f64);
                snap.bandwidth_factor = 1.0 - 0.125 * i as f64; // distinct buckets
                CacheKey::new("m", snap.quantize())
            })
            .collect();
        for k in &keys[..3] {
            cache.put(k.clone(), dummy_plan(4));
        }
        assert_eq!(cache.len(), 3);
        assert_eq!(cache.evictions, 0);
        // freshen keys[0]: the LRU victim chain becomes 1, 2, 0
        assert!(cache.get(&keys[0]).is_some());
        cache.put(keys[3].clone(), dummy_plan(4));
        assert!(!cache.peek(&keys[1]), "victim 1 survived");
        cache.put(keys[4].clone(), dummy_plan(4));
        assert!(!cache.peek(&keys[2]), "victim 2 survived");
        cache.put(keys[5].clone(), dummy_plan(4));
        assert!(!cache.peek(&keys[0]), "victim 0 survived");
        assert_eq!(cache.evictions, 3);
        for k in &keys[3..] {
            assert!(cache.peek(k), "recent entry evicted");
        }
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn keys_report_the_warm_set_without_counting() {
        let mut cache = PlanCache::new(4);
        assert!(cache.keys().is_empty());
        cache.put(key("a", 0.0), dummy_plan(4));
        cache.put(key("b", 0.0), dummy_plan(4));
        let (h0, m0) = (cache.hits, cache.misses);
        let mut keys = cache.keys();
        keys.sort_by(|a, b| a.model.cmp(&b.model));
        assert_eq!(keys.len(), 2);
        assert_eq!(keys[0].model, "a");
        assert_eq!(keys[1].model, "b");
        assert_eq!((cache.hits, cache.misses), (h0, m0), "keys() touched counters");
    }

    #[test]
    fn peek_does_not_refresh_recency_or_count() {
        let mut cache = PlanCache::new(2);
        let a = key("a", 0.0);
        let b = key("b", 0.0);
        cache.put(a.clone(), dummy_plan(4));
        cache.put(b.clone(), dummy_plan(4));
        // peeks at `a` must NOT save it from eviction (get would)
        for _ in 0..5 {
            assert!(cache.peek(&a));
        }
        let (h0, m0) = (cache.hits, cache.misses);
        cache.put(key("c", 0.0), dummy_plan(4));
        assert!(!cache.peek(&a), "peek refreshed recency");
        assert!(cache.peek(&b));
        assert_eq!((cache.hits, cache.misses), (h0, m0), "peek touched counters");
    }

    #[test]
    fn quantized_keys_collide_within_a_bucket_and_split_across() {
        // collisions by construction: distinct snapshots inside one 12.5%
        // bucket share the cell (later put overwrites — one entry), while a
        // bucket step, a speed-bucket step, or any liveness change splits
        let trace = ConditionTrace::stable(4);
        let base = trace.sample(0.0);

        // same-cell collision: 1.00 and 0.97 both round to bucket 8
        let mut near = base.clone();
        near.bandwidth_factor = 0.97;
        let k_base = CacheKey::new("m", base.quantize());
        let k_near = CacheKey::new("m", near.quantize());
        assert_eq!(k_base, k_near);
        let mut cache = PlanCache::new(8);
        cache.put(k_base.clone(), dummy_plan(4));
        cache.put(k_near.clone(), dummy_plan(8));
        assert_eq!(cache.len(), 1, "colliding keys must share one entry");
        assert_eq!(cache.get(&k_base).unwrap().steps.len(), 8, "last write wins");

        // bucket boundary: 0.9375 rounds to 8, 0.93 rounds to 7
        let mut edge_hi = base.clone();
        edge_hi.bandwidth_factor = 0.9375;
        let mut edge_lo = base.clone();
        edge_lo.bandwidth_factor = 0.93;
        assert_eq!(CacheKey::new("m", edge_hi.quantize()), k_base);
        assert_ne!(CacheKey::new("m", edge_lo.quantize()), k_base);

        // per-node speed buckets split the cell per node, not just per value
        let mut slow2 = base.clone();
        slow2.speed_factors[2] = 0.8;
        let mut slow3 = base.clone();
        slow3.speed_factors[3] = 0.8;
        let k2 = CacheKey::new("m", slow2.quantize());
        let k3 = CacheKey::new("m", slow3.quantize());
        assert_ne!(k2, k_base);
        assert_ne!(k2, k3, "same value on a different node must not collide");

        // liveness: losing node 1 vs node 2 are different cells, and the
        // speed-bucket vector compaction must not alias them
        let mut down1 = base.clone();
        down1.alive[1] = false;
        let mut down2 = base.clone();
        down2.alive[2] = false;
        assert_ne!(
            CacheKey::new("m", down1.quantize()),
            CacheKey::new("m", down2.quantize())
        );
    }

    #[test]
    fn cached_plan_equals_fresh_plan_for_same_snapshot() {
        // the end-to-end cache contract: serving a warm plan must be
        // indistinguishable from replanning for the same quantized snapshot
        let model = zoo::edgenet(16);
        let base = Testbed::new(4, Topology::Ring, Bandwidth::gbps(1.0));
        let snap = ConditionTrace::stable(4).sample(0.0);
        let effective = snap.apply(&base);
        let fresh1 = plan_for_testbed(&model, &effective);
        let mut cache = PlanCache::new(4);
        let k = CacheKey::new(&model.name, snap.quantize());
        cache.put(k.clone(), Arc::new(fresh1.clone()));
        let warm = cache.get(&k).unwrap();
        let fresh2 = plan_for_testbed(&model, &effective);
        assert_eq!(*warm, fresh1);
        assert_eq!(fresh1, fresh2, "DPP is deterministic");
    }
}
