//! Deterministic leader election — no node is immortal.
//!
//! The leader owns scatter/ingress and gather, so losing it used to take
//! the whole cluster down. Election here is rank-based over the surviving
//! node set: every node derives the same leader from the same liveness
//! mask with zero communication (exactly how every node already derives
//! the plan geometry independently), so there is no coordination protocol
//! to fail during a failure.
//!
//! * [`elect_leader`] — the pure rule: the lowest-ranked surviving node.
//!   Under [`crate::net::Testbed::subset`] compaction that node becomes
//!   logical node 0, which is precisely the slot the executors' scatter
//!   and gather already address — election and execution cannot disagree.
//! * [`Leadership`] — the observer state machine: feed it liveness masks,
//!   it reports handoffs and numbers them with a monotonically increasing
//!   term. A rejoining lower rank (including original node 0) reclaims
//!   leadership — deterministic, at the cost of one extra handoff, which
//!   the serving layer treats as an ordinary drain boundary.

/// The rank-based election rule: the lowest-ranked surviving node leads.
/// Returns `None` only for an empty surviving set (which the condition
/// layer's survivor-of-last-resort rule prevents in practice).
pub fn elect_leader(alive: &[bool]) -> Option<usize> {
    alive.iter().position(|&a| a)
}

/// One leadership handoff observed by [`Leadership::observe`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LeaderChange {
    /// Original rank of the outgoing leader.
    pub from: usize,
    /// Original rank of the newly elected leader.
    pub to: usize,
    /// Term the new leader serves under (strictly increasing).
    pub term: u64,
}

/// Leadership state derived from a stream of liveness masks.
#[derive(Debug, Clone)]
pub struct Leadership {
    leader: usize,
    term: u64,
}

impl Leadership {
    /// Elect the initial leader (term 1) from `alive`.
    pub fn new(alive: &[bool]) -> Leadership {
        let leader = elect_leader(alive).expect("no surviving node to lead");
        Leadership { leader, term: 1 }
    }

    /// Original rank of the current leader.
    pub fn leader(&self) -> usize {
        self.leader
    }

    /// Current term (bumps on every handoff).
    pub fn term(&self) -> u64 {
        self.term
    }

    /// Re-run the election for `alive`; returns the handoff if leadership
    /// moved. An empty surviving set keeps the current leader (the caller's
    /// condition layer guarantees at least one survivor).
    pub fn observe(&mut self, alive: &[bool]) -> Option<LeaderChange> {
        let new = elect_leader(alive)?;
        if new == self.leader {
            return None;
        }
        let from = self.leader;
        self.leader = new;
        self.term += 1;
        Some(LeaderChange { from, to: new, term: self.term })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lowest_surviving_rank_leads() {
        assert_eq!(elect_leader(&[true, true, true]), Some(0));
        assert_eq!(elect_leader(&[false, true, true]), Some(1));
        assert_eq!(elect_leader(&[false, false, true]), Some(2));
        assert_eq!(elect_leader(&[false, false]), None);
    }

    #[test]
    fn handoff_on_leader_death_and_reclaim_on_rejoin() {
        let mut l = Leadership::new(&[true, true, true, true]);
        assert_eq!((l.leader(), l.term()), (0, 1));
        // a worker death is not a handoff
        assert_eq!(l.observe(&[true, false, true, true]), None);
        // the leader dies: next-lowest surviving rank takes over
        let c = l.observe(&[false, false, true, true]).expect("handoff missed");
        assert_eq!((c.from, c.to, c.term), (0, 2, 2));
        assert_eq!(l.leader(), 2);
        // original node 0 rejoins and reclaims leadership deterministically
        let c = l.observe(&[true, false, true, true]).expect("reclaim missed");
        assert_eq!((c.from, c.to, c.term), (2, 0, 3));
        assert_eq!(l.term(), 3);
    }

    #[test]
    fn empty_survivor_set_keeps_current_leader() {
        let mut l = Leadership::new(&[false, true]);
        assert_eq!(l.leader(), 1);
        assert_eq!(l.observe(&[false, false]), None);
        assert_eq!((l.leader(), l.term()), (1, 1));
    }

    #[test]
    fn election_matches_subset_compaction() {
        // the elected leader is exactly the node that compacts to logical 0
        // under Testbed::subset — the slot scatter/gather address
        let cases = [
            [true, true, true, true],
            [false, true, true, true],
            [false, false, true, true],
        ];
        for alive in cases {
            let leader = elect_leader(&alive).unwrap();
            let compacted_rank_of_leader = alive[..leader].iter().filter(|&&a| a).count();
            assert_eq!(compacted_rank_of_leader, 0);
        }
    }
}
